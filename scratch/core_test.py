"""End-to-end core test: simulate cluster, fit DMM, run cutoff controller."""
import time
import numpy as np

from repro.cluster.simulator import paper_cluster_158
from repro.core.controller import (CutoffController, ElfvingController,
                                   FullSyncController, StaticCutoffController)
from repro.core.cutoff import order_stats
from repro.core.runtime_model.api import RuntimeModel

t0 = time.time()
sim = paper_cluster_158(seed=0)
train_trace = sim.run(300)
print(f"trace: mean={train_trace.mean():.3f} std={train_trace.std():.3f} "
      f"(paper cluster: 1.057 / 0.393)")

rm = RuntimeModel(n_workers=158, lag=20).init(0)
losses = rm.fit(train_trace, steps=300, batch=8, verbose=True)
print(f"fit done in {time.time()-t0:.1f}s; -elbo {losses[0]:.1f} -> {losses[-1]:.1f}")

# --- prediction quality on held-out steps ---
test_trace = sim.run(80)
w = train_trace[-21:]
samples, mu, std = rm.predict_next(w, k_samples=64)
os_mean, os_std = order_stats.mc_order_stats(samples)
actual_sorted = np.sort(test_trace[0])
mae = np.abs(os_mean - actual_sorted).mean()
print(f"order-stat MAE={mae:.4f}s rel={mae/actual_sorted.mean():.1%}")

# --- controller throughput loop ---
ctls = {
    "sync": FullSyncController(158),
    "static(6%)": StaticCutoffController(158),
    "elfving": ElfvingController(158),
    "cutoff(DMM)": CutoffController(rm),
}
ctls["cutoff(DMM)"].seed_window(train_trace)

results = {}
for name, ctl in ctls.items():
    sim2 = paper_cluster_158(seed=7)   # same runtime sequence for all
    total_time, total_grads = 0.0, 0
    oracle_time = 0.0
    for t in range(120):
        times = sim2.step()
        c = ctl.predict_cutoff()
        it = order_stats.iter_time(times, c)
        mask = times <= it + 1e-12
        ctl.observe(times, mask)
        total_time += it
        total_grads += c
        oracle_time += order_stats.iter_time(times, order_stats.oracle_cutoff(times))
    results[name] = (total_grads / total_time, total_time)
    print(f"{name:14s} throughput={total_grads/total_time:8.2f} grads/s "
          f"wall={total_time:7.1f}s")

print(f"speedup cutoff vs sync: "
      f"{results['cutoff(DMM)'][0]/results['sync'][0]:.2f}x throughput, "
      f"{results['sync'][1]/results['cutoff(DMM)'][1]:.2f}x wall-clock")
