import jax, jax.numpy as jnp
import sys

from repro.configs.base import get_config, all_archs
from repro.models import model as M

ARCHS = sys.argv[1:] or list(all_archs())

for name in ARCHS:
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    n = sum(x.size for x in jax.tree.leaves(params))
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.zeros((B, S, cfg.d_model))
        batch["image_mask"] = jnp.zeros((B, S), bool)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model)) * 0.01

    loss, metrics = M.train_loss(cfg, params, batch)
    g = jax.grad(lambda p: M.train_loss(cfg, p, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g)))
    print(f"{name:24s} params={n:9d} loss={float(loss):8.4f} gnorm={float(gn):10.4f} "
          f"finite={bool(jnp.isfinite(loss))}")
