"""Prefill+decode must reproduce full-forward logits (cache correctness)."""
import jax, jax.numpy as jnp
import numpy as np
import sys

from repro.configs.base import get_config, all_archs
from repro.models import model as M

ARCHS = sys.argv[1:] or list(all_archs())

for name in ARCHS:
    cfg = get_config(name).reduced()
    import dataclasses
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(1)
    params = M.init_model(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    batch = {"tokens": toks, "positions": pos}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.zeros((B, S, cfg.d_model))
        batch["image_mask"] = jnp.zeros((B, S), bool)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model)) * 0.01

    # full forward logits
    full_logits, _, _ = M.forward(cfg, params, batch, mode="train")

    # prefill S-2 tokens, then decode tokens S-2 and S-1
    pre = {k: (v[..., :S-2] if v.ndim == 2 else (v[:, :, :S-2] if v.ndim == 3 and k == "positions" else v))
           for k, v in batch.items()}
    pre["tokens"] = toks[:, :S-2]
    if batch["positions"].ndim == 3:
        pre["positions"] = batch["positions"][:, :, :S-2]
    else:
        pre["positions"] = pos[:, :S-2]
    if "patch_embeds" in batch:
        pre["patch_embeds"] = batch["patch_embeds"][:, :S-2]
        pre["image_mask"] = batch["image_mask"][:, :S-2]
    last, caches = M.prefill(cfg, params, pre)
    caches = M.pad_caches(caches, S)
    err0 = float(jnp.max(jnp.abs(last - full_logits[:, S-3])))

    lg1, caches = M.decode_step(cfg, params, toks[:, S-2:S-1], jnp.int32(S-2), caches)
    lg2, caches = M.decode_step(cfg, params, toks[:, S-1:S], jnp.int32(S-1), caches)
    err1 = float(jnp.max(jnp.abs(lg1[:, 0] - full_logits[:, S-2])))
    err2 = float(jnp.max(jnp.abs(lg2[:, 0] - full_logits[:, S-1])))
    ok = max(err0, err1, err2) < 2e-3
    print(f"{name:24s} prefill_err={err0:.2e} dec1_err={err1:.2e} dec2_err={err2:.2e} {'OK' if ok else 'FAIL'}")
