#!/usr/bin/env bash
# Minimal CI: the tier-1 suite on CPU (what the roadmap calls "tier-1
# verify").  Runs from the repo root.
#
#   scripts/ci.sh            # full tier-1 suite
#   scripts/ci.sh -m "not sharded"   # skip the multi-device subprocess tests
#   scripts/ci.sh --bench    # perf runs -> BENCH_agg.json +
#                            #              BENCH_controller.json +
#                            #              BENCH_elastic.json +
#                            #              BENCH_ps.json +
#                            #              BENCH_frontier.json
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench" ]]; then
    shift
    python -m benchmarks.run --quick --only agg "$@"
    python -m benchmarks.run --quick --only controller "$@"
    python -m benchmarks.run --quick --only elastic "$@"
    python -m benchmarks.run --quick --only ps "$@"
    # gate: batched dispatch must not LOSE to J looped dispatches once
    # there is real batching to amortize (J >= 4) — a regression here is
    # the multi-tenant subsystem failing at its one job
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_ps.json"))["decision"]
bad = [r for r in rows if r["n_jobs"] >= 4 and r["speedup"] < 1.0]
for r in bad:
    print(f"ps decision REGRESSION: n={r['n_workers']} J={r['n_jobs']} "
          f"speedup={r['speedup']:.3f}x (< 1.0)", file=sys.stderr)
sys.exit(1 if bad else 0)
EOF
    python -m benchmarks.run --quick --only frontier "$@"
    # gate: at least one non-discard straggler policy (anytime partial
    # sums or stale reuse) must beat full sync on wall-clock-to-loss in
    # the seeded race — the frontier's reason to exist
    python - <<'EOF'
import json, sys
race = json.load(open("BENCH_frontier.json"))["frontier"]["race"]
by = {r["policy"]: r["clock_to_loss"] for r in race}
t_sync = by["sync"]
winners = [p for p in ("anytime", "stale")
           if by[p] is not None and (t_sync is None or by[p] < t_sync)]
if not winners:
    print(f"frontier REGRESSION: no non-discard policy beats full sync "
          f"(sync={t_sync}, anytime={by['anytime']}, stale={by['stale']})",
          file=sys.stderr)
    sys.exit(1)
print(f"frontier gate ok: {', '.join(winners)} beat sync", file=sys.stderr)
EOF
    exit 0
fi

python -m pytest -x -q "$@"
