#!/usr/bin/env bash
# Minimal CI: the tier-1 suite on CPU (what the roadmap calls "tier-1
# verify").  Runs from the repo root.
#
#   scripts/ci.sh            # full tier-1 suite
#   scripts/ci.sh -m "not sharded"   # skip the multi-device subprocess tests
#   scripts/ci.sh --bench    # perf runs -> BENCH_agg.json +
#                            #              BENCH_controller.json +
#                            #              BENCH_elastic.json +
#                            #              BENCH_ps.json +
#                            #              BENCH_frontier.json +
#                            #              BENCH_controlplane.json +
#                            #              BENCH_obs.json
#   scripts/ci.sh --drill    # live fault drills: subprocess kill -9 /
#                            # hang / flaky restart + the supervised
#                            # trainer storm with scripted-replay check
#   scripts/ci.sh --lint     # reprolint --strict over src+tests, then
#                            # the jaxpr audit -> ANALYSIS.json
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench" ]]; then
    shift
    python -m benchmarks.run --quick --only agg "$@"
    python -m benchmarks.run --quick --only controller "$@"
    python -m benchmarks.run --quick --only elastic "$@"
    python -m benchmarks.run --quick --only ps "$@"
    # gate: batched dispatch must not LOSE to J looped dispatches once
    # there is real batching to amortize (J >= 4) — a regression here is
    # the multi-tenant subsystem failing at its one job
    python - <<'EOF'
import json, sys
rows = json.load(open("BENCH_ps.json"))["decision"]
bad = [r for r in rows if r["n_jobs"] >= 4 and r["speedup"] < 1.0]
for r in bad:
    print(f"ps decision REGRESSION: n={r['n_workers']} J={r['n_jobs']} "
          f"speedup={r['speedup']:.3f}x (< 1.0)", file=sys.stderr)
sys.exit(1 if bad else 0)
EOF
    python -m benchmarks.run --quick --only controlplane "$@"
    # gates: (a) detection latency never exceeds the heartbeat deadline
    # + 1 tick (the state machine's determinism contract); (b) the
    # supervisor's restarts keep worker-steps lost strictly below the
    # same storm with nobody watching; (c) the detected schedule stays
    # loss-equivalent to its scripted replay
    python - <<'EOF'
import json, sys
d = json.load(open("BENCH_controlplane.json"))
det, rec = d["detection"], d["recovery"]
bad = []
if det["max_detection_ticks"] > det["dead_after"] + 1:
    bad.append(f"detection latency {det['max_detection_ticks']} ticks "
               f"> deadline {det['dead_after']} + 1")
if det["n_detected"] != det["n_faults"]:
    bad.append(f"only {det['n_detected']}/{det['n_faults']} faults detected")
lost = rec["steps_lost"]
if not lost["supervised"] < lost["unsupervised"]:
    bad.append(f"steps lost supervised={lost['supervised']} not below "
               f"unsupervised={lost['unsupervised']}")
if not rec["scripted_replay_match"]:
    bad.append("supervised run diverged from its scripted replay")
for b in bad:
    print(f"controlplane REGRESSION: {b}", file=sys.stderr)
if not bad:
    print(f"controlplane gate ok: detection <= {det['dead_after'] + 1} "
          f"ticks, steps lost {lost['supervised']} vs "
          f"{lost['unsupervised']} unsupervised", file=sys.stderr)
sys.exit(1 if bad else 0)
EOF
    python -m benchmarks.run --quick --only frontier "$@"
    # gate: at least one non-discard straggler policy (anytime partial
    # sums or stale reuse) must beat full sync on wall-clock-to-loss in
    # the seeded race — the frontier's reason to exist
    python - <<'EOF'
import json, sys
race = json.load(open("BENCH_frontier.json"))["frontier"]["race"]
by = {r["policy"]: r["clock_to_loss"] for r in race}
t_sync = by["sync"]
winners = [p for p in ("anytime", "stale")
           if by[p] is not None and (t_sync is None or by[p] < t_sync)]
if not winners:
    print(f"frontier REGRESSION: no non-discard policy beats full sync "
          f"(sync={t_sync}, anytime={by['anytime']}, stale={by['stale']})",
          file=sys.stderr)
    sys.exit(1)
print(f"frontier gate ok: {', '.join(winners)} beat sync", file=sys.stderr)
EOF
    python -m benchmarks.run --quick --only obs "$@"
    # gate: the telemetry spine must stay effectively free on the hot
    # path — instrumented Trainer step latency within 5% of bare at
    # n=158 (min-of-repeats on both sides)
    python - <<'EOF'
import json, sys
d = json.load(open("BENCH_obs.json"))
rows = {r["n_workers"]: r for r in d["step"]}
r = rows[158]
if r["overhead_frac"] > 0.05:
    print(f"obs REGRESSION: instrumented step {r['instrumented_us']:.1f}us "
          f"vs bare {r['bare_us']:.1f}us at n=158 "
          f"({r['overhead_frac'] * 100:+.1f}% > 5%)", file=sys.stderr)
    sys.exit(1)
print(f"obs gate ok: step overhead {r['overhead_frac'] * 100:+.1f}% "
      f"at n=158 (<= 5%)", file=sys.stderr)
EOF
    exit 0
fi

if [[ "${1:-}" == "--lint" ]]; then
    shift
    # contract lint: any finding (or a reasonless suppression) fails CI
    python -m repro.analysis src tests --strict "$@"
    # device-side proof: hot entries trace transfer-free, donation holds
    python -m repro.analysis --audit
    exit 0
fi

if [[ "${1:-}" == "--drill" ]]; then
    shift
    # live subprocess drill: real kill -9, a real hang, a flaky restart,
    # warm ctl-checkpoint recovery by global worker id
    python tests/sharded/controlplane_drill_check.py "$@"
    # supervised trainer under the seeded storm; exits non-zero unless
    # the detected schedule matches its scripted replay loss-for-loss
    python -m repro.launch.supervised --steps 60
    exit 0
fi

python -m pytest -x -q "$@"
