#!/usr/bin/env bash
# Minimal CI: the tier-1 suite on CPU (what the roadmap calls "tier-1
# verify").  Runs from the repo root.
#
#   scripts/ci.sh            # full tier-1 suite
#   scripts/ci.sh -m "not sharded"   # skip the multi-device subprocess tests
#   scripts/ci.sh --bench    # perf runs -> BENCH_agg.json +
#                            #              BENCH_controller.json +
#                            #              BENCH_elastic.json +
#                            #              BENCH_ps.json
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--bench" ]]; then
    shift
    python -m benchmarks.run --quick --only agg "$@"
    python -m benchmarks.run --quick --only controller "$@"
    python -m benchmarks.run --quick --only elastic "$@"
    python -m benchmarks.run --quick --only ps "$@"
    exit 0
fi

python -m pytest -x -q "$@"
