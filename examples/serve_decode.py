"""Serving example: batched prefill + decode with a KV cache.

Decodes from three different architecture families (dense GQA, xLSTM
matrix-memory, Hymba hybrid) to show the cache machinery is uniform.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServeEngine


def main():
    for name in ["qwen2-0.5b", "xlstm-350m", "hymba-1.5b"]:
        cfg = get_config(name).reduced()
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, size=(4, 12),
                               dtype=np.int32)
        t0 = time.time()
        out = eng.generate(prompts, n_new=16, temperature=0.8, seed=1)
        dt = time.time() - t0
        print(f"{name:14s} batch=4 prompt=12 new=16 "
              f"({dt:.2f}s incl. compile)")
        print(f"   sample continuation ids: {out[0][:10].tolist()}")


if __name__ == "__main__":
    main()
