"""Multi-tenant parameter-server demo: J jobs, one batched decision path.

Three tiny training jobs share one simulated 24-worker cluster (8 workers
each).  A single PSServer multiplexes all three cutoff controllers
through ONE vmapped fused decision per tick; mid-run a churn event kills
two of job1's workers and the per-job elastic protocol (Elfving fallback
+ DMM refit) absorbs it while the other jobs stay on the batched path.
Then the same jobs re-run under capacity pressure (2 of 3 serviced per
tick) to show the scheduler policies' throughput trade-offs.

  PYTHONPATH=src python examples/multi_job_demo.py
"""
import numpy as np

from repro.cluster.simulator import ChurnEvent
from repro.launch.multi_job import build_multi_job, run_ticks
from repro.ps import make_scheduler


def main():
    ticks = 36
    kill_at, back_at = ticks // 3, 2 * ticks // 3

    print("=== phase 1: 3 jobs x 8 workers, one PSServer, round-robin ===")
    events = [ChurnEvent(step=kill_at, kill=(8, 9)),
              ChurnEvent(step=back_at, restore=(8, 9))]
    server, jobs, _ = build_multi_job(3, 8, seed=0, churn_events=events,
                                      refit_steps=60,
                                      priorities=[0.0, 1.0, 2.0])
    out = run_ticks(server, jobs, make_scheduler("rr"), ticks, verbose=True)
    print(f"  {ticks} ticks -> {out['dispatches']} fused dispatches "
          f"({out['dispatches'] / ticks:.2f}/tick for 3 jobs; a looped "
          f"design pays 3/tick)")
    for job_id, run in jobs.items():
        losses = [h["loss"] for h in run.trainer.history[-3:]]
        print(f"  {job_id}: steps={len(run.trainer.history)} "
              f"width={run.handle.n} mode={run.handle.mode} "
              f"loss={np.mean(losses):.4f}")
    assert jobs["job1"].handle.n == 8, "job1 should have recovered"

    print("\n=== phase 2: capacity 2 of 3 — scheduler policy spread ===")
    for policy in ("rr", "priority", "spsf"):
        server, jobs, _ = build_multi_job(3, 8, seed=0,
                                          priorities=[0.0, 1.0, 2.0])
        out = run_ticks(server, jobs, make_scheduler(policy), ticks,
                        capacity=2)
        total = sum(out["serviced"].values())
        clock = {j: round(r.trainer.sim_clock, 1) for j, r in jobs.items()}
        print(f"  {policy:8s}: serviced={out['serviced']} "
              f"(total {total}), per-job sim clock={clock}")
    print("\nround-robin spreads service evenly; priority starves job0; "
          "spsf packs the most total steps into predicted-fast jobs.")


if __name__ == "__main__":
    main()
