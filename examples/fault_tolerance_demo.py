"""Fault-tolerance walkthrough: crash/restart + permanent node failure +
elastic resize.

1. Train 30 steps with async checkpointing.
2. Simulate a crash; restart from the latest checkpoint (exact resume —
   the controller window and data cursor come back too).
3. Kill one worker permanently: the cutoff controller routes around it
   within one step (the paper's mechanism doubling as fault tolerance).
4. Elastic resize mid-run, 8 -> 6 -> 8 workers (``ChurnSim``): the SAME
   trainer keeps stepping across both membership changes — the controller
   window is remapped (survivors column-exact), the checkpoint records the
   degraded membership, and the restored run resumes at the checkpoint's
   worker count.
5. Real DETECTION: phases 3-4 were told who died.  Here a
   ``controlplane.Supervisor`` finds out from missed heartbeats — a
   seeded crash + hang storm is detected within the deadline, the
   membership shrinks, restarts bring the workers back, and the trainer
   rides the detected schedule end to end.

  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import shutil

import jax
import numpy as np

from repro import optim
from repro.cluster.simulator import ChurnEvent, ChurnSim, ClusterSim
from repro.configs.base import get_config
from repro.core.controller import ElfvingController
from repro.data.pipeline import SyntheticTokens
from repro.launch.train import Trainer, jit_train_step
from repro.models import model as M

CKPT = "/tmp/repro_ft_demo"


class FailingCluster(ClusterSim):
    """Worker `dead` becomes a permanent straggler after step `at`."""

    def __init__(self, dead: int, at: int, **kw):
        super().__init__(**kw)
        self.dead, self.at = dead, at

    def step(self):
        t = super().step()
        if self.t >= self.at:
            t[self.dead] = 1e6  # never finishes
        return t


def make_trainer(cfg, n_workers, timer):
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=24, seed=0)
    opt = optim.adamw(3e-3)
    step = jit_train_step(cfg, opt)
    tr = Trainer(cfg=cfg, step_fn=step, data=data,
                 controller=ElfvingController(n_workers, warmup=3),
                 timer=timer, n_workers=n_workers, ckpt_dir=CKPT,
                 ckpt_every=10)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    return tr.restore_or_init(init_fn)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = get_config("qwen2-0.5b").reduced()

    print("=== phase 1: train 30 steps with checkpoints ===")
    tr = make_trainer(cfg, 8, ClusterSim(n_workers=8, n_nodes=2, seed=1))
    tr.run(30, verbose=True)
    loss_before = tr.history[-1]["loss"]

    print("\n=== phase 2: simulated crash; restart from checkpoint ===")
    tr2 = make_trainer(cfg, 8, ClusterSim(n_workers=8, n_nodes=2, seed=1))
    print(f"resumed at step {tr2.step} (clock {tr2.sim_clock:.1f}s)")
    assert tr2.step == 30
    tr2.run(10, verbose=True)
    assert tr2.history[-1]["loss"] < loss_before * 1.5

    print("\n=== phase 3: permanent worker failure at step 45 ===")
    tr3 = make_trainer(cfg, 8, FailingCluster(
        dead=3, at=5, n_workers=8, n_nodes=2, seed=1))
    tr3.run(15, verbose=True)
    cs = [h["c"] for h in tr3.history[-8:]]
    print(f"cutoffs after failure: {cs} (controller routes around the "
          f"dead worker; iteration time stays bounded)")
    assert max(h["iter_time"] for h in tr3.history[-5:]) < 100

    print("\n=== phase 4: elastic resize 8 -> 6 -> 8 workers, mid-run ===")
    shutil.rmtree(CKPT, ignore_errors=True)
    churn = ChurnSim(ClusterSim(n_workers=8, n_nodes=2, seed=2),
                     [ChurnEvent(step=6, kill=(6, 7)),
                      ChurnEvent(step=14, restore=(6, 7))])
    tr4 = make_trainer(cfg, 8, churn)
    tr4.run(20, verbose=True)
    widths = [h["n"] for h in tr4.history]
    print(f"worker counts over the run: {widths}")
    assert 6 in widths and widths[-1] == 8
    # the checkpoint written while degraded carries the 6-wide membership
    from repro.checkpoint import store
    grp = store.restore_group(CKPT, "ctl", step=10)
    print(f"step-10 checkpoint membership: n={int(grp['n'])} "
          f"members={grp['members'].tolist()}")
    tr5 = make_trainer(cfg, 8, ChurnSim(ClusterSim(n_workers=8, n_nodes=2,
                                                   seed=3),
                                        [ChurnEvent(step=0, kill=(6, 7))]))
    print(f"restart from the latest checkpoint: step {tr5.step}, "
          f"n_workers {tr5.n_workers}")
    tr5.run(5, verbose=True)

    print("\n=== phase 5: detected (not scripted) failures, supervised ===")
    from repro.controlplane import drill_report
    from repro.launch.supervised import (build_supervised, default_plan,
                                         run_supervised_trainer)
    shutil.rmtree(CKPT, ignore_errors=True)
    overlay, sup, timer = build_supervised(8, default_plan(8), seed=4)
    # every transient width (8 full, 7 during a detection window) must
    # divide the global batch
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=56, seed=0)
    opt = optim.adamw(3e-3)
    tr6 = Trainer(cfg=cfg, step_fn=jit_train_step(cfg, opt), data=data,
                  controller=ElfvingController(8, warmup=3), timer=timer,
                  n_workers=8)
    tr6.restore_or_init(lambda: {
        "params": (p := M.init_model(cfg, jax.random.PRNGKey(0))),
        "opt": opt.init(p)})
    run_supervised_trainer(tr6, sup, 36)
    rep = drill_report(sup.log.events)
    for i in rep["incidents"]:
        print(f"  {i['kind']} on worker {i['worker']} at tick "
              f"{i['fault_tick']}: detected +{i['detection_ticks']} "
              f"ticks, rejoined at {i['rejoin_tick']}")
    widths6 = sorted({h["n"] for h in tr6.history})
    print(f"widths ridden off detection alone: {widths6}")
    assert rep["n_detected"] == 2 and rep["max_detection_ticks"] <= 5
    assert widths6 == [7, 8] and tr6.history[-1]["n"] == 8
    print("\nall phases OK")


if __name__ == "__main__":
    main()
