"""Quickstart: cutoff SGD end to end in ~a minute on CPU.

Trains a reduced qwen2-0.5b on synthetic tokens with 8 simulated workers:
the DMM runtime model predicts each step's joint worker runtimes, the
controller picks the throughput-optimal cutoff, stragglers' gradients are
masked out of the aggregation, and censored runtimes are imputed.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro import optim
from repro.cluster.simulator import ClusterSim
from repro.configs.base import get_config
from repro.core.controller import CutoffController
from repro.core.runtime_model.api import RuntimeModel
from repro.data.pipeline import SyntheticTokens
from repro.launch.train import Trainer, jit_train_step
from repro.models import model as M


def main():
    n_workers = 8
    cfg = get_config("qwen2-0.5b").reduced()

    # 1. instrument the cluster once, fit the runtime model (paper §3.1)
    sim = ClusterSim(n_workers=n_workers, n_nodes=2, seed=0)
    trace = sim.run(200)
    print(f"recorded trace: mean={trace.mean():.3f}s std={trace.std():.3f}s")
    rm = RuntimeModel(n_workers=n_workers, lag=20).init(0)
    rm.fit(trace, steps=200, batch=8, verbose=True)

    # 2. dynamic-cutoff controller (paper Alg. 1)
    ctl = CutoffController(rm, k_samples=48)
    ctl.seed_window(trace)

    # 3. train with masked gradient aggregation
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32,
                           global_batch=16, seed=0)
    opt = optim.adamw(optim.cosine_schedule(3e-3, 10, 200))
    step = jit_train_step(cfg, opt)
    tr = Trainer(cfg=cfg, step_fn=step, data=data, controller=ctl,
                 timer=ClusterSim(n_workers=n_workers, n_nodes=2, seed=7),
                 n_workers=n_workers)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    tr.restore_or_init(init_fn)
    hist = tr.run(60, verbose=True)

    cs = [h["c"] for h in hist]
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(f"cutoffs: min={min(cs)} max={max(cs)} mean={np.mean(cs):.1f} "
          f"of {n_workers} workers")
    print(f"simulated wall-clock: {tr.sim_clock:.1f}s "
          f"(full sync would have paid the max worker every step)")


if __name__ == "__main__":
    main()
