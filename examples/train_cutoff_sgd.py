"""End-to-end driver: train a ~100M-parameter LM with cutoff SGD.

The full production loop: synthetic-token pipeline with per-worker
sampling-with-replacement, DMM-driven dynamic cutoff, masked gradient
aggregation, async checkpointing, and a comparison against full-sync on the
same simulated cluster clock.

  PYTHONPATH=src python examples/train_cutoff_sgd.py --steps 300
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import optim
from repro.cluster.simulator import ClusterSim
from repro.configs.base import ArchConfig, get_config
from repro.core.controller import CutoffController, FullSyncController
from repro.core.runtime_model.api import RuntimeModel
from repro.data.pipeline import SyntheticTokens
from repro.launch.train import Trainer, jit_train_step
from repro.models import model as M


def model_100m() -> ArchConfig:
    """~100M-parameter dense LM (qwen2-family structure)."""
    return dataclasses.replace(
        get_config("qwen2-0.5b"), name="repro-100m",
        n_layers=10, d_model=640, n_heads=10, n_kv_heads=2, head_dim=64,
        d_ff=1792, vocab_size=32_000, dtype="float32", tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--workers", type=int, default=16)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt_100m")
    ap.add_argument("--method", default="cutoff",
                    choices=["cutoff", "sync"])
    ap.add_argument("--mask-agg", default="weights",
                    choices=["weights", "psum"],
                    help="how the bit array meets the gradients: folded "
                         "per-example weights (production) or the explicit "
                         "per-worker gradient psum")
    ap.add_argument("--obs-dir", default=None,
                    help="write obs telemetry (spans/steps/decisions/"
                         "metrics JSONL) under this directory; render "
                         "with: python -m repro.obs <dir>")
    args = ap.parse_args()

    cfg = model_100m()
    n_params = cfg.n_params()
    print(f"model: {cfg.name} ({n_params/1e6:.0f}M params)")

    sim = ClusterSim(n_workers=args.workers, n_nodes=4, seed=0)
    trace = sim.run(200)
    if args.method == "cutoff":
        rm = RuntimeModel(n_workers=args.workers, lag=20).init(0)
        t0 = time.time()
        rm.fit(trace, steps=300, batch=8)
        print(f"runtime model fitted in {time.time()-t0:.1f}s")
        ctl = CutoffController(rm, k_samples=48)
        ctl.seed_window(trace)
    else:
        ctl = FullSyncController(args.workers)

    obs = None
    if args.obs_dir:
        from repro.obs import ObsRun
        obs = ObsRun(args.obs_dir)
        ctl = obs.wrap(ctl, policy=args.method)

    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=0)
    opt = optim.clip_by_global_norm(
        optim.adamw(optim.cosine_schedule(3e-4, 50, args.steps)), 1.0)
    step = jit_train_step(cfg, opt, mask_agg=args.mask_agg)
    tr = Trainer(cfg=cfg, step_fn=step, data=data, controller=ctl,
                 timer=ClusterSim(n_workers=args.workers, n_nodes=4, seed=9),
                 n_workers=args.workers, mask_agg=args.mask_agg,
                 ckpt_dir=args.ckpt, ckpt_every=100, obs=obs,
                 name=args.method)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    tr.restore_or_init(init_fn)
    t0 = time.time()
    hist = tr.run(args.steps, verbose=True)
    dt = time.time() - t0

    cs = [h["c"] for h in hist]
    print(f"\n=== {args.method} ===")
    print(f"loss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    print(f"simulated cluster wall-clock: {tr.sim_clock:.1f}s "
          f"({tr.sim_clock/len(hist):.3f}s/step)")
    print(f"mean cutoff: {np.mean(cs):.1f}/{args.workers}")
    print(f"host compute time: {dt:.1f}s ({dt/args.steps:.2f}s/step)")
    if obs is not None:
        obs.close()
        print(f"obs streams -> {args.obs_dir} "
              f"(render: python -m repro.obs {args.obs_dir})")


if __name__ == "__main__":
    main()
