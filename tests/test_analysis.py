"""reprolint rule-by-rule contract tests.

Every rule gets a BAD fixture it must flag and a CLEAN fixture it must
not (zero false positives is part of the contract — a linter that cries
wolf gets disabled, not fixed).  Fixtures are inline source strings
written to ``tmp_path`` so the repo's own ``--strict`` run never sees
them as code.  The donation pass additionally pins its documented
order-insensitivity: permuting independent statements (def-use order
preserved) never changes the finding multiset.
"""
from __future__ import annotations

import json
import re
import textwrap

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import all_rules, render_json, rule_ids, run_rules
from repro.analysis.core import discover


def lint(tmp_path, files, select=None):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    project = discover([str(tmp_path)], root=str(tmp_path),
                       known_rules=rule_ids())
    rules = all_rules()
    if select is not None:
        rules = [r for r in rules if r.id in select]
    return run_rules(project, rules)


def rules_hit(findings):
    return {f.rule for f in findings}


# -- host-sync-in-hot-path --------------------------------------------------


HOT_SYNC_BAD = """\
    import jax
    import jax.numpy as jnp

    class CutoffController:
        def observe(self, times):
            x = jnp.asarray(times)
            v = x.sum()
            a = v.item()
            b = float(jnp.mean(x))
            return a + b
"""

HOT_SYNC_VIA_CALLEE = """\
    import jax.numpy as jnp

    def drain(v):
        return v.item()

    class PSServer:
        def flush(self):
            v = jnp.zeros(3).sum()
            return drain(v)
"""

HOT_SYNC_CLEAN = """\
    import jax.numpy as jnp

    class Supervisor:
        def tick(self, now):
            # host bookkeeping: int()/float() of PLAIN host values is fine
            t = int(now) + 1
            frac = float(t) / 2.0
            return t, frac

    def offline_report(x):
        # not reachable from any hot root: syncs are allowed
        return float(jnp.sum(jnp.asarray(x)))
"""


def test_host_sync_flags_item_and_tainted_conversions(tmp_path):
    fs = lint(tmp_path, {"mod.py": HOT_SYNC_BAD},
              select={"host-sync-in-hot-path"})
    assert len(fs) == 2
    assert {f.line for f in fs} == {8, 9}


def test_host_sync_follows_the_call_graph(tmp_path):
    fs = lint(tmp_path, {"mod.py": HOT_SYNC_VIA_CALLEE},
              select={"host-sync-in-hot-path"})
    assert len(fs) == 1
    assert "PSServer.flush" in fs[0].message


def test_host_sync_clean_host_bookkeeping(tmp_path):
    assert lint(tmp_path, {"mod.py": HOT_SYNC_CLEAN},
                select={"host-sync-in-hot-path"}) == []


def test_hot_path_marker_extends_roots(tmp_path):
    src = """\
        import jax.numpy as jnp

        # reprolint: hot-path
        def serve(x):
            return jnp.asarray(x).sum().item()
    """
    fs = lint(tmp_path, {"mod.py": src}, select={"host-sync-in-hot-path"})
    assert len(fs) == 1


# -- donation-after-use -----------------------------------------------------


DONATION_BAD = """\
    import jax

    def f(x):
        return x

    step = jax.jit(f, donate_argnums=(0,))

    def run(state, batch):
        out = step(state)
        return state
"""

DONATION_CLEAN = """\
    import jax

    def f(x):
        return x

    step = jax.jit(f, donate_argnums=(0,))

    def run(state, batch):
        state = step(state)      # rebind-and-forget: the contract
        return state

    def build(cfg, opt):
        s = jax.jit(f, donate_argnums=(0,))   # builder call donates nothing
        return cfg, opt, s
"""


def test_donation_read_after_donate_flags(tmp_path):
    fs = lint(tmp_path, {"mod.py": DONATION_BAD},
              select={"donation-after-use"})
    assert len(fs) == 1
    assert "state" in fs[0].message and fs[0].line == 10


def test_donation_rebind_and_builder_clean(tmp_path):
    assert lint(tmp_path, {"mod.py": DONATION_CLEAN},
                select={"donation-after-use"}) == []


_HEADER = """\
import jax


def f(x):
    return x


def make():
    return 0


"""

_BLOCK = ("step{i} = jax.jit(f, donate_argnums=(0,))\n"
          "s{i} = make()\n"
          "o{i} = step{i}(s{i})\n"
          "r{i} = s{i} + 1\n")


def _interleave(seed, blocks):
    """Deterministic def-use-preserving merge of statement blocks."""
    idxs = [0] * len(blocks)
    out, state = [], seed
    while any(i < len(b) for i, b in zip(idxs, blocks)):
        live = [k for k, b in enumerate(blocks) if idxs[k] < len(b)]
        state = (state * 1103515245 + 12345) % (2 ** 31)
        k = live[state % len(live)]
        out.append(blocks[k][idxs[k]])
        idxs[k] += 1
    return out


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2 ** 30))
def test_donation_findings_order_insensitive(tmp_path_factory, seed):
    """Permuting independent statements never changes WHAT is flagged:
    every block's post-donation read is found, nothing else is."""
    blocks = [_BLOCK.format(i=i).splitlines() for i in range(3)]
    src = _HEADER + "\n".join(_interleave(seed, blocks)) + "\n"
    tmp = tmp_path_factory.mktemp(f"perm{seed % 997}")
    fs = lint(tmp, {"mod.py": src}, select={"donation-after-use"})
    names = sorted(re.search(r"`(s\d+)` is read after", f.message).group(1)
                   for f in fs)
    assert names == ["s0", "s1", "s2"]


# -- colwise-rng ------------------------------------------------------------


COLWISE_BAD = """\
    import jax

    @jax.jit
    def decide(key, times):
        n = times.shape[0]
        eps = jax.random.normal(key, shape=(n,))
        return eps
"""

COLWISE_CLEAN = """\
    import jax
    from repro.core.runtime_model import api

    @jax.jit
    def decide(key, times):
        n = times.shape[0]
        eps = api.colwise_normal(key, n)        # the sanctioned path
        u = jax.random.uniform(key)             # scalar draw: fine
        return eps + u
"""


def test_colwise_rng_flags_width_shaped_raw_draw(tmp_path):
    fs = lint(tmp_path, {"mod.py": COLWISE_BAD}, select={"colwise-rng"})
    assert len(fs) == 1 and fs[0].line == 6


def test_colwise_rng_clean_api_and_scalar_draws(tmp_path):
    assert lint(tmp_path, {"mod.py": COLWISE_CLEAN},
                select={"colwise-rng"}) == []


# -- nonatomic-checkpoint-write ---------------------------------------------


CKPT_BAD = """\
    import os

    def save(ckpt_dir, blob):
        path = os.path.join(ckpt_dir, "step_0000000005")
        with open(path, "w") as f:
            f.write(blob)
        os.rename(path, path + ".bak")
"""

CKPT_CLEAN = """\
    def save_log(log_path, blob):
        with open(log_path, "w") as f:     # not a checkpoint path
            f.write(blob)
"""

CKPT_STORE_EXEMPT = """\
    import os

    def publish(ckpt_dir, tmp):
        os.rename(tmp, ckpt_dir)           # the store OWNS the protocol
"""


def test_checkpoint_write_flags_direct_writes(tmp_path):
    fs = lint(tmp_path, {"mod.py": CKPT_BAD},
              select={"nonatomic-checkpoint-write"})
    assert len(fs) == 2
    assert {f.line for f in fs} == {5, 7}


def test_checkpoint_write_clean_and_store_exempt(tmp_path):
    assert lint(tmp_path, {"mod.py": CKPT_CLEAN},
                select={"nonatomic-checkpoint-write"}) == []
    assert lint(tmp_path, {"checkpoint/store.py": CKPT_STORE_EXEMPT},
                select={"nonatomic-checkpoint-write"}) == []


# -- event-kind-drift -------------------------------------------------------


EVENTS_BAD = """\
    EVENT_KINDS = (
        "alpha",
        "beta",
    )

    class Log:
        def emit(self, tick, kind):
            pass

    def go(log):
        log.emit(0, "alpha")
        log.emit(0, "gamma")
"""

EVENTS_CLEAN = """\
    EVENT_KINDS = ("alpha", "beta")

    class Log:
        def emit(self, tick, kind):
            pass

    def go(log, ev):
        log.emit(0, "alpha")
        log.emit(1, kind="beta")
        log.emit(2, ev.kind)        # dynamic: runtime check owns it
"""


def test_event_kind_drift_both_directions(tmp_path):
    fs = lint(tmp_path, {"mod.py": EVENTS_BAD}, select={"event-kind-drift"})
    blob = "\n".join(f.message for f in fs)
    assert len(fs) == 2
    assert "unregistered kind 'gamma'" in blob
    assert "kind 'beta' in EVENT_KINDS is never emitted" in blob
    # the dead-kind finding anchors at the constant's own line, so it
    # can be suppressed per-kind
    assert {f.line for f in fs if "never emitted" in f.message} == {3}


def test_event_kind_drift_clean(tmp_path):
    assert lint(tmp_path, {"mod.py": EVENTS_CLEAN},
                select={"event-kind-drift"}) == []


# -- static-argnum-width ----------------------------------------------------


STATIC_BAD = """\
    import functools

    import jax

    @functools.partial(jax.jit, static_argnames=("n",))
    def pad_to(x, n):
        return x

    @functools.partial(jax.jit, static_argnums=(1,))
    def floor_at(x, lo):
        return x
"""

STATIC_CLEAN = """\
    import functools

    import jax

    @functools.partial(jax.jit, static_argnames=("mode",))
    def dispatch(x, n, mode):
        return x
"""


def test_static_width_flags_names_and_nums(tmp_path):
    fs = lint(tmp_path, {"mod.py": STATIC_BAD},
              select={"static-argnum-width"})
    assert len(fs) == 2
    assert {f.line for f in fs} == {5, 9}


def test_static_width_clean_mode_static(tmp_path):
    assert lint(tmp_path, {"mod.py": STATIC_CLEAN},
                select={"static-argnum-width"}) == []


# -- twin-epsilon-drift -----------------------------------------------------


TWIN_BAD = """\
    import jax.numpy as jnp
    import numpy as np

    def curve(x):
        return x / np.maximum(x, 1e-9)

    def curve_jax(x):
        return x / jnp.maximum(x, 1e-9)
"""

TWIN_CLEAN = """\
    import jax.numpy as jnp
    import numpy as np

    FLOOR = 1e-9

    def curve(x):
        return x / np.maximum(x, FLOOR)

    def curve_jax(x):
        return x / jnp.maximum(x, FLOOR)

    def lonely(x):
        return x + 1e-9        # no _jax twin: not this rule's business
"""


def test_twin_epsilon_flags_inline_literals_in_twins(tmp_path):
    fs = lint(tmp_path, {"mod.py": TWIN_BAD},
              select={"twin-epsilon-drift"})
    assert len(fs) == 2
    assert {f.line for f in fs} == {5, 8}


def test_twin_epsilon_clean_shared_constant(tmp_path):
    assert lint(tmp_path, {"mod.py": TWIN_CLEAN},
                select={"twin-epsilon-drift"}) == []


# -- suppressions -----------------------------------------------------------


def test_suppression_with_reason_silences(tmp_path):
    src = """\
        import jax

        def f(x):
            return x

        step = jax.jit(f, donate_argnums=(0,))

        def run(state):
            out = step(state)
            # reprolint: disable=donation-after-use -- test double-read on purpose
            return state
    """
    assert lint(tmp_path, {"mod.py": src},
                select={"donation-after-use"}) == []


def test_suppression_without_reason_is_itself_a_finding(tmp_path):
    src = """\
        import jax

        def f(x):
            return x

        step = jax.jit(f, donate_argnums=(0,))

        def run(state):
            out = step(state)
            return state  # reprolint: disable=donation-after-use
    """
    fs = lint(tmp_path, {"mod.py": src})
    assert rules_hit(fs) == {"bad-suppression", "donation-after-use"}


def test_suppression_unknown_rule_is_flagged(tmp_path):
    src = """\
        # reprolint: disable=no-such-rule -- says who
        x = 1
    """
    fs = lint(tmp_path, {"mod.py": src})
    assert rules_hit(fs) == {"bad-suppression"}


# -- reporters --------------------------------------------------------------


def test_json_reporter_schema(tmp_path):
    fs = lint(tmp_path, {"mod.py": DONATION_BAD},
              select={"donation-after-use"})
    doc = json.loads(render_json(fs))
    assert doc["version"] == 1
    assert doc["total"] == len(fs) == len(doc["findings"])
    assert doc["counts"] == {"donation-after-use": 1}
    f = doc["findings"][0]
    assert set(f) >= {"path", "line", "col", "rule", "message"}


def test_parse_error_is_reported_not_raised(tmp_path):
    fs = lint(tmp_path, {"mod.py": "def broken(:\n"})
    assert rules_hit(fs) == {"parse-error"}
