"""Checkpoint crash-window recovery + integrity checksums.

The publish sequence ``rename(final, stale) -> rename(tmp, final) ->
rmtree(stale)`` has three crash windows.  Each test builds the exact
partial disk state a crash at that point leaves behind and asserts
``recover`` (run implicitly by every open) repairs it — most
importantly the window between the two renames, where NO ``step_<step>``
dir exists and the old code's next save deleted both surviving copies
as debris.

Integrity: every group file's CRC-32 lives in the manifest; corruption
raises ``CheckpointError`` NAMING the bad group, and recovery walks back
to the newest fully-valid step (``latest_valid_step`` / the Trainer's
restore fallback).
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import store
from repro.checkpoint.store import CheckpointError


def _state(v: float):
    return {"state": {"w": np.full((3, 2), v), "b": np.arange(4.0) * v},
            "meta": {"step": 0, "clock": 0.0}}


def _save(d, step, v, keep=10):
    st = _state(v)
    st["meta"]["step"] = step
    return store.save(str(d), step, st, keep=keep)


def _restored_value(d, step=None):
    out = store.restore(str(d), _state(0.0), step=step)
    return float(out["state"]["w"][0, 0])


def _park_as(d, step, name):
    """Move the published step dir aside under ``name`` (tmp/stale)."""
    # reprolint: disable=nonatomic-checkpoint-write -- this helper STAGES the crash windows the store must recover from
    os.rename(os.path.join(d, f"step_{step:010d}"), os.path.join(d, name))


# ---------------------------------------------------------------------------
# Crash windows, one partial disk state per test.
# ---------------------------------------------------------------------------


def test_crash_between_renames_promotes_complete_tmp(tmp_path):
    """Crash after rename(final, stale), before rename(tmp, final): no
    final dir at all.  The COMPLETE tmp (manifest present) must win —
    it is the newer checkpoint, fully written."""
    d = str(tmp_path)
    _save(d, 5, v=1.0)
    _park_as(d, 5, "stale.5")           # the old copy, parked
    scratch = tmp_path / "scratch"
    _save(scratch, 5, v=2.0)            # the new copy, fully written...
    # reprolint: disable=nonatomic-checkpoint-write -- simulates a crash mid-publish (tmp dir present, rename never ran)
    os.rename(os.path.join(scratch, f"step_{5:010d}"),
              os.path.join(d, "tmp.5"))  # ...but never published
    assert store.latest_step(d) == 5     # recovery ran on open
    assert _restored_value(d) == 2.0     # the tmp content won
    assert not os.path.exists(os.path.join(d, "tmp.5"))
    assert not os.path.exists(os.path.join(d, "stale.5"))


def test_crash_mid_write_restores_stale(tmp_path):
    """Crash while WRITING tmp (no manifest yet) after parking the old
    dir: the stale copy is the only complete one — put it back."""
    d = str(tmp_path)
    _save(d, 5, v=1.0)
    _park_as(d, 5, "stale.5")
    os.makedirs(os.path.join(d, "tmp.5"))
    # reprolint: disable=nonatomic-checkpoint-write -- simulates a crash mid-WRITE: a half-baked tmp dir the store must discard
    np.savez(os.path.join(d, "tmp.5", "state.npz"), w=np.zeros(2))
    assert store.latest_step(d) == 5
    assert _restored_value(d) == 1.0     # the old checkpoint survived
    assert not os.path.exists(os.path.join(d, "tmp.5"))


def test_crash_before_stale_cleanup_drops_debris(tmp_path):
    """Crash after publishing, before rmtree(stale): the new final is
    current, the parked old copy is debris."""
    d = str(tmp_path)
    _save(d, 5, v=1.0)
    _park_as(d, 5, "stale.5")           # the old copy, parked aside
    scratch = tmp_path / "scratch"
    _save(scratch, 5, v=2.0)
    # reprolint: disable=nonatomic-checkpoint-write -- simulates a crash AFTER publish (stale dir left behind)
    os.rename(os.path.join(scratch, f"step_{5:010d}"),
              os.path.join(d, f"step_{5:010d}"))  # publish completed
    assert store.latest_step(d) == 5
    assert _restored_value(d) == 2.0     # the published copy wins
    assert not os.path.exists(os.path.join(d, "stale.5"))


def test_incomplete_fresh_tmp_is_debris(tmp_path):
    """A fresh-step save that died mid-write leaves only a manifest-less
    tmp; the previous step stays latest."""
    d = str(tmp_path)
    _save(d, 5, v=1.0)
    os.makedirs(os.path.join(d, "tmp.6"))
    # reprolint: disable=nonatomic-checkpoint-write -- simulates an orphaned tmp dir from a NEWER crashed step
    np.savez(os.path.join(d, "tmp.6", "state.npz"), w=np.zeros(2))
    assert store.latest_step(d) == 5
    assert not os.path.exists(os.path.join(d, "tmp.6"))


def test_resave_after_crash_window_does_not_lose_the_step(tmp_path):
    """THE regression: with no step dir on disk (crash between renames),
    the next save of that step used to rmtree both tmp and stale as
    debris before writing — a second crash then lost every copy.  Now
    recovery promotes BEFORE the save touches anything."""
    d = str(tmp_path)
    _save(d, 5, v=1.0)
    _park_as(d, 5, "stale.5")
    scratch = tmp_path / "scratch"
    _save(scratch, 5, v=2.0)
    # reprolint: disable=nonatomic-checkpoint-write -- simulates the crash window a later re-save must win over
    os.rename(os.path.join(scratch, f"step_{5:010d}"),
              os.path.join(d, "tmp.5"))
    _save(d, 5, v=3.0)                  # re-save of the crashed step
    assert _restored_value(d) == 3.0
    assert store.list_steps(d) == [5]


# ---------------------------------------------------------------------------
# Checksums + fallback.
# ---------------------------------------------------------------------------


def _corrupt(d, step, group="state"):
    path = os.path.join(str(d), f"step_{step:010d}", f"{group}.npz")
    # reprolint: disable=nonatomic-checkpoint-write -- deliberate bit-flip so the crc32 manifest check has something to catch
    with open(path, "r+b") as f:
        f.seek(os.path.getsize(path) // 2)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))


def test_corrupt_group_raises_naming_it(tmp_path):
    _save(tmp_path, 5, v=1.0)
    _corrupt(tmp_path, 5, "state")
    with pytest.raises(CheckpointError, match="group 'state'"):
        store.restore(str(tmp_path), _state(0.0))
    with pytest.raises(CheckpointError, match="group 'state'"):
        store.verify_step(str(tmp_path), 5)
    with pytest.raises(CheckpointError, match="group 'state'"):
        store.restore_group(str(tmp_path), "state")


def test_latest_valid_step_walks_past_corruption(tmp_path):
    d = str(tmp_path)
    _save(d, 5, v=1.0)
    _save(d, 10, v=2.0)
    assert store.latest_valid_step(d) == 10
    _corrupt(d, 10)
    assert store.latest_step(d) == 10          # still the newest dir...
    assert store.latest_valid_step(d) == 5     # ...but not the anchor
    assert _restored_value(d, step=5) == 1.0


def test_missing_group_file_raises(tmp_path):
    _save(tmp_path, 5, v=1.0)
    # reprolint: disable=nonatomic-checkpoint-write -- deletes a published group file to drive the missing-file error path
    os.remove(os.path.join(str(tmp_path), f"step_{5:010d}", "state.npz"))
    with pytest.raises(CheckpointError, match="file missing"):
        store.verify_step(str(tmp_path), 5)


def test_torn_manifest_raises(tmp_path):
    _save(tmp_path, 5, v=1.0)
    man = os.path.join(str(tmp_path), f"step_{5:010d}", "manifest.json")
    # reprolint: disable=nonatomic-checkpoint-write -- writes a TORN manifest on purpose to drive the corrupt-manifest error path
    with open(man, "w") as f:
        f.write('{"step": 5, "gro')
    with pytest.raises(CheckpointError, match="manifest"):
        store.verify_step(str(tmp_path), 5)


def test_pre_checksum_manifest_still_restores(tmp_path):
    """Checkpoints written before checksums existed (no crc32 field)
    must keep restoring — integrity is simply not verifiable."""
    d = str(tmp_path)
    _save(d, 5, v=1.0)
    man = os.path.join(d, f"step_{5:010d}", "manifest.json")
    with open(man) as f:
        manifest = json.load(f)
    for g in manifest["groups"].values():
        g.pop("crc32")
    # reprolint: disable=nonatomic-checkpoint-write -- rewrites the manifest sans checksums to simulate a pre-crc32 checkpoint
    with open(man, "w") as f:
        json.dump(manifest, f)
    assert _restored_value(d) == 1.0
    assert store.latest_valid_step(d) == 5


# ---------------------------------------------------------------------------
# Trainer restore fallback (corrupt latest -> previous step, warm).
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_train():
    import jax

    from repro import optim
    from repro.configs.base import bench_tiny_config
    from repro.launch.train import jit_train_step
    from repro.models import model as M

    cfg = bench_tiny_config()
    opt = optim.adamw(1e-3)
    step_fn = jit_train_step(cfg, opt)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    return cfg, step_fn, init_fn


def test_trainer_falls_back_to_previous_step_on_corruption(tmp_path,
                                                           tiny_train):
    from repro.core.controller import ElfvingController
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer

    cfg, step_fn, init_fn = tiny_train
    d = str(tmp_path / "ckpt")

    def make(n=4):
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=16, seed=0)
        return Trainer(cfg=cfg, step_fn=step_fn, data=data,
                       controller=ElfvingController(n), n_workers=n,
                       ckpt_dir=d, ckpt_every=4, keep=5)

    tr = make().restore_or_init(init_fn)
    tr.run(8)                            # checkpoints at steps 4 and 8
    assert store.list_steps(d) == [4, 8]
    _corrupt(d, 8, "state")

    tr2 = make().restore_or_init(init_fn)
    assert tr2.step == 4                 # warm restart from the good step
    assert tr2.sim_clock > 0.0
    # the controller group came from the SAME step as the train state
    grp = store.restore_group(d, "ctl", step=4)
    assert int(grp["step"]) == int(getattr(tr2.controller, "_step", 4))

    tr3 = make().restore_or_init(init_fn)
    _ = tr3  # second restore is idempotent (recovery already ran)


def test_trainer_cold_init_when_every_step_corrupt(tmp_path, tiny_train):
    from repro.core.controller import ElfvingController
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer

    cfg, step_fn, init_fn = tiny_train
    d = str(tmp_path / "ckpt")
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                           global_batch=16, seed=0)

    def make():
        return Trainer(cfg=cfg, step_fn=step_fn, data=data,
                       controller=ElfvingController(4), n_workers=4,
                       ckpt_dir=d, ckpt_every=4)

    tr = make().restore_or_init(init_fn)
    tr.run(4)
    _corrupt(d, 4, "meta")
    tr2 = make().restore_or_init(init_fn)
    assert tr2.step == 0                 # cold, but alive
