"""BENCH_controller.json schema guard.

Runs ``benchmarks.controller_bench.bench_controller`` at minimum size and
asserts the machine-readable output keeps the ``bench_controller/v1``
contract the perf-trajectory tooling consumes.  This is a schema smoke
test, not a perf assertion — timings on a loaded CI box are noise.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    from benchmarks.controller_bench import bench_controller

    out = tmp_path_factory.mktemp("bench") / "BENCH_controller.json"
    bench_controller(quick=True, out_path=str(out), n_list=(8,),
                     k_list=(8,), decision_iters=2, trainer_steps=2,
                     trainer_workers=8)
    with open(out) as f:
        return json.load(f)


def test_bench_controller_schema(bench_json):
    assert bench_json["schema"] == "bench_controller/v1"
    rows = bench_json["decision"]
    assert rows, "decision section empty"
    for row in rows:
        for key in ("n_workers", "k_samples", "numpy_us", "device_us",
                    "speedup", "numpy_blocked_us", "device_blocked_us",
                    "blocked_speedup"):
            assert key in row, key
        assert row["numpy_us"] > 0 and row["device_us"] > 0
        assert row["numpy_blocked_us"] > 0 and row["device_blocked_us"] > 0
    tr = bench_json["trainer"]
    for key in ("sync_steps_per_s", "async_steps_per_s", "async_over_sync",
                "n_workers", "steps", "arch"):
        assert key in tr, key
    assert tr["sync_steps_per_s"] > 0 and tr["async_steps_per_s"] > 0


def test_committed_bench_controller_matches_schema():
    """The checked-in BENCH_controller.json (the perf trajectory's second
    datapoint) must exist and carry the same schema."""
    path = Path(__file__).resolve().parent.parent / "BENCH_controller.json"
    assert path.exists(), "BENCH_controller.json not committed"
    with open(path) as f:
        data = json.load(f)
    assert data["schema"] == "bench_controller/v1"
    combos = {(r["n_workers"], r["k_samples"]) for r in data["decision"]}
    for n in (8, 158, 1024):
        for k in (64, 256):
            assert (n, k) in combos, (n, k)
