"""The composed multi-tenant churn path, end to end.

examples/multi_job_demo.py tells this story; this test pins it down in
tier-1: ChurnEvent kill -> PartitionView shrink -> Trainer's
_sync_membership -> JobHandle.resize -> server degrade (warm Elfving) ->
refit -> rejoin the batched path, with GLOBAL worker ids preserved in
the job registry through every hop, and the other tenant never leaving
the batched DMM path.
"""
import numpy as np
import pytest

from repro.cluster.simulator import ChurnEvent, PartitionedSim, partition_ids


@pytest.fixture(scope="module")
def churn_run():
    from repro.launch.multi_job import build_multi_job, run_ticks
    from repro.ps import make_scheduler

    ticks, kill_at, back_at = 22, 6, 14
    events = [ChurnEvent(step=kill_at, kill=(8, 9)),
              ChurnEvent(step=back_at, restore=(8, 9))]
    server, jobs, sim = build_multi_job(
        2, 8, seed=0, fit_steps=40, churn_events=events,
        refit_steps=30, refit_fresh=3, metrics_every=50)
    sched = make_scheduler("rr")
    timeline = []
    for tick in range(ticks):
        out = run_ticks(server, jobs, sched, 1)
        j1 = server.registry["job1"]
        timeline.append({"tick": tick, "width": j1.width, "mode": j1.mode,
                         "members": j1.members.copy(),
                         "dispatches": out["dispatches"]})
    return server, jobs, timeline, (kill_at, back_at)


def test_churn_shrinks_job_and_preserves_global_ids(churn_run):
    server, jobs, timeline, (kill_at, back_at) = churn_run
    shrunk = [t for t in timeline if kill_at <= t["tick"] < back_at]
    assert all(t["width"] == 6 for t in shrunk)
    # the registry keeps GLOBAL worker ids through the resize — the
    # survivors of partition 1, not a renumbered arange
    for t in shrunk:
        np.testing.assert_array_equal(t["members"], np.arange(10, 16))
    assert shrunk[0]["mode"] == "fallback", "resize must degrade first"
    assert shrunk[-1]["mode"] == "dmm", "refit must rejoin the batch"


def test_churn_recovers_width_and_membership(churn_run):
    server, jobs, timeline, (kill_at, back_at) = churn_run
    final = timeline[-1]
    assert final["width"] == 8
    assert final["mode"] == "dmm"
    np.testing.assert_array_equal(
        np.sort(np.asarray(server.registry["job1"].members)),
        np.arange(8, 16))
    # the unaffected tenant never left the batched DMM path
    assert jobs["job0"].handle.mode == "dmm"
    assert jobs["job0"].handle.n == 8
    # both jobs trained every tick (full capacity, rr)
    assert len(jobs["job0"].trainer.history) == len(timeline)
    assert len(jobs["job1"].trainer.history) == len(timeline)


def test_churn_stays_batched(churn_run):
    """The whole churn run must keep amortizing dispatch: ~1 fused
    dispatch per tick while both jobs share the bucket, bounded well
    below the 2-per-tick looped cost even counting the degraded phases
    (where job1's Elfving fallback costs zero fused dispatches and its
    rejoin re-seeds the ring)."""
    server, jobs, timeline, _ = churn_run
    total = sum(t["dispatches"] for t in timeline)
    assert total < 2 * len(timeline), total


def test_partitioned_sim_prunes_row_cache():
    from repro.cluster.simulator import paper_cluster_158

    sim = PartitionedSim(paper_cluster_158(seed=0, n_workers=8),
                         partition_ids(8, 2))
    va, vb = sim.views()
    for _ in range(50):
        va.step()
        vb.step()
    assert len(sim._rows) <= 2, "cache must be bounded by cursor spread"
    # a view opened after pruning fails loudly, not wrongly
    late = sim.view(0)
    with pytest.raises(IndexError):
        late.step()


def test_partitioned_sim_bounds_cache_under_pinned_view():
    """A starved job's stalled cursor must not grow the row cache without
    bound (the priority policy CAN starve) — past max_cache the pinned
    view loses its rows and reads fail loudly."""
    from repro.cluster.simulator import paper_cluster_158

    sim = PartitionedSim(paper_cluster_158(seed=0, n_workers=8),
                         partition_ids(8, 2), max_cache=16)
    va, vb = sim.views()
    for _ in range(40):
        va.step()               # vb is pinned at t=0
    assert len(sim._rows) <= 16
    with pytest.raises(IndexError):
        vb.step()
