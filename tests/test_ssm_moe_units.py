"""Unit + property tests for the SSM recurrence machinery and MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.dist import sharding as shd
from repro.models import moe as MOE
from repro.models import ssm as S

SETTINGS = dict(max_examples=10, deadline=None)


# ---------------------------------------------------------------------------
# Stabilized linear recurrence.
# ---------------------------------------------------------------------------


def _rand_state(key, B=2, h=2, dq=4, dv=3):
    ks = jax.random.split(key, 4)
    return S.ScanState(
        loga=-jnp.abs(jax.random.normal(ks[0], (B, h))),
        m=jax.random.normal(ks[1], (B, h)),
        C=jax.random.normal(ks[2], (B, h, dq, dv)),
        n=jax.random.normal(ks[3], (B, h, dq)))


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000))
def test_combine_associative(seed):
    k = jax.random.PRNGKey(seed)
    a, b, c = (_rand_state(kk) for kk in jax.random.split(k, 3))
    left = S.combine(S.combine(a, b), c)
    right = S.combine(a, S.combine(b, c))
    for l, r in zip(jax.tree.leaves(left), jax.tree.leaves(right)):
        np.testing.assert_allclose(l, r, atol=1e-4, rtol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000))
def test_combine_identity(seed):
    a = _rand_state(jax.random.PRNGKey(seed))
    ident = S.state_identity(a)
    out = S.combine(ident, a)
    for l, r in zip(jax.tree.leaves(out), jax.tree.leaves(a)):
        np.testing.assert_allclose(l, r, atol=1e-5)


@settings(**SETTINGS)
@given(chunk=st.sampled_from([16, 32, 64, 128]))
def test_linear_recurrence_chunk_invariance(chunk):
    """Output must not depend on the chunk size."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    B, T, h, d = 2, 128, 2, 16
    q = jax.random.normal(ks[0], (B, T, h, d)) * 0.5
    k = jax.random.normal(ks[1], (B, T, h, d)) * 0.5
    v = jax.random.normal(ks[2], (B, T, h, d))
    g = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, T, h)) + 2.0)
    i = jax.random.normal(ks[4], (B, T, h)) * 0.5
    y_ref, st_ref = S.linear_recurrence(q, k, v, g, i, chunk=T,
                                        normalize=True)
    y, st_ = S.linear_recurrence(q, k, v, g, i, chunk=chunk, normalize=True)
    np.testing.assert_allclose(y, y_ref, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(st_.m, st_ref.m, atol=1e-4)


def test_recurrence_step_matches_chunked():
    """Sequential decode steps == chunked prefill over the same tokens."""
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    B, T, h, d = 1, 16, 2, 8
    q = jax.random.normal(ks[0], (B, T, h, d)) * 0.5
    k = jax.random.normal(ks[1], (B, T, h, d)) * 0.5
    v = jax.random.normal(ks[2], (B, T, h, d))
    g = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, T, h)) + 2.0)
    i = jax.random.normal(ks[4], (B, T, h)) * 0.5
    y_chunk, final = S.linear_recurrence(q, k, v, g, i, chunk=8,
                                         normalize=True)
    state = S.ScanState(
        loga=jnp.zeros((B, h)), m=jnp.full((B, h), S.NEG),
        C=jnp.zeros((B, h, d, d)), n=jnp.zeros((B, h, d)))
    ys = []
    for t in range(T):
        y, state = S.recurrence_step(state, q[:, t], k[:, t], v[:, t],
                                     g[:, t], i[:, t], normalize=True)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_seq, y_chunk, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(state.m, final.m, atol=1e-4)


def test_causal_conv1d_matches_numpy():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 10, 3))
    w = jax.random.normal(jax.random.PRNGKey(3), (4, 3))
    y = S.causal_conv1d(x, w)
    xp = np.pad(np.asarray(x), ((0, 0), (3, 0), (0, 0)))
    want = sum(xp[:, j:j + 10] * np.asarray(w)[j] for j in range(4))
    np.testing.assert_allclose(y, want, atol=1e-5)


def test_slstm_normalizer_bounded():
    """sLSTM hidden state stays bounded (|h| <= 1 by construction)."""
    p = S.slstm_init(jax.random.PRNGKey(0), 16, 2, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16)) * 3.0
    h, _ = S.slstm_apply(p, x, 2)
    assert float(jnp.max(jnp.abs(h))) <= 1.0 + 1e-5


# ---------------------------------------------------------------------------
# MoE dispatch.
# ---------------------------------------------------------------------------


def _moe_cfg(E=8, k=2, cf=8.0):
    from repro.configs.base import get_config
    cfg = get_config("deepseek-moe-16b").reduced()
    return dataclasses.replace(cfg, n_experts=E, top_k=k,
                               moe_capacity_factor=cf, n_shared_experts=0)


def _dense_reference(cfg, params, x):
    """Loop-over-experts reference (no capacity, no dispatch)."""
    topk_w, topk_i, f_e, p_e = MOE._route(cfg, params["router"], x)
    B, S_, D = x.shape
    y = jnp.zeros_like(x)
    bank = params["experts"]
    for e in range(cfg.n_experts):
        h = jax.nn.silu(x @ bank["w_gate"][e]) * (x @ bank["w_up"][e])
        out_e = h @ bank["w_down"][e]
        w_e = jnp.sum(jnp.where(topk_i == e, topk_w, 0.0), axis=-1)
        y = y + out_e * w_e[..., None].astype(x.dtype)
    return y


@settings(**SETTINGS)
@given(seed=st.integers(0, 200))
def test_moe_dispatch_matches_dense_reference(seed):
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(seed)
    params = MOE.moe_init(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model))
    y, aux = MOE.moe_apply(cfg, params, x)
    want = _dense_reference(cfg, params, x)
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.25)  # tiny capacity => drops must occur
    key = jax.random.PRNGKey(0)
    params = MOE.moe_init(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, _ = MOE.moe_apply(cfg, params, x)
    want = _dense_reference(cfg, params, x)
    # with heavy dropping the outputs must differ (some tokens got zero)
    assert float(jnp.max(jnp.abs(y - want))) > 1e-3


def test_capacity_for_rounding():
    cfg = _moe_cfg(E=8, k=2, cf=1.0)
    assert MOE.capacity_for(cfg, 64) % 8 == 0
    assert MOE.capacity_for(cfg, 64) >= 16
