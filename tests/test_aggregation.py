"""Masked bit-array aggregation semantics (paper Alg. 1 line 29).

In-process tests cover the LOCAL path of ``dist.collectives`` and the
``example_weights`` production expansion; the mesh shard_map path runs on 8
fake devices in a subprocess (see test_sharded_equivalence.py ->
tests/sharded/dist_check.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation
from repro.dist import collectives


def _worker_grads(key, n_workers=4):
    ks = jax.random.split(key, 2)
    return {
        "w": jax.random.normal(ks[0], (n_workers, 3, 5)),
        "b": jax.random.normal(ks[1], (n_workers, 7)),
    }


def test_example_weights_expansion():
    mask = np.array([1.0, 0.0, 1.0, 1.0], np.float32)
    w = aggregation.example_weights(mask, 8)
    np.testing.assert_array_equal(w, [1, 1, 0, 0, 1, 1, 1, 1])
    with pytest.raises(AssertionError):
        aggregation.example_weights(mask, 6)   # batch must divide workers


def test_local_masked_mean_all_ones_is_plain_mean():
    grads = _worker_grads(jax.random.PRNGKey(0))
    ones = jnp.ones((4,), jnp.float32)
    masked = collectives.masked_grad_mean(grads, ones)
    plain = collectives.grad_mean(grads)
    for a, b in zip(jax.tree.leaves(masked), jax.tree.leaves(plain)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_local_masked_out_worker_has_zero_influence():
    grads = _worker_grads(jax.random.PRNGKey(1))
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    base = collectives.masked_grad_mean(grads, mask)
    # replace the dropped worker's gradient with huge garbage: bit 0 must
    # annihilate it EXACTLY
    poisoned = jax.tree.map(lambda l: l.at[1].set(1e30), grads)
    out = collectives.masked_grad_mean(poisoned, mask)
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_local_masked_mean_matches_manual():
    grads = _worker_grads(jax.random.PRNGKey(2))
    mask = jnp.asarray([1.0, 0.0, 0.0, 1.0])
    out = collectives.masked_grad_mean(grads, mask)
    want = jax.tree.map(lambda l: (l[0] + l[3]) / 2.0, grads)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_local_all_masked_is_safe():
    """c=0 falls back to dividing by 1 — no NaNs/inf out of the update."""
    grads = _worker_grads(jax.random.PRNGKey(3))
    out = collectives.masked_grad_mean(grads, jnp.zeros((4,)))
    for l in jax.tree.leaves(out):
        assert bool(jnp.all(jnp.isfinite(l)))
        np.testing.assert_array_equal(np.asarray(l), 0.0)
