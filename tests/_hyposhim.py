"""Minimal, deterministic stand-in for ``hypothesis`` when it is absent.

The container may not ship hypothesis; the test suite only uses a tiny
slice of it (``given`` with integers/floats/booleans/sampled_from and
``settings(max_examples=..., deadline=...)``).  This shim replays each
test over a fixed number of examples drawn from a seeded RNG keyed on the
test name, so runs are deterministic and CI-stable.  ``tests/conftest.py``
installs it into ``sys.modules`` only when the real package is missing.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


class strategies:  # mirrors ``from hypothesis import strategies as st``
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    booleans = staticmethod(booleans)
    sampled_from = staticmethod(sampled_from)


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode()) & 0xFFFFFFFF
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in strats.items()}
                fn(*args, **drawn, **kwargs)
        # pytest must not see the strategy-drawn params as fixtures
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        wrapper._shim_given = True
        return wrapper
    return deco


def settings(**kw):
    max_examples = kw.get("max_examples", DEFAULT_MAX_EXAMPLES)

    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco
