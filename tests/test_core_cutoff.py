"""The paper's core machinery: order stats, Elfving, censoring, controller,
DMM+guide ELBO, and the cutoff aggregation semantics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.simulator import ClusterSim, paper_cluster_158
from repro.core.controller import (CutoffController, ElfvingController,
                                   FullSyncController,
                                   StaticCutoffController)
from repro.core.cutoff import censoring, elfving, order_stats
from repro.core.runtime_model.api import RuntimeModel

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# Elfving / order statistics (paper §3.1.1, §4.1)
# ---------------------------------------------------------------------------


def test_elfving_reproduces_paper_numbers():
    """Paper §4.1: n=158, mu=1.057, sigma=0.393 -> E[max] ~ 2.1063 s."""
    approx = elfving.expected_max(158, 1.057, 0.393)
    exact = elfving.exact_order_stat_mean(158, 158, 1.057, 0.393)
    # paper prints 2.1063; MC ground truth is 2.1055 +- 0.001
    assert abs(approx - 2.1063) < 3e-3
    assert abs(exact - 2.1055) < 1.5e-3
    # ~1 second of idle per worker (paper: 1.049)
    assert abs((approx - 1.057) - 1.049) < 3e-3


@settings(**SETTINGS)
@given(n=st.integers(4, 500), mu=st.floats(0.5, 5.0),
       sigma=st.floats(0.01, 1.0))
def test_elfving_order_stats_monotone(n, mu, sigma):
    e = elfving.expected_order_stats(n, mu, sigma)
    assert np.all(np.diff(e) >= -1e-12)          # sorted expectations
    # symmetry: the two middle order stats straddle mu
    mid = 0.5 * (e[(n - 1) // 2] + e[n // 2])
    assert abs(mid - mu) < 0.1 * sigma + 1e-6


@settings(**SETTINGS)
@given(n=st.integers(8, 256), seed=st.integers(0, 1000))
def test_mc_order_stats_match_sorted_means(n, seed):
    rng = np.random.default_rng(seed)
    s = rng.exponential(1.0, size=(64, n))
    mean, std = order_stats.mc_order_stats(s)
    assert np.all(np.diff(mean) >= -1e-12)
    assert std.shape == (n,)


@settings(**SETTINGS)
@given(seed=st.integers(0, 1000))
def test_optimal_cutoff_beats_full_sync_throughput(seed):
    rng = np.random.default_rng(seed)
    s = rng.lognormal(0.0, 0.4, size=(128, 64))
    c = order_stats.optimal_cutoff(s)
    omega = order_stats.throughput_curve(s)
    assert omega[c - 1] >= omega[-1] - 1e-9


def test_oracle_cutoff_definition():
    t = np.array([1.0, 1.1, 1.2, 9.0])
    assert order_stats.oracle_cutoff(t) == 3
    assert order_stats.iter_time(t, 3) == pytest.approx(1.2)


# ---------------------------------------------------------------------------
# Censored imputation (paper §4.2)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), cut=st.floats(0.5, 3.0))
def test_truncated_samples_respect_lower_bound(seed, cut):
    rng = np.random.default_rng(seed)
    s = censoring.truncated_normal_sample(
        np.zeros(200), np.ones(200), np.full(200, cut), rng)
    assert np.all(s >= cut - 1e-9)


def test_truncated_mean_matches_theory():
    rng = np.random.default_rng(0)
    s = censoring.truncated_normal_sample(
        np.zeros(200_000), np.ones(200_000), np.ones(200_000), rng)
    # E[X | X>1] for standard normal = phi(1)/(1-Phi(1)) ~ 1.5251
    assert abs(s.mean() - 1.5251) < 0.01


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(2, 64),
       cut=st.floats(0.2, 4.0), frac=st.floats(0.1, 0.9))
def test_impute_censored_samples_above_cutoff_and_finite(seed, n, cut, frac):
    """Property: every imputed entry is finite and >= the observed cutoff
    time, whatever the predictive moments look like."""
    rng = np.random.default_rng(seed)
    observed = rng.uniform(0.1, cut, size=n)
    finished = rng.uniform(size=n) < frac
    mu = rng.uniform(0.1, 3.0, size=n)
    std = rng.uniform(0.0, 1.0, size=n)   # sigma=0 exercises the clamp
    out = censoring.impute_censored(observed, finished, mu, std, cut, rng)
    assert np.all(np.isfinite(out))
    np.testing.assert_array_equal(out[finished], observed[finished])
    assert np.all(out[~finished] >= cut - 1e-9)


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(2, 128),
       min_frac=st.floats(0.0, 1.0))
def test_optimal_cutoff_respects_min_frac(seed, n, min_frac):
    rng = np.random.default_rng(seed)
    s = rng.lognormal(0.0, 0.5, size=(32, n))
    c = order_stats.optimal_cutoff(s, min_frac=min_frac)
    lo = min(int(np.ceil(min_frac * n)), n)
    assert lo <= c <= n


@settings(**SETTINGS)
@given(seed=st.integers(0, 500))
def test_optimal_cutoff_invariant_to_worker_permutation(seed):
    """The cutoff depends only on order statistics, never on worker
    identity: permuting the worker axis of the samples changes nothing."""
    rng = np.random.default_rng(seed)
    s = rng.lognormal(0.0, 0.4, size=(64, 32))
    perm = rng.permutation(32)
    assert (order_stats.optimal_cutoff(s)
            == order_stats.optimal_cutoff(s[:, perm]))
    np.testing.assert_allclose(order_stats.throughput_curve(s),
                               order_stats.throughput_curve(s[:, perm]))


def test_impute_censored_only_touches_missing():
    rng = np.random.default_rng(1)
    obs = np.array([1.0, 2.0, 0.0, 0.0])
    mask = np.array([True, True, False, False])
    out = censoring.impute_censored(obs, mask, np.full(4, 1.5),
                                    np.full(4, 0.3), 2.0, rng)
    assert out[0] == 1.0 and out[1] == 2.0
    assert np.all(out[2:] >= 2.0)


# ---------------------------------------------------------------------------
# Controllers
# ---------------------------------------------------------------------------


def test_static_and_sync_controllers():
    assert FullSyncController(64).predict_cutoff() == 64
    assert StaticCutoffController(100, drop_frac=0.06).predict_cutoff() == 94
    assert StaticCutoffController(64, cutoff=60).predict_cutoff() == 60


def test_elfving_controller_warms_up_then_cuts():
    ctl = ElfvingController(64, warmup=3)
    rng = np.random.default_rng(0)
    assert ctl.predict_cutoff() == 64
    for _ in range(5):
        ctl.observe(rng.normal(1.0, 0.2, 64))
    c = ctl.predict_cutoff()
    assert 32 <= c < 64


def test_cutoff_controller_end_to_end_beats_sync():
    sim = paper_cluster_158(seed=0)
    trace = sim.run(120)
    rm = RuntimeModel(n_workers=158, lag=20).init(0)
    rm.fit(trace, steps=120, batch=8)
    ctl = CutoffController(rm, k_samples=32)
    ctl.seed_window(trace)

    sim2 = paper_cluster_158(seed=3)
    t_cut = t_sync = 0.0
    grads_cut = grads_sync = 0
    for _ in range(60):
        times = sim2.step()
        c = ctl.predict_cutoff()
        it = order_stats.iter_time(times, c)
        ctl.observe(times, times <= it + 1e-12)
        t_cut += it
        grads_cut += c
        t_sync += times.max()
        grads_sync += len(times)
    assert grads_cut / t_cut > 1.15 * (grads_sync / t_sync)


def test_controller_censoring_keeps_window_full():
    sim = paper_cluster_158(seed=1)
    trace = sim.run(60)
    rm = RuntimeModel(n_workers=158, lag=20).init(0)
    rm.fit(trace, steps=60, batch=8)
    ctl = CutoffController(rm, k_samples=16)
    ctl.seed_window(trace)
    for _ in range(5):
        times = sim.step()
        c = ctl.predict_cutoff()
        it = order_stats.iter_time(times, c)
        ctl.observe(times, times <= it + 1e-12)
    w = ctl.window_array()[-5:]
    assert w.shape[1] == 158 and np.all(np.isfinite(w)) and np.all(w > 0)


# ---------------------------------------------------------------------------
# Runtime model (DMM + guide)
# ---------------------------------------------------------------------------


def test_elbo_improves_and_predicts():
    sim = ClusterSim(n_workers=32, n_nodes=4, seed=0)
    trace = sim.run(150)
    rm = RuntimeModel(n_workers=32, lag=10).init(0)
    losses = rm.fit(trace, steps=200, batch=8)
    assert np.mean(losses[-20:]) < np.mean(losses[:20])
    samples, mu, std = rm.predict_next(trace[-11:], k_samples=32)
    assert samples.shape == (32, 32) and np.all(np.isfinite(samples))
    # predictions land in a plausible runtime range
    assert 0.0 < mu.mean() < 5.0 * trace.mean()
