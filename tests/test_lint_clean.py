"""The repo itself lints clean, and ANALYSIS.json stays honest.

Two guards.  First: ``repro.analysis`` over the real ``src`` and
``tests`` trees finds NOTHING — every violation is either fixed or
carries a reasoned suppression, and it stays that way.  Second: the
committed ``ANALYSIS.json`` (the jaxpr audit pin, like the BENCH_*
files) keeps its schema, covers the five hot entry points, and still
says transfer-free with donation effective.
"""
from __future__ import annotations

import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

EXPECTED_ENTRIES = {
    "fused_observe_decide",
    "batched_observe_decide_ragged",
    "train_step[mask_agg=weights]",
    "train_step[mask_agg=psum]",
    "obs_ring_push",
}


def test_repo_lints_clean():
    from repro.analysis import lint_paths

    findings = lint_paths([str(REPO / "src"), str(REPO / "tests")],
                          root=str(REPO))
    assert findings == [], "\n" + "\n".join(f.render() for f in findings)


def test_analysis_json_committed_and_schema():
    path = REPO / "ANALYSIS.json"
    assert path.exists(), "ANALYSIS.json not committed (run " \
        "`python -m repro.analysis --audit`)"
    doc = json.loads(path.read_text())
    assert doc["version"] == 1
    assert doc["ok"] is True
    assert isinstance(doc["jax_version"], str)
    entries = {e["name"]: e for e in doc["entries"]}
    assert set(entries) == EXPECTED_ENTRIES
    for name, e in entries.items():
        assert e["n_eqns"] > 0
        assert e["forbidden_primitives"] == []
        assert e["transfer_free"] is True
        d = e["donation"]
        assert set(d) == {"expected", "n_aliased_outputs", "effective"}
        assert d["effective"] is True
    for name in ("train_step[mask_agg=weights]",
                 "train_step[mask_agg=psum]", "obs_ring_push"):
        assert entries[name]["donation"]["expected"] is True
        assert entries[name]["donation"]["n_aliased_outputs"] > 0


def test_audit_report_matches_committed_schema(tmp_path):
    """A fresh audit writes the same shape the committed pin has (the
    values may drift with jax versions; the schema may not)."""
    from repro.analysis.jaxpr_audit import write_report

    out = tmp_path / "ANALYSIS.json"
    report = write_report(str(out))
    on_disk = json.loads(out.read_text())
    assert on_disk == report
    assert set(report) == {"version", "jax_version", "ok", "entries"}
    assert {e["name"] for e in report["entries"]} == EXPECTED_ENTRIES
    assert report["ok"] is True
