"""Sharded == local equivalences, driven through subprocess payloads.

Each payload (tests/sharded/*_check.py) needs XLA_FLAGS=--xla_force_host_
platform_device_count=8 before jax init, so it runs in a SUBPROCESS (the
main pytest process must keep 1 device per the assignment).  Payloads print
one OK/FAIL line per checked property; a FAIL anywhere fails the test.
"""
import os
import subprocess
import sys

import pytest


def _run_check(args, timeout=1200):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable] + args, capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0 and "FAIL" not in r.stdout, (
        f"\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-2000:]}")
    return r


def _check_path(name):
    return os.path.join(os.path.dirname(__file__), "sharded", name)


@pytest.mark.slow
@pytest.mark.sharded
def test_sharded_equivalence_all_archs(arch_name):
    """Sharded (train_sp 2x4 mesh) == local, loss and grads, per arch."""
    _run_check([_check_path("shard_check.py"), arch_name], timeout=900)


@pytest.mark.slow
@pytest.mark.sharded
def test_ring_ce_equals_dense():
    """Vocab-ring fused CE == dense CE (loss+grads), tied & untied heads."""
    _run_check([_check_path("ring_ce_check.py")])


@pytest.mark.sharded
def test_dist_collectives_and_layout_rules():
    """Masked psum aggregation + named_sharding rules on 8 fake devices."""
    _run_check([_check_path("dist_check.py")], timeout=600)


@pytest.mark.sharded
def test_mask_agg_paths_equivalent_on_mesh():
    """mask_agg="psum" == mask_agg="weights" (losses + updates) over 5
    masked steps on an 8-worker DP mesh; all-ones psum == full sync
    bitwise."""
    _run_check([_check_path("mask_agg_check.py")], timeout=900)


@pytest.mark.sharded
def test_controlplane_subprocess_crash_drill():
    """Real kill -9 / hang / flaky restart against subprocess workers:
    detection within deadline + 1 tick, hung incarnation killed before
    restart, warm ctl-group recovery by global worker id."""
    _run_check([_check_path("controlplane_drill_check.py")], timeout=600)


@pytest.mark.slow
@pytest.mark.sharded
def test_perf_knobs_preserve_numerics():
    """Every perf knob (shardmap gather, ring CE, q-chunk, halo, bf16
    scores) matches the baseline loss+grads on the train_sp mesh."""
    _run_check([_check_path("knob_equiv_check.py"), "qwen2-0.5b",
                "gemma3-12b", "deepseek-moe-16b"], timeout=1800)
