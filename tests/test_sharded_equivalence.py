"""Sharded (train_sp 2x4 mesh) == local, loss and grads, for every arch.

Runs in a SUBPROCESS because it needs XLA_FLAGS=--xla_force_host_platform_
device_count=8 before jax init (the main pytest process must keep 1 device
per the assignment).
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
from repro.configs.base import get_config
from repro.dist import sharding as shd
from repro.models import model as M

name = sys.argv[1]
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(AxisType.Auto, AxisType.Auto))
cfg = get_config(name).reduced()
if cfg.n_experts:
    cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
if cfg.is_encoder_decoder:
    cfg = dataclasses.replace(cfg, encoder_seq_len=32)
key = jax.random.PRNGKey(0)
params = M.init_model(cfg, key)
B, S = 4, 32
batch = {
    "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
    "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    "weights": jnp.asarray([1.0, 0.0, 1.0, 1.0]),
}
if cfg.frontend == "vision_patches":
    batch["patch_embeds"] = jnp.zeros((B, S, cfg.d_model))
    batch["image_mask"] = jnp.zeros((B, S), bool)
    batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
if cfg.is_encoder_decoder:
    batch["frames"] = jax.random.normal(key, (B, 32, cfg.d_model)) * 0.1

loss_fn = lambda p, b: M.train_loss(cfg, p, b)[0]
with shd.use_layout(shd.LOCAL):
    loss_ref = loss_fn(params, batch)
    g_ref = jax.grad(loss_fn)(params, batch)

lay = shd.make_layout(mesh, "train_sp")
stacked = [f"segments/{i}" for i, s in enumerate(
    M.build_segments(M.layer_specs(cfg))) if s.repeats > 1]
pshard = shd.named_sharding(params, lay, stacked_paths=tuple(stacked))
params_s = jax.device_put(params, pshard)

def bspec(k, v):
    if k == "positions" and v.ndim == 3:
        return NamedSharding(mesh, P(None, "data", "model"))
    if k in ("frames", "patch_embeds"):
        return NamedSharding(mesh, P("data", "model", None))
    if v.ndim >= 2:
        return NamedSharding(mesh, P("data", "model"))
    return NamedSharding(mesh, P("data"))
batch_s = {k: jax.device_put(v, bspec(k, v)) for k, v in batch.items()}

def run(p, b):
    with shd.use_layout(lay):
        return loss_fn(p, b), jax.grad(loss_fn)(p, b)

with jax.set_mesh(mesh):
    loss_s, g_s = jax.jit(run)(params_s, batch_s)

dl = abs(float(loss_ref) - float(loss_s))
gerr = max(float(jnp.max(jnp.abs(a - b)))
           for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_s)))
assert dl < 2e-4 and gerr < 2e-2, (name, dl, gerr)
print(f"{name}: dloss={dl:.2e} gerr={gerr:.2e} OK")
"""


@pytest.mark.slow
def test_sharded_equivalence_all_archs(arch_name):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT, arch_name],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-2000:]}"


@pytest.mark.slow
def test_ring_ce_equals_dense():
    """Vocab-ring fused CE == dense CE (loss+grads), tied & untied heads."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    script = os.path.join(os.path.dirname(__file__), "sharded",
                          "ring_ce_check.py")
    r = subprocess.run([sys.executable, script], capture_output=True,
                       text=True, env=env, timeout=1200)
    assert r.returncode == 0 and "FAIL" not in r.stdout, (
        f"\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-2000:]}")
