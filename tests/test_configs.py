"""The 10 assigned architecture configs carry the exact assigned numbers."""
import pytest

from repro.configs.base import SHAPES, all_archs, cells, get_config

ASSIGNED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
    "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
}


def test_all_archs_registered():
    assert set(all_archs()) == set(ASSIGNED)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_assigned_numbers(name):
    cfg = get_config(name)
    L, d, h, kv, ff, v = ASSIGNED[name]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab_size == v


def test_moe_structure():
    ds = get_config("deepseek-moe-16b")
    assert ds.n_experts == 64 and ds.top_k == 6 and ds.n_shared_experts == 2
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert phi.n_experts == 16 and phi.top_k == 2


def test_param_counts_sane():
    # analytic counts should land near the advertised sizes
    approx = {
        "qwen2-vl-7b": 7e9, "deepseek-moe-16b": 16e9,
        "phi3.5-moe-42b-a6.6b": 42e9, "gemma3-12b": 12e9,
        "starcoder2-3b": 3e9, "qwen2-0.5b": 0.5e9,
    }
    for name, n in approx.items():
        got = get_config(name).n_params()
        assert 0.5 * n < got < 1.9 * n, (name, got, n)


def test_cells_40_with_documented_skips():
    rows = list(cells())
    assert len(rows) == 40
    skips = [(c.name, s.name) for c, s, skip in rows if skip]
    # long_500k runs only for sub-quadratic archs (xlstm, hymba)
    assert all(s == "long_500k" for _, s in skips)
    ran_long = [c.name for c, s, skip in rows
                if s.name == "long_500k" and not skip]
    assert sorted(ran_long) == ["hymba-1.5b", "xlstm-350m"]
    assert len(skips) == 8


def test_shapes_assigned():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
