"""BENCH_frontier.json schema guard.

Runs ``benchmarks.frontier_bench.bench_frontier`` at minimum size and
asserts the machine-readable output keeps the ``bench_frontier/v1``
contract.  Schema smoke test only — the seeded full-size race (and the
anytime/stale-beat-sync claim) is gated by ``scripts/ci.sh --bench``.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

POLICIES = ("sync", "static", "firstk", "dmm", "anytime", "stale")


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    from benchmarks.frontier_bench import bench_frontier

    out = tmp_path_factory.mktemp("bench") / "BENCH_frontier.json"
    bench_frontier(quick=True, out_path=str(out), steps=8)
    with open(out) as f:
        return json.load(f)


def test_bench_frontier_schema(bench_json):
    assert bench_json["schema"] == "bench_frontier/v1"
    fr = bench_json["frontier"]
    for key in ("arch", "n_workers", "sync_steps", "clock_budget",
                "grad_accum", "stale_decay", "sim", "target_loss", "race"):
        assert key in fr, key
    assert fr["clock_budget"] > 0
    race = fr["race"]
    assert [r["policy"] for r in race] == list(POLICIES)
    for row in race:
        for key in ("policy", "clock_to_loss", "final_loss", "steps",
                    "total_clock", "mean_cutoff", "steps_per_s"):
            assert key in row, (row["policy"], key)
        assert row["clock_to_loss"] is None or row["clock_to_loss"] > 0
        assert row["steps"] > 0 and row["steps_per_s"] > 0
        assert 1.0 <= row["mean_cutoff"] <= fr["n_workers"]
    by = {r["policy"]: r for r in race}
    # sync waits for everyone; the budget race gives cutoff policies at
    # least as many steps in the same simulated clock
    assert by["sync"]["mean_cutoff"] == fr["n_workers"]
    for p in ("static", "firstk", "dmm", "anytime", "stale"):
        assert by[p]["steps"] >= by["sync"]["steps"], p


def test_committed_bench_frontier_matches_schema():
    """The checked-in BENCH_frontier.json (the frontier datapoint) must
    exist, carry the schema, and show both non-discard policies beating
    full sync with the DMM on the frontier (the PR's acceptance race)."""
    path = Path(__file__).resolve().parent.parent / "BENCH_frontier.json"
    assert path.exists(), "BENCH_frontier.json not committed"
    with open(path) as f:
        data = json.load(f)
    assert data["schema"] == "bench_frontier/v1"
    race = {r["policy"]: r for r in data["frontier"]["race"]}
    assert set(race) == set(POLICIES)
    t = {p: race[p]["clock_to_loss"] for p in POLICIES}
    assert t["anytime"] is not None and t["stale"] is not None
    assert t["dmm"] is not None
    sync_t = t["sync"]
    assert sync_t is None or t["anytime"] < sync_t
    assert sync_t is None or t["stale"] < sync_t
    # the paper's DMM stays on the frontier: it beats every policy that
    # neither taps partial sums nor reuses stale gradients
    for p in ("static", "firstk"):
        assert t[p] is None or t["dmm"] < t[p]
    assert sync_t is None or t["dmm"] < sync_t
