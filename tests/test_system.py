"""End-to-end system behaviour: cutoff trainer, prefill/decode consistency,
masked-aggregation semantics, checkpoint/restart resume, serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg, tiny_batch
from repro import optim
from repro.cluster.simulator import (ClusterSim, paper_cluster_158,
                                     tpu_pod_hosts)
from repro.core import aggregation
from repro.core.controller import (CutoffController, FullSyncController,
                                   StaticCutoffController)
from repro.core.runtime_model.api import RuntimeModel
from repro.data.pipeline import SyntheticTokens
from repro.launch.train import Trainer, clock_to_loss, jit_train_step
from repro.models import model as M
from repro.serving.engine import ServeEngine


# ---------------------------------------------------------------------------
# Prefill + decode == full forward (cache correctness) for every arch.
# ---------------------------------------------------------------------------


def test_prefill_decode_consistency(arch_name):
    cfg = reduced_cfg(arch_name)
    key = jax.random.PRNGKey(1)
    params = M.init_model(cfg, key)
    B, S = 2, 16
    batch = tiny_batch(cfg, key, B=B, S=S, labels=False)
    toks = batch["tokens"]
    full_logits, _, _ = M.forward(cfg, params, batch, mode="train")

    pre = dict(batch)
    pre["tokens"] = toks[:, :S - 2]
    pre["positions"] = (batch["positions"][..., :S - 2])
    if "patch_embeds" in pre:
        pre["patch_embeds"] = pre["patch_embeds"][:, :S - 2]
        pre["image_mask"] = pre["image_mask"][:, :S - 2]
    last, caches = M.prefill(cfg, params, pre)
    caches = M.pad_caches(caches, S)
    assert float(jnp.max(jnp.abs(last - full_logits[:, S - 3]))) < 2e-3

    lg, caches = M.decode_step(cfg, params, toks[:, S - 2:S - 1],
                               jnp.int32(S - 2), caches)
    assert float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, S - 2]))) < 2e-3
    lg, _ = M.decode_step(cfg, params, toks[:, S - 1:S],
                          jnp.int32(S - 1), caches)
    assert float(jnp.max(jnp.abs(lg[:, 0] - full_logits[:, S - 1]))) < 2e-3


# ---------------------------------------------------------------------------
# Cutoff semantics: weight-trick == explicit per-worker gradient mean.
# ---------------------------------------------------------------------------


def test_example_weights_equal_per_worker_masked_mean():
    cfg = reduced_cfg("qwen2-0.5b")
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    n_workers, per = 4, 2
    B, S = n_workers * per, 8
    batch = tiny_batch(cfg, key, B=B, S=S)
    mask = np.array([1.0, 0.0, 1.0, 1.0], np.float32)

    # production path: per-example weights folded into the loss
    batch_w = dict(batch, weights=jnp.asarray(
        aggregation.example_weights(mask, B)))
    loss_fn = lambda p, b: M.train_loss(cfg, p, b, aux_coef=0.0)[0]
    g_prod = jax.grad(loss_fn)(params, batch_w)

    # reference: average the included workers' own gradients (Alg. 1 l.29)
    gs = []
    for w in range(n_workers):
        sub = {k: (v[:, w * per:(w + 1) * per] if k == "positions"
                   and v.ndim == 3 else v[w * per:(w + 1) * per])
               for k, v in batch.items()}
        gs.append(jax.grad(loss_fn)(params, sub))
    included = [g for g, m in zip(gs, mask) if m > 0]
    g_ref = jax.tree.map(lambda *x: sum(x) / len(x), *included)

    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g_prod),
                              jax.tree.leaves(g_ref)))
    assert err < 1e-5, err


# ---------------------------------------------------------------------------
# Trainer: cutoff run + checkpoint/restart resume.
# ---------------------------------------------------------------------------


def _make_trainer(cfg, ckpt_dir, n_steps_data_seed=0):
    n_workers = 4
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=8, seed=n_steps_data_seed)
    opt = optim.adamw(3e-3)
    step = jit_train_step(cfg, opt)
    timer = ClusterSim(n_workers=n_workers, n_nodes=2, seed=5)
    tr = Trainer(cfg=cfg, step_fn=step, data=data,
                 controller=StaticCutoffController(n_workers, cutoff=3),
                 timer=timer, n_workers=n_workers, ckpt_dir=ckpt_dir,
                 ckpt_every=5)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    return tr.restore_or_init(init_fn)


def test_trainer_loss_decreases_and_drops_workers(tmp_path):
    cfg = reduced_cfg("qwen2-0.5b")
    tr = _make_trainer(cfg, str(tmp_path / "ck"))
    hist = tr.run(30)
    assert all(h["c"] == 3 for h in hist)          # static cutoff honored
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first
    assert tr.sim_clock > 0


def test_trainer_checkpoint_restart_resumes(tmp_path):
    cfg = reduced_cfg("qwen2-0.5b")
    d = str(tmp_path / "ck")
    tr1 = _make_trainer(cfg, d)
    tr1.run(10)
    params_at_10 = jax.tree.leaves(tr1.state["params"])

    # crash + restart from the step-10 checkpoint
    tr2 = _make_trainer(cfg, d)
    assert tr2.step == 10
    for a, b in zip(params_at_10, jax.tree.leaves(tr2.state["params"])):
        np.testing.assert_allclose(a, b, atol=1e-7)


# ---------------------------------------------------------------------------
# mask_agg="psum" vs "weights": the Trainer runs both, and they agree.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def agg_cfg_and_steps():
    cfg = reduced_cfg("qwen2-0.5b")
    opt = optim.adamw(3e-3)
    steps = {m: jit_train_step(cfg, opt, mask_agg=m)
             for m in ("weights", "psum")}

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    return cfg, steps, init_fn


def _agg_trainer(cfg, steps, init_fn, mode, controller, timer):
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=8, seed=0)
    tr = Trainer(cfg=cfg, step_fn=steps[mode], data=data,
                 controller=controller, timer=timer, n_workers=8,
                 mask_agg=mode)
    return tr.restore_or_init(init_fn)


def test_trainer_mask_agg_paths_agree(agg_cfg_and_steps):
    """Same controller decisions + data: the explicit psum path and the
    example-weights path track each other step for step."""
    cfg, steps, init_fn = agg_cfg_and_steps
    hists = {}
    final = {}
    for mode in ("weights", "psum"):
        tr = _agg_trainer(cfg, steps, init_fn, mode,
                          StaticCutoffController(8, cutoff=6),
                          ClusterSim(n_workers=8, n_nodes=2, seed=5))
        hists[mode] = tr.run(5)
        final[mode] = tr.state["params"]
    for hw, hp in zip(hists["weights"], hists["psum"]):
        assert abs(hw["loss"] - hp["loss"]) < 1e-4, (hw, hp)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(final["weights"]),
                              jax.tree.leaves(final["psum"])))
    assert err < 1e-3, err


# ---------------------------------------------------------------------------
# Seeded end-to-end regression: the DMM controller's wall-clock-to-loss
# beats static cutoff and full sync on BOTH aggregation paths, on both
# ClusterSim presets (paper cluster scaled to 8 workers, TPU-pod hosts).
# ---------------------------------------------------------------------------


def _preset_sim(preset, seed):
    if preset == "paper_cluster_158":
        return paper_cluster_158(seed, n_workers=8)
    return tpu_pod_hosts(8, seed=seed)


@pytest.fixture(scope="module", params=["paper_cluster_158",
                                        "tpu_pod_hosts"])
def fitted_preset(request):
    trace = _preset_sim(request.param, 0).run(200)
    rm = RuntimeModel(n_workers=8, lag=10).init(0)
    rm.fit(trace, steps=200, batch=8, seed=0)
    return request.param, rm, trace


# the wall-clock-to-loss metric is shared with the benches and demos:
# launch.train.clock_to_loss (None when the target is never reached)


@pytest.mark.parametrize("mode", ["weights", "psum"])
def test_dmm_beats_static_and_sync_wall_clock_to_loss(
        agg_cfg_and_steps, fitted_preset, mode):
    cfg, steps, init_fn = agg_cfg_and_steps
    preset, rm, trace = fitted_preset
    from repro.obs import ObsRun

    dmm = CutoffController(rm, k_samples=32, seed=0)
    dmm.seed_window(trace)
    streams = {}
    for name, ctl in [("dmm", dmm),
                      ("static", StaticCutoffController(8, cutoff=7)),
                      ("sync", FullSyncController(8))]:
        tr = _agg_trainer(cfg, steps, init_fn, mode, ctl,
                          _preset_sim(preset, 9))
        tr.obs, tr.name = ObsRun(), name   # trajectory via the obs stream
        tr.run(40)
        streams[name] = tr.obs.steps
    # the loss every run must reach: full sync's (smoothed) final loss
    target = streams["sync"].final_loss(window=3)
    t_dmm = clock_to_loss(streams["dmm"], target)
    t_static = clock_to_loss(streams["static"], target)
    t_sync = clock_to_loss(streams["sync"], target)
    assert t_dmm is not None
    assert t_static is None or t_dmm < t_static, (preset, mode, t_dmm,
                                                  t_static)
    assert t_sync is None or t_dmm < t_sync, (preset, mode, t_dmm, t_sync)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["qwen2-0.5b", "xlstm-350m", "hymba-1.5b"])
def test_serve_engine_greedy_decode(name):
    cfg = reduced_cfg(name)
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params)
    prompt = np.arange(8, dtype=np.int32).reshape(1, 8) % cfg.vocab_size
    out = eng.generate(prompt, n_new=4)
    assert out.shape == (1, 4)
    assert np.all((0 <= out) & (out < cfg.vocab_size))
    # greedy decode is deterministic
    out2 = eng.generate(prompt, n_new=4)
    np.testing.assert_array_equal(out, out2)
