"""Scheduler policy properties for the multi-tenant PS.

Round-robin is starvation-free by construction: with J jobs at equal
priority and per-tick capacity c, the per-job service counts over ANY
window of J*k consecutive ticks differ by at most 1.  Priority ordering
is a pure function of (priority, job_id) — invariant under permutation
of job insertion order.  Shortest-predicted-step-first ranks by the
DMM's posterior-predictive step time, cold jobs first.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

import pytest

from repro.ps.scheduler import (JobView, PriorityScheduler,
                                RoundRobinScheduler, ShortestStepScheduler,
                                make_scheduler)

SETTINGS = dict(max_examples=25, deadline=None)


def _views(n, priorities=None, order=None):
    order = order if order is not None else range(n)
    return [JobView(job_id=f"j{i}",
                    priority=(priorities[i] if priorities else 0.0),
                    admit_order=o)
            for i, o in zip(range(n), order)]


# ---------------------------------------------------------------------------
# Round-robin fairness.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(J=st.integers(2, 8), cap=st.integers(1, 8), k=st.integers(1, 4),
       seed=st.integers(0, 100))
def test_round_robin_no_starvation_over_any_window(J, cap, k, seed):
    """Equal priorities: service counts over EVERY window of J*k ticks
    differ by at most 1 per job."""
    cap = min(cap, J)
    views = _views(J)
    sched = RoundRobinScheduler()
    # a random warm-up offset makes the windows start mid-cycle
    for _ in range(seed % (J + 1)):
        sched.order(views, cap)
    window = J * k
    total = 3 * window
    served = [sched.order(views, cap) for _ in range(total)]
    for lo in range(total - window + 1):
        counts = {v.job_id: 0 for v in views}
        for tick in served[lo:lo + window]:
            assert len(tick) == cap
            for jid in tick:
                counts[jid] += 1
        assert max(counts.values()) - min(counts.values()) <= 1, (
            lo, counts)


@settings(**SETTINGS)
@given(J=st.integers(2, 8), cap=st.integers(1, 8), k=st.integers(1, 3))
def test_round_robin_exact_share_over_full_cycles(J, cap, k):
    """Over exactly J*k ticks from a cycle boundary, every job is served
    exactly cap*k times."""
    cap = min(cap, J)
    views = _views(J)
    sched = RoundRobinScheduler()
    counts = {v.job_id: 0 for v in views}
    for _ in range(J * k):
        for jid in sched.order(views, cap):
            counts[jid] += 1
    assert set(counts.values()) == {cap * k}


def test_round_robin_no_duplicate_service_within_tick():
    sched = RoundRobinScheduler()
    for _ in range(7):
        tick = sched.order(_views(5), 4)
        assert len(tick) == len(set(tick))


# ---------------------------------------------------------------------------
# Round-robin under membership churn (the cursor-invalidation regression).
# ---------------------------------------------------------------------------


def test_round_robin_evict_does_not_skip_the_next_job():
    """Directed regression: evicting the job just served must hand the
    next tick to its cyclic SUCCESSOR.  An index cursor points one slot
    past the served job; the evict shifts the ring left under it, so it
    lands on j2 and silently skips j1."""
    sched = RoundRobinScheduler()
    views = _views(3)                            # j0, j1, j2
    assert sched.order(views, 1) == ["j0"]
    views = [v for v in views if v.job_id != "j0"]
    assert sched.order(views, 1) == ["j1"]
    assert sched.order(views, 1) == ["j2"]


def test_round_robin_admit_preserves_cycle_position():
    """A mid-cycle admit (admit orders are monotone, so newcomers join
    the END of the ring) must not disturb whose turn is next; the
    newcomer waits for the cycle to reach it."""
    sched = RoundRobinScheduler()
    views = _views(3)
    assert sched.order(views, 1) == ["j0"]
    views = views + [JobView(job_id="j9", priority=0.0, admit_order=9)]
    assert sched.order(views, 1) == ["j1"]
    assert sched.order(views, 1) == ["j2"]
    assert sched.order(views, 1) == ["j9"]
    assert sched.order(views, 1) == ["j0"]


@settings(**SETTINGS)
@given(J=st.integers(2, 6), cap=st.integers(1, 4), seed=st.integers(0, 1000))
def test_round_robin_fairness_survives_churn(J, cap, seed):
    """The fairness bound must hold for jobs that live through arbitrary
    interleaved admit/evict/resize churn around them: at EVERY tick, the
    service counts of any two always-present jobs differ by at most 1,
    because the service sequence stays one consecutive run of the cyclic
    admit order.  An index cursor fails this — a membership change
    shifts which ring slot is "next", double-serving one side of the
    removed slot and skipping the other."""
    rng = np.random.default_rng(seed)
    core = _views(J)
    extras, next_order = [], J
    counts = {v.job_id: 0 for v in core}
    sched = RoundRobinScheduler()
    for tick in range(12 * J):
        ev = rng.integers(0, 4)
        if ev == 0 and len(extras) < 6:          # admit a transient job
            extras.append(JobView(job_id=f"x{next_order}", priority=0.0,
                                  admit_order=next_order))
            next_order += 1
        elif ev == 1 and extras:                 # evict a transient job
            extras.pop(int(rng.integers(0, len(extras))))
        elif ev == 2:                            # resize: views rebuilt,
            core = [JobView(job_id=v.job_id,    # same ids/orders — the
                            priority=v.priority,  # policy must not lean
                            admit_order=v.admit_order)  # on identity
                    for v in core]
        views = core + extras
        served = sched.order(views, min(cap, len(views)))
        assert len(served) == len(set(served))
        for jid in served:
            if jid in counts:
                counts[jid] += 1
        assert max(counts.values()) - min(counts.values()) <= 1, (
            tick, counts)
    # the churn never starved a long-lived job
    assert min(counts.values()) > 0


# ---------------------------------------------------------------------------
# Priority: stable under insertion-order permutation.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(J=st.integers(2, 8), cap=st.integers(1, 8), seed=st.integers(0, 500))
def test_priority_order_invariant_under_insertion_permutation(J, cap, seed):
    rng = np.random.default_rng(seed)
    # coarse priorities force ties, the case where a sloppy tie-break
    # would leak admission order
    prios = [float(p) for p in rng.integers(0, 3, size=J)]
    views_a = _views(J, prios, order=range(J))
    perm = rng.permutation(J)
    views_b = [_views(J, prios, order=perm)[i] for i in rng.permutation(J)]
    sched = PriorityScheduler()
    assert (sched.order(views_a, min(cap, J))
            == sched.order(views_b, min(cap, J)))


def test_priority_serves_highest_first():
    views = _views(4, priorities=[0.0, 2.0, 1.0, 2.0])
    assert PriorityScheduler().order(views, 3) == ["j1", "j3", "j2"]


# ---------------------------------------------------------------------------
# Shortest-predicted-step-first.
# ---------------------------------------------------------------------------


def test_spsf_ranks_by_predicted_step_cold_jobs_first():
    preds = {"j0": 2.0, "j1": 0.5, "j2": None, "j3": 1.0}
    views = [JobView(job_id=j, priority=0.0, admit_order=i,
                     predicted_iter=lambda j=j: preds[j])
             for i, j in enumerate(sorted(preds))]
    assert (ShortestStepScheduler().order(views)
            == ["j2", "j1", "j3", "j0"])
    assert ShortestStepScheduler().order(views, 2) == ["j2", "j1"]


@settings(**SETTINGS)
@given(J=st.integers(2, 6), cap=st.integers(1, 3), seed=st.integers(0, 100))
def test_spsf_starvation_is_bounded(J, cap, seed):
    """Predictions only refresh at service time, so without aging the
    predicted-slowest warm job would be excluded forever.  With
    max_starve, every job is serviced at least once per
    (max_starve + J) ticks."""
    cap = min(cap, J)
    rng = np.random.default_rng(seed)
    preds = {f"j{i}": float(p)
             for i, p in enumerate(rng.uniform(0.5, 3.0, size=J))}
    views = [JobView(job_id=j, priority=0.0, admit_order=i,
                     predicted_iter=lambda j=j: preds[j])
             for i, j in enumerate(sorted(preds))]
    sched = ShortestStepScheduler(max_starve=4)
    last_served = {v.job_id: -1 for v in views}
    for tick in range(40):
        for jid in sched.order(views, cap):
            last_served[jid] = tick
    horizon = 40 - (sched.max_starve + J)
    assert all(t >= horizon for t in last_served.values()), last_served


def test_make_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError):
        make_scheduler("fifo")
    assert isinstance(make_scheduler("rr"), RoundRobinScheduler)
    assert isinstance(make_scheduler("priority"), PriorityScheduler)
    assert isinstance(make_scheduler("spsf"), ShortestStepScheduler)
