"""BENCH_obs.json schema guard.

Runs ``benchmarks.obs_bench.bench_obs`` at quick size and asserts the
machine-readable output keeps the ``bench_obs/v1`` contract.  The hard
5% overhead gate lives in ``scripts/ci.sh --bench`` (min-of-repeats on
a quiet runner); here the assertions are loose sanity so the suite
stays robust to a noisy test machine.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

STEP_KEYS = ("n_workers", "steps", "repeats", "bare_us",
             "instrumented_us", "overhead_frac")
RING_KEYS = ("cap", "pushes", "push_us", "drain_us", "rows_drained",
             "dropped")
SPAN_KEYS = ("n_spans", "us_per_span", "spans_per_trainer_step",
             "us_per_trainer_step")
POLICY_KEYS = ("decisions", "scored", "mean_regret", "mean_idle_frac",
               "mean_discard_frac", "mean_abs_residual", "coverage50",
               "coverage90")


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    from benchmarks.obs_bench import bench_obs

    out = tmp_path_factory.mktemp("bench") / "BENCH_obs.json"
    bench_obs(quick=True, out_path=str(out))
    with open(out) as f:
        return json.load(f)


def _check_payload(data):
    assert data["schema"] == "bench_obs/v1"
    step = {r["n_workers"]: r for r in data["step"]}
    assert set(step) == {8, 158}
    for r in step.values():
        for key in STEP_KEYS:
            assert key in r, key
        assert r["bare_us"] > 0 and r["instrumented_us"] > 0
        # the CI gate pins 5% at n=158; here only "same ballpark", so a
        # loaded test runner can't flake the suite
        assert r["overhead_frac"] < 0.5, r

    ring = data["ring"]
    for key in RING_KEYS:
        assert key in ring, key
    assert ring["push_us"] > 0 and ring["drain_us"] > 0
    # the bench overflows the ring on purpose: overflow is counted,
    # never silent, and the drain returns exactly the kept cap
    assert ring["pushes"] > ring["cap"]
    assert ring["rows_drained"] == ring["cap"]
    assert ring["dropped"] == ring["pushes"] - ring["cap"]

    span = data["span"]
    for key in SPAN_KEYS:
        assert key in span, key
    assert 0 < span["us_per_span"] < 1e4
    assert span["us_per_trainer_step"] == (
        span["spans_per_trainer_step"] * span["us_per_span"])

    cal = data["calibration"]["policies"]
    assert set(cal) == {"sync", "static", "firstk", "dmm"}
    for name, r in cal.items():
        for key in POLICY_KEYS:
            assert key in r, (name, key)
        assert r["decisions"] == data["calibration"]["steps"]
        assert 0.0 <= r["mean_regret"] <= 1.0
        assert 0.0 <= r["mean_idle_frac"] <= 1.0
    # only the DMM draws predictive samples: it alone reports quantile
    # coverage, and full sync by definition discards nothing
    dmm = cal["dmm"]
    assert dmm["scored"] == dmm["decisions"]
    assert 0.0 <= dmm["coverage50"] <= 1.0
    assert 0.0 <= dmm["coverage90"] <= 1.0
    for name in ("sync", "static", "firstk"):
        assert cal[name]["scored"] == 0
        assert cal[name]["coverage50"] is None
    assert cal["sync"]["mean_discard_frac"] == 0.0


def test_bench_obs_schema(bench_json):
    _check_payload(bench_json)
    assert bench_json["quick"] is True


def test_committed_bench_obs_matches_schema():
    """The checked-in BENCH_obs.json must exist and satisfy the same
    contract the CI gate re-derives from a fresh run."""
    path = Path(__file__).resolve().parent.parent / "BENCH_obs.json"
    assert path.exists(), "BENCH_obs.json not committed"
    with open(path) as f:
        _check_payload(json.load(f))
