"""Unit tests for the repro.dist.sharding API itself (satellite of the
dist-subsystem PR): LOCAL is a pure no-op, use_layout nests correctly, and
named_sharding emits the right PartitionSpecs on a 1-device mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import collectives
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh


# ---------------------------------------------------------------------------
# LOCAL is a pure no-op.
# ---------------------------------------------------------------------------


def test_default_layout_is_local():
    lay = shd.layout()
    assert lay is shd.LOCAL
    assert lay.mesh is None and lay.mode == "local"
    assert lay.dp == () and lay.dp_size == 1 and lay.n_shards == 1
    assert lay.axis("dp") is None
    assert lay.axis("sp") is None
    assert lay.axis("tp") is None
    assert lay.dp_for(16) is None


def test_local_act_and_use_weight_are_identity():
    x = jnp.arange(24.0).reshape(2, 3, 4)
    assert shd.act(x, "dp", "sp", "tp") is x
    assert shd.act(x, None, None, None) is x
    tree = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
    assert shd.use_weight(tree) is tree


def test_local_named_sharding_is_all_none():
    tree = {"a": jnp.ones((4, 8)), "seg": [jnp.ones((2, 4))]}
    out = shd.named_sharding(tree, shd.LOCAL)
    assert all(v is None for v in jax.tree.leaves(
        out, is_leaf=lambda x: x is None))


def test_make_layout_none_mesh_returns_local():
    assert shd.make_layout(None, "train_sp") is shd.LOCAL


# ---------------------------------------------------------------------------
# use_layout nesting / unroll_loops.
# ---------------------------------------------------------------------------


def test_use_layout_restores_previous_on_exit():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay1 = shd.make_layout(mesh, "train_sp")
    lay2 = shd.make_layout(mesh, "decode_tp")
    assert shd.layout() is shd.LOCAL
    with shd.use_layout(lay1):
        assert shd.layout() is lay1
        with shd.use_layout(lay2):
            assert shd.layout() is lay2
        assert shd.layout() is lay1
    assert shd.layout() is shd.LOCAL


def test_use_layout_restores_on_exception():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "train_sp")
    with pytest.raises(RuntimeError):
        with shd.use_layout(lay):
            raise RuntimeError("boom")
    assert shd.layout() is shd.LOCAL


def test_unroll_loops_flag():
    assert not shd.unrolled()
    with shd.unroll_loops():
        assert shd.unrolled()
        with shd.unroll_loops(False):
            assert not shd.unrolled()
        assert shd.unrolled()
    assert not shd.unrolled()


# ---------------------------------------------------------------------------
# make_layout mode tables.
# ---------------------------------------------------------------------------


def test_make_layout_modes_single_pod():
    mesh = make_mesh((1, 1), ("data", "model"))
    sp = shd.make_layout(mesh, "train_sp")
    assert sp.dp == ("data",) and sp.model_axis == "model"
    assert sp.seq_axis == "model" and sp.tp_axis is None
    fsdp = shd.make_layout(mesh, "train_fsdp")
    assert fsdp.dp == ("data", "model") and fsdp.seq_axis is None
    dec = shd.make_layout(mesh, "decode_tp")
    assert dec.dp == ("data",) and dec.tp_axis == "model"
    assert dec.seq_axis is None
    with pytest.raises(ValueError):
        shd.make_layout(mesh, "nonsense")


def test_make_layout_multi_pod_dp_axes():
    mesh = make_mesh((1, 1, 1), ("pod", "data", "model"))
    lay = shd.make_layout(mesh, "train_sp")
    assert lay.dp == ("pod", "data")
    assert lay.model_axis == "model"
    assert lay.axis("dp") == ("pod", "data")


def test_dp_for_divisibility():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "train_sp")
    # dp_size == 1 divides everything
    assert lay.dp_for(4) == ("data",)
    assert lay.dp_for(1) == ("data",)


# ---------------------------------------------------------------------------
# named_sharding PartitionSpecs on a 1-device mesh.
# ---------------------------------------------------------------------------


def _tree():
    return {
        "embed": {"table": jnp.ones((8, 4))},
        "segments": [
            # stacked segment: leading dim 3 is the scan repeats dim
            [{"w": jnp.ones((3, 4, 8)), "scale": jnp.ones((3, 4))}],
            # unstacked segment
            [{"w": jnp.ones((4, 8)), "scale": jnp.ones((4,))}],
        ],
        "step": jnp.float32(0.0),
    }


def test_named_sharding_specs_train_sp():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "train_sp")
    out = shd.named_sharding(_tree(), lay, stacked_paths=("segments/0",))
    # unstacked: FSDP dim 0
    assert out["embed"]["table"].spec == P("model", None)
    assert out["segments"][1][0]["w"].spec == P("model", None)
    assert out["segments"][1][0]["scale"].spec == P("model")
    # stacked: dim 0 is the repeats dim -> FSDP dim 1
    assert out["segments"][0][0]["w"].spec == P(None, "model", None)
    assert out["segments"][0][0]["scale"].spec == P(None, "model")
    # scalars replicate
    assert out["step"].spec == P()
    for ns in jax.tree.leaves(out):
        assert ns.mesh is mesh


def test_named_sharding_specs_decode_tp_prefers_last_dim():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "decode_tp")
    out = shd.named_sharding(_tree(), lay, stacked_paths=("segments/0",))
    assert out["embed"]["table"].spec == P(None, "model")
    assert out["segments"][0][0]["w"].spec == P(None, None, "model")


def test_named_sharding_accepts_abstract_leaves():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "train_sp")
    tree = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    out = shd.named_sharding(tree, lay)
    assert out["w"].spec == P("model", None)


# ---------------------------------------------------------------------------
# act on a real (1-device) mesh: shape-preserving, divisibility fallback.
# ---------------------------------------------------------------------------


def test_act_constrains_under_mesh_and_preserves_values():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "train_sp")
    x = jnp.arange(2 * 4 * 6, dtype=jnp.float32).reshape(2, 4, 6)

    with shd.use_layout(lay):
        y = jax.jit(lambda a: shd.act(a, "dp", "sp", None))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    # odd seq dim falls back to replicated instead of erroring
    x2 = jnp.ones((2, 3, 6))
    with shd.use_layout(lay):
        y2 = jax.jit(lambda a: shd.act(a, "dp", "sp", None))(x2)
    assert y2.shape == x2.shape


def test_use_weight_gathers_under_train_layout():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "train_sp")
    w = jnp.arange(16.0).reshape(4, 4)
    with shd.use_layout(lay):
        out = jax.jit(lambda a: shd.use_weight({"w": a}))(w)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))


def test_use_weight_identity_under_decode_tp():
    mesh = make_mesh((1, 1), ("data", "model"))
    lay = shd.make_layout(mesh, "decode_tp")
    tree = {"w": jnp.ones((4, 4))}
    with shd.use_layout(lay):
        assert shd.use_weight(tree) is tree
