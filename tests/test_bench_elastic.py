"""BENCH_elastic.json schema guard.

Runs ``benchmarks.elastic_bench.bench_elastic`` at minimum size and
asserts the machine-readable output keeps the ``bench_elastic/v1``
contract.  Schema smoke test only — timings on a loaded CI box are noise.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    from benchmarks.elastic_bench import bench_elastic

    out = tmp_path_factory.mktemp("bench") / "BENCH_elastic.json"
    bench_elastic(quick=True, out_path=str(out), n_list=(8,),
                  churn_steps=9, refit_steps=5)
    with open(out) as f:
        return json.load(f)


def test_bench_elastic_schema(bench_json):
    assert bench_json["schema"] == "bench_elastic/v1"
    rows = bench_json["resize"]
    assert {r["backend"] for r in rows} == {"device", "numpy"}
    for row in rows:
        for key in ("n_workers", "n_small", "shrink_us", "grow_us"):
            assert key in row, key
        assert row["shrink_us"] > 0 and row["grow_us"] > 0
        assert row["n_small"] < row["n_workers"]
    ch = bench_json["churn"]
    for key in ("arch", "n_workers", "steps", "shrink_at", "recover_at",
                "elastic_steps_per_s", "sync_steps_per_s", "refit_s",
                "n_refits", "clock_to_loss_elastic", "clock_to_loss_sync"):
        assert key in ch, key
    assert ch["elastic_steps_per_s"] > 0 and ch["sync_steps_per_s"] > 0


def test_committed_bench_elastic_matches_schema():
    """The checked-in BENCH_elastic.json (the perf trajectory's churn
    datapoint) must exist and carry the same schema."""
    path = Path(__file__).resolve().parent.parent / "BENCH_elastic.json"
    assert path.exists(), "BENCH_elastic.json not committed"
    with open(path) as f:
        data = json.load(f)
    assert data["schema"] == "bench_elastic/v1"
    assert {r["n_workers"] for r in data["resize"]} == {32, 158}
    assert data["churn"]["n_refits"] >= 1
