"""Device-resident controller == numpy reference controller.

The tentpole contract of the fused decision path: over a seeded
paper_cluster_158 run the device controller (ring buffer + one fused jit
per decision + fused censored imputation) must produce the IDENTICAL
cutoff sequence as the float64 numpy reference, and the two lag windows
must agree to f32 precision.  Plus jax-vs-numpy unit parity for the cutoff
math the fused path reimplements (throughput argmax, MC order stats,
truncated-normal sampling).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.simulator import paper_cluster_158
from repro.core.controller import CutoffController
from repro.core.cutoff import censoring, order_stats
from repro.core.runtime_model.api import RuntimeModel

SETTINGS = dict(max_examples=20, deadline=None)


# ---------------------------------------------------------------------------
# jax-vs-numpy unit parity for the cutoff math.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(2, 128),
       min_frac=st.floats(0.0, 1.0))
def test_optimal_cutoff_jax_parity(seed, n, min_frac):
    """The f32 device argmax picks the same cutoff as the f64 reference —
    or, on a genuine near-tie below f32 resolution, one whose expected
    throughput is indistinguishable from the reference optimum."""
    rng = np.random.default_rng(seed)
    s = rng.lognormal(0.0, 0.5, size=(32, n)).astype(np.float32)
    c_np = order_stats.optimal_cutoff(s, min_frac=min_frac)
    c_jax = int(order_stats.optimal_cutoff_jax(jnp.asarray(s),
                                               min_frac=min_frac))
    lo = order_stats.min_frac_floor(n, min_frac)
    assert lo + 1 <= c_jax <= n
    if c_jax != c_np:
        omega = order_stats.throughput_curve(s)
        np.testing.assert_allclose(omega[c_jax - 1], omega[c_np - 1],
                                   rtol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(8, 128))
def test_mc_order_stats_jax_parity(seed, n):
    rng = np.random.default_rng(seed)
    s = rng.exponential(1.0, size=(64, n)).astype(np.float32)
    mean_np, std_np = order_stats.mc_order_stats(s)
    mean_j, std_j = order_stats.mc_order_stats_jax(jnp.asarray(s))
    np.testing.assert_allclose(mean_j, mean_np, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(std_j, std_np, rtol=1e-4, atol=1e-5)
    assert np.all(np.diff(np.asarray(mean_j)) >= -1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), cut=st.floats(0.5, 3.0))
def test_truncated_normal_jax_respects_lower_bound(seed, cut):
    """Property reuse from test_core_cutoff: every draw >= the bound."""
    u = np.asarray(jax.random.uniform(jax.random.PRNGKey(seed), (200,)))
    s = censoring.truncated_normal_sample_jax(
        jnp.zeros(200), jnp.ones(200), jnp.full(200, cut), jnp.asarray(u))
    s = np.asarray(s)
    assert np.all(np.isfinite(s))
    assert np.all(s >= cut - 1e-6)


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), cut=st.floats(-1.0, 2.5))
def test_truncated_normal_jax_matches_numpy_on_shared_uniforms(seed, cut):
    """Same uniform stream -> the f32 device sampler tracks the f64
    reference wherever f32 can represent the quantile; in the saturated
    far tail (truncation CDF or effective uniform within 1e-5 of 1, where
    the two paths clip at different epsilons) both must still sit within
    a few sigma above the bound."""
    from repro.core.cutoff._normal import ndtr

    u = np.asarray(jax.random.uniform(jax.random.PRNGKey(seed), (256,)))
    mu = np.linspace(0.5, 2.0, 256)
    sigma = np.linspace(0.05, 0.8, 256)
    lower = np.full(256, cut)
    want = censoring.truncated_normal_sample(mu, sigma, lower, u=u)
    got = np.asarray(censoring.truncated_normal_sample_jax(
        jnp.asarray(mu, jnp.float32), jnp.asarray(sigma, jnp.float32),
        jnp.asarray(lower, jnp.float32), jnp.asarray(u, jnp.float32)))
    a = ndtr((lower - mu) / np.maximum(sigma, 1e-9))
    ueff = a + (1 - a) * u
    bulk = ueff < 1 - 1e-5
    np.testing.assert_allclose(got[bulk], want[bulk], rtol=1e-3, atol=1e-3)
    tail = ~bulk
    assert np.all(got[tail] >= cut - 1e-5)
    assert np.all(got[tail] <= np.maximum(want[tail], cut + 8 * sigma[tail]))


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(2, 64),
       cut=st.floats(0.2, 4.0), frac=st.floats(0.1, 0.9))
def test_impute_censored_jax_properties(seed, n, cut, frac):
    rng = np.random.default_rng(seed)
    observed = rng.uniform(0.1, cut, size=n).astype(np.float32)
    finished = rng.uniform(size=n) < frac
    mu = rng.uniform(0.1, 3.0, size=n).astype(np.float32)
    std = rng.uniform(0.0, 1.0, size=n).astype(np.float32)
    u = jax.random.uniform(jax.random.PRNGKey(seed), (n,))
    out = np.asarray(censoring.impute_censored_jax(
        jnp.asarray(observed), jnp.asarray(finished), jnp.asarray(mu),
        jnp.asarray(std), jnp.float32(cut), u))
    assert np.all(np.isfinite(out))
    np.testing.assert_array_equal(out[finished], observed[finished])
    assert np.all(out[~finished] >= cut - 1e-5)


# ---------------------------------------------------------------------------
# The 100-step seeded equivalence suite on paper_cluster_158.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted_158():
    sim = paper_cluster_158(seed=0)
    trace = sim.run(60)
    rm = RuntimeModel(n_workers=158, lag=20).init(0)
    rm.fit(trace, steps=60, batch=8, seed=0)
    return rm, trace


def test_device_controller_matches_numpy_reference(fitted_158):
    rm, trace = fitted_158
    dev = CutoffController(rm, k_samples=32, seed=0, backend="device")
    ref = CutoffController(rm, k_samples=32, seed=0, backend="numpy")
    dev.seed_window(trace)
    ref.seed_window(trace)
    np.testing.assert_allclose(dev.window_array(), ref.window_array(),
                               rtol=1e-6, atol=1e-6)

    sim = paper_cluster_158(seed=7)
    cutoffs, censored_steps = [], 0
    for step in range(100):
        c_dev = dev.predict_cutoff()
        c_ref = ref.predict_cutoff()
        assert c_dev == c_ref, (step, c_dev, c_ref)
        cutoffs.append(c_dev)
        times = sim.step()
        it = order_stats.iter_time(times, c_dev)
        mask = times <= it + 1e-12
        if not mask.all():
            censored_steps += 1
        dev.observe(times, mask)
        ref.observe(times, mask)
        # the shared clip epsilons (censoring._CDF_CLIP) hold the two
        # imputation paths together even through far-tail draws; what
        # remains is f32 arithmetic noise
        np.testing.assert_allclose(
            dev.window_array()[-1], ref.window_array()[-1],
            rtol=2e-3, atol=2e-3, err_msg=f"step {step}")
    # the run must actually exercise the fused imputation and a dynamic
    # cutoff for the equivalence to mean anything
    assert censored_steps >= 50
    assert len(set(cutoffs)) > 1
    np.testing.assert_allclose(dev.window_array(), ref.window_array(),
                               rtol=2e-3, atol=2e-3)


def test_device_controller_deterministic(fitted_158):
    rm, trace = fitted_158
    runs = []
    for _ in range(2):
        ctl = CutoffController(rm, k_samples=16, seed=3, backend="device")
        ctl.seed_window(trace)
        sim = paper_cluster_158(seed=11)
        seq = []
        for _ in range(20):
            c = ctl.predict_cutoff()
            times = sim.step()
            it = order_stats.iter_time(times, c)
            ctl.observe(times, times <= it + 1e-12)
            seq.append(c)
        runs.append(seq)
    assert runs[0] == runs[1]


def test_device_predicted_order_stats_reuses_pending_samples(fitted_158):
    """The diagnostics call must consume the cached samples from the
    preceding predict_cutoff, not re-run inference (satellite fix)."""
    rm, trace = fitted_158
    ctl = CutoffController(rm, k_samples=16, seed=0, backend="device")
    ctl.seed_window(trace)
    ctl.predict_cutoff()
    cached = np.asarray(ctl._pending_pred[2])
    mean, std = ctl.predicted_order_stats()
    want_mean, want_std = order_stats.mc_order_stats(cached)
    np.testing.assert_allclose(mean, want_mean, rtol=1e-6)
    np.testing.assert_allclose(std, want_std, rtol=1e-5, atol=1e-7)
