"""Deterministic controller regressions driven by ClusterSim fixed seeds.

The paper's claim, as a regression test: over 200 simulated steps on the
same runtime sequence, the dynamic DMM controller's gradients/sec beats
both the static-cutoff prior art (Chen et al. 2016) and full sync — and
censored imputation keeps the lag window finite and NaN-free while doing
it.  Everything is seeded; a change that degrades the controller or the
imputation fails loudly here."""
import numpy as np
import pytest

from repro.cluster.simulator import ClusterSim
from repro.core.controller import (CutoffController, FirstKController,
                                   FullSyncController,
                                   StaticCutoffController)
from repro.core.cutoff import order_stats
from repro.core.runtime_model.api import RuntimeModel

N_WORKERS = 32
RACE_STEPS = 200


def _sim(seed):
    """Heavy-tailed, regime-switching cluster — the paper's motivating
    regime: a static cutoff tuned to the average pays for every slow-node
    period; the dynamic controller adapts per step."""
    return ClusterSim(n_workers=N_WORKERS, n_nodes=4, spike_prob=0.05,
                      spike_scale=2.0, regime_stay=0.96, worker_hetero=0.2,
                      seed=seed)


@pytest.fixture(scope="module")
def fitted_model():
    trace = _sim(0).run(200)
    rm = RuntimeModel(n_workers=N_WORKERS, lag=10).init(0)
    rm.fit(trace, steps=250, batch=8, seed=0)
    return rm, trace


def _race(ctl, seed=7, steps=RACE_STEPS):
    """Race a controller over a fixed runtime sequence.

    Returns (grads/sec, wall, window_history) — every controller sees the
    SAME per-step joint runtimes (the sim is independent of the cutoff)."""
    sim = _sim(seed)
    total_t, total_g = 0.0, 0
    for _ in range(steps):
        times = sim.step()
        c = int(ctl.predict_cutoff())
        assert 1 <= c <= N_WORKERS
        it = order_stats.iter_time(times, c)
        ctl.observe(times, times <= it + 1e-12)
        total_t += it
        total_g += c
    return total_g / total_t, total_t


def test_cutoff_beats_static_and_sync_throughput(fitted_model):
    rm, trace = fitted_model
    ctl = CutoffController(rm, k_samples=64, seed=0)
    ctl.seed_window(trace)
    thr_cut, wall_cut = _race(ctl)
    thr_static, _ = _race(StaticCutoffController(N_WORKERS))
    thr_sync, wall_sync = _race(FullSyncController(N_WORKERS))
    assert thr_cut > thr_static, (thr_cut, thr_static)
    assert thr_cut > thr_sync, (thr_cut, thr_sync)
    # and it actually saves wall-clock vs waiting for every straggler
    assert wall_cut < wall_sync


def test_censored_imputation_keeps_window_finite(fitted_model):
    rm, trace = fitted_model
    ctl = CutoffController(rm, k_samples=16, seed=1)
    ctl.seed_window(trace)
    n_censored_steps = 0
    sim = _sim(11)
    for _ in range(40):
        times = sim.step()
        c = int(ctl.predict_cutoff())
        it = order_stats.iter_time(times, c)
        mask = times <= it + 1e-12
        if not mask.all():
            n_censored_steps += 1
        ctl.observe(times, mask)
        row = ctl.window_array()[-1]
        assert row.shape == (N_WORKERS,)
        assert np.all(np.isfinite(row)) and np.all(row > 0)
        # imputed (censored) entries respect the left truncation at the
        # observed cutoff time (up to f32 ring-buffer rounding)
        assert np.all(row[~mask] >= it - 1e-5)
    # the race must actually have censored something for this test to mean
    # anything
    assert n_censored_steps > 0


def test_firstk_is_count_based_and_resize_keeps_backup():
    """Chen et al.'s baseline: accept the first n-b arrivals by COUNT.
    The backup count is provisioned capacity — a resize moves the cutoff
    with the live width but never rescales b."""
    ctl = FirstKController(32, backup=4)
    assert ctl.predict_cutoff() == 28
    ctl.resize(24)
    assert ctl.predict_cutoff() == 20          # still 4 backups
    ctl.resize(3)
    assert ctl.predict_cutoff() == 1           # clamped, never 0
    # default provisioning: ~4% of the fleet, at least one machine
    assert FirstKController(158).predict_cutoff() == 152
    assert FirstKController(8).predict_cutoff() == 7


def test_dmm_beats_firstk_on_wall_clock_to_loss(fitted_model):
    """The error–runtime trade-off, end to end: over the same seeded
    heavy-tailed cluster, the DMM controller reaches the backup-workers
    baseline's mid-run loss in less simulated wall-clock, without
    sacrificing final loss — per-regime adaptivity beats a fixed arrival
    count."""
    import jax

    from repro import optim
    from repro.configs.base import bench_tiny_config
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, clock_to_loss, jit_train_step
    from repro.models import model as M

    rm, trace = fitted_model
    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    def run(ctl, steps=70):
        from repro.obs import ObsRun

        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=N_WORKERS, seed=0)
        tr = Trainer(cfg=cfg, step_fn=step_fn, data=data, controller=ctl,
                     timer=_sim(7), n_workers=N_WORKERS, metrics_every=0,
                     obs=ObsRun())
        tr.restore_or_init(init_fn)
        tr.run(steps)
        return tr.obs.steps            # the one trajectory recorder

    ctl = CutoffController(rm, k_samples=64, seed=0)
    ctl.seed_window(trace)
    hist_dmm = run(ctl)
    hist_fk = run(FirstKController(N_WORKERS, backup=2))
    # target: the baseline's mid-run loss level, averaged over a 10-step
    # window in the STEEP part of the curve — a level both runs comfortably
    # reach, so the comparison is about CLOCK, not about who trained
    # longer.  (The converged tail is a knife-edge: per-step loss noise is
    # ~ the remaining decline there, so a tail-level crossing time measures
    # noise, not throughput.)
    target = float(np.mean([h["loss"] for h in hist_fk.records[35:45]]))
    clock_dmm = clock_to_loss(hist_dmm, target)
    clock_fk = clock_to_loss(hist_fk, target)
    assert clock_dmm is not None and clock_fk is not None
    assert clock_dmm < clock_fk, (clock_dmm, clock_fk)
    # and the speed does not come out of final model quality
    final_dmm = hist_dmm.final_loss(window=3)
    final_fk = hist_fk.final_loss(window=3)
    assert final_dmm <= final_fk + 0.02, (final_dmm, final_fk)
    # the cutoff controller also simply finishes the same steps sooner
    assert hist_dmm.total_clock() < hist_fk.total_clock()


def test_observe_all_false_mask_is_rejected(fitted_model):
    """A step where NO worker finished has no observed cutoff time to
    impute the censored entries at — observe must reject it loudly on
    both backends instead of falling through and corrupting the window."""
    rm, trace = fitted_model
    for backend in ("device", "numpy"):
        ctl = CutoffController(rm, k_samples=16, seed=0, backend=backend)
        ctl.seed_window(trace)
        ctl.predict_cutoff()
        before = np.asarray(ctl.window_array()).copy()
        with pytest.raises(ValueError, match="all-False"):
            ctl.observe(np.ones(N_WORKERS),
                        np.zeros(N_WORKERS, dtype=bool))
        np.testing.assert_array_equal(ctl.window_array(), before)
        # still serviceable after the rejected step
        times = _sim(3).step()
        it = order_stats.iter_time(times, 24)
        ctl.observe(times, times <= it + 1e-12)


def test_race_is_deterministic(fitted_model):
    rm, trace = fitted_model
    runs = []
    for _ in range(2):
        ctl = CutoffController(rm, k_samples=16, seed=2)
        ctl.seed_window(trace)
        runs.append(_race(ctl, seed=9, steps=50))
    assert runs[0] == runs[1]
