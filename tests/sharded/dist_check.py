"""Multi-device checks for repro.dist: masked psum aggregation + layout
sharding rules on a real (fake-8-device) mesh.  Prints FAIL on any
violated property; driven by tests/test_sharded_equivalence.py."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import aggregation
from repro.dist import collectives
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh

failures = []


def check(name, ok):
    print(f"{name:48s} {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(name)


# ---------------------------------------------------------------------------
# masked_psum_mean on an 8-worker DP mesh.
# ---------------------------------------------------------------------------

mesh8 = make_mesh((8,), ("data",))
key = jax.random.PRNGKey(0)
ks = jax.random.split(key, 2)
grads = {"w": jax.random.normal(ks[0], (8, 4, 6)),
         "b": jax.random.normal(ks[1], (8, 16))}

# 1. all-ones mask is bitwise-equal to the plain psum mean
ones = jnp.ones((8,), jnp.float32)
masked = aggregation.masked_psum_mean(grads, ones, mesh8, ("data",))
plain = aggregation.psum_mean(grads, mesh8, ("data",))
check("all-ones masked == plain mean (bitwise)",
      all(bool(jnp.all(a == b)) for a, b in
          zip(jax.tree.leaves(masked), jax.tree.leaves(plain))))

# 2. a masked-out worker's gradient has exactly zero influence
mask = jnp.asarray([1, 1, 0, 1, 1, 1, 0, 1], jnp.float32)
base = aggregation.masked_psum_mean(grads, mask, mesh8, ("data",))
poisoned = jax.tree.map(lambda l: l.at[2].set(1e30).at[6].set(-1e30), grads)
out = aggregation.masked_psum_mean(poisoned, mask, mesh8, ("data",))
check("masked-out workers have zero influence (bitwise)",
      all(bool(jnp.all(a == b)) for a, b in
          zip(jax.tree.leaves(base), jax.tree.leaves(out))))

# 3. mesh path agrees with the LOCAL reference semantics
local = collectives.masked_grad_mean(grads, mask, shd.LOCAL)
check("mesh psum == LOCAL reference (1e-6)",
      all(bool(jnp.max(jnp.abs(a - b)) < 1e-6) for a, b in
          zip(jax.tree.leaves(base), jax.tree.leaves(local))))

# 4. collectives dispatches through the layout's dp axes
lay = shd.Layout(mesh=mesh8, mode="train_sp", dp=("data",))
via_layout = collectives.masked_grad_mean(grads, mask, lay)
check("collectives.masked_grad_mean routes to the mesh",
      all(bool(jnp.all(a == b)) for a, b in
          zip(jax.tree.leaves(base), jax.tree.leaves(via_layout))))

# 5. all-masked step divides by 1, stays finite
dead = aggregation.masked_psum_mean(grads, jnp.zeros((8,)), mesh8, ("data",))
check("all-masked stays finite and zero",
      all(bool(jnp.all(jnp.isfinite(l))) and bool(jnp.all(l == 0.0))
          for l in jax.tree.leaves(dead)))

# ---------------------------------------------------------------------------
# named_sharding divisibility rules at tp=4.
# ---------------------------------------------------------------------------

mesh24 = make_mesh((2, 4), ("data", "model"))
lay_sp = shd.make_layout(mesh24, "train_sp")
specs = shd.named_sharding(
    {"w": jnp.ones((3, 5)),        # nothing divides 4 -> replicate
     "v": jnp.ones((3, 8)),        # dim 1 is the first divisible
     "u": jnp.ones((8, 5)),        # FSDP dim 0
     "seg": [jnp.ones((3, 8, 5))]},  # stacked: dim 1
    lay_sp, stacked_paths=("seg",))
check("indivisible leaf replicates", specs["w"].spec == P(None, None))
check("first divisible dim gets the model axis",
      specs["v"].spec == P(None, "model"))
check("FSDP dim-0 when divisible", specs["u"].spec == P("model", None))
check("stacked leaf shards dim 1", specs["seg"][0].spec == P(None, "model",
                                                             None))

lay_dec = shd.make_layout(mesh24, "decode_tp")
specs_d = shd.named_sharding({"u": jnp.ones((8, 12))}, lay_dec)
check("decode_tp prefers the last dim", specs_d["u"].spec == P(None, "model"))

# act divisibility fallback at tp=4: odd seq dim replicates, no error
lay = lay_sp
x = jnp.ones((4, 6, 8))  # seq 6 % 4 != 0
with shd.use_layout(lay):
    y = jax.jit(lambda a: shd.act(a, "dp", "sp", None))(x)
check("act falls back to replicated on indivisible dims",
      y.shape == x.shape and bool(jnp.all(y == x)))

print("dist_check:", "FAIL" if failures else "OK", failures)
