"""All perf knobs preserve numerics (loss+grads) vs baseline, sharded.

Promoted from scratch/knob_equiv_test.py: runs on 8 fake CPU devices in a
subprocess (driven by tests/test_sharded_equivalence.py).  Archs can be
narrowed via argv to keep CI wall-clock in check."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import sys

import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.launch.train import make_loss_fn
from repro.models import model as M
from repro.perf.knobs import use_knobs

ARCHS = sys.argv[1:] or ["qwen2-0.5b", "gemma3-12b", "deepseek-moe-16b"]
mesh = make_mesh((2, 4), ("data", "model"))

for name in ARCHS:
    cfg = get_config(name).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    B, S = 4, 32
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "weights": jnp.asarray([1.0, 0.0, 1.0, 1.0]),
    }
    lay = shd.make_layout(mesh, "train_sp")
    loss_fn = make_loss_fn(cfg, aux_coef=0.01)
    norm = jnp.float32(3 * S)
    results = {}
    for tag, kw in [("base", {}),
                    ("shardmap", dict(fsdp_gather="shardmap")),
                    ("ring+shardmap", dict(ce_impl="ring",
                                           fsdp_gather="shardmap")),
                    ("qchunk8", dict(q_chunk=8)),
                    ("halo", dict(attn_halo=True)),
                    ("bf16s", dict(attn_scores_bf16=True))]:
        with use_knobs(**kw):
            stacked = [f"segments/{i}" for i, s in enumerate(
                M.build_segments(M.layer_specs(cfg))) if s.repeats > 1]
            pshard = shd.named_sharding(params, lay,
                                        stacked_paths=tuple(stacked))
            params_s = jax.device_put(params, pshard)
            bshard = {k: NamedSharding(mesh, P("data", "model"))
                      if v.ndim == 2 else NamedSharding(mesh, P("data"))
                      for k, v in batch.items()}
            batch_s = {k: jax.device_put(v, bshard[k])
                       for k, v in batch.items()}

            def run(p, b, kw=kw):
                with shd.use_layout(lay), use_knobs(**kw):
                    (l, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(p, b, norm)
                return l, g

            with jax.set_mesh(mesh):
                results[tag] = jax.jit(run)(params_s, batch_s)
    l0, g0 = results["base"]
    for tag in ["shardmap", "ring+shardmap", "qchunk8", "halo", "bf16s"]:
        l, g = results[tag]
        dl = abs(float(l0) - float(l))
        gerr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g)))
        tol = (3e-2, 0.2) if tag == "bf16s" else (1e-4, 1e-3)
        ok = dl < tol[0] and gerr < tol[1]
        print(f"{name:18s} {tag:14s} dloss={dl:.2e} gerr={gerr:.2e} "
              f"{'OK' if ok else 'FAIL'}")
