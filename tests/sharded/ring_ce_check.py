"""Ring vocab-parallel CE == dense CE (loss + grads), tied & untied heads."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses

import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.launch.train import make_loss_fn
from repro.models import model as M
from repro.perf.knobs import use_knobs

mesh = make_mesh((2, 4), ("data", "model"))

for name in ["qwen2-0.5b", "starcoder2-3b"]:  # tied + untied
    cfg = get_config(name).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    B, S = 4, 32
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "weights": jnp.asarray([1.0, 0.0, 1.0, 1.0]),
    }
    lay = shd.make_layout(mesh, "train_sp")
    loss_fn = make_loss_fn(cfg, aux_coef=0.0)
    norm = jnp.float32(3 * S)

    outs = {}
    for impl in ["dense", "ring"]:
        with use_knobs(ce_impl=impl):
            stacked = [f"segments/{i}" for i, s in enumerate(
                M.build_segments(M.layer_specs(cfg))) if s.repeats > 1]
            pshard = shd.named_sharding(params, lay,
                                        stacked_paths=tuple(stacked))
            params_s = jax.device_put(params, pshard)
            bshard = {k: NamedSharding(mesh, P("data", "model"))
                      if v.ndim == 2 else NamedSharding(mesh, P("data"))
                      for k, v in batch.items()}
            batch_s = {k: jax.device_put(v, bshard[k])
                       for k, v in batch.items()}

            def run(p, b):
                with shd.use_layout(lay), use_knobs(ce_impl=impl):
                    (l, _), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(p, b, norm)
                return l, g

            with jax.set_mesh(mesh):
                outs[impl] = jax.jit(run)(params_s, batch_s)

    l_d, g_d = outs["dense"]
    l_r, g_r = outs["ring"]
    dl = abs(float(l_d) - float(l_r))
    gerr = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g_d), jax.tree.leaves(g_r)))
    print(f"{name:16s} tied={cfg.tie_embeddings} dloss={dl:.2e} "
          f"gerr={gerr:.2e} {'OK' if dl < 1e-4 and gerr < 1e-3 else 'FAIL'}")
