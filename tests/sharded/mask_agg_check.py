"""mask_agg="psum" == mask_agg="weights" on an 8-device host mesh.

The two train-step aggregation paths (per-example weights folded into the
loss vs explicit per-worker gradient psum through the Pallas/shard_map
combine) must produce allclose losses and parameter updates over masked
steps, and the all-ones-mask psum path must match the full-sync
``psum_mean`` bitwise.  Prints FAIL on any violated property; driven by
tests/test_sharded_equivalence.py.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import get_config
from repro.core import aggregation
from repro.dist import collectives
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.launch.train import make_train_step
from repro.models import model as M

failures = []


def check(name, ok):
    print(f"{name:52s} {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(name)


W, per, S = 8, 2, 16
B = W * per
cfg = get_config("qwen2-0.5b").reduced()
key = jax.random.PRNGKey(0)
params = M.init_model(cfg, key)
opt = optim.adamw(3e-3)

mesh = make_mesh((8,), ("data",))
# pure-DP layout: 8 workers == 8 dp shards, params replicated (no model
# axis), so the explicit psum runs over the full mesh.
lay = shd.Layout(mesh=mesh, mode="train_fsdp", dp=("data",))

step_w = make_train_step(cfg, opt)
step_p = make_train_step(cfg, opt, mask_agg="psum")


def jit_step(step):
    def run(state, batch):
        with shd.use_layout(lay):
            return step(state, batch)
    return jax.jit(run)


step_w_j, step_p_j = jit_step(step_w), jit_step(step_p)

rep = NamedSharding(mesh, P())
dp2 = NamedSharding(mesh, P("data"))


def shard_state(state):
    return jax.device_put(state, jax.tree.map(lambda _: rep, state))


def make_batch(step_seed):
    k = jax.random.PRNGKey(step_seed)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }
    return {k_: jax.device_put(v, dp2 if v.ndim >= 1 else rep)
            for k_, v in batch.items()}


rng = np.random.default_rng(0)

state_w = shard_state({"params": params, "opt": opt.init(params)})
state_p = jax.tree.map(lambda x: x, state_w)

with jax.set_mesh(mesh):
    max_dl, max_dp = 0.0, 0.0
    for t in range(5):
        # a fresh random mask each step, always with >=1 straggler dropped
        mask = (rng.uniform(size=W) < 0.7).astype(np.float32)
        mask[rng.integers(W)] = 0.0
        if mask.sum() == 0:
            mask[0] = 1.0
        bw = dict(make_batch(t),
                  weights=jax.device_put(
                      jnp.asarray(aggregation.example_weights(mask, B)),
                      dp2))
        bp = dict(make_batch(t), mask=jax.device_put(jnp.asarray(mask), rep))
        state_w, mw = step_w_j(state_w, bw)
        state_p, mp = step_p_j(state_p, bp)
        max_dl = max(max_dl, abs(float(mw["loss"]) - float(mp["loss"])))
        max_dp = max(max_dp, max(
            float(jnp.max(jnp.abs(a - b))) for a, b in
            zip(jax.tree.leaves(state_w["params"]),
                jax.tree.leaves(state_p["params"]))))
    check(f"5-step masked losses allclose (dl={max_dl:.2e})", max_dl < 1e-4)
    check(f"5-step masked updates allclose (dp={max_dp:.2e})", max_dp < 1e-3)

    # all-ones mask: the explicit masked combine must equal the full-sync
    # psum_mean BITWISE on real per-worker model gradients.
    batch = make_batch(99)
    gs = []
    for w in range(W):
        sub = {k_: v[w * per:(w + 1) * per] for k_, v in batch.items()}
        gs.append(jax.jit(jax.grad(
            lambda p, b: M.train_loss(cfg, p, b)[0]))(params, sub))
    stacked = jax.tree.map(lambda *x: jnp.stack(x), *gs)
    ones = jnp.ones((W,), jnp.float32)

    def agg(fn, *args):
        with shd.use_layout(lay):
            return fn(stacked, *args)

    masked = jax.jit(lambda: agg(collectives.masked_grad_mean, ones))()
    sync = jax.jit(lambda: agg(collectives.grad_mean))()
    check("all-ones psum == full-sync psum_mean (bitwise)",
          all(bool(jnp.all(a == b)) for a, b in
              zip(jax.tree.leaves(masked), jax.tree.leaves(sync))))

print("mask_agg_check:", "FAIL" if failures else "OK", failures)
