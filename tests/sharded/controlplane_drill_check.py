"""Live subprocess crash drill for the control plane.

Real OS processes (``python -m repro.controlplane.worker``), a real
``kill -9``, a real hang (flag file: the incarnation spins alive but
silent), one flaky restart incarnation, and warm recovery by GLOBAL
worker id from a pre-saved ``"ctl"`` checkpoint group.  Prints one
OK/FAIL line per property; driven by tests/test_sharded_equivalence.py
and ``scripts/ci.sh --drill``.
"""
import shutil
import sys
import tempfile
import time

import numpy as np

from repro.checkpoint import store
from repro.controlplane import (Fault, FaultInjector, FaultPlan,
                                ProcWorkerPool, Supervisor)
from repro.controlplane.supervisor import drill_report

failures = []


def check(name, ok):
    print(f"{name:56s} {'OK' if ok else 'FAIL'}")
    if not ok:
        failures.append(name)


N = 3
TICK = 0.25                 # wall seconds per control tick
SUSPECT, DEAD_AFTER = 2, 4
CKPT_STEP = 7

root = tempfile.mkdtemp(prefix="cp_drill_")
run_dir, ckpt_dir = f"{root}/run", f"{root}/ckpt"

# the checkpoint every incarnation warm-starts from, keyed by GLOBAL id
store.save(ckpt_dir, CKPT_STEP,
           {"ctl": {"step": np.int64(CKPT_STEP),
                    "members": np.arange(N)}})

# worker 0's first restart attempt exits on arrival (flaky incarnation)
inj = FaultInjector(FaultPlan([Fault(at=0, kind="flaky_restart",
                                     worker=0, fails=1)]))
inj.fire(0)                 # arm the flaky budget

pool = ProcWorkerPool(N, run_dir, period=0.05, ckpt_dir=ckpt_dir,
                      injector=inj)
sup = Supervisor(pool, suspect_after=SUSPECT, dead_after=DEAD_AFTER,
                 grace=30, restart_base=2, restart_cap=8, flap_limit=3,
                 seed=0)
pool.launch_all()

CRASH_AT = HANG_AT = 8
shrank = False
try:
    for t in range(1, 49):
        time.sleep(TICK)
        if t == CRASH_AT:
            pool.sigkill(0)                       # the real crash
            sup.log.emit(t, "fault", 0, fault="crash")
        if t == HANG_AT:
            pool.hang(2)                          # alive but silent
            sup.log.emit(t, "fault", 2, fault="hang")
        sup.tick(t)
        if sup.membership().size < N:
            shrank = True

    evs = sup.log.events
    rep = drill_report(evs)

    check("both faults detected", rep["n_detected"] == 2)
    check("detection within deadline + 1 tick",
          rep["max_detection_ticks"] is not None
          and rep["max_detection_ticks"] <= DEAD_AFTER + 1)
    check("dead workers left the membership", shrank)

    kills = [e for e in evs if e.kind == "kill"]
    check("hung worker killed before restart (exactly one kill)",
          [e.worker for e in kills] == [2]
          and kills[0].data.get("reason") == "hung")

    fails = [e for e in evs if e.kind == "restart_failed"]
    check("flaky incarnation burned one failed attempt",
          [e.worker for e in fails] == [0])
    restarts = [e for e in evs if e.kind == "restart"]
    check("both fallen workers restarted",
          sorted({e.worker for e in restarts}) == [0, 2])
    r0 = [e for e in restarts if e.worker == 0]
    check("flaky worker's landing attempt is #2",
          len(r0) == 1 and r0[0].data.get("attempt") == 2)

    recs = [e for e in evs if e.kind == "recover"]
    by_w = {w: [e for e in recs if e.worker == w] for w in range(N)}
    check("every incarnation recovered warm from the ctl group",
          recs != [] and all(e.data.get("step") == CKPT_STEP
                             and e.data.get("warm") for e in recs))
    check("restarted workers recovered AGAIN by global id",
          len(by_w[0]) >= 2 and len(by_w[2]) >= 2 and len(by_w[1]) == 1)

    check("membership healed to full width",
          [int(w) for w in sup.membership()] == list(range(N)))
    check("no evictions", rep["evicted"] == [])
    check("all incarnations alive at the end",
          all(pool.proc_running(w) for w in range(N)))
finally:
    pool.shutdown()
    shutil.rmtree(root, ignore_errors=True)

print("controlplane_drill_check:", "FAIL" if failures else "OK", failures)
sys.exit(1 if failures else 0)
