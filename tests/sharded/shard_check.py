"""Sharded (train_sp, 2x4 mesh) vs local: loss and grads must match."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import sys

import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import get_config, all_archs
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import model as M

ARCHS = sys.argv[1:] or list(all_archs())
mesh = make_mesh((2, 4), ("data", "model"))

for name in ARCHS:
    cfg = get_config(name).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    B, S = 4, 32
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "weights": jnp.asarray([1.0, 0.0, 1.0, 1.0]),  # cutoff mask!
    }
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.zeros((B, S, cfg.d_model))
        batch["image_mask"] = jnp.zeros((B, S), bool)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    if cfg.is_encoder_decoder:
        # reduced() pins encoder_seq_len=32; frames must match it
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq_len, cfg.d_model)) * 0.1

    def loss_fn(p, b):
        return M.train_loss(cfg, p, b)[0]

    # ---- local reference ----
    with shd.use_layout(shd.LOCAL):
        loss_ref = loss_fn(params, batch)
        g_ref = jax.grad(loss_fn)(params, batch)

    # ---- sharded ----
    lay = shd.make_layout(mesh, "train_sp")
    stacked = [f"segments/{i}" for i, s in enumerate(
        M.build_segments(M.layer_specs(cfg))) if s.repeats > 1]
    if cfg.is_encoder_decoder:
        stacked += [f"encoder/segments/{i}" for i, s in enumerate(
            M.build_segments(M.encoder_layer_specs(cfg))) if s.repeats > 1]
    pshard = shd.named_sharding(params, lay, stacked_paths=tuple(stacked))
    params_s = jax.device_put(params, pshard)

    def bspec(k, v):
        if k == "positions" and v.ndim == 3:
            return NamedSharding(mesh, P(None, "data", "model"))
        if v.ndim >= 2:
            return NamedSharding(mesh, P("data", "model"))
        return NamedSharding(mesh, P("data"))
    bshard = {k: bspec(k, v) for k, v in batch.items()}
    bshard["weights"] = NamedSharding(mesh, P("data"))
    if "frames" in batch:
        bshard["frames"] = NamedSharding(mesh, P("data", "model", None))
    if "patch_embeds" in batch:
        bshard["patch_embeds"] = NamedSharding(mesh, P("data", "model", None))
    batch_s = {k: jax.device_put(v, bshard[k]) for k, v in batch.items()}

    def run(p, b):
        with shd.use_layout(lay):
            l = loss_fn(p, b)
            g = jax.grad(loss_fn)(p, b)
        return l, g

    with jax.set_mesh(mesh):
        loss_s, g_s = jax.jit(run)(params_s, batch_s)

    dl = abs(float(loss_ref) - float(loss_s))
    gerr = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_s)))
    ok = dl < 2e-4 and gerr < 2e-2
    print(f"{name:24s} dloss={dl:.2e} gerr={gerr:.2e} {'OK' if ok else 'FAIL'}")
