"""Direct coverage for serving/engine.py (previously only smoke-tested).

Two contracts: (1) seeded decode determinism — greedy and temperature
sampling are pure functions of (params, prompt, seed), and temperature
actually changes the trajectory; (2) the prefill/decode cache-shape
contract — ``pad_caches`` grows every KV leaf's sequence axis to the
decode horizon and ``decode_step`` preserves cache shapes step to step
(no silent reallocation in the decode loop).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import bench_tiny_config
from repro.models import model as M
from repro.serving.engine import ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = bench_tiny_config()
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params)


@pytest.fixture(scope="module")
def prompts(engine):
    rng = np.random.default_rng(0)
    return rng.integers(0, engine.cfg.vocab_size, size=(2, 6),
                        dtype=np.int32)


def test_generate_shape_and_vocab_range(engine, prompts):
    out = engine.generate(prompts, n_new=5, temperature=0.0)
    assert out.shape == (2, 5)
    assert out.dtype == np.int32
    assert np.all((0 <= out) & (out < engine.cfg.vocab_size))


def test_greedy_decode_deterministic_and_seed_independent(engine, prompts):
    a = engine.generate(prompts, n_new=6, temperature=0.0, seed=0)
    b = engine.generate(prompts, n_new=6, temperature=0.0, seed=123)
    np.testing.assert_array_equal(a, b)   # greedy ignores the sample key


def test_temperature_decode_seeded_determinism(engine, prompts):
    a = engine.generate(prompts, n_new=8, temperature=0.8, seed=7)
    b = engine.generate(prompts, n_new=8, temperature=0.8, seed=7)
    np.testing.assert_array_equal(a, b)
    c = engine.generate(prompts, n_new=8, temperature=0.8, seed=8)
    assert not np.array_equal(a, c), "different seeds, identical sample path"


def test_temperature_changes_trajectory_vs_greedy(engine, prompts):
    greedy = engine.generate(prompts, n_new=8, temperature=0.0, seed=7)
    hot = engine.generate(prompts, n_new=8, temperature=2.0, seed=7)
    assert not np.array_equal(greedy, hot)


def _kv_leaves(caches):
    """Every attention-cache k/v leaf (the pad_caches contract: the
    sequence axis is ndim-3)."""
    out = []

    def walk(node):
        if isinstance(node, dict):
            for k, v in node.items():
                if k in ("k", "v") and hasattr(v, "ndim"):
                    out.append(v)
                else:
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(caches)
    return out


def test_prefill_decode_cache_shape_contract(engine, prompts):
    B, S = prompts.shape
    n_new = 4
    batch = {"tokens": jnp.asarray(prompts),
             "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))}
    last_logits, caches = M.prefill(engine.cfg, engine.params, batch)
    assert last_logits.shape == (B, engine.cfg.vocab_size)
    kv = _kv_leaves(caches)
    assert kv, "tiny dense config must carry attention KV caches"
    for leaf in kv:
        assert leaf.shape[leaf.ndim - 3] == S, leaf.shape

    caches = M.pad_caches(caches, S + n_new)
    kv = _kv_leaves(caches)
    for leaf in kv:
        assert leaf.shape[leaf.ndim - 3] == S + n_new, leaf.shape

    # decode_step must preserve every cache leaf's shape (and write into
    # the padded slots rather than reallocating)
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
    for t in range(n_new):
        shapes_before = [leaf.shape for leaf in _kv_leaves(caches)]
        logits, caches = M.decode_step(engine.cfg, engine.params, tok,
                                       jnp.int32(S + t), caches)
        assert logits.shape == (B, 1, engine.cfg.vocab_size)
        shapes_after = [leaf.shape for leaf in _kv_leaves(caches)]
        assert shapes_before == shapes_after
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]


def test_generate_matches_manual_prefill_decode_loop(engine, prompts):
    """ServeEngine.generate's greedy path == the raw prefill/decode loop
    (the engine adds batching/caching plumbing, not semantics)."""
    B, S = prompts.shape
    n_new = 5
    want = engine.generate(prompts, n_new=n_new, temperature=0.0)
    batch = {"tokens": jnp.asarray(prompts),
             "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))}
    last_logits, caches = M.prefill(engine.cfg, engine.params, batch)
    caches = M.pad_caches(caches, S + n_new)
    tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    got = []
    for t in range(n_new):
        got.append(np.asarray(tok))
        logits, caches = M.decode_step(engine.cfg, engine.params,
                                       tok[:, None], jnp.int32(S + t),
                                       caches)
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(want, np.stack(got, axis=1))
