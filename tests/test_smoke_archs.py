"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs (the assignment's required smoke tier)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import reduced_cfg, tiny_batch
from repro import optim
from repro.launch.train import make_train_step
from repro.models import model as M


def test_forward_and_train_step(arch_name):
    cfg = reduced_cfg(arch_name)
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    batch = tiny_batch(cfg, key, B=2, S=16)

    logits, _, aux = M.forward(cfg, params, batch, mode="train")
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    opt = optim.adamw(1e-3)
    step = make_train_step(cfg, opt)
    state = {"params": params, "opt": opt.init(params)}
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["gnorm"]))
    # params actually changed
    changed = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(state["params"])))
    assert changed


def test_loss_decreases_two_steps(arch_name):
    cfg = reduced_cfg(arch_name)
    key = jax.random.PRNGKey(1)
    params = M.init_model(cfg, key)
    batch = tiny_batch(cfg, key, B=2, S=16)
    opt = optim.adamw(5e-3)
    step = jax.jit(make_train_step(cfg, opt))
    state = {"params": params, "opt": opt.init(params)}
    losses = []
    for _ in range(3):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_grad_accum_matches(arch_name):
    cfg = reduced_cfg(arch_name)
    key = jax.random.PRNGKey(2)
    params = M.init_model(cfg, key)
    batch = tiny_batch(cfg, key, B=4, S=16)
    opt = optim.sgd(1e-2)
    s1 = {"params": params, "opt": opt.init(params)}
    s2 = {"params": params, "opt": opt.init(params)}
    st1, m1 = jax.jit(make_train_step(cfg, opt, grad_accum=1))(s1, batch)
    st2, m2 = jax.jit(make_train_step(cfg, opt, grad_accum=2))(s2, batch)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(st1["params"]),
                              jax.tree.leaves(st2["params"])))
    # MoE: each microbatch computes its own load-balance aux (mean-of-
    # products != product-of-means) and scatter-add order differs -- a
    # documented, standard semantic of microbatched MoE training.
    tol = 2e-3 if cfg.n_experts else 2e-5
    assert err < tol, err
