"""BENCH_ps.json schema guard.

Runs ``benchmarks.ps_bench.bench_ps`` at minimum size and asserts the
machine-readable output keeps the ``bench_ps/v2`` contract.  Schema smoke
test only — timings on a loaded CI box are noise, so the quick run checks
structure and the structural invariants that are timing-independent
(one dispatch per tick for the ragged mix, the async refit never
blocking); the committed BENCH_ps.json carries the acceptance numbers
(batched >= 1.0x at every J in {1, 4, 16, 64, 256} on n=158).
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    from benchmarks.ps_bench import bench_ps

    out = tmp_path_factory.mktemp("bench") / "BENCH_ps.json"
    bench_ps(quick=True, out_path=str(out), n_list=(8,), j_list=(1, 2),
             decision_iters=2, agg_jobs=2, agg_ticks=3, sched_ticks=3,
             ragged_widths=(10, 6), churn_ticks=8)
    with open(out) as f:
        return json.load(f)


def test_bench_ps_schema(bench_json):
    assert bench_json["schema"] == "bench_ps/v2"
    rows = bench_json["decision"]
    assert {(r["n_workers"], r["n_jobs"]) for r in rows} == {(8, 1), (8, 2)}
    for row in rows:
        for key in ("n_workers", "n_jobs", "k_samples", "looped_us",
                    "batched_us", "speedup"):
            assert key in row, key
        assert row["looped_us"] > 0 and row["batched_us"] > 0
    agg = bench_json["aggregate"]
    for key in ("arch", "n_jobs", "n_per_job", "ticks",
                "multi_steps_per_s", "independent_steps_per_s",
                "multi_over_independent"):
        assert key in agg, key
    assert agg["multi_steps_per_s"] > 0
    assert agg["independent_steps_per_s"] > 0
    sched = bench_json["sched"]
    assert {r["policy"] for r in sched} == {"rr", "priority", "spsf"}
    for row in sched:
        for key in ("capacity", "total_steps", "steps_per_s",
                    "service_spread", "serviced"):
            assert key in row, key
        assert row["total_steps"] == row["capacity"] * row["ticks"]
        assert row["steps_per_s"] > 0
    # round-robin is the starvation-free policy even at bench size
    rr = next(r for r in sched if r["policy"] == "rr")
    assert rr["service_spread"] <= 1


def test_bench_ps_ragged_section(bench_json):
    """The ragged mix pays exactly ONE dispatch per tick — structural,
    not a timing, so it must hold even on a loaded box."""
    row = bench_json["ragged"]
    for key in ("widths", "n_pad", "n_jobs", "looped_us", "batched_us",
                "speedup", "dispatches_per_tick"):
        assert key in row, key
    assert row["n_pad"] == max(row["widths"])
    assert row["dispatches_per_tick"] == 1.0, row


def test_bench_ps_refit_section(bench_json):
    """The gated-fit probe: every timed tick completed while the refit
    thread was still alive, and the refit installed once released."""
    row = bench_json["refit"]
    for key in ("ticks_during_refit", "tick_p50_us", "tick_max_us",
                "fit_wall_s", "nonblocking", "rejoined"):
        assert key in row, key
    assert row["nonblocking"] is True, row
    assert row["rejoined"] is True, row


def test_bench_ps_sched_churn_section(bench_json):
    row = bench_json["sched_churn"]
    for key in ("ticks", "capacity", "events", "total_steps",
                "steps_per_s", "core_service_spread", "core_modes"):
        assert key in row, key
    assert row["steps_per_s"] > 0
    # the RR fairness bound for the long-lived jobs survives the churn
    assert row["core_service_spread"] <= 1, row


def test_committed_bench_ps_matches_schema():
    """The checked-in BENCH_ps.json (the perf trajectory's multi-tenant
    datapoint) must exist, keep the v2 schema, and show the batched
    vmapped decision at parity or better with J looped dispatches at
    EVERY point of the J sweep on n=158 — plus the ragged and refit
    structural invariants."""
    path = Path(__file__).resolve().parent.parent / "BENCH_ps.json"
    assert path.exists(), "BENCH_ps.json not committed"
    with open(path) as f:
        data = json.load(f)
    assert data["schema"] == "bench_ps/v2"
    combos = {(r["n_workers"], r["n_jobs"]) for r in data["decision"]}
    for n in (8, 158):
        for J in (1, 4, 16, 64, 256):
            assert (n, J) in combos, (n, J)
    for row in data["decision"]:
        if row["n_workers"] == 158:
            assert row["speedup"] >= 1.0, row
    assert data["ragged"]["dispatches_per_tick"] == 1.0
    assert data["ragged"]["speedup"] >= 1.0, data["ragged"]
    assert data["refit"]["nonblocking"] is True
    assert data["refit"]["rejoined"] is True
    assert data["sched_churn"]["core_service_spread"] <= 1
