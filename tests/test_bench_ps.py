"""BENCH_ps.json schema guard.

Runs ``benchmarks.ps_bench.bench_ps`` at minimum size and asserts the
machine-readable output keeps the ``bench_ps/v1`` contract.  Schema smoke
test only — timings on a loaded CI box are noise; the committed
BENCH_ps.json carries the acceptance number (batched beats looped at
J=16, n=158).
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    from benchmarks.ps_bench import bench_ps

    out = tmp_path_factory.mktemp("bench") / "BENCH_ps.json"
    bench_ps(quick=True, out_path=str(out), n_list=(8,), j_list=(1, 2),
             decision_iters=2, agg_jobs=2, agg_ticks=3, sched_ticks=3)
    with open(out) as f:
        return json.load(f)


def test_bench_ps_schema(bench_json):
    assert bench_json["schema"] == "bench_ps/v1"
    rows = bench_json["decision"]
    assert {(r["n_workers"], r["n_jobs"]) for r in rows} == {(8, 1), (8, 2)}
    for row in rows:
        for key in ("n_workers", "n_jobs", "k_samples", "looped_us",
                    "batched_us", "speedup"):
            assert key in row, key
        assert row["looped_us"] > 0 and row["batched_us"] > 0
    agg = bench_json["aggregate"]
    for key in ("arch", "n_jobs", "n_per_job", "ticks",
                "multi_steps_per_s", "independent_steps_per_s",
                "multi_over_independent"):
        assert key in agg, key
    assert agg["multi_steps_per_s"] > 0
    assert agg["independent_steps_per_s"] > 0
    sched = bench_json["sched"]
    assert {r["policy"] for r in sched} == {"rr", "priority", "spsf"}
    for row in sched:
        for key in ("capacity", "total_steps", "steps_per_s",
                    "service_spread", "serviced"):
            assert key in row, key
        assert row["total_steps"] == row["capacity"] * row["ticks"]
        assert row["steps_per_s"] > 0
    # round-robin is the starvation-free policy even at bench size
    rr = next(r for r in sched if r["policy"] == "rr")
    assert rr["service_spread"] <= 1


def test_committed_bench_ps_matches_schema():
    """The checked-in BENCH_ps.json (the perf trajectory's multi-tenant
    datapoint) must exist, keep the schema, and show the batched vmapped
    decision beating J looped dispatches at J=16, n=158 — the number the
    subsystem exists for."""
    path = Path(__file__).resolve().parent.parent / "BENCH_ps.json"
    assert path.exists(), "BENCH_ps.json not committed"
    with open(path) as f:
        data = json.load(f)
    assert data["schema"] == "bench_ps/v1"
    combos = {(r["n_workers"], r["n_jobs"]) for r in data["decision"]}
    for n in (8, 158):
        for J in (1, 4, 16):
            assert (n, J) in combos, (n, J)
    flagship = next(r for r in data["decision"]
                    if r["n_workers"] == 158 and r["n_jobs"] == 16)
    assert flagship["speedup"] > 1.0, flagship
