"""Optim, data pipeline, checkpoint, compression, cluster sim."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint import store
from repro.cluster.simulator import ClusterSim, cray_xc40_2175, paper_cluster_158
from repro.data.pipeline import SyntheticImages, SyntheticTokens

SETTINGS = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------


def test_adam_matches_closed_form():
    params = {"w": jnp.array([1.0, -2.0])}
    grads = {"w": jnp.array([0.5, 0.5])}
    opt = optim.adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
    state = opt.init(params)
    ups, state = opt.update(grads, state, params)
    m = 0.1 * 0.5
    v = 0.001 * 0.25
    want = -0.1 * (m / 0.1) / (np.sqrt(v / 0.001) + 1e-8)
    np.testing.assert_allclose(ups["w"], [want, want], rtol=1e-5)


def test_clip_by_global_norm():
    params = {"w": jnp.zeros(4)}
    grads = {"w": jnp.full(4, 10.0)}
    opt = optim.clip_by_global_norm(optim.sgd(1.0), 1.0)
    state = opt.init(params)
    ups, _ = opt.update(grads, state, params)
    assert float(optim.global_norm(ups)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    sch = optim.cosine_schedule(1.0, 10, 100)
    assert float(sch(jnp.int32(0))) < 0.2
    assert float(sch(jnp.int32(10))) == pytest.approx(1.0, abs=0.1)
    assert float(sch(jnp.int32(99))) < 0.2


@settings(**SETTINGS)
@given(seed=st.integers(0, 100))
def test_error_feedback_unbiased_over_time(seed):
    """With EF, the *cumulative* applied update converges to the cumulative
    true gradient (residual stays bounded)."""
    rng = np.random.default_rng(seed)
    g_true = jnp.asarray(rng.normal(size=257) * 0.1)
    res = None
    applied = jnp.zeros(257)
    for _ in range(20):
        sent, res = optim.error_feedback_compress({"g": g_true},
                                                  res)
        applied = applied + sent["g"]
        res = res
    total_err = float(jnp.max(jnp.abs(applied - 20 * g_true)))
    scale = float(jnp.max(jnp.abs(g_true)))
    assert total_err <= scale / 127.0 * 1.5 + 1e-6  # residual bound, no drift


@settings(**SETTINGS)
@given(seed=st.integers(0, 100))
def test_int8_roundtrip_error_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=1000))
    q, s = optim.compress_int8(x)
    back = optim.decompress_int8(q, s)
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-7


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_tokens_deterministic_and_with_replacement():
    ds = SyntheticTokens(vocab_size=128, seq_len=16, global_batch=8, seed=0)
    b1 = ds.batch(3)
    b2 = ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # per-worker draws are independent of other workers (with replacement):
    w0 = ds.batch(3, worker=0, n_workers=4)
    w0_again = ds.batch(3, worker=0, n_workers=4)
    np.testing.assert_array_equal(w0["tokens"], w0_again["tokens"])
    w1 = ds.batch(3, worker=1, n_workers=4)
    assert not np.array_equal(w0["tokens"], w1["tokens"])


def test_tokens_worker_draws_cover_global_batch():
    # divisible: per-worker draws tile the full global batch exactly
    ds = SyntheticTokens(vocab_size=128, seq_len=16, global_batch=8, seed=0)
    total = sum(ds.batch(0, worker=w, n_workers=4)["tokens"].shape[0]
                for w in range(4))
    assert total == ds.global_batch
    assert ds.batch(0)["tokens"].shape[0] == ds.global_batch


def test_tokens_nondivisible_worker_count_raises():
    # non-divisible worker counts used to silently truncate (3 workers x
    # 10//3 = 9 of 10 examples); now they fail loudly like the Trainer
    ds = SyntheticTokens(vocab_size=128, seq_len=16, global_batch=10, seed=0)
    with pytest.raises(ValueError, match="not divisible"):
        ds.batch(0, worker=0, n_workers=3)
    # the full-batch path is unaffected
    assert ds.batch(0)["tokens"].shape[0] == 10


def test_tokens_learnable_structure():
    ds = SyntheticTokens(vocab_size=64, seq_len=32, global_batch=16, seed=0)
    b = ds.batch(0)
    # successor structure: every (t, t+1) pair is in the transition table
    ok = 0
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for a, b_ in zip(row_t, row_l):
            ok += b_ in ds.succ[a]
    assert ok == 16 * 32


def test_images_shapes():
    ds = SyntheticImages(seed=0)
    x, y = ds.batch(0, 32)
    assert x.shape == (32, 28, 28) and y.shape == (32,)
    xv, yv = ds.valid_set()
    assert xv.shape[0] == ds.n_valid


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"a": jnp.arange(6.0).reshape(2, 3),
                        "nested": [{"b": jnp.ones(4)}]},
             "meta": {"step": 7, "clock": 1.5}}
    store.save(str(tmp_path), 7, state)
    out = store.restore(str(tmp_path), state)
    np.testing.assert_array_equal(out["params"]["a"], state["params"]["a"])
    np.testing.assert_array_equal(out["params"]["nested"][0]["b"],
                                  jnp.ones(4))
    assert store.latest_step(str(tmp_path)) == 7


def test_checkpoint_keep_n_and_atomic(tmp_path):
    state = {"x": {"v": jnp.zeros(2)}}
    for s in range(5):
        store.save(str(tmp_path), s, state, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_0000000003", "step_0000000004"]
    assert not any(d.startswith("tmp.") for d in os.listdir(tmp_path))


def test_checkpoint_resave_same_step(tmp_path):
    # warm-restart pattern: save step 7, restore, save step 7 AGAIN —
    # publish must replace the old dir atomically instead of crashing on
    # os.rename into an existing directory (or leaving a window with no
    # step_7 at all)
    d = str(tmp_path)
    store.save(d, 7, {"x": {"v": jnp.zeros(3)}})
    restored = store.restore(d, {"x": {"v": jnp.zeros(3)}})
    np.testing.assert_array_equal(restored["x"]["v"], jnp.zeros(3))
    store.save(d, 7, {"x": {"v": jnp.arange(3.0)}})   # re-save same step
    out = store.restore(d, {"x": {"v": jnp.zeros(3)}})
    np.testing.assert_array_equal(out["x"]["v"], jnp.arange(3.0))
    assert store.latest_step(d) == 7
    leftovers = [f for f in os.listdir(d)
                 if f.startswith(("tmp.", "stale."))]
    assert leftovers == []


def test_async_checkpointer(tmp_path):
    ck = store.AsyncCheckpointer(str(tmp_path))
    ck.save(1, {"x": {"v": jnp.arange(3.0)}})
    ck.wait()
    out = store.restore(str(tmp_path), {"x": {"v": jnp.zeros(3)}})
    np.testing.assert_array_equal(out["x"]["v"], jnp.arange(3.0))


# ---------------------------------------------------------------------------
# cluster sim
# ---------------------------------------------------------------------------


def test_cluster_sim_properties():
    sim = paper_cluster_158(seed=0)
    t = sim.run(100)
    assert t.shape == (100, 158) and np.all(t > 0)
    # node correlation: workers on the same node co-vary more
    c_same = np.corrcoef(t[:, 0], t[:, 1])[0, 1]
    c_diff = np.corrcoef(t[:, 0], t[:, 120])[0, 1]
    assert c_same > c_diff - 0.2  # same node at least as correlated


def test_cluster_sim_regimes_change_distribution():
    sim = ClusterSim(n_workers=64, n_nodes=4, regime_stay=0.0, seed=0)
    t = sim.run(200)
    stds = t.std(axis=1)
    assert stds.max() > 2.0 * stds.min()  # regime switching is visible


def test_cray_preset_size():
    assert cray_xc40_2175(0).n_workers == 2175
