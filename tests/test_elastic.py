"""Elastic worker membership: churn simulation, the resize protocol, and
warm checkpoint restarts across membership changes.

The acceptance scenario: a seeded 8 -> 6 -> 8 churn run on the
paper_cluster_158 phenomenology, driven end-to-end by the
ElasticController (DMM while the shape matches, Elfving fallback + refit
across each resize), must beat full sync on wall-clock-to-loss — and a
checkpoint written mid-churn must restore a WARM (allclose) controller
window at the degraded worker count.
"""
import threading

import jax
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import store
from repro.cluster.simulator import (ChurnEvent, ChurnSim, ClusterSim,
                                     paper_cluster_158, resize_schedule)
from repro.cluster.trace import TraceReplay, load_trace, save_trace
from repro.core.controller import (CutoffController, ElasticController,
                                   ElfvingController, FullSyncController,
                                   RefitError, StaticCutoffController,
                                   _poll_refit_task, _spawn_refit,
                                   remap_columns)
from repro.core.runtime_model.api import RuntimeModel
from repro.configs.base import bench_tiny_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.train import Trainer, clock_to_loss, jit_train_step
from repro.models import model as M


# ---------------------------------------------------------------------------
# ChurnSim / TraceReplay / trace-file contracts.
# ---------------------------------------------------------------------------


def test_churnsim_membership_schedule():
    churn = ChurnSim(ClusterSim(n_workers=8, n_nodes=2, seed=0),
                     [ChurnEvent(step=3, kill=(2, 5)),
                      ChurnEvent(step=6, restore=(2,))])
    widths, ids = [], []
    for _ in range(8):
        ids.append(churn.active_ids.tolist())
        widths.append(len(churn.step()))
    assert widths == [8, 8, 8, 6, 6, 6, 7, 7]
    assert ids[3] == [0, 1, 3, 4, 6, 7]
    assert ids[6] == [0, 1, 2, 3, 4, 6, 7]


def test_churnsim_survivors_column_exact():
    """The base phenomenology is independent of membership: a survivor's
    runtime series matches the full-width run column for column."""
    full = ClusterSim(n_workers=8, n_nodes=2, seed=4).run(10)
    churn = ChurnSim(ClusterSim(n_workers=8, n_nodes=2, seed=4),
                     [ChurnEvent(step=4, kill=(1, 6))])
    rows = churn.run(10)
    keep = [0, 2, 3, 4, 5, 7]
    for t in range(4):
        np.testing.assert_array_equal(rows[t], full[t])
    for t in range(4, 10):
        np.testing.assert_array_equal(rows[t], full[t][keep])


def test_resize_schedule_width_plan():
    churn = resize_schedule(ClusterSim(n_workers=8, n_nodes=2, seed=1),
                            [(2, 5), (4, 8)])
    widths = [len(churn.step()) for _ in range(6)]
    assert widths == [8, 8, 5, 5, 8, 8]


def test_trace_replay_segments_and_exhaustion():
    segs = [np.full((2, 4), 1.0), np.full((3, 6), 2.0)]
    rep = TraceReplay(segs, loop=False)
    assert rep.n_workers == 4
    assert rep.step().shape == (4,)
    rep.step()
    assert rep.n_workers == 6          # next row comes from segment 2
    for _ in range(3):
        assert rep.step().shape == (6,)
    with pytest.raises(IndexError):     # NOT a bare StopIteration
        rep.step()

    looped = TraceReplay(segs, loop=True)
    widths = [looped.step().shape[0] for _ in range(10)]
    assert widths == [4, 4, 6, 6, 6] * 2


def test_trace_meta_roundtrip(tmp_path):
    path = str(tmp_path / "t.npz")
    times = np.random.default_rng(0).uniform(0.5, 2.0, size=(6, 4))
    save_trace(path, times, meta={"cluster": "paper_158", "n_nodes": 4})
    plain = load_trace(path)
    np.testing.assert_allclose(plain, times, atol=1e-6)
    t2, meta = load_trace(path, with_meta=True)
    np.testing.assert_allclose(t2, times, atol=1e-6)
    assert meta == {"cluster": "paper_158", "n_nodes": 4}


# ---------------------------------------------------------------------------
# Window remapping + controller resize units.
# ---------------------------------------------------------------------------


def test_remap_columns_survivors_exact_and_mean_fill():
    rows = np.arange(20, dtype=np.float64).reshape(4, 5)
    col_map = np.array([3, 0, -1, 4])
    out = remap_columns(rows, 4, col_map)
    np.testing.assert_array_equal(out[:, 0], rows[:, 3])
    np.testing.assert_array_equal(out[:, 1], rows[:, 0])
    np.testing.assert_array_equal(out[:, 3], rows[:, 4])
    np.testing.assert_allclose(out[:, 2], rows[:, [3, 0, 4]].mean(axis=1))
    # default map: identity prefix, extras are cluster-mean seeded
    grown = remap_columns(rows, 7)
    np.testing.assert_array_equal(grown[:, :5], rows)
    np.testing.assert_allclose(grown[:, 5], rows.mean(axis=1))


@pytest.fixture(scope="module")
def fitted8():
    trace = paper_cluster_158(0, n_workers=8).run(200)
    rm = RuntimeModel(n_workers=8, lag=10).init(0)
    rm.fit(trace, steps=200, batch=8, seed=0)
    return rm, trace


def _unfitted_model(n, template):
    rm = RuntimeModel(n_workers=n, lag=template.lag,
                      z_dim=template.z_dim, hidden=template.hidden).init(1)
    rm.norm_scale = template.norm_scale
    return rm


@pytest.mark.parametrize("backend", ["device", "numpy"])
def test_cutoff_controller_resize_ring_remap(fitted8, backend):
    rm, trace = fitted8
    ctl = CutoffController(rm, k_samples=16, seed=0, backend=backend)
    ctl.seed_window(trace)
    before = ctl.window_array()
    col_map = np.array([0, 1, 2, 3, 4, 6])      # worker 5 and 7 depart
    ctl.resize(6, col_map=col_map, model=_unfitted_model(6, rm))
    after = ctl.window_array()
    assert after.shape == (before.shape[0], 6)
    # survivors are column-exact (device path: f32 ring, exact copy)
    np.testing.assert_array_equal(after, before[:, col_map])
    # the controller still decides at the new width
    c = ctl.predict_cutoff()
    assert 1 <= c <= 6

    # grow back to 8: new columns seeded from the survivors' cluster mean
    grow_map = np.array([0, 1, 2, 3, 4, 5, -1, -1])
    ctl.resize(8, col_map=grow_map, model=_unfitted_model(8, rm))
    grown = ctl.window_array()
    np.testing.assert_array_equal(grown[:, :6], after)
    np.testing.assert_allclose(grown[:, 6], after.mean(axis=1), rtol=1e-6)
    np.testing.assert_allclose(grown[:, 7], grown[:, 6])


def test_static_cutoff_resize_keeps_explicit_cutoff_through_churn():
    ctl = StaticCutoffController(8, cutoff=7)
    ctl.resize(4)
    assert ctl.c == 4                   # clamped to the live width
    ctl.resize(8)
    assert ctl.c == 7                   # configured cutoff restored
    frac = StaticCutoffController(100)  # drop_frac mode rescales
    ctl_c = frac.c
    frac.resize(50)
    assert frac.c == max(1, int(round(50 * (1 - frac.drop_frac))))
    frac.resize(100)
    assert frac.c == ctl_c


@pytest.mark.parametrize("backend", ["device", "numpy"])
def test_window_array_empty_raises(fitted8, backend):
    """A cold controller must refuse to materialize a window — the
    checkpoint path skips persisting it rather than saving zeros."""
    rm, _ = fitted8
    ctl = CutoffController(rm, k_samples=8, seed=0, backend=backend)
    with pytest.raises(ValueError):
        ctl.window_array()


def test_numpy_window_stays_bounded(fitted8):
    rm, trace = fitted8
    ctl = CutoffController(rm, k_samples=8, seed=0, backend="numpy")
    ctl.seed_window(trace)
    for _ in range(30):
        ctl.predict_cutoff()
        ctl.observe(np.full(8, 1.0))
    assert len(ctl._window) <= ctl._cap + 1


def test_cutoff_controller_resize_requires_matching_model(fitted8):
    rm, trace = fitted8
    ctl = CutoffController(rm, k_samples=16, seed=0)
    ctl.seed_window(trace)
    with pytest.raises(ValueError, match="RuntimeModel of that width"):
        ctl.resize(6)


def test_elastic_resize_rejects_wrong_width_model(fitted8):
    rm, trace = fitted8
    ctl = ElasticController(rm, k_samples=16, seed=0)
    ctl.seed_window(trace[-40:])
    with pytest.raises(ValueError, match="width"):
        ctl.resize(6, model=rm)            # rm is still width 8


def test_elastic_async_refit_dropped_by_generation(fitted8):
    """A resize abandons an in-flight async refit without joining it;
    its late result is discarded by generation, never installed."""
    rm, trace = fitted8
    ctl = ElasticController(rm, k_samples=16, seed=0, refit_async=True)
    ctl.seed_window(trace[-40:])
    ctl.resize(6)
    assert ctl.mode == "fallback" and ctl._refit_job is None
    model6 = RuntimeModel(n_workers=6, lag=rm.lag, z_dim=rm.z_dim,
                          hidden=rm.hidden).init(0)
    model6.norm_scale = rm.norm_scale
    done = threading.Thread(target=lambda: None)
    done.start()
    done.join()
    # a finished fit from a PREVIOUS resize generation: stale, dropped
    ctl._refit_job = (done, {"model": model6}, ctl._resize_count - 1)
    ctl._poll_refit()
    assert ctl.mode == "fallback"
    # the same result at the CURRENT generation installs
    ctl._refit_job = (done, {"model": model6}, ctl._resize_count)
    ctl._poll_refit()
    assert ctl.mode == "dmm" and ctl._dmm.n == 6


def _finished_thread():
    t = threading.Thread(target=lambda: None)
    t.start()
    t.join()
    return t


def test_spawn_refit_captures_exception():
    """A fit that raises is captured in the result box and surfaced from
    the poll — never lost on the worker thread."""
    task = _spawn_refit(lambda: 1 / 0, 3)
    task[0].join()
    done, model, err = _poll_refit_task(task, 3, 8)
    assert done and model is None
    assert isinstance(err, ZeroDivisionError)
    # the SAME failure at a stale generation is discarded like a result
    done, model, err = _poll_refit_task(task, 4, 8)
    assert done and model is None and err is None


def test_elastic_refit_failure_retries_then_raises(fitted8, monkeypatch):
    """First async fit failure: logged, one retry scheduled with doubled
    fresh-observation backoff; second failure past the budget raises
    RefitError from the poll (the owner's thread, not the fit thread)."""
    rm, trace = fitted8
    ctl = ElasticController(rm, k_samples=16, seed=0, refit_async=True,
                            refit_fresh=2, refit_retries=1)
    ctl.seed_window(trace[-40:])
    ctl.resize(6)

    def boom(rows, n, seed):
        raise RuntimeError("ELBO diverged")

    monkeypatch.setattr(ctl, "_fit_model", boom)
    for _ in range(2):
        ctl.observe(np.ones(6))
    assert ctl._refit_job is not None      # spawned at refit_fresh
    ctl._refit_job[0].join()
    ctl.predict_cutoff()                   # failure #1: retry, no raise
    assert ctl.mode == "fallback"
    assert ctl._refit_failures == 1 and ctl._fresh == 0
    # backoff: refit_fresh observations are no longer enough to respawn
    for _ in range(2):
        ctl.observe(np.ones(6))
    assert ctl._refit_job is None
    for _ in range(2):
        ctl.observe(np.ones(6))
    assert ctl._refit_job is not None      # retry at 2x refit_fresh
    ctl._refit_job[0].join()
    with pytest.raises(RefitError, match="retry budget"):
        ctl.predict_cutoff()


def test_elastic_stale_refit_failure_burns_no_budget(fitted8):
    """An error from an ABANDONED generation (resize since spawn) is
    dropped exactly like a stale success — no retry burned, no raise."""
    rm, trace = fitted8
    ctl = ElasticController(rm, k_samples=16, seed=0, refit_async=True,
                            refit_retries=0)
    ctl.seed_window(trace[-40:])
    ctl.resize(6)
    ctl._refit_job = (_finished_thread(),
                      {"error": RuntimeError("boom")},
                      ctl._resize_count - 1)
    ctl._poll_refit()                      # would raise if not stale
    assert ctl._refit_failures == 0 and ctl.mode == "fallback"


# ---------------------------------------------------------------------------
# Satellite fixes: Elfving censoring, mixture variance.
# ---------------------------------------------------------------------------


def test_elfving_observe_imputes_censored_at_cutoff_time():
    ctl = ElfvingController(4, warmup=1)
    ctl.observe(np.array([1.0, 2.0, 777.0, 3.0]),
                np.array([True, True, False, True]))
    row = ctl.buf[-1]
    assert row.shape == (4,)                  # censored entry KEPT, imputed
    np.testing.assert_allclose(row, [1.0, 2.0, 3.0, 3.0])
    # full-sync observation unchanged
    ctl.observe(np.array([1.0, 2.0, 2.5, 3.0]))
    np.testing.assert_allclose(ctl.buf[-1], [1.0, 2.0, 2.5, 3.0])


def test_predictive_std_follows_mixture_variance_law(fitted8):
    rm, trace = fitted8
    ctl = CutoffController(rm, k_samples=32, seed=0, backend="numpy")
    ctl.seed_window(trace)
    window = ctl.window_array()
    ctl.predict_cutoff()
    _, mu, std = rm.predict_next(window, 32, seed=ctl.seed + ctl._step)
    want = np.sqrt(np.mean(std ** 2, axis=0) + mu.var(axis=0))
    np.testing.assert_allclose(ctl._pending_pred[1], want, rtol=1e-6)
    # guard: distinct from the old (wrong) E[std]^2 formula
    wrong = np.sqrt(np.mean(std, axis=0) ** 2 + mu.var(axis=0))
    assert not np.allclose(want, wrong)
    assert np.all(want >= wrong - 1e-12)      # Jensen: the fix widens sigma


# ---------------------------------------------------------------------------
# Trainer-level elastic plumbing.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_train():
    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step = jit_train_step(cfg, opt)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    return cfg, step, init_fn


def _trainer(cfg, step, init_fn, ctl, timer, n, *, batch=24, ckpt=None,
             ckpt_every=50, mask_agg="weights"):
    from repro.obs import ObsRun

    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                           global_batch=batch, seed=0)
    # every trainer records to its own in-memory obs run; the churn
    # acceptance test reads trajectories from the step streams
    tr = Trainer(cfg=cfg, step_fn=step, data=data, controller=ctl,
                 timer=timer, n_workers=n, mask_agg=mask_agg,
                 ckpt_dir=ckpt, ckpt_every=ckpt_every, obs=ObsRun())
    return tr.restore_or_init(init_fn)


def test_trainer_resize_rejects_non_divisible_batch(tiny_train):
    cfg, step, init_fn = tiny_train
    tr = _trainer(cfg, step, init_fn, FullSyncController(8), None, 8,
                  mask_agg="psum")
    with pytest.raises(ValueError, match="not divisible"):
        tr.resize(5)                           # 24 % 5 != 0
    tr.resize(6)                               # 24 % 6 == 0: fine
    assert tr.n_workers == 6 and tr.controller.n == 6


def test_trainer_mask_and_observe_agree_under_ties(tiny_train):
    """Under tied runtimes the old times<=iter_time mask marked MORE
    workers finished than the c-hot bit array the gradients used; the
    controller must see exactly the order[:c] selection."""
    cfg, step, init_fn = tiny_train

    observed = []

    class Rec(StaticCutoffController):
        def observe(self, times, finished_mask=None):
            observed.append(np.asarray(finished_mask, bool))

    timer = TraceReplay(np.ones((4, 8)))       # every runtime tied
    tr = _trainer(cfg, step, init_fn, Rec(8, cutoff=3), timer, 8)
    tr.run(4)
    for m in observed:
        assert m.sum() == 3                    # == c, never more


# ---------------------------------------------------------------------------
# The acceptance scenario: seeded 8 -> 6 -> 8 churn, elastic controller.
# ---------------------------------------------------------------------------

SHRINK_AT, RECOVER_AT, CHURN_STEPS = 15, 30, 45


def _churn_timer(seed):
    return ChurnSim(paper_cluster_158(seed, n_workers=8),
                    [ChurnEvent(step=SHRINK_AT, kill=(6, 7)),
                     ChurnEvent(step=RECOVER_AT, restore=(6, 7))])


def _elastic(rm, trace, **kw):
    ctl = ElasticController(rm, k_samples=32, seed=0, refit_steps=60,
                            refit_fresh=3, fallback_warmup=2, **kw)
    ctl.seed_window(trace[-60:])
    return ctl


def test_elastic_churn_beats_full_sync(tiny_train, fitted8):
    cfg, step, init_fn = tiny_train
    rm, trace = fitted8
    ctl = _elastic(rm, trace)
    tr_el = _trainer(cfg, step, init_fn, ctl, _churn_timer(9), 8)
    tr_el.run(CHURN_STEPS)
    widths = [h["n"] for h in tr_el.history]
    assert 6 in widths and widths[0] == 8 and widths[-1] == 8
    # across the run the DMM came back from the fallback at least once
    assert ctl.mode == "dmm"
    # cutoffs kept tracking the live width
    for h in tr_el.history:
        assert 1 <= h["c"] <= h["n"]

    tr_sync = _trainer(cfg, step, init_fn, FullSyncController(8),
                       _churn_timer(9), 8)
    tr_sync.run(CHURN_STEPS)
    # both trajectories come off the obs step streams (the one recorder)
    target = tr_sync.obs.steps.final_loss(window=3)
    t_el = clock_to_loss(tr_el.obs.steps, target)
    t_sync = clock_to_loss(tr_sync.obs.steps, target)
    assert t_el is not None
    assert t_sync is None or t_el < t_sync, (t_el, t_sync)


def test_restore_remaps_by_saved_membership_not_prefix(tiny_train, fitted8,
                                                       tmp_path):
    """A mid-churn checkpoint whose survivors are NOT a prefix (workers
    2,3 die) must restore by GLOBAL worker id: new column 2 is old
    worker 4's series, not old worker 2's."""
    cfg, step, init_fn = tiny_train
    rm, trace = fitted8
    d = str(tmp_path / "ck")
    ctl = _elastic(rm, trace)
    timer = ChurnSim(paper_cluster_158(13, n_workers=8),
                     [ChurnEvent(step=5, kill=(2, 3))])
    tr = _trainer(cfg, step, init_fn, ctl, timer, 8, ckpt=d, ckpt_every=8)
    tr.run(10)                    # ckpt at step 8: width 6, non-prefix set
    saved = store.restore_group(d, "ctl")
    assert saved["members"].tolist() == [0, 1, 4, 5, 6, 7]

    # restart controller carries a marker trace: column j holds value j
    ctl2 = ElasticController(rm, k_samples=32, seed=0, refit_steps=60,
                             refit_fresh=3, fallback_warmup=2)
    ctl2.seed_window(np.tile(np.arange(8.0), (rm.lag + 15, 1)))
    timer2 = ChurnSim(paper_cluster_158(13, n_workers=8),
                      [ChurnEvent(step=0, kill=(2, 3))])
    tr2 = _trainer(cfg, step, init_fn, ctl2, timer2, 8, ckpt=d,
                   ckpt_every=8)
    assert tr2.n_workers == 6
    assert tr2.members.tolist() == [0, 1, 4, 5, 6, 7]
    # marker rows (before the warm-restored tail) carry the survivors'
    # global columns — the prefix remap would leave [0, 1, 2, 3, 4, 5]
    np.testing.assert_allclose(ctl2._trace[0], [0, 1, 4, 5, 6, 7])
    np.testing.assert_allclose(ctl2.window_array(), saved["window"],
                               rtol=1e-7, atol=1e-9)


def test_mid_churn_checkpoint_restart_resumes_warm(tiny_train, fitted8,
                                                   tmp_path):
    cfg, step, init_fn = tiny_train
    rm, trace = fitted8
    d = str(tmp_path / "ck")
    ctl = _elastic(rm, trace)
    tr = _trainer(cfg, step, init_fn, ctl, _churn_timer(11), 8,
                  ckpt=d, ckpt_every=20)
    tr.run(25)                                  # ckpt at step 20: width 6
    saved = store.restore_group(d, "ctl")
    assert saved is not None and int(saved["n"]) == 6
    assert saved["members"].tolist() == [0, 1, 2, 3, 4, 5]
    assert saved["window"].shape[1] == 6

    # crash + restart: a fresh trainer at the original width adopts the
    # checkpoint's degraded membership and a WARM controller window
    ctl2 = _elastic(rm, trace)
    timer2 = _churn_timer(11)
    for _ in range(20):
        timer2.step()
    tr2 = _trainer(cfg, step, init_fn, ctl2, timer2, 8, ckpt=d,
                   ckpt_every=20)
    assert tr2.step == 20 and tr2.n_workers == 6
    assert ctl2.n == 6
    np.testing.assert_allclose(ctl2.window_array(), saved["window"],
                               rtol=1e-7, atol=1e-9)
    tr2.run(3)                                  # and it keeps stepping
    assert tr2.step == 23 and tr2.history[-1]["n"] == 6
