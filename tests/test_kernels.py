"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU) —
fixed cases + hypothesis shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_adam import fused_adam
from repro.kernels.masked_grad_agg import masked_grad_agg
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.kernels import ops

SETTINGS = dict(max_examples=8, deadline=None)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_flash_attention_basic(causal, window):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    want = ref.reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([1, 2]),
    s=st.sampled_from([128, 256, 384]),
    heads=st.sampled_from([(4, 4), (4, 2), (8, 1)]),
    hd=st.sampled_from([32, 64, 128]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    causal=st.booleans(),
)
def test_flash_attention_sweep(b, s, heads, hd, dtype, causal):
    H, KV = heads
    key = jax.random.PRNGKey(hash((b, s, H, KV, hd, causal)) % 2**31)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, KV, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, KV, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.reference_attention(q, k, v, causal=causal)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=atol, rtol=0.05)


def test_flash_matches_model_attention_core():
    """The kernel contract equals the model stack's attn_core path."""
    from repro.models.attention import attn_core
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    qpos = jnp.broadcast_to(jnp.arange(256)[None], (2, 256))
    core = attn_core(q, k, v, qpos, jnp.arange(256), causal=True, window=0)
    kern = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(core, kern, atol=3e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# mlstm chunk
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    s=st.sampled_from([128, 256]),
    chunk=st.sampled_from([32, 64, 128]),
    hd=st.sampled_from([16, 32, 64]),
    h=st.sampled_from([1, 2]),
)
def test_mlstm_chunk_sweep(s, chunk, hd, h):
    key = jax.random.PRNGKey(hash((s, chunk, hd, h)) % 2**31)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (2, s, h, hd)) * 0.5
    k = jax.random.normal(ks[1], (2, s, h, hd)) * 0.5
    v = jax.random.normal(ks[2], (2, s, h, hd))
    g = jax.nn.log_sigmoid(jax.random.normal(ks[3], (2, s, h)) + 3.0)
    i = jax.random.normal(ks[4], (2, s, h)) * 0.5
    out = mlstm_chunk(q, k, v, g, i, chunk=chunk, interpret=True)
    want = ref.reference_mlstm(q, k, v, g, i)
    np.testing.assert_allclose(out, want, atol=5e-4, rtol=5e-4)


def test_mlstm_kernel_matches_model_recurrence():
    from repro.models import ssm as S
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (1, 128, 2, 32)) * 0.5
    k = jax.random.normal(ks[1], (1, 128, 2, 32)) * 0.5
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    g = jax.nn.log_sigmoid(jax.random.normal(ks[3], (1, 128, 2)) + 3.0)
    i = jax.random.normal(ks[4], (1, 128, 2)) * 0.5
    kern = mlstm_chunk(q, k, v, g, i, chunk=64, interpret=True)
    model, _ = S.linear_recurrence(q, k, v, g, i, chunk=64, normalize=True)
    np.testing.assert_allclose(kern, model, atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# fused adam
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    shape=st.sampled_from([(8, 128), (16, 256), (8, 1024)]),
    wd=st.sampled_from([0.0, 0.01]),
    step=st.sampled_from([1, 100]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_fused_adam_sweep(shape, wd, step, dtype):
    key = jax.random.PRNGKey(hash((shape, wd, step)) % 2**31)
    ks = jax.random.split(key, 4)
    p = jax.random.normal(ks[0], shape).astype(dtype)
    g = jax.random.normal(ks[1], shape).astype(dtype)
    m = jax.random.normal(ks[2], shape) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], shape)) * 0.01
    sc = jnp.array([1e-3, 1 - 0.9 ** step, 1 - 0.999 ** step], jnp.float32)
    po, mo, vo = fused_adam(p, g, m, v, sc, wd=wd, interpret=True)
    pw, mw, vw = ref.reference_adam(p, g, m, v, sc, wd=wd)
    np.testing.assert_allclose(mo, mw, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(vo, vw, atol=1e-6, rtol=1e-5)
    np.testing.assert_allclose(po.astype(np.float32), pw.astype(np.float32),
                               atol=2e-3 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("wd", [0.0, 0.01])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_optim_adam_fused_matches_unfused(wd, backend, monkeypatch):
    """optim.adam(fused=True) — the kernel-backed optimizer — tracks the
    unfused reference over several steps, through both the pure-jnp
    fallback and the Pallas interpret path (pad plumbing included)."""
    from repro import optim
    monkeypatch.setattr(ops, "KERNEL_BACKEND", backend)
    key = jax.random.PRNGKey(11)
    ks = jax.random.split(key, 3)
    params = {"w": jax.random.normal(ks[0], (37, 5)),
              "b": jax.random.normal(ks[1], (13,)),
              "s": jax.random.normal(ks[2], (1,))}
    ref_opt = optim.adam(3e-3, weight_decay=wd)
    fus_opt = optim.adam(3e-3, weight_decay=wd, fused=True)
    p_ref, p_fus = params, params
    s_ref, s_fus = ref_opt.init(params), fus_opt.init(params)
    for i in range(3):
        grads = jax.tree.map(
            lambda p: 0.1 * jax.random.normal(jax.random.PRNGKey(i),
                                              p.shape), p_ref)
        u_ref, s_ref = ref_opt.update(grads, s_ref, p_ref)
        p_ref = optim.apply_updates(p_ref, u_ref)
        u_fus, s_fus = fus_opt.update(grads, s_fus, p_fus)
        p_fus = optim.apply_updates(p_fus, u_fus)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_fus)):
        np.testing.assert_allclose(a, b, atol=1e-6, rtol=1e-6)
    for a, b in zip(jax.tree.leaves(s_ref["m"]), jax.tree.leaves(s_fus["m"])):
        np.testing.assert_allclose(a, b.reshape(a.shape), atol=1e-6)
    for a, b in zip(jax.tree.leaves(s_ref["v"]), jax.tree.leaves(s_fus["v"])):
        np.testing.assert_allclose(a, b.reshape(a.shape), atol=1e-7)
    assert int(s_fus["step"]) == 3


def test_optim_adam_fused_jits_with_donation():
    """The fused optimizer composes with the donation-clean train-step jit
    pattern (state donated, params updated in place)."""
    from repro import optim
    import functools
    opt = optim.adam(1e-3, fused=True)
    params = {"w": jnp.ones((8, 16))}
    state = opt.init(params)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, state, grads):
        ups, state = opt.update(grads, state, params)
        return optim.apply_updates(params, ups), state

    grads = {"w": jnp.full((8, 16), 0.5)}
    p1, s1 = step(params, state, grads)
    assert int(s1["step"]) == 1   # read before s1 is donated away
    p2, _ = step(p1, s1, grads)
    assert np.all(np.isfinite(np.asarray(p2["w"])))


def test_adam_tree_wrapper_matches_optim():
    """ops.adam_update_tree (xla path) == repro.optim.adam update."""
    from repro import optim
    key = jax.random.PRNGKey(7)
    params = {"a": jax.random.normal(key, (37,)),
              "b": jax.random.normal(key, (5, 13))}
    grads = jax.tree.map(lambda x: x * 0.1, params)
    opt = optim.adam(1e-3)
    state = opt.init(params)
    ups, _ = opt.update(grads, state, params)
    want = optim.apply_updates(params, ups)
    m = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    got, _, _ = ops.adam_update_tree(params, grads, m, v,
                                     jnp.int32(0), 1e-3)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_allclose(a, b.reshape(a.shape), atol=1e-6)


# ---------------------------------------------------------------------------
# masked aggregation
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    w=st.sampled_from([4, 8, 16]),
    n=st.sampled_from([128, 384, 1024]),
    frac=st.floats(0.1, 1.0),
)
def test_masked_agg_sweep(w, n, frac):
    key = jax.random.PRNGKey(hash((w, n, int(frac * 100))) % 2**31)
    g = jax.random.normal(key, (w, n))
    rng = np.random.default_rng(0)
    mask = (rng.uniform(size=w) < frac).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    m = jnp.asarray(mask).reshape(w, 1)
    out = masked_grad_agg(g, m, interpret=True)
    want = ref.reference_masked_agg(g, m)
    np.testing.assert_allclose(out, want, atol=1e-5, rtol=1e-5)


def test_masked_agg_is_paper_update():
    """sum(bit*g)/c == the paper's Alg.1 line 29 for included workers."""
    g = jnp.arange(12.0).reshape(4, 3)
    mask = jnp.array([1.0, 0.0, 1.0, 0.0]).reshape(4, 1)
    out = ops.masked_aggregate(g, mask[:, 0])
    want = (g[0] + g[2]) / 2
    np.testing.assert_allclose(out, want)


@pytest.mark.parametrize("w", [2, 8, 158])
def test_masked_agg_kernel_worker_counts(w):
    """Interpret mode == jnp reference from 2 workers up to the paper's
    158-worker cluster."""
    key = jax.random.PRNGKey(w)
    g = jax.random.normal(key, (w, 256))
    mask = (jnp.arange(w) % 3 != 0).astype(jnp.float32).reshape(w, 1)
    out = masked_grad_agg(g, mask, interpret=True)
    want = ref.reference_masked_agg(g, mask)
    np.testing.assert_allclose(out, want, atol=1e-6, rtol=1e-6)


def test_masked_agg_kernel_all_zero_mask_clamps_c():
    """c = max(sum(bit), 1): an all-dropped step yields exact zeros, not
    NaNs."""
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    out = masked_grad_agg(g, jnp.zeros((8, 1)), interpret=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_masked_agg_kernel_bf16():
    g = jax.random.normal(jax.random.PRNGKey(1), (8, 384)).astype(
        jnp.bfloat16)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 1], jnp.float32).reshape(8, 1)
    out = masked_grad_agg(g, mask, interpret=True)
    want = ref.reference_masked_agg(g, mask)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(np.float32),
                               want.astype(np.float32), atol=1e-2, rtol=1e-2)


@pytest.mark.parametrize("n", [1, 100, 333, 1000])
def test_masked_agg_ops_padding_path(n, monkeypatch):
    """Non-multiple-of-128 N goes through the ops.py pad plumbing — both
    the single-block (pad to 128) and tiled (pad to block) regimes."""
    monkeypatch.setattr(ops, "KERNEL_BACKEND", "interpret")
    key = jax.random.PRNGKey(n)
    g = jax.random.normal(key, (4, n))
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    out = ops.masked_aggregate(g, mask, block=256)
    want = ref.reference_masked_agg(g, mask.reshape(4, 1))[0]
    np.testing.assert_allclose(out, want, atol=1e-6, rtol=1e-6)


def test_masked_aggregate_tree_kernel_matches_local(monkeypatch):
    """The fused flatten+concat tree combine (interpret kernel) == the
    pure-jnp LOCAL reference on a ragged pytree of leaf shapes."""
    from repro.core import aggregation
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 4)
    grads = {"w": jax.random.normal(ks[0], (4, 3, 5)),
             "b": jax.random.normal(ks[1], (4, 7)),
             "scale": jax.random.normal(ks[2], (4, 1)),
             "emb": jax.random.normal(ks[3], (4, 11, 13))}
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    want = aggregation.masked_mean_local(grads, mask)
    monkeypatch.setattr(ops, "KERNEL_BACKEND", "interpret")
    got = ops.masked_aggregate_tree(grads, mask)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)
