"""Multi-tenant PSServer == looped single-job controllers.

The tentpole contract of the batched decision path: batching amortizes
dispatch, it NEVER changes the decision.  A PSServer with J=1 must
produce the IDENTICAL cutoff sequence as a bare
``CutoffController(backend="device")`` over a seeded paper_cluster_158
run, and J>1 jobs must match J looped single-job controllers cutoff-
for-cutoff with allclose windows.  Plus bit-level parity for the
host-built key stacks the batched path feeds the vmapped threefry, and
the registry/elasticity contracts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.simulator import paper_cluster_158
from repro.core.controller import (CutoffController, RefitError,
                                   _batched_impute_keys, _impute_key,
                                   stacked_prng_keys)
from repro.core.cutoff import order_stats
from repro.core.runtime_model.api import RuntimeModel, stack_models
from repro.ps import PSServer


# ---------------------------------------------------------------------------
# Key-stack bit parity (the host-built fast path must equal PRNGKey).
# ---------------------------------------------------------------------------


def test_stacked_prng_keys_match_prngkey():
    seeds = [0, 1, 7, 123456789, 2**31, 2**33 + 5]
    stack = np.asarray(stacked_prng_keys(seeds))
    for row, s in zip(stack, seeds):
        np.testing.assert_array_equal(row, np.asarray(jax.random.PRNGKey(s)))


def test_batched_impute_keys_match_single():
    seeds, steps = [3, 9, 250], [5, 11, 40]
    base = stacked_prng_keys([s + 1_000_003 for s in seeds])
    got = np.asarray(_batched_impute_keys(
        base, jnp.asarray(steps, jnp.uint32)))
    want = np.stack([np.asarray(_impute_key(s, t))
                     for s, t in zip(seeds, steps)])
    np.testing.assert_array_equal(got, want)


def test_stack_models_rejects_mixed_shapes():
    a = RuntimeModel(n_workers=8, lag=10).init(0)
    b = RuntimeModel(n_workers=6, lag=10).init(0)
    with pytest.raises(ValueError):
        stack_models([a, b])
    params, scales = stack_models([a, a])
    assert scales.shape == (2,)
    leaf = jax.tree.leaves(params)[0]
    assert leaf.shape[0] == 2


# ---------------------------------------------------------------------------
# Parity fixtures.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted_16():
    trace = paper_cluster_158(seed=0, n_workers=16).run(60)
    rm = RuntimeModel(n_workers=16, lag=10).init(0)
    rm.fit(trace, steps=60, batch=8, seed=0)
    return rm, trace


@pytest.fixture(scope="module")
def fitted_158():
    sim = paper_cluster_158(seed=0)
    trace = sim.run(60)
    rm = RuntimeModel(n_workers=158, lag=20).init(0)
    rm.fit(trace, steps=60, batch=8, seed=0)
    return rm, trace


def _drive(controller, sim, steps, prefetch=None, flush=None):
    """Standard predict/observe cycle; returns the cutoff sequence."""
    seq = []
    for _ in range(steps):
        if prefetch is not None:
            prefetch()
        c = controller.predict_cutoff()
        times = sim.step()
        it = order_stats.iter_time(times, c)
        controller.observe(times, times <= it + 1e-12)
        if flush is not None:
            flush()
        seq.append(int(c))
    return seq


def test_psserver_j1_identical_cutoffs_158(fitted_158):
    """Acceptance criterion: PSServer at J=1 is bit-exact on the cutoff
    sequence vs a bare device controller over 100 paper-cluster steps."""
    rm, trace = fitted_158
    ref = CutoffController(rm, k_samples=32, seed=0, backend="device")
    ref.seed_window(trace)
    srv = PSServer()
    h = srv.admit("job0", rm, window=trace, k_samples=32, seed=0)
    np.testing.assert_allclose(h.window_array(), ref.window_array(),
                               rtol=0, atol=0)

    sim_a = paper_cluster_158(seed=7)
    sim_b = paper_cluster_158(seed=7)
    cutoffs_ref, censored = [], 0
    for step in range(100):
        c_ref = ref.predict_cutoff()
        c_ps = h.predict_cutoff()
        assert c_ref == c_ps, (step, c_ref, c_ps)
        cutoffs_ref.append(c_ref)
        times = sim_a.step()
        times_b = sim_b.step()
        np.testing.assert_array_equal(times, times_b)
        it = order_stats.iter_time(times, c_ref)
        mask = times <= it + 1e-12
        censored += int(not mask.all())
        ref.observe(times, mask)
        h.observe(times_b, mask)
        srv.flush()
    # the run must exercise the fused imputation and a dynamic cutoff for
    # the parity to mean anything
    assert censored >= 50
    assert len(set(cutoffs_ref)) > 1
    # windows agree to f32/vmap reassociation noise
    np.testing.assert_allclose(h.window_array(), ref.window_array(),
                               rtol=1e-4, atol=1e-4)


def test_psserver_j3_matches_looped_controllers(fitted_16):
    """J jobs through one batched dispatch == J looped single-job
    controllers, cutoff-for-cutoff, with allclose windows — and the
    server actually batches (dispatch count ~1/tick, not J/tick)."""
    rm, _ = fitted_16
    J, steps = 3, 50
    srv = PSServer()
    refs, handles = [], []
    for j in range(J):
        tr = paper_cluster_158(seed=100 + j, n_workers=16).run(40)
        ref = CutoffController(rm, k_samples=16, seed=7 * j,
                               backend="device")
        ref.seed_window(tr)
        refs.append(ref)
        handles.append(srv.admit(f"job{j}", rm, window=tr, k_samples=16,
                                 seed=7 * j))
    sims_a = [paper_cluster_158(seed=200 + j, n_workers=16)
              for j in range(J)]
    sims_b = [paper_cluster_158(seed=200 + j, n_workers=16)
              for j in range(J)]
    d0 = srv.dispatches
    for step in range(steps):
        srv.prefetch()
        for j in range(J):
            c_ref = refs[j].predict_cutoff()
            c_ps = handles[j].predict_cutoff()
            assert c_ref == c_ps, (step, j, c_ref, c_ps)
            t = sims_a[j].step()
            it = order_stats.iter_time(t, c_ref)
            mask = t <= it + 1e-12
            refs[j].observe(t, mask)
            handles[j].observe(sims_b[j].step(), mask)
        srv.flush()
    for j in range(J):
        np.testing.assert_allclose(handles[j].window_array(),
                                   refs[j].window_array(),
                                   rtol=1e-4, atol=1e-4)
    # one batched dispatch per tick in steady state (plus the warm-up
    # prefetch and occasional plain/censored mode splits), not J per tick
    assert srv.dispatches - d0 <= steps + 5, (srv.dispatches - d0, steps)


def test_psserver_deterministic(fitted_16):
    rm, trace = fitted_16
    runs = []
    for _ in range(2):
        srv = PSServer()
        h = srv.admit("a", rm, window=trace, k_samples=16, seed=3)
        runs.append(_drive(h, paper_cluster_158(seed=11, n_workers=16), 20,
                           prefetch=srv.prefetch, flush=srv.flush))
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# Registry / elasticity / checkpoint contracts.
# ---------------------------------------------------------------------------


def test_registry_admission_contracts(fitted_16):
    rm, trace = fitted_16
    srv = PSServer()
    srv.admit("a", rm, window=trace, seed=0)
    with pytest.raises(ValueError):
        srv.admit("a", rm)                       # duplicate id
    with pytest.raises(ValueError):
        srv.admit("b", rm, members=np.arange(4))  # wrong membership width
    with pytest.raises(ValueError):
        srv.admit("c", RuntimeModel(n_workers=16, lag=10))  # unfitted
    assert srv.registry.ids() == ["a"]
    out = srv.evict("a")
    assert out["window"].shape[1] == 16
    assert "a" not in srv.registry


def test_mixed_architectures_bucket_separately():
    """Two same-width jobs with different DMM architectures cannot share
    a param stack — the bucket signature must split them, not crash the
    shared dispatch."""
    trace = paper_cluster_158(seed=0, n_workers=8).run(20)
    a = RuntimeModel(n_workers=8, lag=5, z_dim=8).init(0)
    b = RuntimeModel(n_workers=8, lag=5, z_dim=16).init(0)
    for rm in (a, b):
        rm.norm_scale = float(2.0 * trace[:6].mean())
    srv = PSServer()
    ha = srv.admit("a", a, window=trace, k_samples=8, seed=0)
    hb = srv.admit("b", b, window=trace, k_samples=8, seed=1)
    assert (srv.registry["a"].bucket_sig != srv.registry["b"].bucket_sig)
    for h in (ha, hb):
        c = h.predict_cutoff()
        assert 1 <= c <= 8
        times = paper_cluster_158(seed=3, n_workers=8).step()
        h.observe(times, times <= np.sort(times)[c - 1] + 1e-12)
    assert srv.flush() == 2          # one dispatch per architecture


def test_observe_width_is_strict(fitted_16):
    rm, trace = fitted_16
    srv = PSServer()
    h = srv.admit("a", rm, window=trace, seed=0)
    h.predict_cutoff()
    with pytest.raises(ValueError):
        h.observe(np.ones(12))


def test_resize_without_model_degrades_then_refits(fitted_16):
    rm, trace = fitted_16
    srv = PSServer(refit_steps=30, refit_fresh=3)
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0)
    win_before = h.window_array()
    h.resize(12, col_map=np.arange(12))
    assert h.mode == "fallback" and h.n == 12
    # survivors carried over column-exactly into the remapped trace
    np.testing.assert_allclose(h.window_array()[-win_before.shape[0]:],
                               win_before[:, :12], rtol=1e-6, atol=1e-6)
    seq = _drive(h, paper_cluster_158(seed=6, n_workers=12), 25,
                 flush=srv.flush)
    assert all(1 <= c <= 12 for c in seq)
    assert h.mode == "dmm", "refit should have rejoined the batched path"
    assert h.job.model.n_workers == 12


def test_ps_refit_failure_retries_with_backoff_then_recovers(fitted_16,
                                                             monkeypatch):
    """A failed async refit is logged and retried once the doubled
    fresh-row backoff is met; a later success clears the failure count
    and rejoins the batched path."""
    rm, trace = fitted_16
    srv = PSServer(refit_steps=30, refit_fresh=3, refit_async=True,
                   refit_retries=1)
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0)
    h.resize(12, col_map=np.arange(12))
    real, calls = srv._fit_model, {"n": 0}

    def flaky(*a, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("ELBO diverged")
        return real(*a, **kw)

    monkeypatch.setattr(srv, "_fit_model", flaky)
    _drive(h, paper_cluster_158(seed=6, n_workers=12), 3, flush=srv.flush)
    srv.wait_refits()                     # first fit fails: logged only
    assert h.mode == "fallback" and h.job.refit_failures == 1
    _drive(h, paper_cluster_158(seed=7, n_workers=12), 3, flush=srv.flush)
    assert h.job.refit_task is None       # 3 fresh < 6 needed under backoff
    _drive(h, paper_cluster_158(seed=8, n_workers=12), 3, flush=srv.flush)
    srv.wait_refits()                     # retry spawned at 2x fresh, wins
    assert h.mode == "dmm" and h.job.refit_failures == 0
    assert calls["n"] == 2


def test_ps_refit_failure_past_budget_raises_naming_job(fitted_16,
                                                        monkeypatch):
    """Past the retry budget the failure surfaces as RefitError naming
    the job — from the server's poll, never lost on the fit thread."""
    rm, trace = fitted_16
    srv = PSServer(refit_steps=30, refit_fresh=2, refit_async=True,
                   refit_retries=0)
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0)
    h.resize(12, col_map=np.arange(12))

    def boom(*a, **kw):
        raise RuntimeError("ELBO diverged")

    monkeypatch.setattr(srv, "_fit_model", boom)
    _drive(h, paper_cluster_158(seed=6, n_workers=12), 2, flush=srv.flush)
    with pytest.raises(RefitError, match="job 'a'"):
        srv.wait_refits()


def test_resize_same_width_is_a_noop(fitted_16):
    """Re-asserting the current width (a reconciliation loop's idempotent
    call) must not degrade a healthy DMM job — the ElasticController
    no-op guard, mirrored."""
    rm, trace = fitted_16
    srv = PSServer()
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0,
                  members=np.arange(30, 46))
    h.resize(16)
    assert h.mode == "dmm"
    assert h.job.model is rm
    np.testing.assert_array_equal(h.job.members, np.arange(30, 46))


def test_resize_with_model_stays_on_dmm_path(fitted_16):
    rm, trace = fitted_16
    rm12 = RuntimeModel(n_workers=12, lag=10).init(1)
    rm12.norm_scale = rm.norm_scale
    srv = PSServer()
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0)
    h.resize(12, col_map=np.arange(12), model=rm12)
    assert h.mode == "dmm" and h.n == 12
    with pytest.raises(ValueError):
        h.resize(10, model=rm12)                 # wrong-width model
    seq = _drive(h, paper_cluster_158(seed=6, n_workers=12), 5,
                 flush=srv.flush)
    assert all(1 <= c <= 12 for c in seq)


def test_checkpoint_group_roundtrip(fitted_16):
    rm, trace = fitted_16
    srv = PSServer()
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0,
                  members=np.arange(30, 46))
    _drive(h, paper_cluster_158(seed=5, n_workers=16), 4, flush=srv.flush)
    grp = srv.checkpoint_groups()["ps/a"]
    assert int(grp["n"]) == 16 and int(grp["step"]) == 4
    np.testing.assert_array_equal(grp["members"], np.arange(30, 46))
    # restore into a fresh server: window warm, step continues
    srv2 = PSServer()
    h2 = srv2.admit("a", rm, k_samples=16, seed=0)
    h2.seed_window(grp["window"])
    h2._step = int(grp["step"])
    np.testing.assert_allclose(h2.window_array(), h.window_array(),
                               rtol=1e-6, atol=1e-6)
    # both servers produce the same next decision from the same state
    assert h2.predict_cutoff() == h.predict_cutoff()


# ---------------------------------------------------------------------------
# Ragged mixed-width dispatch (the pad-to-bucket tentpole).
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fitted_mixed():
    """Three fitted DMMs at DIFFERENT worker widths but the same decision
    architecture (lag/z_dim/hidden/k) — the ragged-bucket case."""
    out = []
    for n in (16, 10, 6):
        trace = paper_cluster_158(seed=n, n_workers=n).run(40)
        rm = RuntimeModel(n_workers=n, lag=10).init(0)
        rm.fit(trace, steps=50, batch=8, seed=0)
        out.append((rm, trace))
    return out


def test_ragged_mixed_widths_one_bucket_one_dispatch(fitted_mixed):
    """The tentpole acceptance: jobs at widths 16/10/6 share ONE padded
    bucket and ONE vmapped dispatch per tick, and every job's cutoff
    sequence is identical to its own single-job device controller —
    padding amortizes dispatch, it never changes the decision."""
    J = len(fitted_mixed)
    srv = PSServer()
    refs, handles = [], []
    for j, (rm, tr) in enumerate(fitted_mixed):
        ref = CutoffController(rm, k_samples=16, seed=11 * j,
                               backend="device")
        ref.seed_window(tr)
        refs.append(ref)
        handles.append(srv.admit(f"job{j}", rm, window=tr, k_samples=16,
                                 seed=11 * j))
    assert len({srv.registry[f"job{j}"].bucket_sig
                for j in range(J)}) == 1, "mixed widths must share a bucket"
    widths = [rm.n_workers for rm, _ in fitted_mixed]
    sims_a = [paper_cluster_158(seed=300 + j, n_workers=w)
              for j, w in enumerate(widths)]
    sims_b = [paper_cluster_158(seed=300 + j, n_workers=w)
              for j, w in enumerate(widths)]
    censored = 0
    for step in range(40):
        srv.prefetch()
        for j in range(J):
            c_ref = refs[j].predict_cutoff()
            c_ps = handles[j].predict_cutoff()
            assert c_ref == c_ps, (step, j, c_ref, c_ps)
            t = sims_a[j].step()
            it = order_stats.iter_time(t, c_ref)
            mask = t <= it + 1e-12
            censored += int(not mask.all())
            refs[j].observe(t, mask)
            handles[j].observe(sims_b[j].step(), mask)
        assert srv.flush() == 1, step   # the whole ragged mix: ONE dispatch
    assert censored > 0         # the run exercised the censored path
    for j in range(J):
        np.testing.assert_allclose(handles[j].window_array(),
                                   refs[j].window_array(),
                                   rtol=1e-4, atol=1e-4)


def test_ragged_bucket_repacks_on_widest_evict(fitted_mixed):
    """Evicting the widest job must shrink the bucket's pad width so the
    survivors stop paying for the departed job's columns — and the
    survivors' decisions keep matching their references across the
    repack."""
    srv = PSServer()
    handles = []
    for j, (rm, tr) in enumerate(fitted_mixed):
        handles.append(srv.admit(f"job{j}", rm, window=tr, k_samples=16,
                                 seed=11 * j))
    sig = srv.registry["job1"].bucket_sig
    assert srv._buckets[sig].n_pad == 16
    srv.evict("job0")                    # the width-16 job
    assert srv._buckets[sig].n_pad == 10
    rm1, _ = fitted_mixed[1]
    ref = CutoffController(rm1, k_samples=16, seed=11, backend="device")
    ref.seed_window(np.asarray(handles[1].window_array()))
    sim = paper_cluster_158(seed=42, n_workers=10)
    for step in range(10):
        c_ref = ref.predict_cutoff()
        c_ps = handles[1].predict_cutoff()
        assert c_ref == c_ps, (step, c_ref, c_ps)
        t = sim.step()
        it = order_stats.iter_time(t, c_ref)
        mask = t <= it + 1e-12
        ref.observe(t, mask)
        handles[1].observe(t.copy(), mask)
        srv.flush()


# ---------------------------------------------------------------------------
# Observe-path regressions (all-False mask, width-0 members).
# ---------------------------------------------------------------------------


def test_observe_all_false_mask_is_rejected(fitted_16):
    """A step with zero finished workers has no observed cutoff time to
    impute against; the old path fell through and polluted the refit
    trace with fully-censored times as if observed."""
    rm, trace = fitted_16
    srv = PSServer()
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0)
    h.predict_cutoff()
    before = np.asarray(h.window_array()).copy()
    trace_len = len(h.job.trace)
    with pytest.raises(ValueError, match="all-False"):
        h.observe(np.ones(16), np.zeros(16, dtype=bool))
    # the rejected step mutated nothing
    np.testing.assert_array_equal(h.window_array(), before)
    assert len(h.job.trace) == trace_len
    # and the job is still serviceable
    t = paper_cluster_158(seed=2, n_workers=16).step()
    h.observe(t, t <= np.sort(t)[7] + 1e-12)
    assert srv.flush() == 1


def test_resized_members_width0_is_a_clear_error():
    with pytest.raises(ValueError, match="width-0"):
        PSServer._resized_members(np.array([], dtype=int), 4, None, None)
    # explicit members always work, including from width 0
    got = PSServer._resized_members(np.array([], dtype=int), 3,
                                    None, np.array([7, 8, 9]))
    np.testing.assert_array_equal(got, [7, 8, 9])


# ---------------------------------------------------------------------------
# Async refit: a tick during an active refit never blocks on model.fit.
# ---------------------------------------------------------------------------


def test_async_refit_never_blocks_a_tick(fitted_16, monkeypatch):
    import threading
    rm, trace = fitted_16
    srv = PSServer(refit_steps=5, refit_fresh=2, refit_async=True)
    ha = srv.admit("a", rm, window=trace, k_samples=16, seed=0)
    hb = srv.admit("b", rm, window=trace, k_samples=16, seed=1)
    gate = threading.Event()
    real_fit = RuntimeModel.fit

    def gated_fit(self, *args, **kwargs):
        gate.wait(timeout=60)
        return real_fit(self, *args, **kwargs)

    monkeypatch.setattr(RuntimeModel, "fit", gated_fit)
    hb.resize(12, col_map=np.arange(12))
    assert hb.mode == "fallback"
    sim_a = paper_cluster_158(seed=6, n_workers=16)
    sim_b = paper_cluster_158(seed=7, n_workers=12)
    # tick both jobs well past the refit trigger while the fit thread is
    # gated shut: every tick must complete without blocking on the fit
    for step in range(12):
        for h, sim in ((ha, sim_a), (hb, sim_b)):
            c = h.predict_cutoff()
            t = sim.step()
            it = order_stats.iter_time(t, c)
            h.observe(t, t <= it + 1e-12)
        srv.flush()
    task = srv.registry["b"].refit_task
    assert task is not None and task[0].is_alive(), \
        "the refit should still be running in the background"
    assert hb.mode == "fallback"     # stale result never pre-installed
    gate.set()
    srv.wait_refits()
    assert hb.mode == "dmm" and hb.job.model.n_workers == 12
    # the healthy wide job never lost its model to b's refit churn
    assert ha.mode == "dmm" and ha.job.model is rm


def test_predicted_iter_time_matches_samples(fitted_16):
    """The scheduler's ranking key must equal E[x_(c)] of the decision's
    own sample cloud."""
    rm, trace = fitted_16
    srv = PSServer()
    h = srv.admit("a", rm, window=trace, k_samples=16, seed=0)
    c = h.predict_cutoff()
    t = h.predicted_iter_time()
    samples = np.asarray(h.job.pending_pred[2][h.job.pending_pred[3]])
    want = float(np.sort(samples, axis=1)[:, c - 1].mean())
    np.testing.assert_allclose(t, want, rtol=1e-5)
