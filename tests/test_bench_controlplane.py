"""BENCH_controlplane.json schema guard.

Runs ``benchmarks.controlplane_bench.bench_controlplane`` at quick size
and asserts the machine-readable output keeps the
``bench_controlplane/v1`` contract — including the two hard gates
``scripts/ci.sh --bench`` pins: detection within deadline + 1 tick, and
supervised steps-lost strictly below the unsupervised baseline.
"""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

DETECTION_KEYS = ("n_workers", "ticks", "dead_after", "suspect_after",
                  "n_faults", "n_detected", "max_detection_ticks",
                  "mean_detection_ticks", "restarts", "evicted",
                  "us_per_tick")
RECOVERY_KEYS = ("n_workers", "steps", "n_faults", "n_detected",
                 "max_detection_ticks", "mean_recovery_ticks",
                 "restarts", "failed_restarts", "evicted",
                 "widths_seen", "steps_lost", "clock", "timeout_steps",
                 "throughput_retained", "scripted_replay_match")


@pytest.fixture(scope="module")
def bench_json(tmp_path_factory):
    from benchmarks.controlplane_bench import bench_controlplane

    out = tmp_path_factory.mktemp("bench") / "BENCH_controlplane.json"
    bench_controlplane(quick=True, out_path=str(out))
    with open(out) as f:
        return json.load(f)


def _check_payload(data):
    assert data["schema"] == "bench_controlplane/v1"
    det, rec = data["detection"], data["recovery"]
    for key in DETECTION_KEYS:
        assert key in det, key
    for key in RECOVERY_KEYS:
        assert key in rec, key
    # every storm fault is a crash or hang: all must be detected, and
    # never later than the heartbeat deadline + 1 tick
    assert det["n_detected"] == det["n_faults"] > 0
    assert 1 <= det["max_detection_ticks"] <= det["dead_after"] + 1
    assert det["us_per_tick"] > 0
    assert rec["n_detected"] == rec["n_faults"] == 2
    assert rec["max_detection_ticks"] <= det["dead_after"] + 1
    # the supervisor restarts what it kills: strictly fewer worker-steps
    # lost than the same storm with nobody watching
    lost = rec["steps_lost"]
    assert 0 < lost["supervised"] < lost["unsupervised"]
    assert rec["restarts"] >= 2 and rec["failed_restarts"] >= 1
    assert rec["evicted"] == []
    assert rec["scripted_replay_match"] is True
    assert rec["throughput_retained"] > 0
    assert rec["clock"]["fault_free"] > 0


def test_bench_controlplane_schema(bench_json):
    _check_payload(bench_json)
    assert bench_json["quick"] is True


def test_committed_bench_controlplane_matches_schema():
    """The checked-in BENCH_controlplane.json must exist and satisfy the
    same contract the CI gate re-derives from a fresh run."""
    path = (Path(__file__).resolve().parent.parent
            / "BENCH_controlplane.json")
    assert path.exists(), "BENCH_controlplane.json not committed"
    with open(path) as f:
        _check_payload(json.load(f))
