"""Obs spine contracts: bit-exactness, ring drain, spans, streams, CLI.

The load-bearing promise of ``repro.obs`` is that attaching it changes
NOTHING: a seeded Trainer run and a J=3 PSServer run must produce
bit-identical losses and cutoff sequences with obs on vs off.  Around
that sit the mechanism contracts — ring overflow drops oldest and is
counted, spans nest lexically and export as Chrome trace, the JSONL
streams keep the ``controlplane.events`` monotone-seq / torn-tail
conventions, and the CLI renders a run from artifacts alone.
"""
import json

import numpy as np
import pytest

from repro.cluster.simulator import ClusterSim, paper_cluster_158
from repro.core.controller import CutoffController
from repro.core.cutoff import order_stats
from repro.core.runtime_model.api import RuntimeModel
from repro.obs import ObsRun
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import OBS_KINDS, ObsLog, Tracer, chrome_trace
from repro.ps import PSServer


# ---------------------------------------------------------------------------
# Metric rings: drain contract.
# ---------------------------------------------------------------------------


def test_ring_drain_returns_pushed_rows_oldest_first():
    reg = MetricsRegistry()
    ring = reg.ring("r", ("x", "y"), cap=8)
    for i in range(5):
        ring.push((float(i), float(10 * i)))
    p = ring.drain()
    assert p["dropped"] == 0 and p["pushed"] == 5
    np.testing.assert_array_equal(
        np.asarray(p["rows"])[:, 0], [0.0, 1.0, 2.0, 3.0, 4.0])
    # nothing new since: drain is None, not a repeat
    assert ring.drain() is None
    ring.push((99.0, 0.0))
    assert np.asarray(ring.drain()["rows"])[:, 0] == [99.0]


def test_ring_overflow_drops_oldest_and_counts():
    ring = MetricsRegistry().ring("r", ("v",), cap=4)
    for i in range(11):
        ring.push((float(i),))
    p = ring.drain()
    # the ring keeps the most recent cap rows; the 7 oldest are dropped
    # and the drop is COUNTED — truncation is never silent
    assert p["dropped"] == 7
    np.testing.assert_array_equal(np.asarray(p["rows"])[:, 0],
                                  [7.0, 8.0, 9.0, 10.0])
    assert ring.drain() is None


def test_ring_rejects_arity_and_column_drift():
    reg = MetricsRegistry()
    ring = reg.ring("r", ("a", "b"))
    with pytest.raises(ValueError, match="wants 2 values"):
        ring.push((1.0,))
    with pytest.raises(ValueError, match="re-registered"):
        reg.ring("r", ("a", "c"))


# ---------------------------------------------------------------------------
# Bit-exactness: obs attached changes nothing.
# ---------------------------------------------------------------------------


def _scale_model(n, trace, seed=0):
    rm = RuntimeModel(n_workers=n, lag=10).init(seed)
    rm.norm_scale = float(2.0 * trace[:21].mean())
    return rm


_CACHE = {}


def _run_trainer(obs, steps=50, n=8):
    import jax

    from repro import optim
    from repro.configs.base import bench_tiny_config
    from repro.launch.train import Trainer, jit_train_step
    from repro.models import model as M

    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    if "step_fn" not in _CACHE:                # share one compile cache
        _CACHE["step_fn"] = jit_train_step(cfg, opt)
    step_fn = _CACHE["step_fn"]
    trace = paper_cluster_158(seed=0, n_workers=n).run(60)
    ctl = CutoffController(_scale_model(n, trace), k_samples=16, seed=0)
    ctl.seed_window(trace)
    from repro.data.pipeline import SyntheticTokens
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                           global_batch=n * 3, seed=0)
    tr = Trainer(cfg=cfg, step_fn=step_fn, data=data,
                 controller=obs.wrap(ctl, policy="dmm") if obs else ctl,
                 timer=ClusterSim(n_workers=n, n_nodes=2, seed=5),
                 n_workers=n, metrics_every=7, obs=obs, name="dmm")

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    tr.restore_or_init(init_fn)
    tr.run(steps)
    return tr


def test_trainer_bit_exact_with_obs_attached():
    """Seeded 50-step run: identical losses AND cutoff sequences with the
    full spine on (spans + ring pushes + quality wrapper) vs bare."""
    bare = _run_trainer(None)
    obs = ObsRun()
    inst = _run_trainer(obs)
    assert [h["c"] for h in inst.history] == [h["c"] for h in bare.history]
    assert ([h["loss"] for h in inst.history]
            == [h["loss"] for h in bare.history])
    # and the spine actually recorded: the step stream mirrors history,
    # every decision was scored, the trainer ring drained its pushes
    assert len(obs.steps) == len(bare.history) == 50
    assert len(obs.decisions.records) == 50
    names = {s["name"] for s in obs.trace.spans}
    assert {"trainer.step", "controller.predict_cutoff", "train.dispatch",
            "controller.observe", "obs.drain"} <= names
    assert obs.metrics.ring("trainer[dmm]",
                            ("loss", "gnorm", "c", "iter_time")).pushed == 50


def _drive_ps(obs, J=3, steps=25, n=8):
    trace = paper_cluster_158(seed=0, n_workers=n).run(60)
    rm = _scale_model(n, trace)
    srv = PSServer(obs=obs)
    ctls = []
    for j in range(J):
        h = srv.admit(f"job{j}", rm,
                      window=paper_cluster_158(seed=30 + j,
                                               n_workers=n).run(40),
                      k_samples=16, seed=7 * j)
        ctls.append(obs.wrap(h, policy=f"job{j}") if obs else h)
    sims = [paper_cluster_158(seed=50 + j, n_workers=n) for j in range(J)]
    seqs = [[] for _ in range(J)]
    for _ in range(steps):
        for j in range(J):
            c = ctls[j].predict_cutoff()
            times = sims[j].step()
            it = order_stats.iter_time(times, c)
            ctls[j].observe(times, times <= it + 1e-12)
            seqs[j].append(int(c))
        srv.flush()
    if obs is not None:
        obs.drain()
    return seqs


def test_psserver_bit_exact_with_obs_attached():
    """J=3 batched server: identical cutoff sequences with flush spans +
    refit counters + per-job quality wrappers on vs off."""
    bare = _drive_ps(None)
    obs = ObsRun()
    inst = _drive_ps(obs)
    assert inst == bare
    assert len(set(map(tuple, bare))) == 3     # three distinct jobs
    # flush spans recorded, dispatch nested strictly inside flush
    by_name = {}
    for s in obs.trace.spans:
        by_name.setdefault(s["name"], []).append(s)
    assert len(by_name["ps.flush"]) == 25
    assert by_name["ps.dispatch"]
    flush_depth = by_name["ps.flush"][0]["depth"]
    assert all(s["depth"] == flush_depth + 1
               for s in by_name["ps.dispatch"])
    # every decision scored with the shared schema, lazy samples included
    recs = obs.decisions.records
    assert len(recs) == 3 * 25
    assert {r["policy"] for r in recs} == {"job0", "job1", "job2"}
    assert all(r["cov50"] is not None for r in recs)


# ---------------------------------------------------------------------------
# Spans + chrome export.
# ---------------------------------------------------------------------------


def test_span_nesting_and_chrome_export():
    tracer = Tracer()
    with tracer.span("outer", track="t", tick=3):
        with tracer.span("inner", track="t", step=9):
            pass
    inner, outer = tracer.spans            # completion order: inner first
    assert (outer["name"], outer["depth"]) == ("outer", 1)
    assert (inner["name"], inner["depth"]) == ("inner", 2)
    # attribution rides in a nested dict: component clocks named
    # tick/step can never collide with the EventLog wire fields
    assert outer["attrs"] == {"tick": 3} and inner["attrs"] == {"step": 9}
    assert outer["ts_us"] <= inner["ts_us"]
    assert outer["dur_us"] >= inner["dur_us"]

    doc = chrome_trace(tracer.spans)
    evs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert [e["name"] for e in evs] == ["outer", "inner"]  # start order
    assert evs[0]["args"] == {"tick": 3, "depth": 1}
    assert meta[0]["args"]["name"] == "t"


# ---------------------------------------------------------------------------
# Streams: monotone seq, torn tails, CLI render.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def recorded_run(tmp_path_factory):
    d = tmp_path_factory.mktemp("obs") / "run"
    obs = ObsRun(str(d))
    _run_trainer(obs, steps=12)
    obs.close()
    return str(d)


def test_obslog_streams_monotone_seq_and_kinds(recorded_run):
    from repro.controlplane.events import read_events

    for stream in ("spans", "steps", "decisions", "metrics"):
        events = read_events(f"{recorded_run}/{stream}.jsonl")
        assert events, stream
        seqs = [e.seq for e in events]
        assert seqs == sorted(set(seqs)), stream     # strictly monotone
        assert all(e.kind in OBS_KINDS for e in events), stream
    mets = read_events(f"{recorded_run}/metrics.jsonl")
    assert mets[0].kind == "run" and mets[0].data["phase"] == "start"
    assert mets[-1].kind == "run" and mets[-1].data["phase"] == "end"
    assert "counters" in mets[-1].data["summary"]


def test_torn_tail_still_renders(recorded_run, tmp_path):
    """A crashed writer's half-line tail must not poison the readers."""
    import shutil

    from repro.obs import report as R

    d = tmp_path / "torn"
    shutil.copytree(recorded_run, d)
    with open(d / "spans.jsonl", "a") as f:
        f.write('{"seq": 999999, "tick": 999, "kind": "sp')   # torn write
    run = R.load_run(str(d))
    whole = R.load_run(recorded_run)
    assert len(run["spans"]) == len(whole["spans"])   # tail dropped, rest kept
    assert R.render(run)


def test_cli_renders_timeline_and_calibration(recorded_run, tmp_path,
                                              capsys):
    from repro.obs.__main__ import main

    chrome = tmp_path / "trace.json"
    assert main([recorded_run, "--chrome", str(chrome)]) == 0
    out = capsys.readouterr().out
    assert "12 step records" in out
    assert "timeline" in out and "decision quality" in out
    assert "trainer.step" in out and "dmm" in out
    with open(chrome) as f:
        doc = json.load(f)
    assert any(e.get("ph") == "X" for e in doc["traceEvents"])


def test_cli_empty_dir_is_an_error(tmp_path):
    from repro.obs.__main__ import main

    assert main([str(tmp_path)]) == 1


def test_obslog_rejects_unknown_kind():
    log = ObsLog(None)
    with pytest.raises(ValueError):
        # reprolint: disable=event-kind-drift -- deliberately unregistered: this pins the runtime rejection the lint rule mirrors
        log.emit(log.autotick(), "not-a-kind")
