"""Straggler-policy frontier: anytime partial gradients + stale reuse.

The contract under test (core/README.md policy table):

  * ``AnytimeController.contribution`` generalizes the discard bit array
    to a per-worker f32 vector — and REDUCES to it bit-for-bit whenever
    stragglers completed zero microbatches by the cutoff (in particular
    always at ``n_micro=1``), so a Trainer run through either aggregation
    path is bit-identical to plain discard in that regime.
  * fractional contributions aggregate the TRUE partial microbatch sums
    on the psum path: grads == sum_w f_w * ghat_w / sum_w f_w where
    ghat_w is worker w's mean gradient over its completed prefix.
  * ``StaleReuseController`` with ``decay=0`` is exactly the discard
    policy (the in-jit fold multiplies by 1.0/0.0).
  * both wrappers satisfy the elastic ``resize(n, col_map, model,
    members)`` protocol and the checkpoint window protocol by delegation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_cfg
from repro import optim
from repro.cluster.simulator import (ClusterSim, microbatch_progress,
                                     paper_cluster_158)
from repro.core.controller import (AnytimeController, CutoffController,
                                   FullSyncController, StaleReuseController,
                                   StaticCutoffController)
from repro.core.runtime_model.api import RuntimeModel
from repro.data.pipeline import SyntheticTokens
from repro.launch.train import Trainer, jit_train_step, make_train_step
from repro.models import model as M


# ---------------------------------------------------------------------------
# Simulator progress query.
# ---------------------------------------------------------------------------


def test_microbatch_progress_basic():
    times = np.array([2.0, 4.0, 8.0])
    # at t=4 with 4 microbatches: worker0 done (capped at 1), worker1
    # exactly done, worker2 finished 2 of 4
    p = microbatch_progress(times, 4.0, 4)
    np.testing.assert_allclose(p, [1.0, 1.0, 0.5])
    # exact k/n ratios never floor down to (k-1)/n
    np.testing.assert_allclose(microbatch_progress(np.array([3.0]), 1.0, 3),
                               [1.0 / 3.0])
    # n_micro=1: pure 0/1 — partial work is invisible
    np.testing.assert_allclose(microbatch_progress(times, 4.0, 1),
                               [1.0, 1.0, 0.0])
    with pytest.raises(ValueError):
        microbatch_progress(times, 4.0, 0)


def test_anytime_contribution_vector():
    ctl = AnytimeController(StaticCutoffController(4, cutoff=2), n_micro=4)
    times = np.array([1.0, 2.0, 3.0, 8.0])
    contrib = ctl.contribution(times, 2)
    # finishers exactly 1.0; stragglers their completed fraction at the
    # cutoff time (t=2): worker2 did floor(2/3*4)=2 of 4, worker3 1 of 4
    np.testing.assert_allclose(contrib, [1.0, 1.0, 0.5, 0.25])
    assert contrib.dtype == np.float32


def test_anytime_contribution_reduces_to_bit_array():
    # n_micro=1 (or stragglers with no completed microbatch): the vector
    # IS the discard bit array, bit for bit
    inner = StaticCutoffController(6, cutoff=4)
    ctl = AnytimeController(inner, n_micro=1)
    rng = np.random.default_rng(0)
    for _ in range(20):
        times = rng.uniform(1.0, 10.0, size=6)
        c = 4
        contrib = ctl.contribution(times, c)
        order = np.argsort(times, kind="stable")
        bits = np.zeros(6, np.float32)
        bits[order[:c]] = 1.0
        assert np.array_equal(contrib, bits)


# ---------------------------------------------------------------------------
# Train-step math: true partial sums on the psum path.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = reduced_cfg("qwen2-0.5b")
    opt = optim.adamw(3e-3)
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": opt.init(params)}
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    return cfg, opt, state, batch


def test_fractional_psum_aggregates_true_partial_sums(tiny_setup):
    """contribution [1, 1, 1, 0.5] with grad_accum=2: the straggler's
    term is its FIRST-microbatch gradient (normalized by its completed
    tokens), weighted 0.5 in the masked mean."""
    cfg, opt, state, batch = tiny_setup

    # reference: per-worker gradients by hand.  ghat_w = grad of the MEAN
    # CE over worker w's completed prefix (the step normalizes the partial
    # sum by its completed token count); the masked mean weights by f.
    W, G = 4, 2
    loss_fn = lambda p, b: M.train_loss(cfg, p, b, aux_coef=0.0)[0]
    B, S = batch["tokens"].shape
    per = B // W
    ghats = []
    for w in range(W):
        sub = {k: v[w * per:(w + 1) * per] for k, v in batch.items()}
        if w == 3:
            # straggler: first of its 2 microbatches only
            sub = {k: v[:per // G] for k, v in sub.items()}
        ghats.append(jax.grad(loss_fn)(state["params"], sub))
    f = np.array([1.0, 1.0, 1.0, 0.5], np.float32)
    g_ref = jax.tree.map(
        lambda *g: sum(fi * gi for fi, gi in zip(f, g)) / f.sum(), *ghats)

    # pull the step's aggregated gradient out with a probe "optimizer"
    # that records the gradient it is handed and applies a zero update
    b = dict(batch, mask=jnp.asarray(f))
    recorded = {}

    class Probe:
        def init(self, params):
            return {"step": jnp.int32(0)}

        def update(self, grads, opt, params):
            recorded["g"] = grads
            return jax.tree.map(jnp.zeros_like, grads), opt

    probe_step = make_train_step(cfg, Probe(), grad_accum=G,
                                 mask_agg="psum", aux_coef=0.0)
    probe_state = {"params": state["params"],
                   "opt": {"step": jnp.int32(0)}}
    probe_step(probe_state, b)
    err = max(float(jnp.max(jnp.abs(a - r))) for a, r in
              zip(jax.tree.leaves(recorded["g"]), jax.tree.leaves(g_ref)))
    assert err < 1e-5, err


# ---------------------------------------------------------------------------
# Bit-exact reductions through the Trainer, both aggregation paths.
# ---------------------------------------------------------------------------


def _run_trainer(cfg, opt, step_fn, controller, mask_agg, n_steps=4,
                 grad_accum=1):
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=8, seed=0)
    tr = Trainer(cfg=cfg, step_fn=step_fn, data=data, controller=controller,
                 timer=ClusterSim(n_workers=4, n_nodes=2, seed=5),
                 n_workers=4, mask_agg=mask_agg, metrics_every=0)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    tr.restore_or_init(init_fn)
    tr.run(n_steps)
    return tr


def _params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a["params"]),
                               jax.tree.leaves(b["params"])))


@pytest.mark.parametrize("mode", ["weights", "psum"])
def test_anytime_n_micro_1_bitwise_equals_discard(mode, tiny_setup):
    cfg, opt, _, _ = tiny_setup
    step = jit_train_step(cfg, opt, donate=False, grad_accum=2,
                          mask_agg=mode)
    tr_discard = _run_trainer(cfg, opt, step,
                              StaticCutoffController(4, cutoff=3), mode)
    tr_any = _run_trainer(
        cfg, opt, step,
        AnytimeController(StaticCutoffController(4, cutoff=3), n_micro=1),
        mode)
    assert _params_equal(tr_discard.state, tr_any.state)
    for hd, ha in zip(tr_discard.history, tr_any.history):
        assert hd["loss"] == ha["loss"]


def test_stale_reuse_decay_0_bitwise_equals_discard(tiny_setup):
    cfg, opt, _, _ = tiny_setup
    plain = jit_train_step(cfg, opt, donate=False, grad_accum=2,
                           mask_agg="psum")
    sr = jit_train_step(cfg, opt, donate=False, grad_accum=2,
                        mask_agg="psum", stale_reuse=True)
    tr_discard = _run_trainer(cfg, opt, plain,
                              StaticCutoffController(4, cutoff=3), "psum")
    tr_stale = _run_trainer(
        cfg, opt, sr,
        StaleReuseController(StaticCutoffController(4, cutoff=3), decay=0.0),
        "psum")
    assert _params_equal(tr_discard.state, tr_stale.state)


def test_stale_reuse_decay_changes_updates(tiny_setup):
    cfg, opt, _, _ = tiny_setup
    sr = jit_train_step(cfg, opt, donate=False, grad_accum=2,
                        mask_agg="psum", stale_reuse=True)
    tr0 = _run_trainer(
        cfg, opt, sr,
        StaleReuseController(StaticCutoffController(4, cutoff=3), decay=0.0),
        "psum")
    tr5 = _run_trainer(
        cfg, opt, sr,
        StaleReuseController(StaticCutoffController(4, cutoff=3), decay=0.5),
        "psum")
    assert not _params_equal(tr0.state, tr5.state)


# ---------------------------------------------------------------------------
# Guard rails.
# ---------------------------------------------------------------------------


def test_stale_reuse_needs_psum(tiny_setup):
    cfg, opt, _, _ = tiny_setup
    with pytest.raises(ValueError, match="psum"):
        make_train_step(cfg, opt, mask_agg="weights", stale_reuse=True)


def test_stale_controller_rejects_weights_trainer(tiny_setup):
    cfg, opt, _, _ = tiny_setup
    step = jit_train_step(cfg, opt, donate=False, mask_agg="weights")
    with pytest.raises(ValueError, match="psum"):
        _run_trainer(
            cfg, opt, step,
            StaleReuseController(StaticCutoffController(4, cutoff=3)),
            "weights", n_steps=1)


def test_stale_controller_rejects_plain_step(tiny_setup):
    cfg, opt, _, _ = tiny_setup
    step = jit_train_step(cfg, opt, donate=False, mask_agg="psum")
    with pytest.raises(ValueError, match="stale_reuse=True"):
        _run_trainer(
            cfg, opt, step,
            StaleReuseController(StaticCutoffController(4, cutoff=3)),
            "psum", n_steps=1)


def test_policy_wrapper_validation():
    with pytest.raises(ValueError):
        AnytimeController(FullSyncController(4), n_micro=0)
    with pytest.raises(ValueError):
        StaleReuseController(FullSyncController(4), decay=1.5)


# ---------------------------------------------------------------------------
# Elastic + checkpoint protocol by delegation.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wrap", [
    lambda inner: AnytimeController(inner, n_micro=4),
    lambda inner: StaleReuseController(inner, decay=0.5),
])
def test_policy_wrappers_satisfy_resize_protocol(wrap):
    # static inner: width-only resize
    ctl = wrap(StaticCutoffController(8, cutoff=6))
    assert ctl.n == 8
    ctl.resize(4, col_map=None, model=None, members=np.arange(4))
    assert ctl.n == 4
    assert 1 <= ctl.predict_cutoff() <= 4

    # DMM inner: the lag window must remap column-exactly through the
    # wrapper, same as the bare controller
    trace = paper_cluster_158(0, n_workers=8).run(60)
    rm = RuntimeModel(n_workers=8, lag=6).init(0)
    rm.fit(trace, steps=60, batch=8, seed=0)
    rm4 = RuntimeModel(n_workers=4, lag=6).init(1)
    rm4.norm_scale = rm.norm_scale
    bare = CutoffController(rm, k_samples=16, seed=0)
    bare.seed_window(trace)
    wrapped = wrap(CutoffController(rm, k_samples=16, seed=0))
    wrapped.seed_window(trace)
    col_map = np.array([0, 2, 4, 6])
    bare.resize(4, col_map=col_map, model=rm4)
    wrapped.resize(4, col_map=col_map, model=rm4, members=np.arange(4))
    np.testing.assert_array_equal(bare.window_array(),
                                  wrapped.window_array())
    assert wrapped.predict_cutoff() == bare.predict_cutoff()


def test_policy_wrapper_window_protocol():
    # inner without a window: the checkpoint path's ValueError contract
    ctl = AnytimeController(StaticCutoffController(4, cutoff=3))
    with pytest.raises(ValueError):
        ctl.window_array()
    ctl.seed_window(np.ones((3, 4)))      # no-op, must not raise
