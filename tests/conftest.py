"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device; the
multi-device sharding tests spawn their own subprocesses (see
test_sharded_equivalence.py)."""
import dataclasses
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:  # deterministic fallback, see tests/_hyposhim.py
    import _hyposhim
    sys.modules["hypothesis"] = _hyposhim
    sys.modules["hypothesis.strategies"] = _hyposhim.strategies

import jax
import numpy as np
import pytest

from repro.configs.base import all_archs, get_config


@pytest.fixture(scope="session", params=sorted(all_archs()))
def arch_name(request):
    return request.param


def reduced_cfg(name, drop_free_moe=True):
    cfg = get_config(name).reduced()
    if drop_free_moe and cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
    return cfg


def tiny_batch(cfg, key, B=2, S=16, labels=True):
    import jax.numpy as jnp
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
    }
    if labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.zeros((B, S, cfg.d_model))
        batch["image_mask"] = jnp.zeros((B, S), bool)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S)[None, None], (3, B, S))
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model)) * 0.01
    return batch
