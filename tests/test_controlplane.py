"""Control plane: event stream, heartbeat state machine, supervisor.

The contracts the subsystem stands on:

  * the heartbeat monitor NEVER declares a worker dead before its
    deadline, and (advanced every tick) declares it dead at EXACTLY
    ``last_beat + dead_after + 1`` — so detection latency is the
    deadline + 1 tick, which the controlplane bench gates on;
  * ``admit`` always re-admits under the flap limit; permanent eviction
    is the supervisor's call, never the monitor's;
  * the event stream is monotone in (seq, tick) and survives a writer
    crash mid-append;
  * the supervisor run of a seeded fault plan equals the SAME schedule
    replayed as a scripted ChurnSim run, loss for loss — detected
    elasticity is a faithful stand-in for an oracle script.
"""
import json
import os
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.cluster.simulator import ClusterSim, OverlaySim
from repro.controlplane.events import Event, EventLog, read_events
from repro.controlplane.faults import Fault, FaultInjector, FaultPlan
from repro.controlplane.heartbeat import (ALIVE, DEAD, SUSPECT,
                                          HeartbeatMonitor)
from repro.controlplane.supervisor import (SimWorkerPool, SupervisedTimer,
                                           Supervisor, drill_report)

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Event stream.
# ---------------------------------------------------------------------------


def test_event_log_roundtrip(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with EventLog(path) as log:
        log.emit(0, "run", phase="start")
        log.emit(3, "suspect", 2, silent_ticks=3)
        log.emit(5, "dead", 2, last_beat=0, silent_ticks=5)
    back = read_events(path)
    assert [e.kind for e in back] == ["run", "suspect", "dead"]
    assert back[1].worker == 2 and back[1].data["silent_ticks"] == 3
    assert [e.seq for e in back] == [0, 1, 2]
    # the file is the in-memory stream (wall stamps round to µs on disk)
    assert [(e.seq, e.tick, e.kind, e.worker, e.data) for e in back] == \
        [(e.seq, e.tick, e.kind, e.worker, e.data) for e in log.events]


def test_event_log_rejects_unknown_kind_and_backwards_tick():
    log = EventLog()
    with pytest.raises(ValueError, match="unknown event kind"):
        # reprolint: disable=event-kind-drift -- negative test: 'explode' must stay unregistered for the ValueError to fire
        log.emit(0, "explode")
    log.emit(5, "dead", 0)
    with pytest.raises(ValueError, match="backwards"):
        log.emit(4, "rejoin", 0)


def test_read_events_tolerates_partial_trailing_line(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with EventLog(path) as log:
        log.emit(0, "run")
        log.emit(1, "dead", 3)
    with open(path, "a") as f:          # writer died mid-append
        f.write('{"seq": 2, "tick": 2, "ki')
    back = read_events(path)
    assert [e.kind for e in back] == ["run", "dead"]
    # but a malformed COMPLETE line is an error, not silently skipped
    with open(path, "a") as f:
        f.write("garbage }{\n")
    with pytest.raises(json.JSONDecodeError):
        read_events(path)


def test_event_json_roundtrip_preserves_payload():
    ev = Event(seq=7, tick=42, kind="restart", worker=3, wall=1.5,
               data={"attempt": 2, "failures": 1})
    back = Event.from_json(ev.to_json())
    assert back == ev


def test_of_kind_filters():
    log = EventLog()
    log.emit(0, "run")
    log.emit(1, "dead", 0)
    log.emit(2, "restart", 0, attempt=1)
    assert [e.kind for e in log.of_kind("dead", "restart")] == [
        "dead", "restart"]


# ---------------------------------------------------------------------------
# Fault plans / injector.
# ---------------------------------------------------------------------------


def test_fault_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(at=1, kind="meteor", worker=0)
    with pytest.raises(ValueError, match="needs a worker"):
        Fault(at=1, kind="crash")
    Fault(at=1, kind="corrupt_ckpt")    # the one worker-free kind


@settings(**SETTINGS)
@given(seed=st.integers(0, 200), n=st.integers(3, 12))
def test_storm_one_fault_per_worker_with_gap(seed, n):
    k = min(3, n)
    plan = FaultPlan.storm(n, k, horizon=60, seed=seed, min_gap=3)
    workers = [f.worker for f in plan.faults]
    assert len(set(workers)) == len(workers) == k
    ticks = sorted(f.at for f in plan.faults)
    assert all(b - a >= 3 for a, b in zip(ticks, ticks[1:]))
    assert all(f.at >= 1 for f in plan.faults)


def test_injector_fires_each_fault_once_and_burns_flaky_budget():
    plan = FaultPlan([Fault(at=2, kind="crash", worker=0),
                      Fault(at=2, kind="flaky_restart", worker=1, fails=2)])
    inj = FaultInjector(plan)
    assert [f.kind for f in inj.fire(2)] == ["crash", "flaky_restart"]
    assert inj.fire(2) == []            # once means once
    assert inj.restart_should_fail(1)
    assert inj.restart_should_fail(1)
    assert not inj.restart_should_fail(1)   # budget spent
    assert not inj.restart_should_fail(0)   # never armed


# ---------------------------------------------------------------------------
# Heartbeat state machine: property tests.
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(2, 8),
       suspect_after=st.integers(1, 4), extra=st.integers(1, 4))
def test_never_dead_before_deadline_and_exact_detection(seed, n,
                                                        suspect_after,
                                                        extra):
    """Advanced every tick under a random beat schedule: nobody is dead
    while silence <= dead_after, and death lands at exactly
    last_beat + dead_after + 1."""
    dead_after = suspect_after + extra
    rng = np.random.default_rng(seed)
    m = HeartbeatMonitor(range(n), suspect_after=suspect_after,
                         dead_after=dead_after)
    last = {w: 0 for w in range(n)}
    dead_at = {}
    for tick in range(1, 40):
        for w in range(n):
            if w not in dead_at and rng.uniform() < 0.6:
                m.beat(w, tick)
                last[w] = tick
        for (w, _old, new) in m.advance(tick):
            if new == DEAD:
                dead_at[w] = tick
        for w in range(n):
            silent = tick - last[w]
            if silent <= dead_after:
                assert m.state(w) != DEAD, (w, tick, last[w])
    for w, t in dead_at.items():
        assert t == last[w] + dead_after + 1


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(2, 6))
def test_admit_always_readmits_and_restarts_deadline(seed, n):
    rng = np.random.default_rng(seed)
    m = HeartbeatMonitor(range(n), suspect_after=2, dead_after=4)
    admitted_at = {}
    for tick in range(1, 40):
        m.advance(tick)
        # the deadline clock restarted on admit: not even suspect
        # within suspect_after ticks of the re-admission
        for w, at in admitted_at.items():
            if tick - at <= 2:
                assert m.state(w) == ALIVE
        for w in range(n):
            if m.state(w) == DEAD and rng.uniform() < 0.5:
                m.admit(w, tick)
                assert m.state(w) == ALIVE
                assert w in m.members()
                admitted_at[w] = tick


@settings(**SETTINGS)
@given(seed=st.integers(0, 500), n=st.integers(2, 6))
def test_event_stream_monotone(seed, n):
    rng = np.random.default_rng(seed)
    log = EventLog()
    m = HeartbeatMonitor(range(n), suspect_after=1, dead_after=2, log=log)
    for tick in range(1, 25):
        for w in range(n):
            if rng.uniform() < 0.4:
                m.beat(w, tick)
        m.advance(tick)
        for w in range(n):
            if m.state(w) == DEAD and rng.uniform() < 0.3:
                m.admit(w, tick)
    seqs = [e.seq for e in log.events]
    ticks = [e.tick for e in log.events]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    assert ticks == sorted(ticks)


# ---------------------------------------------------------------------------
# Heartbeat: directed drills.
# ---------------------------------------------------------------------------


def test_suspect_then_false_alarm_rejoin():
    log = EventLog()
    m = HeartbeatMonitor([0], suspect_after=2, dead_after=5, log=log)
    m.advance(3)                        # silent 3 > 2: suspect
    assert m.state(0) == SUSPECT
    assert 0 in m.members()             # a suspect still holds its lease
    m.beat(0, 4)                        # false alarm
    assert m.state(0) == ALIVE
    rejoins = log.of_kind("rejoin")
    assert len(rejoins) == 1 and rejoins[0].data["false_alarm"]


def test_dead_workers_late_beat_is_dropped():
    m = HeartbeatMonitor([0], suspect_after=1, dead_after=2)
    m.advance(3)
    assert m.state(0) == DEAD
    m.beat(0, 4)                        # too late: membership already shrank
    assert m.state(0) == DEAD and m.members().size == 0
    m.admit(0, 5)                       # the supervisor's restart path
    assert m.state(0) == ALIVE


def test_grace_covers_slow_first_beat():
    """A freshly admitted worker gets grace ticks for its first beat
    (subprocess interpreter startup); after the first beat the normal
    deadline applies."""
    m = HeartbeatMonitor([0], suspect_after=2, dead_after=4, grace=10,
                         start_tick=0)
    m.advance(8)                        # silent 8 <= grace 10
    assert m.state(0) == ALIVE
    m.beat(0, 9)
    m.advance(14)                       # silent 5 > dead_after: grace is over
    assert m.state(0) == DEAD


def test_monitor_validates_deadlines():
    with pytest.raises(ValueError, match="suspect_after"):
        HeartbeatMonitor([0], suspect_after=4, dead_after=4)


# ---------------------------------------------------------------------------
# Supervisor over the simulated pool.
# ---------------------------------------------------------------------------


def _sim_stack(n=4, faults=(), seed=0, **sup_kw):
    overlay = OverlaySim(ClusterSim(n_workers=n, n_nodes=2, seed=seed))
    inj = FaultInjector(FaultPlan(list(faults)), seed=seed)
    pool = SimWorkerPool(overlay, inj)
    kw = dict(suspect_after=2, dead_after=4, restart_base=2,
              restart_cap=16, flap_limit=3, seed=seed)
    kw.update(sup_kw)
    return overlay, Supervisor(pool, **kw)


def test_crash_detected_within_deadline_plus_one_and_restarted():
    overlay, sup = _sim_stack(faults=[Fault(at=5, kind="crash", worker=3)])
    for t in range(40):
        sup.tick(t)
    report = drill_report(sup.log.events)
    [inc] = report["incidents"]
    assert inc["detected"]
    # last beat was tick 4 (fault fired before the tick-5 beat round):
    # detection at 4 + dead_after + 1 = 9, i.e. fault + dead_after
    assert inc["dead_tick"] == 9
    assert inc["detection_ticks"] <= sup.monitor.dead_after + 1
    assert inc["rejoin_tick"] is not None
    assert not overlay.stalled[3]       # the restart cleared the stall
    assert sup.membership().tolist() == [0, 1, 2, 3]
    # membership events mark both the shrink and the regrow
    members = [e.data["members"] for e in sup.log.of_kind("membership")]
    assert [0, 1, 2] in members and [0, 1, 2, 3] in members


def test_hung_worker_is_killed_before_restart():
    _, sup = _sim_stack(faults=[Fault(at=5, kind="hang", worker=1)])
    for t in range(30):
        sup.tick(t)
    kills = sup.log.of_kind("kill")
    assert [e.worker for e in kills] == [1]
    restarts = sup.log.of_kind("restart")
    assert [e.worker for e in restarts] == [1]
    # the kill lands before the restart in the stream
    assert kills[0].seq < restarts[0].seq


def test_flaky_restarts_back_off_then_evict():
    _, sup = _sim_stack(
        faults=[Fault(at=5, kind="crash", worker=2),
                Fault(at=5, kind="flaky_restart", worker=2, fails=3)],
        flap_limit=3)
    for t in range(80):
        sup.tick(t)
    fails = sup.log.of_kind("restart_failed")
    assert [e.worker for e in fails] == [2, 2, 2]
    # capped exponential backoff between attempts: 2, 4, 8
    gaps = np.diff([e.tick for e in fails])
    assert gaps.tolist() == [4, 8]
    evicts = sup.log.of_kind("evict")
    assert [e.worker for e in evicts] == [2]
    assert 2 in sup.evicted
    assert sup.membership().tolist() == [0, 1, 3]   # permanently out
    assert not sup.log.of_kind("restart")           # never came back


def test_slowdown_never_triggers_detection():
    """Slowdowns keep heartbeats flowing — the cutoff controller's case,
    not the supervisor's; membership must not budge."""
    overlay, sup = _sim_stack(
        faults=[Fault(at=5, kind="slowdown", worker=0, factor=5.0,
                      duration=6)])
    for t in range(20):
        sup.tick(t)
    assert not sup.log.of_kind("dead", "suspect", "kill")
    assert sup.membership().size == 4
    assert overlay.mult[0] == 1.0       # expired after duration ticks


def test_supervised_timer_tracks_membership():
    overlay, sup = _sim_stack(faults=[Fault(at=5, kind="crash", worker=3)])
    timer = SupervisedTimer(overlay, sup)
    widths = []
    for t in range(16):
        sup.tick(t)
        row = timer.step()
        widths.append(row.size)
        assert row.size == timer.n_workers == timer.active_ids.size
    assert 3 in widths and 4 in widths  # shrank on detection, regrew


def test_sim_pool_emits_warm_recover_from_ctl_group(tmp_path):
    from repro.checkpoint import store
    ckpt = str(tmp_path / "ckpt")
    store.save(ckpt, 7, {"ctl": {"n": np.int64(4),
                                 "members": np.arange(4),
                                 "step": np.int64(7)}})
    _, sup = _sim_stack(faults=[Fault(at=5, kind="crash", worker=2)])
    sup.pool.ckpt_dir = ckpt
    for t in range(30):
        sup.tick(t)
    [rec] = sup.log.of_kind("recover")
    assert rec.worker == 2 and rec.data["step"] == 7 and rec.data["warm"]


# ---------------------------------------------------------------------------
# Supervised run == scripted replay (the equivalence drill, sim mode).
# ---------------------------------------------------------------------------


def test_supervised_equals_scripted_replay():
    from repro.launch.supervised import run_supervised
    out = run_supervised(steps=36, seed=0, n_workers=6, verbose=False)
    assert out["match"], "supervised losses diverged from scripted replay"
    report = out["report"]
    assert report["n_detected"] == 2            # the crash and the hang
    assert report["max_detection_ticks"] <= 4 + 1
    assert report["failed_restarts"] == 1       # the flaky incarnation
    assert report["evicted"] == []
    assert sorted(set(out["widths"])) == [5, 6]
