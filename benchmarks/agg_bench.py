"""Aggregation-path benchmark: weights vs psum train steps + the combine.

Times the three ways the cutoff bit array can meet the gradients:

  * the production example-weights train step (``mask_agg="weights"``),
  * the explicit per-worker psum train step (``mask_agg="psum"``),
  * the stacked host combine itself — pure-jnp reference vs the Pallas
    masked_grad_agg kernel (interpret mode on CPU, so that number is
    Python overhead; the derived TPU roofline bound is what matters).

Emits the usual CSV rows AND a machine-readable ``BENCH_agg.json`` so the
perf trajectory of the aggregation path accumulates across PRs.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.perf.hlo_stats import HBM_BW


def _combine_bench(quick: bool):
    from repro.core import aggregation
    from repro.kernels import ops

    W = 8
    N = 2**18 if quick else 2**21
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (W, N))
    mask = (jnp.arange(W) % 3 != 0).astype(jnp.float32)

    fn = jax.jit(lambda a, m: aggregation.masked_mean_local({"g": a}, m)["g"])
    jnp_us = timeit(fn, g, mask, iters=5)
    stream = g.size * 4
    bound_us = stream / HBM_BW * 1e6
    emit("agg/combine_jnp_local", jnp_us, f"tpu_mem_bound_us={bound_us:.1f}")

    # interpret mode measures the Pallas interpreter, not silicon — keep N
    # small enough that the grid stays a few dozen steps.
    Nk = 2**14 if quick else 2**15
    gk = g[:, :Nk]
    saved = ops.KERNEL_BACKEND
    ops.KERNEL_BACKEND = "interpret"
    try:
        kfn = jax.jit(lambda a, m: ops.masked_aggregate_tree({"g": a}, m)["g"])
        kernel_us = timeit(kfn, gk, mask, iters=2)
    finally:
        ops.KERNEL_BACKEND = saved
    emit("agg/combine_kernel_interpret", kernel_us,
         f"n={Nk};tpu_mem_bound_us={Nk * W * 4 / HBM_BW * 1e6:.1f}")

    return {"W": W, "N": N, "jnp_local_us": jnp_us,
            "kernel_interpret_us": kernel_us, "kernel_interpret_n": Nk,
            "tpu_mem_bound_us": bound_us}


def _train_step_bench(quick: bool):
    from repro import optim
    from repro.configs.base import get_config
    from repro.core import aggregation
    from repro.launch.train import make_train_step
    from repro.models import model as M
    import numpy as np

    cfg = get_config("qwen2-0.5b").reduced()
    opt = optim.adamw(3e-3)
    W, per, S = 8, 2, 16
    B = W * per
    key = jax.random.PRNGKey(0)
    params = M.init_model(cfg, key)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S)),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    mask = np.asarray([1, 0, 1, 1, 1, 0, 1, 1], np.float32)
    iters = 3 if quick else 10
    out = {"arch": f"{cfg.name}/reduced", "B": B, "S": S, "W": W}
    for mode in ("weights", "psum"):
        step = jax.jit(make_train_step(cfg, opt, mask_agg=mode))
        state = {"params": params, "opt": opt.init(params)}
        if mode == "psum":
            b = dict(batch, mask=jnp.asarray(mask))
        else:
            b = dict(batch, weights=jnp.asarray(
                aggregation.example_weights(mask, B)))

        def one(s, bb):
            s2, m = step(s, bb)
            return m["loss"]

        us = timeit(one, state, b, iters=iters)
        out[f"{mode}_us"] = us
        emit(f"agg/train_step_{mode}", us, f"arch={cfg.name};W={W}")
    out["psum_over_weights"] = out["psum_us"] / out["weights_us"]
    return out


def bench_agg(quick: bool = False, out_path: str = "BENCH_agg.json"):
    results = {
        "schema": "bench_agg/v1",
        "quick": quick,
        "combine": _combine_bench(quick),
        "train_step": _train_step_bench(quick),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("agg/json_written", 0.0, out_path)
    return results
