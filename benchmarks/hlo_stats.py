"""Re-export: canonical implementation lives in repro.perf.hlo_stats."""
from repro.perf.hlo_stats import *  # noqa: F401,F403
from repro.perf.hlo_stats import (collective_bytes, roofline_terms,
                                  PEAK_FLOPS_BF16, HBM_BW, ICI_BW)
