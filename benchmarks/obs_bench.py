"""Obs overhead benchmark: what the telemetry spine costs, measured.

Four sections, all seeded, emitted as CSV rows AND into
``BENCH_obs.json`` (schema ``bench_obs/v1``):

  * ``step`` — the headline gate: end-to-end Trainer step latency with a
    full ``ObsRun`` attached (step/predict/dispatch/observe spans, one
    donated metric-ring push per step, the decision-quality wrapper)
    vs the identical bare trainer, at n ∈ {8, 158}.  Min-of-repeats on
    both sides; ``scripts/ci.sh --bench`` pins ``overhead_frac`` at
    n=158 to <= 5% — the "zero-sync" claim, priced.
  * ``ring`` — the device collector path in isolation: µs per
    ``MetricRing.push`` (one donated jit dispatch, nothing fetched) and
    per ``MetricsRegistry.drain`` of a full 256-row ring (the ONLY
    device read the spine ever does).
  * ``span`` — µs per tracer span (two ``perf_counter`` stamps + one
    in-memory record), and that cost multiplied by the 4 spans a
    Trainer step emits.
  * ``calibration`` — a seeded controller-level mini-race (sync /
    static / firstk / dmm over the same paper-cluster draws) recorded
    through ``--obs-dir`` artifacts, then summarized with
    ``repro.obs.report.calibration_report`` — the frontier story
    (regret / idle / discard / DMM quantile coverage) reproduced from
    JSONL alone, exactly what ``python -m repro.obs`` renders.
"""
from __future__ import annotations

import json
import tempfile
import time

from benchmarks.common import emit

STEP_NS = (8, 158)
RING_CAP = 256


# ---------------------------------------------------------------------------
# step: instrumented vs bare Trainer.
# ---------------------------------------------------------------------------


def _step_bench(n_list, steps: int, repeats: int = 3):
    import jax

    from repro import optim
    from repro.cluster.simulator import paper_cluster_158
    from repro.configs.base import bench_tiny_config
    from repro.core.controller import CutoffController
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, jit_train_step
    from repro.models import model as M
    from repro.obs import ObsRun

    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    rows = []
    for n in n_list:
        trace = paper_cluster_158(seed=0, n_workers=n).run(40)

        def make_ctl():
            # analytic-scale model (no fit): decisions are deterministic
            # and identical across the bare/instrumented runs, which is
            # all a latency comparison needs
            rm = RuntimeModel(n_workers=n, lag=20).init(0)
            rm.norm_scale = float(2.0 * trace[:21].mean())
            ctl = CutoffController(rm, k_samples=16, seed=0)
            ctl.seed_window(trace)
            return ctl

        def run_once(instrument: bool) -> float:
            obs = ObsRun() if instrument else None
            ctl = make_ctl()
            data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                                   global_batch=n, seed=0)
            tr = Trainer(cfg=cfg, step_fn=step_fn, data=data,
                         controller=obs.wrap(ctl, policy="dmm")
                         if instrument else ctl,
                         timer=paper_cluster_158(seed=9, n_workers=n),
                         n_workers=n, metrics_every=0, obs=obs,
                         name="dmm" if instrument else None)
            tr.restore_or_init(init_fn)
            tr.run(3)                       # warm the compile caches
            t0 = time.perf_counter()
            tr.run(steps)
            return (time.perf_counter() - t0) / steps * 1e6

        bare = min(run_once(False) for _ in range(repeats))
        inst = min(run_once(True) for _ in range(repeats))
        frac = inst / bare - 1.0
        rows.append({"n_workers": n, "steps": steps, "repeats": repeats,
                     "bare_us": bare, "instrumented_us": inst,
                     "overhead_frac": frac})
        emit(f"obs/step_overhead_n{n}", inst,
             f"bare={bare:.1f}us;frac={frac * 100:+.1f}%")
    return rows


# ---------------------------------------------------------------------------
# ring + span micro-costs.
# ---------------------------------------------------------------------------


def _ring_bench(n_push: int = 512):
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    ring = reg.ring("bench", ("a", "b", "c", "d"), cap=RING_CAP)
    ring.push((0.0, 1.0, 2.0, 3.0))         # warm the donated jit
    reg.drain()
    t0 = time.perf_counter()
    for i in range(n_push):
        ring.push((float(i), 1.0, 2.0, 3.0))
    push_us = (time.perf_counter() - t0) / n_push * 1e6
    t0 = time.perf_counter()
    payloads = reg.drain()
    drain_us = (time.perf_counter() - t0) * 1e6
    p = payloads[0]
    out = {"cap": RING_CAP, "pushes": n_push, "push_us": push_us,
           "drain_us": drain_us, "rows_drained": len(p["rows"]),
           "dropped": p["dropped"]}
    emit("obs/ring_push", push_us, f"cap={RING_CAP}")
    emit("obs/ring_drain", drain_us,
         f"rows={out['rows_drained']};dropped={out['dropped']}")
    return out


def _span_bench(n_spans: int = 4000):
    from repro.obs.trace import ObsLog, Tracer

    tracer = Tracer(log=ObsLog(None))
    t0 = time.perf_counter()
    for i in range(n_spans):
        with tracer.span("bench.span", track="bench", step=i):
            pass
    us = (time.perf_counter() - t0) / n_spans * 1e6
    # a Trainer step opens 4 spans: trainer.step + predict/dispatch/observe
    out = {"n_spans": n_spans, "us_per_span": us,
           "spans_per_trainer_step": 4, "us_per_trainer_step": 4 * us}
    emit("obs/span", us, f"{4 * us:.1f}us/trainer-step")
    return out


# ---------------------------------------------------------------------------
# calibration: the frontier story from artifacts alone.
# ---------------------------------------------------------------------------


def _calibration_bench(steps: int, n: int = 8, seed: int = 0):
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.controller import (CutoffController, FirstKController,
                                       FullSyncController,
                                       StaticCutoffController)
    from repro.core.cutoff import order_stats
    from repro.core.runtime_model.api import RuntimeModel
    from repro.obs import ObsRun
    from repro.obs import report as R

    trace = paper_cluster_158(seed=seed, n_workers=n).run(120)
    rm = RuntimeModel(n_workers=n, lag=10).init(seed)
    rm.fit(trace, steps=80, batch=8, seed=seed)
    dmm = CutoffController(rm, k_samples=32, seed=seed)
    dmm.seed_window(trace[-40:])
    policies = [("sync", FullSyncController(n)),
                ("static", StaticCutoffController(n, cutoff=n - 1)),
                ("firstk", FirstKController(n, backup=1)),
                ("dmm", dmm)]

    obs_dir = tempfile.mkdtemp(prefix="obs_bench_")
    with ObsRun(obs_dir) as obs:
        for name, bare in policies:
            ctl = obs.wrap(bare, policy=name)
            sim = paper_cluster_158(seed=seed + 9, n_workers=n)
            for _ in range(steps):
                c = ctl.predict_cutoff()
                times = sim.step()
                it = order_stats.iter_time(times, c)
                ctl.observe(times, times <= it + 1e-12)
            obs.drain()

    # round-trip THROUGH the artifacts: what the CLI renders, the bench
    # reports — no live objects survive to this point
    run = R.load_run(obs_dir)
    cal = R.calibration_report(run["decisions"])
    for name, r in cal.items():
        fmt = lambda v: "-" if v is None else f"{v:.3f}"
        emit(f"obs/calibration_{name}", 0.0,
             f"regret={fmt(r['mean_regret'])};"
             f"idle={fmt(r['mean_idle_frac'])};"
             f"cov50={fmt(r['coverage50'])};cov90={fmt(r['coverage90'])}")
    return {"n_workers": n, "steps": steps, "obs_dir": obs_dir,
            "policies": cal}


def bench_obs(quick: bool = False, out_path: str = "BENCH_obs.json",
              n_list=STEP_NS, steps: int = None):
    steps = steps if steps is not None else (25 if quick else 50)
    results = {
        "schema": "bench_obs/v1",
        "quick": quick,
        "step": _step_bench(n_list, steps, repeats=3),
        "ring": _ring_bench(),
        "span": _span_bench(),
        "calibration": _calibration_bench(30 if quick else 60),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("obs/json_written", 0.0, out_path)
    return results
