"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the 2175-worker Cray model + shrink fig4")
    args = ap.parse_args()

    from benchmarks import kernels_bench, paper_figures, roofline

    t0 = time.time()
    print("name,us_per_call,derived")
    paper_figures.bench_elfving_table()
    paper_figures.bench_fig2_throughput()
    paper_figures.bench_fig3_prediction(cray=not args.quick)
    paper_figures.bench_fig4_convergence(
        steps=60 if args.quick else 150)
    paper_figures.bench_censoring_ablation()
    kernels_bench.bench_kernels()
    roofline.bench_roofline()
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
