"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
                [--only agg|controller|elastic|ps|frontier|controlplane|obs]

``--only agg`` / ``--only controller`` / ``--only elastic`` / ``--only
ps`` / ``--only frontier`` / ``--only controlplane`` / ``--only obs``
run a single section (what ``scripts/ci.sh --bench`` uses); they also
write ``BENCH_agg.json`` / ``BENCH_controller.json`` /
``BENCH_elastic.json`` / ``BENCH_ps.json`` / ``BENCH_frontier.json`` /
``BENCH_controlplane.json`` / ``BENCH_obs.json`` respectively.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="skip the 2175-worker Cray model + shrink fig4")
    ap.add_argument("--only", default=None,
                    choices=["agg", "controller", "elastic", "ps",
                             "frontier", "controlplane", "obs"],
                    help="run a single benchmark section")
    args = ap.parse_args()

    from benchmarks import (agg_bench, controller_bench,
                            controlplane_bench, elastic_bench,
                            frontier_bench, kernels_bench, obs_bench,
                            paper_figures, ps_bench, roofline)

    t0 = time.time()
    print("name,us_per_call,derived")
    if args.only == "agg":
        agg_bench.bench_agg(quick=args.quick)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return
    if args.only == "controller":
        controller_bench.bench_controller(quick=args.quick)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return
    if args.only == "elastic":
        elastic_bench.bench_elastic(quick=args.quick)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return
    if args.only == "ps":
        ps_bench.bench_ps(quick=args.quick)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return
    if args.only == "frontier":
        frontier_bench.bench_frontier(quick=args.quick)
        paper_figures.bench_frontier_panel()
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return
    if args.only == "controlplane":
        controlplane_bench.bench_controlplane(quick=args.quick)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return
    if args.only == "obs":
        obs_bench.bench_obs(quick=args.quick)
        print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
        return
    paper_figures.bench_elfving_table()
    paper_figures.bench_fig2_throughput()
    paper_figures.bench_fig3_prediction(cray=not args.quick)
    paper_figures.bench_fig4_convergence(
        steps=60 if args.quick else 150)
    paper_figures.bench_censoring_ablation()
    kernels_bench.bench_kernels()
    roofline.bench_roofline()
    agg_bench.bench_agg(quick=args.quick)
    controller_bench.bench_controller(quick=args.quick)
    elastic_bench.bench_elastic(quick=args.quick)
    ps_bench.bench_ps(quick=args.quick)
    frontier_bench.bench_frontier(quick=args.quick)
    paper_figures.bench_frontier_panel()
    controlplane_bench.bench_controlplane(quick=args.quick)
    obs_bench.bench_obs(quick=args.quick)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
