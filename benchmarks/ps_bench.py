"""Multi-tenant PS benchmark: batched vs looped decisions + schedulers.

Three sections, emitted as CSV rows AND into a machine-readable
``BENCH_ps.json`` (schema ``bench_ps/v1``) — the perf trajectory's fourth
datapoint after agg/controller/elastic:

  * ``decision`` — per-tick decision latency for J concurrent jobs:
    J looped single-job ``CutoffController(backend="device")`` fused
    dispatches vs ONE ``PSServer`` vmapped batched dispatch, over
    J x n_workers.  This is the number the subsystem exists for: at
    J=16, n=158 the batched path must win (dispatch overhead paid once).
  * ``aggregate`` — end-to-end multi-job Trainer throughput: J tiny
    training jobs through one PSServer vs J independent Trainers each
    with its own device controller (the "J independent servers"
    baseline).
  * ``sched`` — under capacity pressure (C < J serviced per tick), the
    throughput/service spread of the round-robin, priority and
    shortest-predicted-step-first policies.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit


DECISION_NS = (8, 158)
DECISION_JS = (1, 4, 16)


def _model_for(n: int, trace, lag: int = 20):
    from repro.core.runtime_model.api import RuntimeModel

    # untrained weights time identically to trained ones; skip the fit
    rm = RuntimeModel(n_workers=n, lag=lag).init(0)
    rm.norm_scale = float(2.0 * trace[: lag + 1].mean())
    return rm


def _looped_tick(ctls, sims):
    from repro.core.cutoff import order_stats

    for ctl, sim in zip(ctls, sims):
        times = sim.step()
        c = ctl.predict_cutoff()
        it = order_stats.iter_time(times, c)
        ctl.observe(times, times <= it + 1e-12)


def _batched_tick(server, handles, sims):
    from repro.core.cutoff import order_stats

    for h, sim in zip(handles, sims):
        times = sim.step()
        c = h.predict_cutoff()
        it = order_stats.iter_time(times, c)
        h.observe(times, times <= it + 1e-12)
    server.flush()


def _decision_bench(n_list, j_list, iters: int, k_samples: int = 64,
                    blocks: int = 3):
    """Batched vs looped per-tick latency, interleaved best-of blocks."""
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.controller import CutoffController
    from repro.ps import PSServer

    rows = []
    for n in n_list:
        trace = paper_cluster_158(seed=0, n_workers=n).run(25)
        rm = _model_for(n, trace)
        for J in j_list:
            ctls = [CutoffController(rm, k_samples=k_samples, seed=j,
                                     backend="device") for j in range(J)]
            server = PSServer()
            handles = []
            for j, ctl in enumerate(ctls):
                tr = paper_cluster_158(seed=10 + j, n_workers=n).run(25)
                ctl.seed_window(tr)
                handles.append(server.admit(
                    f"job{j}", rm, window=tr, k_samples=k_samples, seed=j))

            def sims(s):
                return [paper_cluster_158(seed=s + j, n_workers=n)
                        for j in range(J)]

            # warmup: compile every fused variant on both paths
            for _ in range(3):
                _looped_tick(ctls, sims(900))
                _batched_tick(server, handles, sims(900))
            best = {"looped": float("inf"), "batched": float("inf")}
            for _ in range(blocks):
                s_l, s_b = sims(500), sims(500)
                t0 = time.perf_counter()
                for _ in range(iters):
                    _looped_tick(ctls, s_l)
                best["looped"] = min(best["looped"],
                                     (time.perf_counter() - t0) / iters * 1e6)
                t0 = time.perf_counter()
                for _ in range(iters):
                    _batched_tick(server, handles, s_b)
                best["batched"] = min(
                    best["batched"],
                    (time.perf_counter() - t0) / iters * 1e6)
            entry = {"n_workers": n, "n_jobs": J, "k_samples": k_samples,
                     "looped_us": best["looped"],
                     "batched_us": best["batched"],
                     "speedup": best["looped"] / best["batched"]}
            emit(f"ps/decision_looped_n{n}_j{J}", best["looped"],
                 f"n={n};J={J};K={k_samples}")
            emit(f"ps/decision_batched_n{n}_j{J}", best["batched"],
                 f"n={n};J={J};K={k_samples}")
            emit(f"ps/decision_speedup_n{n}_j{J}", 0.0,
                 f"{entry['speedup']:.2f}x")
            rows.append(entry)
    return rows


def _aggregate_bench(n_jobs: int, ticks: int, blocks: int = 2):
    """J training jobs through one PSServer vs J independent servers."""
    import jax

    from repro import optim
    from repro.cluster.simulator import paper_cluster_158
    from repro.configs.base import bench_tiny_config
    from repro.core.controller import CutoffController
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.multi_job import build_multi_job, run_ticks
    from repro.launch.train import Trainer, jit_train_step
    from repro.models import model as M
    from repro.ps import make_scheduler

    n_per_job = 8
    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    # -- independent baseline: one CutoffController per job -------------
    def build_independent():
        trainers = []
        for j in range(n_jobs):
            trace = paper_cluster_158(seed=10 + j,
                                      n_workers=n_per_job).run(40)
            rm = RuntimeModel(n_workers=n_per_job, lag=10).init(j)
            rm.norm_scale = float(2.0 * trace[:11].mean())
            ctl = CutoffController(rm, k_samples=32, seed=100 * j,
                                   backend="device")
            ctl.seed_window(trace[-11:])
            data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                                   global_batch=24, seed=j)
            tr = Trainer(cfg=cfg, step_fn=step_fn, data=data,
                         controller=ctl,
                         timer=paper_cluster_158(seed=200 + j,
                                                 n_workers=n_per_job),
                         n_workers=n_per_job, metrics_every=50)

            def init_fn(jj=j):
                params = M.init_model(cfg, jax.random.PRNGKey(jj))
                return {"params": params, "opt": opt.init(params)}

            tr.restore_or_init(init_fn)
            trainers.append(tr)
        return trainers

    # warm both paths, then interleaved best-of blocks
    server, jobs, _ = build_multi_job(n_jobs, n_per_job, seed=0,
                                      fit_steps=0, metrics_every=50)
    sched = make_scheduler("rr")
    run_ticks(server, jobs, sched, 2)
    indep = build_independent()
    for tr in indep:
        tr.run(2)
    best = {"multi": float("inf"), "independent": float("inf")}
    for _ in range(blocks):
        t0 = time.perf_counter()
        run_ticks(server, jobs, sched, ticks)
        best["multi"] = min(best["multi"], (time.perf_counter() - t0))
        t0 = time.perf_counter()
        for _ in range(ticks):
            for tr in indep:
                tr.run(1)
        best["independent"] = min(best["independent"],
                                  (time.perf_counter() - t0))
    steps = ticks * n_jobs
    out = {"arch": f"{cfg.name}/bench_tiny", "n_jobs": n_jobs,
           "n_per_job": n_per_job, "ticks": ticks,
           "multi_steps_per_s": steps / best["multi"],
           "independent_steps_per_s": steps / best["independent"]}
    out["multi_over_independent"] = (out["multi_steps_per_s"]
                                     / out["independent_steps_per_s"])
    emit("ps/aggregate_multi_steps_per_s", best["multi"] / steps * 1e6,
         f"{out['multi_steps_per_s']:.2f} steps/s")
    emit("ps/aggregate_independent_steps_per_s",
         best["independent"] / steps * 1e6,
         f"{out['independent_steps_per_s']:.2f} steps/s")
    emit("ps/aggregate_speedup", 0.0,
         f"{out['multi_over_independent']:.2f}x")
    return out


def _sched_bench(n_jobs: int, ticks: int, capacity: int):
    """Scheduler-policy spread under capacity pressure."""
    from repro.launch.multi_job import build_multi_job, run_ticks
    from repro.ps import make_scheduler

    rows = []
    for policy in ("rr", "priority", "spsf"):
        server, jobs, _ = build_multi_job(
            n_jobs, 8, seed=0, fit_steps=60,
            priorities=list(range(n_jobs)), metrics_every=50)
        sched = make_scheduler(policy)
        # compile both the full-capacity and the capacity-C dispatch
        # shapes before timing (the jit cache is process-global, so the
        # first policy would otherwise eat every trace)
        run_ticks(server, jobs, sched, 2)
        run_ticks(server, jobs, sched, 3, capacity=capacity)
        t0 = time.perf_counter()
        out = run_ticks(server, jobs, sched, ticks, capacity=capacity)
        wall = time.perf_counter() - t0
        counts = list(out["serviced"].values())
        total = sum(counts)
        row = {"policy": policy, "n_jobs": n_jobs, "capacity": capacity,
               "ticks": ticks, "total_steps": total,
               "steps_per_s": total / wall,
               "service_spread": max(counts) - min(counts),
               "serviced": out["serviced"],
               "sim_clock": {j.job_id: j.trainer.sim_clock
                             for j in jobs.values()}}
        emit(f"ps/sched_{policy}_steps_per_s", wall / max(total, 1) * 1e6,
             f"{row['steps_per_s']:.2f} steps/s;"
             f"spread={row['service_spread']}")
        rows.append(row)
    return rows


def bench_ps(quick: bool = False, out_path: str = "BENCH_ps.json",
             n_list=DECISION_NS, j_list=DECISION_JS,
             decision_iters: int = None, agg_jobs: int = None,
             agg_ticks: int = None, sched_ticks: int = None):
    iters = decision_iters if decision_iters is not None else (
        4 if quick else 10)
    a_jobs = agg_jobs if agg_jobs is not None else (3 if quick else 4)
    a_ticks = agg_ticks if agg_ticks is not None else (8 if quick else 20)
    s_ticks = sched_ticks if sched_ticks is not None else (
        8 if quick else 24)
    results = {
        "schema": "bench_ps/v1",
        "quick": quick,
        "decision": _decision_bench(n_list, j_list, iters),
        "aggregate": _aggregate_bench(a_jobs, a_ticks),
        "sched": _sched_bench(a_jobs, s_ticks, capacity=max(1, a_jobs - 1)),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("ps/json_written", 0.0, out_path)
    return results
