"""Multi-tenant PS benchmark: batched vs looped decisions + schedulers.

Six sections, emitted as CSV rows AND into a machine-readable
``BENCH_ps.json`` (schema ``bench_ps/v2``) — the perf trajectory's fourth
datapoint after agg/controller/elastic:

  * ``decision`` — per-tick decision latency for J concurrent jobs:
    J looped single-job ``CutoffController(backend="device")`` fused
    dispatches vs ONE ``PSServer`` vmapped batched dispatch, swept over
    J in {1, 4, 16, 64, 256} x n_workers.  This is the number the
    subsystem exists for: dispatch overhead paid once per tick, so the
    batched path must not lose anywhere and must win from J=4 up
    (scripts/ci.sh --bench gates on it).
  * ``ragged`` — a MIXED-width job set (the pad-to-bucket tentpole):
    jobs at different worker widths share one padded bucket, so the
    whole mix still costs exactly one dispatch per tick
    (``dispatches_per_tick == 1.0`` is asserted into the row).
  * ``aggregate`` — end-to-end multi-job Trainer throughput: J tiny
    training jobs through one PSServer vs J independent Trainers each
    with its own device controller (the "J independent servers"
    baseline).
  * ``refit`` — tick latency WHILE an async ELBO refit is running on a
    worker thread: the tick path must not block on ``model.fit``
    (``nonblocking`` is measured with the fit gated open only after the
    timed ticks complete).
  * ``sched`` — under capacity pressure (C < J serviced per tick), the
    throughput/service spread of the round-robin, priority and
    shortest-predicted-step-first policies.
  * ``sched_churn`` — adversarial admit/evict/resize churn around three
    long-lived mixed-width jobs while round-robin serves under capacity
    pressure: throughput plus the long-lived jobs' service spread (the
    cursor-invalidation regression, measured instead of unit-tested).
"""
from __future__ import annotations

import json
import threading
import time

import numpy as np

from benchmarks.common import emit


DECISION_NS = (8, 158)
DECISION_JS = (1, 4, 16, 64, 256)
QUICK_JS = (1, 4, 16)
RAGGED_WIDTHS = (158, 96, 32, 8)
QUICK_RAGGED_WIDTHS = (16, 10, 6)


def _model_for(n: int, trace, lag: int = 20):
    from repro.core.runtime_model.api import RuntimeModel

    # untrained weights time identically to trained ones; skip the fit
    rm = RuntimeModel(n_workers=n, lag=lag).init(0)
    rm.norm_scale = float(2.0 * trace[: lag + 1].mean())
    return rm


def _looped_tick(ctls, sims):
    from repro.core.cutoff import order_stats

    for ctl, sim in zip(ctls, sims):
        times = sim.step()
        c = ctl.predict_cutoff()
        it = order_stats.iter_time(times, c)
        ctl.observe(times, times <= it + 1e-12)


def _batched_tick(server, handles, sims):
    from repro.core.cutoff import order_stats

    for h, sim in zip(handles, sims):
        times = sim.step()
        c = h.predict_cutoff()
        it = order_stats.iter_time(times, c)
        h.observe(times, times <= it + 1e-12)
    server.flush()


def _decision_bench(n_list, j_list, iters: int, k_samples: int = 64,
                    blocks: int = 3):
    """Batched vs looped per-tick latency, interleaved best-of blocks."""
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.controller import CutoffController
    from repro.ps import PSServer

    rows = []
    for n in n_list:
        trace = paper_cluster_158(seed=0, n_workers=n).run(25)
        rm = _model_for(n, trace)
        for J in j_list:
            ctls = [CutoffController(rm, k_samples=k_samples, seed=j,
                                     backend="device") for j in range(J)]
            server = PSServer()
            handles = []
            for j, ctl in enumerate(ctls):
                tr = paper_cluster_158(seed=10 + j, n_workers=n).run(25)
                ctl.seed_window(tr)
                handles.append(server.admit(
                    f"job{j}", rm, window=tr, k_samples=k_samples, seed=j))

            def sims(s):
                return [paper_cluster_158(seed=s + j, n_workers=n)
                        for j in range(J)]

            # warmup: compile every fused variant on both paths
            for _ in range(3):
                _looped_tick(ctls, sims(900))
                _batched_tick(server, handles, sims(900))
            # large-J loops are dominated by the looped baseline's J
            # dispatches; fewer timed iters keep the sweep bounded
            it_j = iters if J <= 16 else max(2, iters // 4)
            best = {"looped": float("inf"), "batched": float("inf")}
            for _ in range(blocks):
                s_l, s_b = sims(500), sims(500)
                t0 = time.perf_counter()
                for _ in range(it_j):
                    _looped_tick(ctls, s_l)
                best["looped"] = min(best["looped"],
                                     (time.perf_counter() - t0) / it_j * 1e6)
                t0 = time.perf_counter()
                for _ in range(it_j):
                    _batched_tick(server, handles, s_b)
                best["batched"] = min(
                    best["batched"],
                    (time.perf_counter() - t0) / it_j * 1e6)
            entry = {"n_workers": n, "n_jobs": J, "k_samples": k_samples,
                     "looped_us": best["looped"],
                     "batched_us": best["batched"],
                     "speedup": best["looped"] / best["batched"]}
            emit(f"ps/decision_looped_n{n}_j{J}", best["looped"],
                 f"n={n};J={J};K={k_samples}")
            emit(f"ps/decision_batched_n{n}_j{J}", best["batched"],
                 f"n={n};J={J};K={k_samples}")
            emit(f"ps/decision_speedup_n{n}_j{J}", 0.0,
                 f"{entry['speedup']:.2f}x")
            rows.append(entry)
    return rows


def _ragged_bench(iters: int, widths=RAGGED_WIDTHS, k_samples: int = 32,
                  blocks: int = 3):
    """Mixed-width job set through ONE padded bucket vs looped per-width
    controllers — the pad-to-bucket tentpole's latency and its
    one-dispatch-per-tick contract."""
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.controller import CutoffController
    from repro.ps import PSServer

    ctls, handles = [], []
    server = PSServer()
    for j, w in enumerate(widths):
        trace = paper_cluster_158(seed=w, n_workers=w).run(25)
        rm = _model_for(w, trace)
        ctl = CutoffController(rm, k_samples=k_samples, seed=j,
                               backend="device")
        ctl.seed_window(trace)
        ctls.append(ctl)
        handles.append(server.admit(f"job{j}", rm, window=trace,
                                    k_samples=k_samples, seed=j))
    sigs = {server.registry[f"job{j}"].bucket_sig
            for j in range(len(widths))}
    assert len(sigs) == 1, "mixed widths must share one bucket"

    def sims(s):
        return [paper_cluster_158(seed=s + j, n_workers=w)
                for j, w in enumerate(widths)]

    for _ in range(3):
        _looped_tick(ctls, sims(900))
        _batched_tick(server, handles, sims(900))
    d0, t0c = server.dispatches, server.ticks
    best = {"looped": float("inf"), "batched": float("inf")}
    for _ in range(blocks):
        s_l, s_b = sims(500), sims(500)
        t0 = time.perf_counter()
        for _ in range(iters):
            _looped_tick(ctls, s_l)
        best["looped"] = min(best["looped"],
                             (time.perf_counter() - t0) / iters * 1e6)
        t0 = time.perf_counter()
        for _ in range(iters):
            _batched_tick(server, handles, s_b)
        best["batched"] = min(best["batched"],
                              (time.perf_counter() - t0) / iters * 1e6)
    dpt = ((server.dispatches - d0)
           / max(1, server.ticks - t0c))
    row = {"widths": list(widths), "n_pad": int(max(widths)),
           "n_jobs": len(widths), "k_samples": k_samples,
           "looped_us": best["looped"], "batched_us": best["batched"],
           "speedup": best["looped"] / best["batched"],
           "dispatches_per_tick": dpt}
    emit("ps/ragged_looped_us", best["looped"],
         f"widths={'x'.join(map(str, widths))}")
    emit("ps/ragged_batched_us", best["batched"],
         f"widths={'x'.join(map(str, widths))}")
    emit("ps/ragged_speedup", 0.0,
         f"{row['speedup']:.2f}x;dpt={dpt:.2f}")
    return row


def _refit_bench(ticks: int = 12):
    """Tick latency during an ACTIVE async refit.  The fit thread is
    gated shut for the whole timed window, so any blocking would show up
    as a tick stall; the gate opens afterwards and the real ELBO fit
    wall-clock is recorded for scale."""
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.cutoff import order_stats
    from repro.ps import PSServer

    n = 16
    trace = paper_cluster_158(seed=0, n_workers=n).run(30)
    rm = _model_for(n, trace, lag=10)
    srv = PSServer(refit_steps=60, refit_batch=8, refit_fresh=2,
                   refit_async=True)
    ha = srv.admit("a", rm, window=trace[-11:], k_samples=32, seed=0)
    hb = srv.admit("b", rm, window=trace[-11:], k_samples=32, seed=1)
    gate = threading.Event()
    fit_wall = {}
    real_fit = srv._fit_model

    def gated_fit(job, rows, nw, seed):
        gate.wait(timeout=120)
        t0 = time.perf_counter()
        out = real_fit(job, rows, nw, seed)
        fit_wall["s"] = time.perf_counter() - t0
        return out

    srv._fit_model = gated_fit
    hb.resize(12, col_map=np.arange(12))
    sims = {"a": paper_cluster_158(seed=5, n_workers=16),
            "b": paper_cluster_158(seed=6, n_workers=12)}

    def tick():
        for h, s in ((ha, sims["a"]), (hb, sims["b"])):
            times = s.step()
            c = h.predict_cutoff()
            it = order_stats.iter_time(times, c)
            h.observe(times, times <= it + 1e-12)
        srv.flush()

    # warm compile AND grow b's trace past the refit-trigger floor so the
    # gated refit is already in flight when the timed window starts
    for _ in range(10):
        tick()
    lat = []
    for _ in range(ticks):
        t0 = time.perf_counter()
        tick()
        lat.append(time.perf_counter() - t0)
    task = srv.registry["b"].refit_task
    nonblocking = task is not None and task[0].is_alive()
    gate.set()
    srv.wait_refits()
    row = {"ticks_during_refit": ticks,
           "tick_p50_us": float(np.median(lat) * 1e6),
           "tick_max_us": float(np.max(lat) * 1e6),
           "fit_wall_s": float(fit_wall.get("s", 0.0)),
           "nonblocking": bool(nonblocking),
           "rejoined": bool(hb.mode == "dmm")}
    emit("ps/refit_tick_p50_us", row["tick_p50_us"],
         f"nonblocking={row['nonblocking']};rejoined={row['rejoined']}")
    emit("ps/refit_fit_wall_s", row["fit_wall_s"] * 1e6, "gated ELBO fit")
    return row


def _sched_churn_bench(ticks: int, capacity: int = 3, seed: int = 0):
    """Adversarial churn: admit/evict transient jobs and resize the
    long-lived ones while round-robin serves under capacity pressure —
    bucket repacks, fallback degradations and async refits all ride the
    tick loop.  The long-lived jobs' service spread is the measured form
    of the cursor-invalidation regression."""
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.cutoff import order_stats
    from repro.ps import PSServer, RoundRobinScheduler
    from repro.ps.scheduler import job_views

    widths = (16, 10, 6)
    traces = {w: paper_cluster_158(seed=w, n_workers=w).run(25)
              for w in widths}
    models = {w: _model_for(w, traces[w], lag=10) for w in widths}
    srv = PSServer(refit_steps=30, refit_fresh=4, refit_async=True)
    rng = np.random.default_rng(seed)
    sims, counts, base_w = {}, {}, {}
    state = {"next": 0}

    def admit_one(w):
        jid = f"job{state['next']}"
        state["next"] += 1
        srv.admit(jid, models[w], window=traces[w], k_samples=16,
                  seed=state["next"])
        sims[jid] = paper_cluster_158(seed=1000 + state["next"],
                                      n_workers=w)
        counts[jid] = 0
        base_w[jid] = w
        return jid

    core = [admit_one(w) for w in widths]     # long-lived
    extras = []
    sched = RoundRobinScheduler()
    events = {"admit": 0, "evict": 0, "resize": 0}
    # warm the dispatch shapes before timing
    for jid in core:
        h = srv.handle(jid)
        t = sims[jid].step()
        c = h.predict_cutoff()
        h.observe(t, t <= order_stats.iter_time(t, c) + 1e-12)
    srv.flush()
    t_start = time.perf_counter()
    for tick in range(ticks):
        ev = rng.integers(0, 5)
        if ev == 0 and len(extras) < 4:
            extras.append(admit_one(int(rng.choice(widths))))
            events["admit"] += 1
        elif ev == 1 and extras:
            jid = extras.pop(int(rng.integers(len(extras))))
            srv.evict(jid)
            sims.pop(jid)
            events["evict"] += 1
        elif ev == 2:
            jid = core[int(rng.integers(len(core)))]
            h = srv.handle(jid)
            w_new = (h.n - 2) if h.n == base_w[jid] else base_w[jid]
            h.resize(w_new)
            sims[jid] = paper_cluster_158(seed=2000 + tick,
                                          n_workers=w_new)
            events["resize"] += 1
        order = sched.order(job_views(srv), capacity)
        srv.prefetch(order)
        for jid in order:
            h = srv.handle(jid)
            t = sims[jid].step()
            c = h.predict_cutoff()
            h.observe(t, t <= order_stats.iter_time(t, c) + 1e-12)
            counts[jid] += 1
        srv.flush()
    wall = time.perf_counter() - t_start
    srv.wait_refits(core)
    core_counts = [counts[j] for j in core]
    total = sum(counts.values())
    row = {"ticks": ticks, "capacity": capacity, "events": events,
           "total_steps": total, "steps_per_s": total / wall,
           "core_service_spread": max(core_counts) - min(core_counts),
           "core_modes": {j: srv.handle(j).mode for j in core}}
    emit("ps/sched_churn_steps_per_s", wall / max(total, 1) * 1e6,
         f"{row['steps_per_s']:.2f} steps/s;"
         f"spread={row['core_service_spread']};"
         f"admit={events['admit']};evict={events['evict']};"
         f"resize={events['resize']}")
    return row


def _aggregate_bench(n_jobs: int, ticks: int, blocks: int = 2):
    """J training jobs through one PSServer vs J independent servers."""
    import jax

    from repro import optim
    from repro.cluster.simulator import paper_cluster_158
    from repro.configs.base import bench_tiny_config
    from repro.core.controller import CutoffController
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.multi_job import build_multi_job, run_ticks
    from repro.launch.train import Trainer, jit_train_step
    from repro.models import model as M
    from repro.ps import make_scheduler

    n_per_job = 8
    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    # -- independent baseline: one CutoffController per job -------------
    def build_independent():
        trainers = []
        for j in range(n_jobs):
            trace = paper_cluster_158(seed=10 + j,
                                      n_workers=n_per_job).run(40)
            rm = RuntimeModel(n_workers=n_per_job, lag=10).init(j)
            rm.norm_scale = float(2.0 * trace[:11].mean())
            ctl = CutoffController(rm, k_samples=32, seed=100 * j,
                                   backend="device")
            ctl.seed_window(trace[-11:])
            data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                                   global_batch=24, seed=j)
            tr = Trainer(cfg=cfg, step_fn=step_fn, data=data,
                         controller=ctl,
                         timer=paper_cluster_158(seed=200 + j,
                                                 n_workers=n_per_job),
                         n_workers=n_per_job, metrics_every=50)

            def init_fn(jj=j):
                params = M.init_model(cfg, jax.random.PRNGKey(jj))
                return {"params": params, "opt": opt.init(params)}

            tr.restore_or_init(init_fn)
            trainers.append(tr)
        return trainers

    # warm both paths, then interleaved best-of blocks
    server, jobs, _ = build_multi_job(n_jobs, n_per_job, seed=0,
                                      fit_steps=0, metrics_every=50)
    sched = make_scheduler("rr")
    run_ticks(server, jobs, sched, 2)
    indep = build_independent()
    for tr in indep:
        tr.run(2)
    best = {"multi": float("inf"), "independent": float("inf")}
    for _ in range(blocks):
        t0 = time.perf_counter()
        run_ticks(server, jobs, sched, ticks)
        best["multi"] = min(best["multi"], (time.perf_counter() - t0))
        t0 = time.perf_counter()
        for _ in range(ticks):
            for tr in indep:
                tr.run(1)
        best["independent"] = min(best["independent"],
                                  (time.perf_counter() - t0))
    steps = ticks * n_jobs
    out = {"arch": f"{cfg.name}/bench_tiny", "n_jobs": n_jobs,
           "n_per_job": n_per_job, "ticks": ticks,
           "multi_steps_per_s": steps / best["multi"],
           "independent_steps_per_s": steps / best["independent"]}
    out["multi_over_independent"] = (out["multi_steps_per_s"]
                                     / out["independent_steps_per_s"])
    emit("ps/aggregate_multi_steps_per_s", best["multi"] / steps * 1e6,
         f"{out['multi_steps_per_s']:.2f} steps/s")
    emit("ps/aggregate_independent_steps_per_s",
         best["independent"] / steps * 1e6,
         f"{out['independent_steps_per_s']:.2f} steps/s")
    emit("ps/aggregate_speedup", 0.0,
         f"{out['multi_over_independent']:.2f}x")
    return out


def _sched_bench(n_jobs: int, ticks: int, capacity: int):
    """Scheduler-policy spread under capacity pressure."""
    from repro.launch.multi_job import build_multi_job, run_ticks
    from repro.ps import make_scheduler

    rows = []
    for policy in ("rr", "priority", "spsf"):
        server, jobs, _ = build_multi_job(
            n_jobs, 8, seed=0, fit_steps=60,
            priorities=list(range(n_jobs)), metrics_every=50)
        sched = make_scheduler(policy)
        # compile both the full-capacity and the capacity-C dispatch
        # shapes before timing (the jit cache is process-global, so the
        # first policy would otherwise eat every trace)
        run_ticks(server, jobs, sched, 2)
        run_ticks(server, jobs, sched, 3, capacity=capacity)
        t0 = time.perf_counter()
        out = run_ticks(server, jobs, sched, ticks, capacity=capacity)
        wall = time.perf_counter() - t0
        counts = list(out["serviced"].values())
        total = sum(counts)
        row = {"policy": policy, "n_jobs": n_jobs, "capacity": capacity,
               "ticks": ticks, "total_steps": total,
               "steps_per_s": total / wall,
               "service_spread": max(counts) - min(counts),
               "serviced": out["serviced"],
               "sim_clock": {j.job_id: j.trainer.sim_clock
                             for j in jobs.values()}}
        emit(f"ps/sched_{policy}_steps_per_s", wall / max(total, 1) * 1e6,
             f"{row['steps_per_s']:.2f} steps/s;"
             f"spread={row['service_spread']}")
        rows.append(row)
    return rows


def bench_ps(quick: bool = False, out_path: str = "BENCH_ps.json",
             n_list=DECISION_NS, j_list=None,
             decision_iters: int = None, agg_jobs: int = None,
             agg_ticks: int = None, sched_ticks: int = None,
             ragged_widths=None, churn_ticks: int = None):
    iters = decision_iters if decision_iters is not None else (
        4 if quick else 10)
    js = j_list if j_list is not None else (
        QUICK_JS if quick else DECISION_JS)
    widths = ragged_widths if ragged_widths is not None else (
        QUICK_RAGGED_WIDTHS if quick else RAGGED_WIDTHS)
    a_jobs = agg_jobs if agg_jobs is not None else (3 if quick else 4)
    a_ticks = agg_ticks if agg_ticks is not None else (8 if quick else 20)
    s_ticks = sched_ticks if sched_ticks is not None else (
        8 if quick else 24)
    c_ticks = churn_ticks if churn_ticks is not None else (
        12 if quick else 60)
    results = {
        "schema": "bench_ps/v2",
        "quick": quick,
        "decision": _decision_bench(n_list, js, iters),
        "ragged": _ragged_bench(iters, widths=widths),
        "aggregate": _aggregate_bench(a_jobs, a_ticks),
        "refit": _refit_bench(ticks=4 if quick else 12),
        "sched": _sched_bench(a_jobs, s_ticks, capacity=max(1, a_jobs - 1)),
        "sched_churn": _sched_churn_bench(c_ticks,
                                          capacity=max(1, a_jobs - 1)),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("ps/json_written", 0.0, out_path)
    return results
