"""Straggler-policy frontier: six policies raced on wall-clock-to-loss.

The paper's cutoff discard is ONE point on an error–runtime frontier.
This bench races the whole frontier on a seeded straggler-heavy cluster
— identical init, data stream, and step-time draws for every policy,
only the straggler policy differs:

  * ``sync``     — full sync (wait for everyone; no straggler error)
  * ``static``   — fixed cutoff c < n (Chen et al.)
  * ``firstk``   — first n - b arrivals by count (backup workers)
  * ``dmm``      — the paper's runtime-model cutoff (CutoffController)
  * ``anytime``  — DMM cutoff + stragglers contribute completed-microbatch
                   PARTIAL sums weighted by their fraction (Ferdinand &
                   Draper; ``AnytimeController``)
  * ``stale``    — DMM cutoff + a dropped step's mean gradient folded into
                   the NEXT step with a decayed weight (Dutta et al.;
                   ``StaleReuseController``)

Race protocol: full sync runs ``steps`` steps and sets BOTH the loss
target (its trailing final loss) and the simulated clock budget; every
other policy then runs until it exhausts that same clock budget — a
cutoff policy takes MORE steps in the same wall-clock, which is exactly
the trade the frontier measures.  ``launch.train.clock_to_loss`` (full
trailing window) decides who got to the target first.

All six run the explicit ``mask_agg="psum"`` aggregation (the only path
that materializes per-worker partial sums), ``GRAD_ACCUM`` microbatches
per worker.  Output: CSV rows + ``BENCH_frontier.json``
(schema ``bench_frontier/v1``), consumed by the ``scripts/ci.sh --bench``
gate and the ``paper_figures.bench_frontier_panel`` figure.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit

GRAD_ACCUM = 4
DECAY = 0.5
# heavy straggler tail (the paper's Fig. 2 motivation): ~1 spiked worker
# per step at ~3.5x runtime — the regime where discarding pays and where
# the partial/stale policies have real work to recover
SIM = dict(n_nodes=4, spike_prob=0.12, spike_scale=2.5)


def _race(steps: int):
    import jax

    from repro import optim
    from repro.cluster.simulator import ClusterSim
    from repro.configs.base import bench_tiny_config
    from repro.core.controller import (AnytimeController, CutoffController,
                                       FirstKController, FullSyncController,
                                       StaleReuseController,
                                       StaticCutoffController)
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, clock_to_loss, jit_train_step
    from repro.models import model as M
    from repro.obs import ObsRun

    cfg = bench_tiny_config()
    n = 8
    trace = ClusterSim(n_workers=n, seed=0, **SIM).run(120)
    rm = RuntimeModel(n_workers=n, lag=10).init(0)
    rm.fit(trace, steps=100, batch=8, seed=0)
    opt = optim.adamw(1e-2)
    step_fn = jit_train_step(cfg, opt, grad_accum=GRAD_ACCUM,
                             mask_agg="psum")
    step_fn_stale = jit_train_step(cfg, opt, grad_accum=GRAD_ACCUM,
                                   mask_agg="psum", stale_reuse=True)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    def dmm():
        ctl = CutoffController(rm, k_samples=32, seed=0)
        ctl.seed_window(trace[-40:])
        return ctl

    policies = [
        ("sync", FullSyncController(n), step_fn),
        ("static", StaticCutoffController(n, cutoff=7), step_fn),
        ("firstk", FirstKController(n, backup=1), step_fn),
        ("dmm", dmm(), step_fn),
        ("anytime", AnytimeController(dmm(), n_micro=GRAD_ACCUM), step_fn),
        ("stale", StaleReuseController(dmm(), decay=DECAY), step_fn_stale),
    ]

    runs = {}
    budget = None
    for name, ctl, fn in policies:
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16,
                               global_batch=32, seed=0)
        # every policy records to its own in-memory ObsRun: the race
        # trajectory is read back from the obs step stream (the one
        # recorder) and the quality wrapper scores each decision —
        # decisions stay bit-identical under the wrap
        obs = ObsRun()
        tr = Trainer(cfg=cfg, step_fn=fn, data=data,
                     controller=obs.wrap(ctl, policy=name),
                     timer=ClusterSim(n_workers=n, seed=9, **SIM),
                     n_workers=n, mask_agg="psum", metrics_every=0,
                     obs=obs, name=name)
        tr.restore_or_init(init_fn)
        t0 = time.perf_counter()
        if name == "sync":
            tr.run(steps)
            budget = tr.sim_clock      # everyone gets sync's clock budget
        else:
            while tr.sim_clock < budget and tr.step < 6 * steps:
                tr.run(10)
        wall = time.perf_counter() - t0
        runs[name] = {"tr": tr, "steps_per_s": tr.step / wall}

    target = runs["sync"]["tr"].obs.steps.final_loss(window=3)

    race = []
    for name, _, _ in policies:
        tr = runs[name]["tr"]
        steps_stream = tr.obs.steps
        t_loss = clock_to_loss(steps_stream, target)
        row = {"policy": name,
               "clock_to_loss": t_loss,
               "final_loss": steps_stream.final_loss(window=3),
               "steps": len(steps_stream),
               "total_clock": steps_stream.total_clock(),
               "mean_cutoff": float(np.mean(
                   [r["c"] for r in steps_stream.records])),
               "steps_per_s": runs[name]["steps_per_s"]}
        race.append(row)
        fmt = "n/a" if t_loss is None else f"{t_loss:.1f}s"
        emit(f"frontier/{name}_clock_to_loss", 0.0,
             f"{fmt};final={row['final_loss']:.3f};"
             f"c={row['mean_cutoff']:.2f};steps={row['steps']}")
    return {"arch": f"{cfg.name}/bench_tiny", "n_workers": n,
            "sync_steps": steps, "clock_budget": float(budget),
            "grad_accum": GRAD_ACCUM, "stale_decay": DECAY,
            "sim": dict(SIM), "target_loss": target, "race": race}


def bench_frontier(quick: bool = False,
                   out_path: str = "BENCH_frontier.json",
                   steps: int = None):
    steps = steps if steps is not None else (60 if quick else 120)
    results = {
        "schema": "bench_frontier/v1",
        "quick": quick,
        "frontier": _race(steps),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("frontier/json_written", 0.0, out_path)
    return results
