"""Elastic-membership benchmark: resize overhead + churn-scenario run.

Two sections, both emitted as CSV rows AND into a machine-readable
``BENCH_elastic.json`` (schema ``bench_elastic/v1``) — the perf
trajectory's third datapoint after ``BENCH_agg.json`` and
``BENCH_controller.json``:

  * ``resize`` — wall time of ``CutoffController.resize`` (window remap +
    ring rebuild) per backend across shrink/grow transitions; this is the
    synchronous cost every membership change pays on the decision path;
  * ``churn`` — end-to-end Trainer steps/s over a seeded 8 -> 6 -> 8
    ``ChurnSim`` schedule with the ``ElasticController`` (fallback +
    refit) vs full sync, plus the refit wall time the fallback period has
    to cover and the simulated wall-clock-to-loss ratio.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import emit


RESIZE_NS = (32, 158)


def _resize_bench(n_list, repeats: int = 3):
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.controller import CutoffController
    from repro.core.runtime_model.api import RuntimeModel

    rows = []
    for n in n_list:
        trace = paper_cluster_158(seed=0, n_workers=n).run(25)

        def model_for(w):
            rm = RuntimeModel(n_workers=w, lag=20).init(0)
            rm.norm_scale = float(2.0 * trace[:21].mean())
            return rm

        n_small = n - max(2, n // 8)
        models = {n: model_for(n), n_small: model_for(n_small)}
        for backend in ("device", "numpy"):
            best = {"shrink": float("inf"), "grow": float("inf")}
            for _ in range(repeats):
                ctl = CutoffController(models[n], k_samples=16, seed=0,
                                       backend=backend)
                ctl.seed_window(trace)
                t0 = time.perf_counter()
                ctl.resize(n_small, model=models[n_small])
                best["shrink"] = min(best["shrink"],
                                     (time.perf_counter() - t0) * 1e6)
                t0 = time.perf_counter()
                ctl.resize(n, model=models[n])
                best["grow"] = min(best["grow"],
                                   (time.perf_counter() - t0) * 1e6)
            entry = {"n_workers": n, "n_small": n_small, "backend": backend,
                     "shrink_us": best["shrink"], "grow_us": best["grow"]}
            emit(f"elastic/resize_shrink_{backend}_n{n}", best["shrink"],
                 f"{n}->{n_small}")
            emit(f"elastic/resize_grow_{backend}_n{n}", best["grow"],
                 f"{n_small}->{n}")
            rows.append(entry)
    return rows


def _churn_bench(steps: int, refit_steps: int):
    import jax

    from repro import optim
    from repro.cluster.simulator import (ChurnEvent, ChurnSim,
                                         paper_cluster_158)
    from repro.configs.base import bench_tiny_config
    from repro.core.controller import ElasticController, FullSyncController
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, clock_to_loss, jit_train_step
    from repro.models import model as M
    from repro.obs import ObsRun

    cfg = bench_tiny_config()
    n = 8
    shrink_at, recover_at = steps // 3, 2 * steps // 3
    trace = paper_cluster_158(seed=0, n_workers=n).run(120)
    rm = RuntimeModel(n_workers=n, lag=10).init(0)
    rm.fit(trace, steps=100, batch=8, seed=0)
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    def make_timer():
        return ChurnSim(paper_cluster_158(seed=5, n_workers=n),
                        [ChurnEvent(step=shrink_at, kill=(6, 7)),
                         ChurnEvent(step=recover_at, restore=(6, 7))])

    refit_wall = []

    class TimedElastic(ElasticController):
        def _fit_model(self, rows, n, seed):
            t0 = time.perf_counter()
            model = super()._fit_model(rows, n, seed)
            refit_wall.append(time.perf_counter() - t0)
            return model

    runs = {}
    for name, ctl in [
            ("elastic", None),
            ("sync", FullSyncController(n))]:
        if ctl is None:
            ctl = TimedElastic(rm, k_samples=32, seed=0,
                               refit_steps=refit_steps, refit_fresh=3,
                               fallback_warmup=2)
            ctl.seed_window(trace[-40:])
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=24, seed=0)
        # per-run in-memory obs: clock-to-loss reads the step stream
        tr = Trainer(cfg=cfg, step_fn=step_fn, data=data, controller=ctl,
                     timer=make_timer(), n_workers=n, obs=ObsRun(),
                     name=name)
        tr.restore_or_init(init_fn)
        tr.run(2)                          # compile the width-8 step
        t0 = time.perf_counter()
        tr.run(steps)
        wall = time.perf_counter() - t0
        runs[name] = {"tr": tr, "steps_per_s": steps / wall}

    el, sync = runs["elastic"]["tr"], runs["sync"]["tr"]
    target = sync.obs.steps.final_loss(window=3)
    clock_to = lambda stream: clock_to_loss(stream, target)

    out = {"arch": f"{cfg.name}/bench_tiny", "n_workers": n, "steps": steps,
           "shrink_at": shrink_at, "recover_at": recover_at,
           "elastic_steps_per_s": runs["elastic"]["steps_per_s"],
           "sync_steps_per_s": runs["sync"]["steps_per_s"],
           "refit_s": refit_wall, "n_refits": len(refit_wall),
           "clock_to_loss_elastic": clock_to(el.obs.steps),
           "clock_to_loss_sync": clock_to(sync.obs.steps)}
    emit("elastic/churn_elastic_steps_per_s",
         1e6 / out["elastic_steps_per_s"],
         f"{out['elastic_steps_per_s']:.2f} steps/s")
    emit("elastic/churn_sync_steps_per_s", 1e6 / out["sync_steps_per_s"],
         f"{out['sync_steps_per_s']:.2f} steps/s")
    for i, s in enumerate(refit_wall):
        emit(f"elastic/refit_{i}_s", s * 1e6, "DMM refit wall time")
    fmt = lambda v: "n/a" if v is None else f"{v:.1f}s"
    emit("elastic/churn_clock_to_loss", 0.0,
         f"elastic={fmt(out['clock_to_loss_elastic'])};"
         f"sync={fmt(out['clock_to_loss_sync'])}")
    return out


def bench_elastic(quick: bool = False, out_path: str = "BENCH_elastic.json",
                  n_list=RESIZE_NS, churn_steps: int = None,
                  refit_steps: int = None):
    steps = churn_steps if churn_steps is not None else (36 if quick else 45)
    rsteps = refit_steps if refit_steps is not None else (
        30 if quick else 60)
    results = {
        "schema": "bench_elastic/v1",
        "quick": quick,
        "resize": _resize_bench(n_list),
        "churn": _churn_bench(steps, rsteps),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("elastic/json_written", 0.0, out_path)
    return results
