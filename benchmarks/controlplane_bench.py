"""Control-plane drill bench: detection latency, recovery, steps lost.

Two sections, both seeded and deterministic:

  * ``detection`` — a :meth:`FaultPlan.storm` of crashes and hangs over
    a 16-worker sim pool, supervisor ticking every step.  Measures the
    per-tick supervisor overhead and the detection-latency distribution;
    the CI gate pins ``max_detection_ticks <= dead_after + 1`` (the
    heartbeat determinism contract — a deadline miss is detected the
    tick after it expires, never later).

  * ``recovery`` — the full supervised trainer drill
    (``launch.supervised.run_supervised``: crash + hang + flaky restart
    + slowdown) raced against (a) an UNSUPERVISED baseline suffering the
    same faults with nobody restarting the fallen workers and (b) a
    fault-free run of the same trainer.  Reports worker-steps lost
    (sum over steps of ``n_full - healthy``), the throughput retained
    vs fault-free, and the scripted-replay equivalence bit.  The CI
    gate pins supervised steps-lost strictly below the unsupervised
    baseline and the replay ``match``.

Output: CSV rows + ``BENCH_controlplane.json`` (schema
``bench_controlplane/v1``), consumed by ``scripts/ci.sh --bench`` and
guarded by ``tests/test_bench_controlplane.py``.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit

DEAD_AFTER = 4
SUSPECT_AFTER = 2


def _storm_detection(n_workers: int = 16, n_faults: int = 6,
                     horizon: int = 40, seed: int = 0) -> dict:
    from repro.cluster.simulator import OverlaySim, paper_cluster_158
    from repro.controlplane import (FaultInjector, FaultPlan,
                                    SimWorkerPool, Supervisor,
                                    drill_report)

    overlay = OverlaySim(paper_cluster_158(seed + 1, n_workers=n_workers))
    plan = FaultPlan.storm(n_workers, n_faults, horizon, seed=seed,
                           kinds=("crash", "hang"))
    pool = SimWorkerPool(overlay, FaultInjector(plan, seed=seed))
    sup = Supervisor(pool, suspect_after=SUSPECT_AFTER,
                     dead_after=DEAD_AFTER, seed=seed)
    ticks = plan.horizon + 30            # room for every restart to land
    t0 = time.perf_counter()
    for t in range(1, ticks + 1):
        sup.tick(t)
    us_per_tick = (time.perf_counter() - t0) / ticks * 1e6
    rep = drill_report(sup.log.events)
    emit("controlplane/supervisor_tick", us_per_tick,
         f"n={n_workers};faults={rep['n_faults']}")
    emit("controlplane/detection_latency", 0.0,
         f"max={rep['max_detection_ticks']};"
         f"mean={rep['mean_detection_ticks']:.2f};"
         f"deadline={DEAD_AFTER}")
    return {"n_workers": n_workers, "ticks": ticks,
            "dead_after": DEAD_AFTER, "suspect_after": SUSPECT_AFTER,
            "n_faults": rep["n_faults"], "n_detected": rep["n_detected"],
            "max_detection_ticks": rep["max_detection_ticks"],
            "mean_detection_ticks": rep["mean_detection_ticks"],
            "restarts": rep["restarts"], "evicted": rep["evicted"],
            "us_per_tick": us_per_tick}


class _UnsupervisedTimer:
    """The same faults, nobody watching: full-width timer whose fallen
    workers stall forever (no detection, no restarts)."""

    def __init__(self, overlay, pool, monitor, log):
        self.overlay, self.pool = overlay, pool
        self.monitor, self.log = monitor, log
        self.healthy = []

    @property
    def n_workers(self) -> int:
        return self.overlay.n_workers

    @property
    def active_ids(self):
        return np.arange(self.overlay.n_workers)

    @property
    def t(self) -> int:
        return self.overlay.t

    def step(self):
        self.pool.pump(self.overlay.t, self.monitor, self.log)
        self.healthy.append(self.pool.healthy_count(self.active_ids))
        return self.overlay.step()


def _recovery_race(steps: int = 60, seed: int = 0,
                   n_workers: int = 6) -> dict:
    import jax

    from repro import optim
    from repro.cluster.simulator import OverlaySim, paper_cluster_158
    from repro.configs.base import bench_tiny_config
    from repro.controlplane import (EventLog, FaultInjector,
                                    HeartbeatMonitor, SimWorkerPool)
    from repro.core.controller import ElfvingController
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.supervised import default_plan, run_supervised
    from repro.launch.train import Trainer, jit_train_step

    sup_out = run_supervised(steps=steps, seed=seed, n_workers=n_workers,
                             verbose=False)
    rep = sup_out["report"]
    sup_lost = int(sum(n_workers - h["n"] for h in sup_out["history"]))
    sup_clock = float(sup_out["history"][-1]["clock"])

    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    def init_fn():
        from repro.models import model as M
        params = M.init_model(cfg, jax.random.PRNGKey(seed))
        return {"params": params, "opt": opt.init(params)}

    def run_with(timer):
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=60, seed=seed)
        tr = Trainer(cfg=cfg, step_fn=step_fn, data=data,
                     controller=ElfvingController(n_workers),
                     timer=timer, n_workers=timer.n_workers)
        tr.restore_or_init(init_fn).run(steps)
        return tr

    # (a) unsupervised: identical storm, the fallen never come back
    overlay = OverlaySim(paper_cluster_158(seed + 1, n_workers=n_workers))
    pool = SimWorkerPool(overlay,
                         FaultInjector(default_plan(n_workers), seed=seed))
    base_timer = _UnsupervisedTimer(
        overlay, pool, HeartbeatMonitor(pool.worker_ids()), EventLog())
    run_with(base_timer)
    base_lost = int(sum(n_workers - h for h in base_timer.healthy))

    # (b) fault-free: the throughput the storm is measured against
    ff = run_with(paper_cluster_158(seed + 1, n_workers=n_workers))
    ff_clock = float(ff.history[-1]["clock"])

    # A step whose iter time includes a not-yet-detected stalled worker
    # pays the sim's STALL timeout — that's the detection window's cost,
    # counted separately so the steady-state throughput ratio stays
    # meaningful.
    sup_its = np.diff([0.0] + [h["clock"] for h in sup_out["history"]])
    ff_its = np.diff([0.0] + [h["clock"] for h in ff.history])
    timeout = 1e6
    n_timeout_steps = int(np.sum(sup_its >= timeout))
    sup_mean_it = float(np.mean(sup_its[sup_its < timeout]))
    retained = float(np.mean(ff_its)) / sup_mean_it

    emit("controlplane/steps_lost", 0.0,
         f"supervised={sup_lost};unsupervised={base_lost}")
    emit("controlplane/throughput_retained", 0.0,
         f"{retained:.3f};timeout_steps={n_timeout_steps};"
         f"ff_clock={ff_clock:.1f}")
    emit("controlplane/scripted_replay_match", 0.0,
         str(sup_out["match"]))
    return {"n_workers": n_workers, "steps": steps,
            "n_faults": rep["n_faults"], "n_detected": rep["n_detected"],
            "max_detection_ticks": rep["max_detection_ticks"],
            "mean_recovery_ticks": rep["mean_recovery_ticks"],
            "restarts": rep["restarts"],
            "failed_restarts": rep["failed_restarts"],
            "evicted": rep["evicted"],
            "widths_seen": sorted({int(h["n"])
                                   for h in sup_out["history"]}),
            "steps_lost": {"supervised": sup_lost,
                           "unsupervised": base_lost},
            "clock": {"supervised": sup_clock, "fault_free": ff_clock},
            "timeout_steps": n_timeout_steps,
            "throughput_retained": retained,
            "scripted_replay_match": bool(sup_out["match"])}


def bench_controlplane(quick: bool = False,
                       out_path: str = "BENCH_controlplane.json"):
    results = {
        "schema": "bench_controlplane/v1",
        "quick": quick,
        "detection": _storm_detection(
            n_faults=4 if quick else 6, horizon=30 if quick else 40),
        "recovery": _recovery_race(steps=40 if quick else 60),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("controlplane/json_written", 0.0, out_path)
    return results
