"""Controller hot-path benchmark: per-decision latency + Trainer loop rate.

Times the parameter server's critical path two ways:

  * ``decision`` — one full controller iteration (predict_cutoff + observe
    with censored imputation) for the seed-style numpy host path vs the
    fused device-resident path, across n_workers x k_samples;
  * ``trainer`` — end-to-end Trainer steps/s for the seed-style blocking
    loop (numpy controller, per-step loss fetch, no donation) vs the async
    loop (device controller, batched metrics drain, donated state).

Emits the usual CSV rows AND a machine-readable ``BENCH_controller.json``
(schema ``bench_controller/v1``) — the perf trajectory's second datapoint
after ``BENCH_agg.json``.
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks.common import emit


DECISION_NS = (8, 158, 1024)
DECISION_KS = (64, 256)


def _cycles(ctl, sim, k: int) -> float:
    """Run k predict+observe iterations; return seconds elapsed."""
    from repro.core.cutoff import order_stats

    t0 = time.perf_counter()
    for _ in range(k):
        times = sim.step()
        c = ctl.predict_cutoff()
        it = order_stats.iter_time(times, c)
        ctl.observe(times, times <= it + 1e-12)
    return time.perf_counter() - t0


def _blocked_us(ctl, sim, k: int, worker_ms: float) -> float:
    """Decision latency on the PS critical path: time blocked inside
    ``predict_cutoff`` when the workers take ``worker_ms`` per step.

    This is the paper's operating regime — the controller has a whole
    worker step of wall-clock between observing iteration t and deciding
    iteration t+1.  The device backend dispatches its fused
    observe+decide at observe time, so the inference overlaps the
    workers' compute and the predict only fetches a scalar; the seed host
    path runs everything inside the predict call.
    """
    from repro.core.cutoff import order_stats

    blocked = 0.0
    for _ in range(k):
        times = sim.step()
        t0 = time.perf_counter()
        c = ctl.predict_cutoff()
        blocked += time.perf_counter() - t0
        it = order_stats.iter_time(times, c)
        ctl.observe(times, times <= it + 1e-12)
        time.sleep(worker_ms / 1e3)   # the workers computing gradients
    return blocked / k * 1e6


def _decision_bench(n_list, k_list, iters: int, blocks: int = 4):
    """Per-decision latency, numpy host path vs fused device path.

    The two backends are measured in INTERLEAVED blocks and each reports
    its best block — on a small shared box a background spike would
    otherwise land on one backend and fake (or hide) a speedup.
    """
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.controller import CutoffController
    from repro.core.runtime_model.api import RuntimeModel

    rows = []
    for n in n_list:
        sim = paper_cluster_158(seed=0, n_workers=n)
        trace = sim.run(25)
        # untrained weights time identically to trained ones; skip the fit
        rm = RuntimeModel(n_workers=n, lag=20).init(0)
        rm.norm_scale = float(2.0 * trace[:21].mean())
        for k in k_list:
            ctls = {}
            for backend in ("numpy", "device"):
                ctl = CutoffController(rm, k_samples=k, seed=0,
                                       backend=backend)
                ctl.seed_window(trace)
                # warmup: compile every fused variant (decide-only +
                # observe+decide) before timing
                _cycles(ctl, paper_cluster_158(seed=3, n_workers=n), 3)
                ctls[backend] = ctl
            best = {b: float("inf") for b in ctls}
            blocked = {b: float("inf") for b in ctls}
            for _ in range(blocks):
                for backend, ctl in ctls.items():
                    dt = _cycles(ctl, paper_cluster_158(seed=5, n_workers=n),
                                 iters)
                    best[backend] = min(best[backend], dt / iters * 1e6)
                for backend, ctl in ctls.items():
                    us = _blocked_us(ctl,
                                     paper_cluster_158(seed=6, n_workers=n),
                                     iters, worker_ms=20.0)
                    blocked[backend] = min(blocked[backend], us)
            entry = {"n_workers": n, "k_samples": k,
                     "numpy_us": best["numpy"], "device_us": best["device"],
                     "numpy_blocked_us": blocked["numpy"],
                     "device_blocked_us": blocked["device"]}
            for backend in ("numpy", "device"):
                emit(f"controller/decision_{backend}_n{n}_k{k}",
                     best[backend], f"n={n};K={k}")
                emit(f"controller/decision_blocked_{backend}_n{n}_k{k}",
                     blocked[backend], f"n={n};K={k};worker_ms=20")
            entry["speedup"] = entry["numpy_us"] / entry["device_us"]
            entry["blocked_speedup"] = (entry["numpy_blocked_us"]
                                        / entry["device_blocked_us"])
            emit(f"controller/decision_speedup_n{n}_k{k}", 0.0,
                 f"cycle={entry['speedup']:.2f}x;"
                 f"critical_path={entry['blocked_speedup']:.2f}x")
            rows.append(entry)
    return rows


def _tiny_cfg():
    from repro.configs.base import bench_tiny_config

    return bench_tiny_config()


def _trainer_bench(steps: int, n_workers: int, k_samples: int):
    import jax

    from repro import optim
    from repro.cluster.simulator import paper_cluster_158
    from repro.core.controller import CutoffController
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, jit_train_step, make_train_step
    from repro.models import model as M

    cfg = _tiny_cfg()
    sim = paper_cluster_158(seed=0, n_workers=n_workers)
    trace = sim.run(25)
    rm = RuntimeModel(n_workers=n_workers, lag=20).init(0)
    rm.norm_scale = float(2.0 * trace[:21].mean())
    opt = optim.adamw(3e-3)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(0))
        return {"params": params, "opt": opt.init(params)}

    out = {"arch": f"{cfg.name}/bench_tiny", "n_workers": n_workers,
           "k_samples": k_samples, "steps": steps}
    variants = {
        # the seed hot loop: host controller, no donation, loss fetched
        # (metrics_every=1) every step
        "sync": dict(step_fn=jax.jit(make_train_step(cfg, opt)),
                     backend="numpy", metrics_every=1),
        # the PR's hot loop: fused device controller, donated state,
        # metrics drained in batches
        "async": dict(step_fn=jit_train_step(cfg, opt),
                      backend="device", metrics_every=50),
    }
    trainers = {}
    for name, v in variants.items():
        ctl = CutoffController(rm, k_samples=k_samples, seed=0,
                               backend=v["backend"])
        ctl.seed_window(trace)
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=n_workers, seed=0)
        tr = Trainer(cfg=cfg, step_fn=v["step_fn"], data=data,
                     controller=ctl,
                     timer=paper_cluster_158(seed=9, n_workers=n_workers),
                     n_workers=n_workers, metrics_every=v["metrics_every"])
        tr.restore_or_init(init_fn)
        tr.run(4)                     # compile + warm the jits
        trainers[name] = tr
    # interleaved blocks, best block per variant (ambient-load robust)
    best = {name: float("inf") for name in trainers}
    blocks = 4
    for _ in range(blocks):
        for name, tr in trainers.items():
            t0 = time.perf_counter()
            tr.run(steps)
            best[name] = min(best[name], (time.perf_counter() - t0) / steps)
    for name in trainers:
        out[f"{name}_steps_per_s"] = 1.0 / best[name]
        emit(f"controller/trainer_{name}_steps_per_s", best[name] * 1e6,
             f"{1.0 / best[name]:.2f} steps/s")
    out["async_over_sync"] = out["async_steps_per_s"] / out["sync_steps_per_s"]
    emit("controller/trainer_async_speedup", 0.0,
         f"{out['async_over_sync']:.2f}x")
    return out


def bench_controller(quick: bool = False,
                     out_path: str = "BENCH_controller.json",
                     n_list=DECISION_NS, k_list=DECISION_KS,
                     decision_iters: int = None,
                     trainer_steps: int = None,
                     trainer_workers: int = 158):
    iters = decision_iters if decision_iters is not None else (
        5 if quick else 20)
    tsteps = trainer_steps if trainer_steps is not None else (
        20 if quick else 40)
    results = {
        "schema": "bench_controller/v1",
        "quick": quick,
        "decision": _decision_bench(n_list, k_list, iters),
        "trainer": _trainer_bench(tsteps, trainer_workers, 128),
    }
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("controller/json_written", 0.0, out_path)
    return results
