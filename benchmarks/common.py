"""Shared benchmark plumbing: CSV emission + the simulated-cluster loop."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np

from repro.core.cutoff import order_stats

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row)


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run_cutoff_loop(controller, timer, n_steps: int) -> Dict[str, float]:
    """Run a controller against a runtime source; return throughput stats."""
    total_t = 0.0
    total_g = 0
    oracle_t = 0.0
    per_iter = []
    for _ in range(n_steps):
        times = timer.step()
        c = int(controller.predict_cutoff())
        it = order_stats.iter_time(times, c)
        controller.observe(times, times <= it + 1e-12)
        total_t += it
        total_g += c
        oracle_t += order_stats.iter_time(
            times, order_stats.oracle_cutoff(times))
        per_iter.append(it)
    return {"throughput": total_g / total_t, "wall": total_t,
            "oracle_wall": oracle_t, "mean_iter": float(np.mean(per_iter))}
