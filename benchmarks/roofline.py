"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and reports, per (arch x shape x mesh):
compute/memory/collective terms in seconds, the dominant bound,
MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE), and the useful-FLOPs
ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro.configs.base import SHAPES, get_config

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def model_flops(cfg, shape) -> float:
    """6 * N(_active) * tokens, the global useful-FLOPs yardstick.

    train: 6ND (fwd+bwd).  prefill: 2ND.  decode: 2ND per generated token.
    """
    n = cfg.n_active_params() if cfg.family == "moe" else cfg.n_params()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load_cells(mesh_filter=None):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh_filter and rec.get("mesh") != mesh_filter:
            continue
        rows.append(rec)
    return rows


def table(mesh="single_pod_16x16"):
    rows = []
    for rec in load_cells(mesh):
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "error": rec.get("error", "?")})
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES[rec["shape"]]
        rl = rec["roofline"]
        mf = model_flops(cfg, shape)
        hlo_global = rec["flops_per_device"] * rec["n_devices"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "bound": rl["bound"],
            "model_flops": mf, "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            "roofline_frac": (rl["compute_s"]
                              / max(rl["step_s_lower_bound"], 1e-12)),
            "mem_gb": rec["memory"]["peak_live_est"] / 2**30,
            "grad_accum": rec.get("grad_accum", 1),
        })
    return rows


def bench_roofline():
    for r in table():
        if "error" in r:
            emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                 f"ERROR {r['error'][:60]}")
            continue
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"bound={r['bound']} compute={r['compute_s']:.4f}s "
             f"memory={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
             f"useful={r['useful_ratio']:.2f} "
             f"frac={r['roofline_frac']:.3f} mem={r['mem_gb']:.1f}GB")


def markdown_table(mesh="single_pod_16x16"):
    lines = ["| arch | shape | compute s | memory s | coll s | bound | "
             "MODEL/HLO | roofline frac | mem GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in table(mesh):
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"ERROR | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {r['bound']} |"
            f" {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['mem_gb']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
