"""Per-kernel microbenchmarks.

CPU wall-times here are for the XLA-reference path (the Pallas kernels only
execute on TPU or under interpret mode, which measures Python, not silicon);
the 'derived' column therefore reports the TPU roofline bound for each
kernel instead: bytes-streamed / HBM_BW and FLOPs / peak.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.kernels import ref
from repro.perf.hlo_stats import HBM_BW, PEAK_FLOPS_BF16


def bench_kernels():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    # flash attention: B=4, S=2048, H=16, hd=128 bf16
    B, S, H, hd = 4, 2048, 16, 128
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.bfloat16)
    fn = jax.jit(lambda a, b, c: ref.reference_attention(a, b, c))
    us = timeit(fn, q, k, v, iters=3)
    flops = 4 * B * H * S * S * hd  # qk + pv
    stream = 4 * B * S * H * hd * 2
    emit("kernel/flash_attention_cpu_ref", us,
         f"tpu_compute_bound_us={flops / PEAK_FLOPS_BF16 * 1e6:.1f};"
         f"tpu_mem_bound_us={stream / HBM_BW * 1e6:.1f}")

    # mlstm chunk: B=4, S=2048, H=4, hd=256
    B, S, H, hd = 4, 2048, 4, 256
    q = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    kk = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    vv = jax.random.normal(ks[2], (B, S, H, hd))
    g = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)) + 3.0)
    i = jax.random.normal(ks[4], (B, S, H)) * 0.5
    from repro.models.ssm import linear_recurrence
    fn = jax.jit(lambda *a: linear_recurrence(*a, chunk=128,
                                              normalize=True)[0])
    us = timeit(fn, q, kk, vv, g, i, iters=2)
    c = 128
    flops = B * H * (S // c) * (2 * c * c * hd * 2 + 4 * c * hd * hd)
    emit("kernel/mlstm_chunk_cpu_ref", us,
         f"tpu_compute_bound_us={flops / PEAK_FLOPS_BF16 * 1e6:.1f}")

    # fused adam: 16M params
    n = 16 * 2**20
    p = jax.random.normal(ks[0], (n // 1024, 1024))
    g2 = jax.random.normal(ks[1], (n // 1024, 1024))
    m = jnp.zeros_like(p)
    v2 = jnp.zeros_like(p)
    sc = jnp.array([1e-3, 0.1, 0.001], jnp.float32)
    fn = jax.jit(lambda *a: ref.reference_adam(*a)[0])
    us = timeit(fn, p, g2, m, v2, sc, iters=3)
    stream = n * 4 * 7  # 4 reads + 3 writes, fp32
    emit("kernel/fused_adam_cpu_ref", us,
         f"tpu_mem_bound_us={stream / HBM_BW * 1e6:.1f}")

    # the optim-level fused backend (optim.adam(fused=True) ->
    # ops.adam_update_tree) vs the unfused tree-map optimizer, same tree
    from repro import optim
    tree_p = {"a": p[: n // 2048], "b": p[n // 2048:]}
    tree_g = {"a": g2[: n // 2048], "b": g2[n // 2048:]}
    for label, opt in (("unfused", optim.adam(1e-3)),
                       ("fused_xla", optim.adam(1e-3, fused=True))):
        state = opt.init(tree_p)

        def step(pp, st, gg, _opt=opt):
            ups, st = _opt.update(gg, st, pp)
            return optim.apply_updates(pp, ups)["a"]

        us = timeit(jax.jit(step), tree_p, state, tree_g, iters=3)
        emit(f"kernel/adam_tree_{label}", us,
             f"tpu_mem_bound_us={stream / HBM_BW * 1e6:.1f}")

    # masked grad agg: 16 workers x 4M
    g3 = jax.random.normal(ks[2], (16, 4 * 2**20))
    mask = (jnp.arange(16) % 3 != 0).astype(jnp.float32).reshape(16, 1)
    fn = jax.jit(ref.reference_masked_agg)
    us = timeit(fn, g3, mask, iters=3)
    stream = g3.size * 4
    emit("kernel/masked_grad_agg_cpu_ref", us,
         f"tpu_mem_bound_us={stream / HBM_BW * 1e6:.1f}")
