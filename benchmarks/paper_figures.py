"""Paper-figure benchmarks: Fig 2 (throughput), Fig 3 (prediction quality),
Fig 4 (wall-clock convergence, 4 methods), §4.1 Elfving table and the §4.2
censoring ablation."""
from __future__ import annotations

import heapq

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, run_cutoff_loop
from repro import optim
from repro.cluster.simulator import ClusterSim, cray_xc40_2175, paper_cluster_158
from repro.core.controller import (CutoffController, ElfvingController,
                                   FullSyncController,
                                   StaticCutoffController)
from repro.core.cutoff import elfving, order_stats
from repro.core.runtime_model.api import RuntimeModel
from repro.data.pipeline import SyntheticImages
from repro.models.cnn import cnn_apply, cnn_init, cnn_loss


def _fit_model(sim, n, steps=400, lag=20, seed=0):
    trace = sim.run(300)
    rm = RuntimeModel(n_workers=n, lag=lag).init(seed)
    rm.fit(trace, steps=steps, batch=8, seed=seed)
    return rm, trace


# ---------------------------------------------------------------------------
# §4.1 table: Elfving / exact order statistics.
# ---------------------------------------------------------------------------


def bench_elfving_table():
    approx = elfving.expected_max(158, 1.057, 0.393)
    exact = elfving.exact_order_stat_mean(158, 158, 1.057, 0.393)
    emit("elfving/expected_max_approx_s", 0.0,
         f"{approx:.4f} (paper prints 2.1063)")
    emit("elfving/expected_max_exact_s", 0.0, f"{exact:.4f}")
    emit("elfving/idle_per_worker_s", 0.0,
         f"{approx - 1.057:.4f} (paper: 1.049)")


# ---------------------------------------------------------------------------
# Fig. 2: throughput vs sync vs oracle across regime changes.
# ---------------------------------------------------------------------------


def bench_fig2_throughput(n_steps=120):
    sim = paper_cluster_158(seed=0)
    rm, trace = _fit_model(sim, 158)

    rows = {}
    for name, ctl in [
        ("sync", FullSyncController(158)),
        ("cutoff_dmm", CutoffController(rm, k_samples=48)),
    ]:
        if isinstance(ctl, CutoffController):
            ctl.seed_window(trace)
        stats = run_cutoff_loop(ctl, paper_cluster_158(seed=11), n_steps)
        rows[name] = stats
        emit(f"fig2/{name}_grads_per_s", 0.0, f"{stats['throughput']:.2f}")
    # oracle throughput: per-step best cutoff
    sim_o = paper_cluster_158(seed=11)
    tot_g = tot_t = 0.0
    for _ in range(n_steps):
        t = sim_o.step()
        c = order_stats.oracle_cutoff(t)
        tot_g += c
        tot_t += order_stats.iter_time(t, c)
    emit("fig2/oracle_grads_per_s", 0.0, f"{tot_g / tot_t:.2f}")
    emit("fig2/cutoff_frac_of_oracle", 0.0,
         f"{rows['cutoff_dmm']['throughput'] / (tot_g / tot_t):.3f}")
    emit("fig2/speedup_vs_sync", 0.0,
         f"{rows['cutoff_dmm']['throughput'] / rows['sync']['throughput']:.2f}x")


# ---------------------------------------------------------------------------
# Fig. 3: predicted order statistics vs observed (both cluster scales).
# ---------------------------------------------------------------------------


def bench_fig3_prediction(cray: bool = True):
    for label, sim_fn, n, fit_steps in [
        ("local158", paper_cluster_158, 158, 700),
        ("cray2175", cray_xc40_2175, 2175, 400),
    ]:
        if not cray and label == "cray2175":
            continue
        sim = sim_fn(seed=0)
        rm, trace = _fit_model(sim, n, steps=fit_steps)
        test = sim.run(20)
        window = trace[-21:].copy()
        maes, covs = [], []
        for t in range(20):
            samples, _, _ = rm.predict_next(window, k_samples=48, seed=t)
            mean, std = order_stats.mc_order_stats(samples)
            actual = np.sort(test[t])
            maes.append(np.abs(mean - actual).mean() / actual.mean())
            covs.append(np.mean(np.abs(mean - actual) <= 2 * std + 1e-9))
            window = np.vstack([window[1:], test[t]])
        emit(f"fig3/{label}_orderstat_rel_mae", 0.0,
             f"{np.mean(maes):.4f}")
        emit(f"fig3/{label}_2sigma_coverage", 0.0, f"{np.mean(covs):.3f}")


# ---------------------------------------------------------------------------
# Fig. 4: wall-clock convergence of sync / cutoff / order / wild.
# ---------------------------------------------------------------------------


def _make_cnn_step(lr):
    opt = optim.momentum(lr, 0.9)

    @jax.jit
    def step(params, state, x, y, w):
        loss, g = jax.value_and_grad(cnn_loss)(params, x, y, w)
        ups, state = opt.update(g, state, params)
        return optim.apply_updates(params, ups), state, loss

    return opt, step


def bench_fig4_convergence(n_workers=32, steps=150, batch=512, lr=0.05,
                           eval_every=10):
    """Simulated wall-clock convergence on the synthetic-MNIST CNN.

    Paper setting scaled to this container (n=158->32 workers, batch
    10112->512, lr scaled for stability at the smaller batch); relative
    ordering of methods is the claim under test.  Hogwild uses vanilla
    clipped SGD (Recht et al.) at lr*(1-beta)^-1/n — the momentum-equivalent
    per-sample step.
    """
    data = SyntheticImages(seed=0, noise=0.9)
    xv, yv = data.valid_set()
    xv, yv = jnp.asarray(xv[:2000]), jnp.asarray(yv[:2000])

    sim0 = ClusterSim(n_workers=n_workers, n_nodes=4, seed=0)
    rm, trace = _fit_model(sim0, n_workers, steps=300)

    results = {}
    for method in ["sync", "cutoff", "order", "wild"]:
        params = cnn_init(jax.random.PRNGKey(0))
        timer = ClusterSim(n_workers=n_workers, n_nodes=4, seed=21)
        per = batch // n_workers
        curve = []

        if method == "wild":
            # Hogwild: event-driven async, vanilla clipped SGD at the
            # momentum-equivalent per-sample lr (paper Fig. 4 scales 1/n)
            opt = optim.clip_by_global_norm(
                optim.sgd(lr * 10.0 / n_workers), 1.0)
            state = opt.init(params)
            q = []
            t0 = timer.step()
            for w in range(n_workers):
                heapq.heappush(q, (float(t0[w]), w, params))
            n_updates, clock = 0, 0.0
            while n_updates < steps * n_workers:
                clock, w, p_start = heapq.heappop(q)
                x, y = data.batch(n_updates, per, worker=w)
                loss, g = jax.value_and_grad(cnn_loss)(
                    p_start, jnp.asarray(x), jnp.asarray(y), None)
                ups, state = opt.update(g, state, params)
                params = optim.apply_updates(params, ups)
                n_updates += 1
                if n_updates % (eval_every * n_workers) == 0:
                    vl = float(cnn_loss(params, xv, yv))
                    curve.append((clock, vl))
                heapq.heappush(
                    q, (clock + float(timer.step()[w]), w, params))
        else:
            if method == "sync":
                ctl = FullSyncController(n_workers)
            elif method == "order":
                ctl = ElfvingController(n_workers)
            else:
                ctl = CutoffController(rm, k_samples=48)
                ctl.seed_window(trace[-21:])
            opt, step = _make_cnn_step(lr)
            state = opt.init(params)
            clock = 0.0
            for it in range(steps):
                times = timer.step()
                c = int(ctl.predict_cutoff())
                itime = order_stats.iter_time(times, c)
                ctl.observe(times, times <= itime + 1e-12)
                clock += itime
                mask = (times <= itime + 1e-12).astype(np.float32)
                xs, ys, ws = [], [], []
                for w in range(n_workers):
                    x, y = data.batch(it, per, worker=w)
                    xs.append(x)
                    ys.append(y)
                    ws.append(np.full(per, mask[w], np.float32))
                params, state, loss = step(
                    params, state, jnp.asarray(np.concatenate(xs)),
                    jnp.asarray(np.concatenate(ys)),
                    jnp.asarray(np.concatenate(ws)))
                if (it + 1) % eval_every == 0:
                    curve.append((clock, float(cnn_loss(params, xv, yv))))
        results[method] = curve
        emit(f"fig4/{method}_final_valloss", 0.0, f"{curve[-1][1]:.4f}")
        emit(f"fig4/{method}_wallclock_s", 0.0, f"{curve[-1][0]:.1f}")
    # paper claims: cutoff fastest among synchronous; wild converges higher
    sync_t = results["sync"][-1][0]
    cut_t = results["cutoff"][-1][0]
    emit("fig4/cutoff_speedup_vs_sync", 0.0, f"{sync_t / cut_t:.2f}x")
    return results


# ---------------------------------------------------------------------------
# §4.2 censoring ablation.
# ---------------------------------------------------------------------------


def bench_censoring_ablation(steps=60):
    sim = paper_cluster_158(seed=0)
    rm, trace = _fit_model(sim, 158)

    for label, impute in [("with_imputation", True),
                          ("max_fill", False)]:
        ctl = CutoffController(rm, k_samples=32, seed=3)
        ctl.seed_window(trace)
        if not impute:
            ctl._pending_pred = None  # forces max-fill path
        timer = paper_cluster_158(seed=9)
        maes = []
        for _ in range(steps):
            times = timer.step()
            c = ctl.predict_cutoff()
            if not impute:
                ctl._pending_pred = None
            it = order_stats.iter_time(times, c)
            pred = ctl.predicted_order_stats()
            if pred is not None:
                maes.append(np.abs(pred[0] - np.sort(times)).mean()
                            / times.mean())
            ctl.observe(times, times <= it + 1e-12)
        emit(f"censoring/{label}_rel_mae", 0.0, f"{np.mean(maes):.4f}")


# ---------------------------------------------------------------------------
# Straggler-policy frontier panel (PAPERS.md: Ferdinand & Draper; Dutta
# et al.) — the error–runtime frontier as a figure-style table.
# ---------------------------------------------------------------------------


def bench_frontier_panel(steps=60, json_path="BENCH_frontier.json"):
    """Wall-clock-to-loss per straggler policy, normalized to full sync.

    Reuses an existing ``BENCH_frontier.json`` when present (the bench
    already raced at full size); otherwise runs the quick race inline.
    Emits one row per policy: speedup over full sync on clock-to-target
    (n/a when the policy never reached it inside sync's clock budget)
    plus its final loss at the shared budget.
    """
    import json as _json
    import os

    if os.path.exists(json_path):
        with open(json_path) as f:
            frontier = _json.load(f)["frontier"]
    else:
        from benchmarks.frontier_bench import _race
        frontier = _race(steps)

    by = {r["policy"]: r for r in frontier["race"]}
    t_sync = by["sync"]["clock_to_loss"]
    for name, row in by.items():
        t = row["clock_to_loss"]
        speedup = ("n/a" if t is None or t_sync is None
                   else f"{t_sync / t:.2f}x")
        emit(f"frontierfig/{name}", 0.0,
             f"speedup_vs_sync={speedup};final={row['final_loss']:.3f};"
             f"c={row['mean_cutoff']:.2f}")
    return frontier
