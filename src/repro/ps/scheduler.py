"""Admission/ordering policies for the multi-tenant parameter server.

Which jobs' gradients does the shared cluster service this tick?  A
policy sees lightweight job views and returns an ordered service list of
at most ``capacity`` job ids.  Chen et al. and Dutta et al. frame
straggler mitigation as a per-job error–runtime trade-off; on a shared
cluster the scheduler is where those trade-offs meet.

Contracts the property tests pin down (tests/test_ps_scheduler.py):

  * ``RoundRobinScheduler`` — starvation-free: with J jobs at equal
    priority and capacity c, per-job service counts over ANY window of
    J*k ticks differ by at most 1.
  * ``PriorityScheduler`` — deterministic in (priority, job_id) only:
    the service order is invariant under permutation of job insertion
    order (ties break on job_id, never on admission order).
  * ``ShortestStepScheduler`` — shortest-predicted-step-first, ranked by
    the DMM's posterior-predictive E[x_(c)] step time fetched lazily from
    the server (jobs without a prediction yet sort first — they need
    service to warm up).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence


@dataclass(frozen=True)
class JobView:
    """What a policy is allowed to see about a job."""
    job_id: str
    priority: float
    admit_order: int
    predicted_iter: Optional[Callable[[], Optional[float]]] = None


def _capacity(views: Sequence[JobView], capacity: Optional[int]) -> int:
    if capacity is None:
        return len(views)
    return max(0, min(int(capacity), len(views)))


class RoundRobinScheduler:
    """Cyclic, starvation-free service order at equal priorities.

    The ring is the admission order; each tick serves the next
    ``capacity`` jobs, so the service sequence is one consecutive run of
    the cyclic job sequence — which is what makes the fairness bound
    exact.

    The resume point is tracked as the ADMIT ORDER of the last job
    served, never as an index into the ring: admits and evicts change the
    ring's length, and an index cursor would silently land on a
    different job after any membership change (serving someone twice and
    skipping someone else, which breaks the fairness bound the property
    tests pin).  Admit orders are unique and monotone, so "the first
    ring entry admitted after the last one served (wrapping)" is
    well-defined no matter who joined or left in between — an evicted
    resume point degrades to its cyclic successor.
    """

    def __init__(self):
        self._last: Optional[int] = None    # admit_order of last served

    def order(self, views: Sequence[JobView],
              capacity: Optional[int] = None) -> List[str]:
        ring = sorted(views, key=lambda v: v.admit_order)
        cap = _capacity(ring, capacity)
        if cap == 0:
            return []
        m = len(ring)
        start = 0
        if self._last is not None:
            start = next((i for i, v in enumerate(ring)
                          if v.admit_order > self._last), 0)
        picks = [ring[(start + i) % m] for i in range(cap)]
        self._last = picks[-1].admit_order
        return [v.job_id for v in picks]


class PriorityScheduler:
    """Strict priority: highest first, ties broken by job_id (stable
    under any permutation of admission order — deliberately NOT
    admit_order, which would make the policy depend on arrival history).
    Low-priority jobs CAN starve under capacity pressure; that is the
    policy, not a bug."""

    def order(self, views: Sequence[JobView],
              capacity: Optional[int] = None) -> List[str]:
        ranked = sorted(views, key=lambda v: (-v.priority, v.job_id))
        return [v.job_id for v in ranked[:_capacity(views, capacity)]]


class ShortestStepScheduler:
    """Shortest-predicted-step-first (SPSF) with bounded starvation.

    Ranks by the DMM's posterior-predictive E[x_(c)] for each job's next
    step — the same quantity the fused decision already computed, fetched
    lazily (one scalar per job).  Serving predicted-fast jobs first packs
    more completed steps into a tick budget when the cluster cannot
    service everyone.

    Two classes of job jump the queue: jobs without a prediction (cold,
    or in the Elfving fallback — they need service to warm up), and jobs
    unserviced for ``max_starve`` consecutive ticks.  The latter matters
    because an unserviced job's prediction can NEVER refresh (predictions
    are made at service time): without aging, the job whose last decision
    predicted the slowest step would be excluded forever even after the
    cluster regime that made it slow has passed.
    """

    def __init__(self, max_starve: int = 16):
        self.max_starve = max_starve
        self._age: dict = {}

    def order(self, views: Sequence[JobView],
              capacity: Optional[int] = None) -> List[str]:
        age = self._age

        def key(v: JobView):
            t = v.predicted_iter() if v.predicted_iter is not None else None
            a = age.get(v.job_id, 0)
            if t is None or a >= self.max_starve:
                # urgent tier, most-starved first: ordering urgents by t
                # would let fast jobs re-age into the tier and leapfrog
                # the slowest forever
                return (0, -a, v.job_id)
            return (1, t, v.job_id)

        ranked = sorted(views, key=key)
        picks = [v.job_id for v in ranked[:_capacity(views, capacity)]]
        chosen = set(picks)
        self._age = {v.job_id: (0 if v.job_id in chosen
                                else age.get(v.job_id, 0) + 1)
                     for v in views}
        return picks


_POLICIES = {
    "rr": RoundRobinScheduler,
    "round_robin": RoundRobinScheduler,
    "priority": PriorityScheduler,
    "spsf": ShortestStepScheduler,
    "shortest": ShortestStepScheduler,
}


def make_scheduler(policy: str):
    if policy not in _POLICIES:
        raise ValueError(f"unknown scheduler policy {policy!r} "
                         f"(want one of {sorted(_POLICIES)})")
    return _POLICIES[policy]()


def job_views(server) -> List[JobView]:
    """Build policy views over a :class:`~repro.ps.server.PSServer`'s
    admitted jobs (predicted step times close over the server, fetched
    only if a policy asks)."""
    views = []
    for job in server.registry.jobs():
        views.append(JobView(
            job_id=job.job_id, priority=job.priority,
            admit_order=job.admit_order,
            predicted_iter=(lambda jid=job.job_id:
                            server.predicted_iter_time(jid))))
    return views
