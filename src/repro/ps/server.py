"""Multi-tenant parameter server: batched device decisions for J jobs.

A production cluster runs many training jobs at once, and each one needs
the paper's cutoff decision every step.  Dispatching J separate fused
jits per tick pays the dispatch overhead J times for tiny per-job
compute; this module multiplexes every job through ONE vmapped decision:

  * :class:`JobRegistry` — admit/evict/resize bookkeeping.  Each job owns
    its :class:`~repro.core.runtime_model.api.RuntimeModel`, its worker
    membership, a priority, and a checkpoint-group name.
  * :class:`PSServer` — the decision plane.  Jobs of the same decision
    shape (n_workers, lag, k_samples, min_frac floor) share a *bucket*
    whose lag windows live stacked in a ``(J_b, lag+1, n)`` device ring;
    ``flush()`` dispatches one ``controller._batched_observe_decide`` per
    (bucket, imputation-mode) group per tick, and ``predict_cutoff`` only
    materializes the job's int32 lazily out of the batched result.
  * :class:`JobHandle` — a controller-protocol facade (`predict_cutoff` /
    `observe` / `resize` / `seed_window` / `window_array`), so one
    ``launch.train.Trainer`` per job drives the shared server unchanged,
    checkpointing included (the ``"ctl"`` group works verbatim).

Per-job elasticity follows the :class:`~repro.core.controller
.ElasticController` protocol: ``resize`` without a refit model remaps the
job's window (survivors column-exact), detaches it from the batched path
onto a warm-seeded Elfving fallback, and refits the DMM from the
surviving trace once ``refit_fresh`` fresh observations arrive — then the
job rejoins its (new) bucket.

Semantics contract: a ``PSServer`` with J=1 produces the IDENTICAL cutoff
sequence as a bare ``CutoffController(backend="device")`` over a seeded
run (tests/test_ps_server.py), and J>1 jobs match J looped single-job
controllers to f32-window precision — batching amortizes dispatch, it
never changes the decision.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core.cutoff import order_stats
from repro.core.runtime_model.api import RuntimeModel, stack_models


# ---------------------------------------------------------------------------
# Gather-in-jit batched entry: service an arbitrary subset of a bucket in
# ONE dispatch (gather rows -> vmapped observe+decide -> scatter back).
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("mode", "k_samples", "lo"))
def _subset_observe_decide(params, rings, heads, idx, obs, keys, scales, *,
                           mode: str, k_samples: int, lo: int):
    p = jax.tree.map(lambda x: x[idx], params)
    r, h, cut, samp, mu, std, it = C._batched_observe_decide(
        p, rings[idx], heads[idx], obs, keys, scales[idx],
        mode=mode, k_samples=k_samples, lo=lo)
    return rings.at[idx].set(r), heads.at[idx].set(h), cut, samp, mu, std, it


@functools.partial(jax.jit, static_argnames=("k_samples", "lo"))
def _subset_decide(params, rings, heads, idx, keys, scales, *,
                   k_samples: int, lo: int):
    # decide-only never mutates the ring, so return just the decision —
    # scattering identical rows back would copy the whole bucket stack
    p = jax.tree.map(lambda x: x[idx], params)
    _, _, cut, samp, mu, std, it = C._batched_decide(
        p, rings[idx], heads[idx], keys, scales[idx],
        k_samples=k_samples, lo=lo)
    return cut, samp, mu, std, it


def _seed_ring(rows: np.ndarray, cap: int, n: int):
    """Build the (cap, n) f32 ring + head a fresh controller would reach
    by appending ``rows`` with full masks — without cap device dispatches.
    Plain appends write the f32 times verbatim, so this is bit-exact."""
    rows = np.asarray(rows, np.float32)[-cap:]
    ring = np.zeros((cap, n), np.float32)
    m = rows.shape[0]
    ring[:m] = rows
    return ring, m % cap, min(m, cap)


# ---------------------------------------------------------------------------
# Job records + registry.
# ---------------------------------------------------------------------------


@dataclass
class PSJob:
    """One tenant of the shared parameter server (registry record)."""
    job_id: str
    model: Optional[RuntimeModel]
    members: np.ndarray                 # global worker ids
    priority: float
    admit_order: int
    k_samples: int
    min_frac: float
    seed: int
    ckpt_group: str

    width: int = 0                      # current worker count
    step: int = 0                       # controller step counter
    count: int = 0                      # rows in the lag window
    mode: str = "dmm"                   # "dmm" | "fallback"
    slot: int = -1                      # row in the bucket stack
    bucket_sig: Optional[tuple] = None
    fallback: Optional[C.ElfvingController] = None
    fresh: int = 0                      # observations since last (re)fit
    resize_count: int = 0
    fallback_steps: int = 0
    trace: list = field(default_factory=list, repr=False)  # refit data
    # decision plumbing (device refs, fetched lazily)
    pending: Optional[tuple] = None     # (dstep, row, outputs dict)
    pending_pred: Optional[tuple] = None  # (mu_src, std_src, samp_src, row)
    last_iter: Optional[tuple] = None   # (iter_array, row)
    queued: bool = False
    # architecture template for refits (widths change, shapes don't)
    lag: int = 20
    z_dim: int = 32
    hidden: int = 64

    @property
    def cap(self) -> int:
        return self.lag + 1

    @property
    def warmed_up(self) -> bool:
        return self.mode == "dmm" and self.count >= self.cap


class JobRegistry:
    """Admission bookkeeping for the multi-tenant server.

    Owns the job records: who is admitted, their RuntimeModel, worker
    membership, scheduling priority, and per-job checkpoint-group name
    (``ps/<job_id>``).  The decision-plane state (stacked rings, pending
    batched outputs) belongs to :class:`PSServer`.
    """

    def __init__(self):
        self._jobs: Dict[str, PSJob] = {}
        self._admitted = 0

    def admit(self, job_id: str, model: RuntimeModel, *,
              members=None, priority: float = 0.0, k_samples: int = 64,
              min_frac: float = 0.5, seed: int = 0) -> PSJob:
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} already admitted")
        if model.params is None:
            raise ValueError(f"job {job_id!r}: admit a fitted RuntimeModel")
        members = (np.asarray(members, int) if members is not None
                   else np.arange(model.n_workers))
        if members.shape != (model.n_workers,):
            raise ValueError(
                f"job {job_id!r}: {members.shape[0]} members for a "
                f"width-{model.n_workers} model")
        job = PSJob(job_id=job_id, model=model, members=members,
                    priority=float(priority), admit_order=self._admitted,
                    k_samples=int(k_samples), min_frac=float(min_frac),
                    seed=int(seed), ckpt_group=f"ps/{job_id}",
                    width=model.n_workers, lag=model.lag,
                    z_dim=model.z_dim, hidden=model.hidden)
        self._jobs[job_id] = job
        self._admitted += 1
        return job

    def evict(self, job_id: str) -> PSJob:
        return self._jobs.pop(job_id)

    def __getitem__(self, job_id: str) -> PSJob:
        return self._jobs[job_id]

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs

    def __len__(self) -> int:
        return len(self._jobs)

    def ids(self) -> List[str]:
        """Admitted job ids in admission order."""
        return [j.job_id for j in
                sorted(self._jobs.values(), key=lambda j: j.admit_order)]

    def jobs(self) -> List[PSJob]:
        return [self._jobs[i] for i in self.ids()]

    def set_priority(self, job_id: str, priority: float):
        self._jobs[job_id].priority = float(priority)


# ---------------------------------------------------------------------------
# The decision plane.
# ---------------------------------------------------------------------------


class _Bucket:
    """Jobs of one decision shape, windows stacked in ONE device ring."""

    def __init__(self, cap: int, n: int):
        self.cap, self.n = cap, n
        self.jobs: List[PSJob] = []
        self.rings = jnp.zeros((0, cap, n), jnp.float32)
        self.heads = jnp.zeros((0,), jnp.int32)
        self._stacked = None            # (params, scales) cache

    def stacked(self):
        if self._stacked is None:
            self._stacked = stack_models([j.model for j in self.jobs])
        return self._stacked

    def dirty(self):
        self._stacked = None


class PSServer:
    """The multi-tenant decision plane (see module docstring).

    Tick protocol (what ``launch.multi_job.MultiJobDriver`` runs)::

        server.prefetch(serviced)        # cold decisions, one dispatch
        for job_id in serviced:          # scheduler's order
            c = server.predict_cutoff(job_id)   # lazy int32 fetch
            ... run the job's train step with the bit array ...
            server.observe(job_id, times, mask)  # enqueues
        server.flush()                   # ONE vmapped dispatch per
                                         # (bucket, mode) group

    ``flush`` is also called implicitly whenever a job with a queued
    observation is asked to predict, so a ``JobHandle`` behaves like a
    plain controller even without a driver calling ``flush``.
    """

    def __init__(self, registry: Optional[JobRegistry] = None, *,
                 history: int = 512, refit_steps: int = 150,
                 refit_batch: int = 8, refit_fresh: int = 4,
                 fallback_warmup: int = 3):
        self.registry = registry if registry is not None else JobRegistry()
        self.history = history
        self.refit_steps = refit_steps
        self.refit_batch = refit_batch
        self.refit_fresh = refit_fresh
        self.fallback_warmup = fallback_warmup
        self._buckets: Dict[tuple, _Bucket] = {}
        self._queue: List[dict] = []
        self.dispatches = 0             # fused decision dispatches issued
        self.ticks = 0                  # flush() calls that dispatched

    # -- admission ------------------------------------------------------
    def admit(self, job_id: str, model: RuntimeModel, *, window=None,
              members=None, priority: float = 0.0, k_samples: int = 64,
              min_frac: float = 0.5, seed: int = 0) -> "JobHandle":
        """Admit a job; ``window`` warm-starts its lag window (rows of
        raw runtimes, as ``CutoffController.seed_window``)."""
        self.flush()
        job = self.registry.admit(job_id, model, members=members,
                                  priority=priority, k_samples=k_samples,
                                  min_frac=min_frac, seed=seed)
        self._place(job, window)
        if window is not None:
            job.trace = [np.asarray(r, np.float64)
                         for r in np.asarray(window)][-self.history:]
        return JobHandle(self, job_id)

    def evict(self, job_id: str) -> dict:
        """Remove a job; returns its final window (or None) and trace."""
        self.flush()
        job = self.registry[job_id]
        window = None
        if job.mode == "dmm" and job.count > 0:
            window = self.window_array(job_id)
        if job.bucket_sig is not None:
            self._remove(job)
        self.registry.evict(job_id)
        return {"window": window, "trace": np.array(job.trace)}

    def handle(self, job_id: str) -> "JobHandle":
        if job_id not in self.registry:
            raise KeyError(job_id)
        return JobHandle(self, job_id)

    # -- bucket plumbing ------------------------------------------------
    def _sig(self, job: PSJob) -> tuple:
        """The full decision shape: window dims, sampling statics, AND
        the model architecture — two same-width jobs with different
        (z_dim, hidden) cannot share a param stack."""
        lo = order_stats.min_frac_floor(job.width, job.min_frac)
        return (job.width, job.cap, job.k_samples, lo, job.z_dim,
                job.hidden)

    def _place(self, job: PSJob, window=None):
        """Insert a dmm-mode job into its shape bucket, seeding its ring."""
        sig = self._sig(job)
        b = self._buckets.get(sig)
        if b is None:
            b = self._buckets[sig] = _Bucket(job.cap, job.width)
        rows = np.asarray(window, np.float64) if window is not None else None
        if rows is not None and rows.ndim != 2:
            raise ValueError(f"seed window must be (T, n), got {rows.shape}")
        if rows is not None and rows.shape[1] != job.width:
            raise ValueError(f"seed window width {rows.shape[1]} != "
                             f"job width {job.width}")
        ring, head, count = _seed_ring(
            rows if rows is not None else np.zeros((0, job.width)),
            job.cap, job.width)
        b.rings = jnp.concatenate([b.rings, jnp.asarray(ring)[None]])
        b.heads = jnp.concatenate(
            [b.heads, jnp.asarray([head], jnp.int32)])
        job.slot = len(b.jobs)
        b.jobs.append(job)
        b.dirty()
        job.bucket_sig = sig
        job.count = count
        job.mode = "dmm"

    def _remove(self, job: PSJob):
        b = self._buckets[job.bucket_sig]
        i = job.slot
        keep = np.array([k for k in range(len(b.jobs)) if k != i])
        if keep.size:
            ka = jnp.asarray(keep)
            b.rings = b.rings[ka]
            b.heads = b.heads[ka]
        else:
            b.rings = b.rings[:0]
            b.heads = b.heads[:0]
        b.jobs.pop(i)
        for k, other in enumerate(b.jobs):
            other.slot = k
        b.dirty()
        job.bucket_sig = None
        job.slot = -1

    # -- window diagnostics / checkpointing -----------------------------
    def window_array(self, job_id: str) -> np.ndarray:
        """The job's lag window, oldest row first (host copy).

        Raises ValueError while empty — the Trainer's checkpoint path
        relies on this to skip cold controllers."""
        self.flush()
        job = self.registry[job_id]
        if job.mode != "dmm":
            if not job.trace:
                raise ValueError("window is empty")
            return np.stack(job.trace[-job.cap:])
        if job.count == 0:
            raise ValueError("window is empty")
        b = self._buckets[job.bucket_sig]
        head = int(b.heads[job.slot])
        w = np.asarray(jnp.roll(b.rings[job.slot], -head, axis=0))
        return w[-job.count:] if job.count < job.cap else w

    def seed_window(self, job_id: str, rows: np.ndarray):
        """Warm-start the job's window from recorded traces (checkpoint
        restore path)."""
        self.flush()
        job = self.registry[job_id]
        rows = np.asarray(rows, np.float64)
        if rows.shape[1] != job.width:
            raise ValueError(f"seed rows have width {rows.shape[1]}, "
                             f"job width is {job.width}")
        job.trace = (job.trace + [r for r in rows])[-self.history:]
        if job.mode != "dmm":
            for r in rows[-50:]:
                job.fallback.buf.append(np.asarray(r, np.float64))
            return
        b = self._buckets[job.bucket_sig]
        old_head = int(b.heads[job.slot])
        old = np.asarray(b.rings[job.slot])
        old = np.roll(old, -old_head, axis=0)
        if job.count < job.cap:
            old = old[job.cap - job.count:] if job.count else old[:0]
        merged = np.concatenate([old, np.asarray(rows, np.float32)])
        ring, head, count = _seed_ring(merged, job.cap, job.width)
        b.rings = b.rings.at[job.slot].set(jnp.asarray(ring))
        b.heads = b.heads.at[job.slot].set(head)
        job.count = count
        job.pending = None
        job.pending_pred = None

    def checkpoint_group(self, job_id: str) -> Dict[str, np.ndarray]:
        """The job's persistable controller state (``"ctl"``-group shape:
        width, members, step, window), under its registry group name."""
        job = self.registry[job_id]
        grp = {"n": np.int64(job.width),
               "members": np.asarray(job.members, np.int64),
               "step": np.int64(job.step)}
        try:
            grp["window"] = np.asarray(self.window_array(job_id), np.float64)
        except ValueError:
            pass
        return grp

    def checkpoint_groups(self) -> Dict[str, Dict[str, np.ndarray]]:
        return {self.registry[i].ckpt_group: self.checkpoint_group(i)
                for i in self.registry.ids()}

    # -- the decision path ----------------------------------------------
    def predict_cutoff(self, job_id: str) -> int:
        job = self.registry[job_id]
        if job.queued:
            self.flush()
        job.step += 1
        if job.mode == "fallback":
            job.fallback_steps += 1
            return min(job.fallback.predict_cutoff(), job.width)
        if not job.warmed_up:
            job.pending_pred = None
            return job.width
        if job.pending is None or job.pending[0] != job.step:
            # first decision after seeding/rejoin, or out-of-cadence
            # call: dispatch one now (prefetch() batches this for a
            # whole service set)
            self._decide_jobs([job], [job.step])
        _, row, out = job.pending
        job.pending = None
        job.pending_pred = (out["mu"], out["std"], out["samples"], row)
        job.last_iter = (out["iter"], row)
        # the only per-job host sync on the hot path: one int32
        return int(out["cutoff"][row])

    def prefetch(self, job_ids=None):
        """Batch the decide-only dispatch for every warmed job in
        ``job_ids`` (default: all) that has no decision in flight for its
        next step — one fused call per bucket instead of one per job."""
        ids = job_ids if job_ids is not None else self.registry.ids()
        jobs = [self.registry[i] for i in ids]
        need = [j for j in jobs
                if j.mode == "dmm" and j.warmed_up and not j.queued
                and (j.pending is None or j.pending[0] != j.step + 1)]
        by_bucket: Dict[tuple, list] = {}
        for j in need:
            by_bucket.setdefault(j.bucket_sig, []).append(j)
        for group in by_bucket.values():
            self._decide_jobs(group, [j.step + 1 for j in group])

    def _decide_jobs(self, jobs: List[PSJob], dsteps: List[int]):
        """Decide-only batched dispatch for same-bucket jobs.  ``dsteps``
        are the decision steps: the caller's current step when invoked
        from ``predict_cutoff`` (which already incremented), step+1 when
        prefetching."""
        b = self._buckets[jobs[0].bucket_sig]
        sig = jobs[0].bucket_sig
        idx = jnp.asarray([j.slot for j in jobs], jnp.int32)
        keys = C.stacked_prng_keys(
            [j.seed + d for j, d in zip(jobs, dsteps)])
        params, scales = b.stacked()
        lo = sig[3]
        cut, samp, mu, std, it = _subset_decide(
            params, b.rings, b.heads, idx, keys, scales,
            k_samples=sig[2], lo=lo)
        self.dispatches += 1
        out = {"cutoff": cut, "samples": samp, "mu": mu, "std": std,
               "iter": it}
        for row, (j, d) in enumerate(zip(jobs, dsteps)):
            j.pending = (d, row, out)

    def observe(self, job_id: str, times, finished_mask=None):
        job = self.registry[job_id]
        t = np.asarray(times, np.float64)
        if t.shape != (job.width,):
            raise ValueError(
                f"job {job_id!r}: observe got {t.shape[0]} runtimes at "
                f"width {job.width}; resize() before the resized step")
        mask = (np.ones(job.width, bool) if finished_mask is None
                else np.asarray(finished_mask, bool))
        # rolling imputed trace: refit training data (plain imputation at
        # the observed cutoff time, as ElasticController keeps it)
        row = np.where(mask, t, t[mask].max()) if (
            mask.any() and not mask.all()) else t
        job.trace = (job.trace + [row])[-self.history:]
        job.fresh += 1
        if job.mode == "fallback":
            job.fallback.observe(times, finished_mask)
            self._maybe_refit(job)
            return
        if job.queued:
            self.flush()        # one observation in flight per job, max
        t32 = t.astype(np.float32)
        # mirror CutoffController.observe's mode selection exactly: a
        # full-sync observation takes the plain append even when moments
        # are pending (cheaper, and equivalence-by-construction with the
        # single-job reference rather than by where-merge accident)
        mode = ("plain" if job.pending_pred is None or bool(mask.all())
                else "censored")
        if job.pending_pred is not None:
            # moments stay valid for the queued imputation; the sample
            # cache does not survive the window change
            job.pending_pred = job.pending_pred[:2] + (None,
                                                       job.pending_pred[3])
        job.count = min(job.count + 1, job.cap)
        if job.warmed_up:
            self._queue.append({
                "job": job, "times": t32, "mask": mask, "mode": mode,
                "dstep": job.step + 1,
                "pred": (job.pending_pred[:2] + (job.pending_pred[3],)
                         if mode == "censored" else None)})
            job.queued = True
        else:
            # warmup: plain append straight into the job's ring slot
            b = self._buckets[job.bucket_sig]
            obs = {"times": jnp.asarray(t32),
                   "mask": jnp.asarray(mask)}
            ring, head = C._ring_append(b.rings[job.slot],
                                        b.heads[job.slot], obs, mode="plain")
            b.rings = b.rings.at[job.slot].set(ring)
            b.heads = b.heads.at[job.slot].set(head)

    def flush(self) -> int:
        """Dispatch every queued observation+decision: ONE vmapped fused
        call per (bucket, imputation-mode) group.  Returns the number of
        dispatches issued."""
        if not self._queue:
            return 0
        queue, self._queue = self._queue, []
        groups: Dict[tuple, list] = {}
        for e in queue:
            groups.setdefault((e["job"].bucket_sig, e["mode"]),
                              []).append(e)
        issued = 0
        for (sig, mode), entries in groups.items():
            b = self._buckets[sig]
            jobs = [e["job"] for e in entries]
            idx = jnp.asarray([j.slot for j in jobs], jnp.int32)
            obs = {"times": jnp.asarray(np.stack(
                       [e["times"] for e in entries])),
                   "mask": jnp.asarray(np.stack(
                       [e["mask"] for e in entries]))}
            if mode == "censored":
                obs["mu"] = self._stack_pred(entries, 0)
                obs["std"] = self._stack_pred(entries, 1)
                base = C.stacked_prng_keys(
                    [j.seed + 1_000_003 for j in jobs])
                obs["key"] = C._batched_impute_keys(
                    base, jnp.asarray([j.step for j in jobs], jnp.uint32))
            keys = C.stacked_prng_keys(
                [j.seed + e["dstep"] for j, e in zip(jobs, entries)])
            params, scales = b.stacked()
            (b.rings, b.heads, cut, samp, mu, std, it) = (
                _subset_observe_decide(
                    params, b.rings, b.heads, idx, obs, keys, scales,
                    mode=mode, k_samples=sig[2], lo=sig[3]))
            issued += 1
            out = {"cutoff": cut, "samples": samp, "mu": mu, "std": std,
                   "iter": it}
            for row, (j, e) in enumerate(zip(jobs, entries)):
                j.pending = (e["dstep"], row, out)
                j.queued = False
        self.dispatches += issued
        self.ticks += 1
        return issued

    @staticmethod
    def _stack_pred(entries, which: int) -> jnp.ndarray:
        """(m, n) predictive moments for a censored group.

        Fast path: every entry's moments are rows of the SAME previous
        batched output in stack order (the steady-state tick) — pass that
        array through untouched.  Otherwise gather row by row."""
        srcs = [e["pred"][which] for e in entries]
        rows = [e["pred"][2] for e in entries]
        first = srcs[0]
        same = all(s is first for s in srcs)
        if (same and first.ndim == 2 and len(rows) == first.shape[0]
                and rows == list(range(len(rows)))):
            return first
        return jnp.stack([s[r] for s, r in zip(srcs, rows)])

    # -- diagnostics -----------------------------------------------------
    def predicted_iter_time(self, job_id: str) -> Optional[float]:
        """Posterior-predictive E[x_(c)] of the job's latest decision (raw
        seconds) — the shortest-predicted-step-first scheduler's key.
        None before the first warmed-up decision (and in fallback mode,
        where the analytic controller has no sample cloud)."""
        job = self.registry[job_id]
        if job.last_iter is None:
            return None
        arr, row = job.last_iter
        return float(arr[row])

    def predicted_order_stats(self, job_id: str):
        job = self.registry[job_id]
        if job.pending_pred is None or job.pending_pred[2] is None:
            return None
        samples = np.asarray(job.pending_pred[2][job.pending_pred[3]])
        return order_stats.mc_order_stats(samples)

    # -- elasticity ------------------------------------------------------
    def resize(self, job_id: str, n_workers: int, col_map=None,
               model: Optional[RuntimeModel] = None, members=None):
        """Per-job worker-set change, ElasticController protocol: remap
        the window (survivors column-exact), then either swap in a
        ``model`` fitted at the new width (job stays on the batched DMM
        path) or degrade to a warm-seeded Elfving fallback until the
        refit lands (``_maybe_refit``)."""
        self.flush()
        job = self.registry[job_id]
        n_new = int(n_workers)
        if (n_new == job.width and col_map is None and model is None
                and members is None):
            return          # idempotent: re-asserting the current width
                            # must not degrade a healthy DMM job
        if model is not None and model.n_workers != n_new:
            raise ValueError(
                f"resize({n_new}) got a RuntimeModel of width "
                f"{model.n_workers}; refit it for the new width first")
        rows = None
        if job.mode == "dmm" and job.count > 0:
            rows = self.window_array(job_id)
        if job.bucket_sig is not None:
            self._remove(job)
        if job.trace:
            job.trace = [r for r in C.remap_columns(
                np.stack(job.trace), n_new, col_map)]
        if rows is not None:
            rows = C.remap_columns(np.asarray(rows, np.float64), n_new,
                                   col_map)
        elif job.trace:
            rows = np.stack(job.trace[-job.cap:])
        job.width = n_new
        job.members = self._resized_members(job.members, n_new, col_map,
                                            members)
        job.resize_count += 1
        job.fresh = 0
        job.pending = None
        job.pending_pred = None
        job.last_iter = None
        if model is not None:
            job.model = model
            self._place(job, rows)
            return
        job.model = None
        job.mode = "fallback"
        job.count = 0
        job.fallback = C.ElfvingController(
            n_new, warmup=self.fallback_warmup, min_frac=job.min_frac)
        for r in job.trace[-50:]:
            job.fallback.buf.append(np.asarray(r, np.float64))

    @staticmethod
    def _resized_members(old: np.ndarray, n_new: int, col_map,
                         members) -> np.ndarray:
        """GLOBAL worker ids across a resize.  Survivors keep their ids
        (via ``col_map``, the same remap the window uses); workers whose
        global id the caller didn't supply are marked ``-1`` — never
        silently renumbered, so the per-job checkpoint group's
        restore-by-global-id protocol stays sound."""
        if members is not None:
            members = np.asarray(members, int)
            if members.shape != (n_new,):
                raise ValueError(f"members must be ({n_new},), got "
                                 f"{members.shape}")
            return members
        if col_map is None:
            col_map = np.concatenate([
                np.arange(min(old.size, n_new)),
                np.full(max(0, n_new - old.size), -1, int)])
        cm = np.asarray(col_map, int)
        return np.where(cm >= 0, old[np.clip(cm, 0, old.size - 1)], -1)

    def _maybe_refit(self, job: PSJob):
        if (job.fresh < self.refit_fresh
                or len(job.trace) < job.cap + self.refit_batch):
            return
        model = RuntimeModel(n_workers=job.width, lag=job.lag,
                             z_dim=job.z_dim, hidden=job.hidden)
        model.fit(np.stack(job.trace), steps=self.refit_steps,
                  batch=self.refit_batch,
                  seed=job.seed + job.resize_count)
        job.model = model
        job.mode = "dmm"
        job.fallback = None
        self._place(job, np.stack(job.trace[-job.cap:]))


# ---------------------------------------------------------------------------
# Controller-protocol facade.
# ---------------------------------------------------------------------------


class JobHandle:
    """One job's controller-shaped view of the shared server.

    Implements the full controller protocol (`predict_cutoff`, `observe`,
    `resize`, `seed_window`, `window_array`, `predicted_order_stats`,
    `_step`), so a ``launch.train.Trainer`` drives the multi-tenant
    server without knowing it — including the checkpoint ``"ctl"`` group
    and the elastic ``_sync_membership`` path.
    """

    def __init__(self, server: PSServer, job_id: str):
        self.server = server
        self.job_id = job_id

    @property
    def job(self) -> PSJob:
        return self.server.registry[self.job_id]

    @property
    def n(self) -> int:
        return self.job.width

    @property
    def warmed_up(self) -> bool:
        return self.job.warmed_up

    @property
    def mode(self) -> str:
        return self.job.mode

    @property
    def _step(self) -> int:
        return self.job.step

    @_step.setter
    def _step(self, value: int):
        self.job.step = int(value)

    def predict_cutoff(self) -> int:
        return self.server.predict_cutoff(self.job_id)

    def observe(self, times, finished_mask=None):
        return self.server.observe(self.job_id, times, finished_mask)

    def resize(self, n_workers: int, col_map=None, model=None,
               members=None):
        return self.server.resize(self.job_id, n_workers, col_map=col_map,
                                  model=model, members=members)

    def seed_window(self, traces):
        return self.server.seed_window(self.job_id, traces)

    def window_array(self) -> np.ndarray:
        return self.server.window_array(self.job_id)

    def predicted_order_stats(self):
        return self.server.predicted_order_stats(self.job_id)

    def predicted_iter_time(self) -> Optional[float]:
        return self.server.predicted_iter_time(self.job_id)
