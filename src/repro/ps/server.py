"""Multi-tenant parameter server: batched device decisions for J jobs.

A production cluster runs many training jobs at once, and each one needs
the paper's cutoff decision every step.  Dispatching J separate fused
jits per tick pays the dispatch overhead J times for tiny per-job
compute; this module multiplexes every job through ONE vmapped decision:

  * :class:`JobRegistry` — admit/evict/resize bookkeeping.  Each job owns
    its :class:`~repro.core.runtime_model.api.RuntimeModel`, its worker
    membership, a priority, and a checkpoint-group name.
  * :class:`PSServer` — the decision plane.  Jobs of the same DMM
    architecture (lag, k_samples, z_dim, hidden) share a *bucket* even at
    MIXED worker widths: their lag windows live stacked in one
    ``(J_b, lag+1, n_pad)`` device ring, their params are zero-padded to
    the bucket width (``stack_models_padded``), and per-job TRACED width
    masks inside the jit (``controller._batched_observe_decide_ragged``)
    keep each job's decision exactly its own.  ``flush()`` therefore
    issues ONE vmapped dispatch per tick regardless of the job mix —
    observation rows, masks, predictive moments, PRNG keys and censor
    flags travel in one host-packed upload.
  * :class:`JobHandle` — a controller-protocol facade (`predict_cutoff` /
    `observe` / `resize` / `seed_window` / `window_array`), so one
    ``launch.train.Trainer`` per job drives the shared server unchanged,
    checkpointing included (the ``"ctl"`` group works verbatim).

Per-job elasticity follows the :class:`~repro.core.controller
.ElasticController` protocol: ``resize`` without a refit model remaps the
job's window (survivors column-exact), detaches it from the batched path
onto a warm-seeded Elfving fallback, and refits the DMM from the
surviving trace once ``refit_fresh`` fresh observations arrive — then the
job rejoins its (new) bucket.  With ``refit_async=True`` the ELBO refit
runs on a worker thread (``controller._spawn_refit`` — the exact task
shape :class:`~repro.core.controller.ElasticController` uses), so a tick
served during an active refit never blocks on ``model.fit``; results
stale by resize generation are discarded, never installed.

Semantics contract: a ``PSServer`` with J=1 produces the IDENTICAL cutoff
sequence as a bare ``CutoffController(backend="device")`` over a seeded
run (tests/test_ps_server.py), and J>1 jobs — mixed widths included —
match J looped single-job controllers to f32-window precision: batching
amortizes dispatch, it never changes the decision.
"""
from __future__ import annotations

import functools
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import controller as C
from repro.core.cutoff import order_stats
from repro.core.runtime_model.api import RuntimeModel, stack_models_padded


# ---------------------------------------------------------------------------
# Batched jit entries.  The flush path uploads ONE host-packed
# (4, m, n_pad) f32 block [times, mask, mu, std], ONE (m, 4) uint32 key
# block [decide key | impute base key], the (m,) impute steps and the (m,)
# censor flags; everything else (key folding, mask decode, gather/scatter)
# happens in-jit, so a tick costs a fixed number of transfers no matter
# how many jobs it serves.
# ---------------------------------------------------------------------------


def _unpack_obs(pack, keys, steps, cen):
    """Decode the packed observation block into the per-job obs pytree
    ``controller._ragged_append_core`` consumes.  The impute keys are
    folded in-jit (vmapped ``fold_in``), bit-identical to
    ``controller._impute_key(seed, step)`` per job."""
    return {"times": pack[0], "mask": pack[1] > 0.5,
            "mu": pack[2], "std": pack[3],
            "key": jax.vmap(jax.random.fold_in)(keys[:, 2:], steps),
            "cen": cen}


@functools.partial(jax.jit, static_argnames=("k_samples",))
def _full_observe_decide(params, rings, heads, pack, keys, steps, cen,
                         scales, widths, los, *, k_samples: int):
    """The steady-state tick: every bucket row is serviced, in slot
    order — no gather, no scatter, the whole stack updates in place."""
    obs = _unpack_obs(pack, keys, steps, cen)
    return C._batched_observe_decide_ragged(
        params, rings, heads, obs, keys[:, :2], scales, widths, los,
        k_samples=k_samples)


@functools.partial(jax.jit, static_argnames=("k_samples",))
def _subset_observe_decide(params, rings, heads, idx, pack, keys, steps,
                           cen, scales, widths, los, *, k_samples: int):
    """Service an arbitrary subset of a bucket in ONE dispatch (gather
    rows -> vmapped observe+decide -> scatter back)."""
    p = jax.tree.map(lambda x: x[idx], params)
    obs = _unpack_obs(pack, keys, steps, cen)
    r, h, cut, samp, mu, std, it = C._batched_observe_decide_ragged(
        p, rings[idx], heads[idx], obs, keys[:, :2], scales[idx],
        widths[idx], los[idx], k_samples=k_samples)
    return rings.at[idx].set(r), heads.at[idx].set(h), cut, samp, mu, std, it


@functools.partial(jax.jit, static_argnames=("k_samples",))
def _full_decide(params, rings, heads, keys, scales, widths, los, *,
                 k_samples: int):
    return C._batched_decide_ragged(params, rings, heads, keys, scales,
                                    widths, los, k_samples=k_samples)


@functools.partial(jax.jit, static_argnames=("k_samples",))
def _subset_decide(params, rings, heads, idx, keys, scales, widths, los,
                   *, k_samples: int):
    # decide-only never mutates the ring, so return just the decision —
    # scattering identical rows back would copy the whole bucket stack
    p = jax.tree.map(lambda x: x[idx], params)
    return C._batched_decide_ragged(p, rings[idx], heads[idx], keys,
                                    scales[idx], widths[idx], los[idx],
                                    k_samples=k_samples)


def _seed_ring(rows: np.ndarray, cap: int, n: int, n_pad: int):
    """Build the (cap, n_pad) f32 ring + head a fresh controller would
    reach by appending width-n ``rows`` with full masks — without cap
    device dispatches.  Plain appends write the f32 times verbatim, so
    the real columns are bit-exact; pad columns stay zero (the decision
    masks them out in-jit, it never reads them)."""
    rows = np.asarray(rows, np.float32)[-cap:]
    ring = np.zeros((cap, n_pad), np.float32)
    m = rows.shape[0]
    ring[:m, :n] = rows
    return ring, m % cap, min(m, cap)


# ---------------------------------------------------------------------------
# Job records + registry.
# ---------------------------------------------------------------------------


@dataclass
class PSJob:
    """One tenant of the shared parameter server (registry record)."""
    job_id: str
    model: Optional[RuntimeModel]
    members: np.ndarray                 # global worker ids
    priority: float
    admit_order: int
    k_samples: int
    min_frac: float
    seed: int
    ckpt_group: str

    width: int = 0                      # current worker count
    step: int = 0                       # controller step counter
    count: int = 0                      # rows in the lag window
    mode: str = "dmm"                   # "dmm" | "fallback"
    slot: int = -1                      # row in the bucket stack
    bucket_sig: Optional[tuple] = None
    fallback: Optional[C.ElfvingController] = None
    fresh: int = 0                      # observations since last (re)fit
    resize_count: int = 0
    refit_failures: int = 0             # consecutive failed async fits
    fallback_steps: int = 0
    trace: list = field(default_factory=list, repr=False)  # refit data
    # decision plumbing (device refs, fetched lazily)
    pending: Optional[tuple] = None     # (dstep, row, outputs dict)
    pending_pred: Optional[tuple] = None  # (mu row, std row, samples, row)
    last_iter: Optional[float] = None   # E[x_(c)] of the last decision
    queued: bool = False
    # async refit in flight: controller._spawn_refit triple
    refit_task: Optional[tuple] = None
    # architecture template for refits (widths change, shapes don't)
    lag: int = 20
    z_dim: int = 32
    hidden: int = 64

    @property
    def cap(self) -> int:
        return self.lag + 1

    @property
    def warmed_up(self) -> bool:
        return self.mode == "dmm" and self.count >= self.cap


class JobRegistry:
    """Admission bookkeeping for the multi-tenant server.

    Owns the job records: who is admitted, their RuntimeModel, worker
    membership, scheduling priority, and per-job checkpoint-group name
    (``ps/<job_id>``).  The decision-plane state (stacked rings, pending
    batched outputs) belongs to :class:`PSServer`.
    """

    def __init__(self):
        self._jobs: Dict[str, PSJob] = {}
        self._admitted = 0

    def admit(self, job_id: str, model: RuntimeModel, *,
              members=None, priority: float = 0.0, k_samples: int = 64,
              min_frac: float = 0.5, seed: int = 0) -> PSJob:
        if job_id in self._jobs:
            raise ValueError(f"job {job_id!r} already admitted")
        if model.params is None:
            raise ValueError(f"job {job_id!r}: admit a fitted RuntimeModel")
        members = (np.asarray(members, int) if members is not None
                   else np.arange(model.n_workers))
        if members.shape != (model.n_workers,):
            raise ValueError(
                f"job {job_id!r}: {members.shape[0]} members for a "
                f"width-{model.n_workers} model")
        job = PSJob(job_id=job_id, model=model, members=members,
                    priority=float(priority), admit_order=self._admitted,
                    k_samples=int(k_samples), min_frac=float(min_frac),
                    seed=int(seed), ckpt_group=f"ps/{job_id}",
                    width=model.n_workers, lag=model.lag,
                    z_dim=model.z_dim, hidden=model.hidden)
        self._jobs[job_id] = job
        self._admitted += 1
        return job

    def evict(self, job_id: str) -> PSJob:
        return self._jobs.pop(job_id)

    def __getitem__(self, job_id: str) -> PSJob:
        return self._jobs[job_id]

    def __contains__(self, job_id: str) -> bool:
        return job_id in self._jobs

    def __len__(self) -> int:
        return len(self._jobs)

    def ids(self) -> List[str]:
        """Admitted job ids in admission order."""
        return [j.job_id for j in
                sorted(self._jobs.values(), key=lambda j: j.admit_order)]

    def jobs(self) -> List[PSJob]:
        return [self._jobs[i] for i in self.ids()]

    def set_priority(self, job_id: str, priority: float):
        self._jobs[job_id].priority = float(priority)


# ---------------------------------------------------------------------------
# The decision plane.
# ---------------------------------------------------------------------------


class _Bucket:
    """Jobs of one DMM architecture, windows stacked in ONE device ring.

    ``n_pad`` is the bucket's pad width — the max worker width of its
    jobs.  It grows when a wider job joins (host repack, one upload) and
    shrinks when the widest leaves, so a bucket that happens to be
    same-width carries zero padding and its math is shape-identical to
    an unpadded stack."""

    def __init__(self, cap: int, k_samples: int):
        self.cap = cap
        self.k_samples = k_samples
        self.n_pad = 0
        self.jobs: List[PSJob] = []
        self.rings = jnp.zeros((0, cap, 0), jnp.float32)
        self.heads = jnp.zeros((0,), jnp.int32)
        self._stacked = None    # (params, scales, widths, los) cache

    def stacked(self):
        if self._stacked is None:
            params, scales = stack_models_padded(
                [j.model for j in self.jobs], self.n_pad)
            widths = jnp.asarray([j.width for j in self.jobs], jnp.int32)
            los = jnp.asarray(
                [order_stats.min_frac_floor(j.width, j.min_frac)
                 for j in self.jobs], jnp.int32)
            self._stacked = (params, scales, widths, los)
        return self._stacked

    def dirty(self):
        self._stacked = None

    def repack(self, n_pad_new: int):
        """Re-home every ring at a new pad width (host roundtrip, ONE
        upload).  Caller guarantees every job width fits ``n_pad_new``,
        so truncation only ever drops zero pad columns."""
        if self.jobs:
            old = np.asarray(self.rings)
            new = np.zeros((old.shape[0], self.cap, n_pad_new), np.float32)
            w = min(old.shape[2], n_pad_new)
            new[:, :, :w] = old[:, :, :w]
            self.rings = jnp.asarray(new)
        else:
            self.rings = jnp.zeros((0, self.cap, n_pad_new), jnp.float32)
        self.n_pad = n_pad_new
        self.dirty()


class PSServer:
    """The multi-tenant decision plane (see module docstring).

    Tick protocol (what ``launch.multi_job.MultiJobDriver`` runs)::

        server.prefetch(serviced)        # cold decisions, one dispatch
        for job_id in serviced:          # scheduler's order
            c = server.predict_cutoff(job_id)   # lazy host fetch
            ... run the job's train step with the bit array ...
            server.observe(job_id, times, mask)  # enqueues
        server.flush()                   # ONE vmapped dispatch per
                                         # architecture bucket — widths
                                         # and impute modes all ride it

    ``flush`` is also called implicitly whenever a job with a queued
    observation is asked to predict, so a ``JobHandle`` behaves like a
    plain controller even without a driver calling ``flush``.
    """

    def __init__(self, registry: Optional[JobRegistry] = None, *,
                 history: int = 512, refit_steps: int = 150,
                 refit_batch: int = 8, refit_fresh: int = 4,
                 refit_async: bool = False, fallback_warmup: int = 3,
                 refit_retries: int = 1, obs=None):
        self.registry = registry if registry is not None else JobRegistry()
        self.history = history
        self.refit_steps = refit_steps
        self.refit_batch = refit_batch
        self.refit_fresh = refit_fresh
        self.refit_async = refit_async
        self.fallback_warmup = fallback_warmup
        self.refit_retries = refit_retries
        # optional repro.obs.ObsRun: flush/dispatch spans are host
        # perf_counter edges only (they time DISPATCH, the async cost
        # model) and refit-gate activity lands on host counters — with
        # obs attached the decision sequence is bit-identical
        self.obs = obs
        self._buckets: Dict[tuple, _Bucket] = {}
        self._queue: List[dict] = []
        self.dispatches = 0             # fused decision dispatches issued
        self.ticks = 0                  # flush() calls that dispatched

    # -- admission ------------------------------------------------------
    def admit(self, job_id: str, model: RuntimeModel, *, window=None,
              members=None, priority: float = 0.0, k_samples: int = 64,
              min_frac: float = 0.5, seed: int = 0) -> "JobHandle":
        """Admit a job; ``window`` warm-starts its lag window (rows of
        raw runtimes, as ``CutoffController.seed_window``)."""
        self.flush()
        job = self.registry.admit(job_id, model, members=members,
                                  priority=priority, k_samples=k_samples,
                                  min_frac=min_frac, seed=seed)
        self._place(job, window)
        if window is not None:
            job.trace = [np.asarray(r, np.float64)
                         for r in np.asarray(window)][-self.history:]
        return JobHandle(self, job_id)

    def evict(self, job_id: str) -> dict:
        """Remove a job; returns its final window (or None) and trace."""
        self.flush()
        job = self.registry[job_id]
        window = None
        if job.mode == "dmm" and job.count > 0:
            window = self.window_array(job_id)
        if job.bucket_sig is not None:
            self._remove(job)
        job.refit_task = None
        self.registry.evict(job_id)
        return {"window": window, "trace": np.array(job.trace)}

    def handle(self, job_id: str) -> "JobHandle":
        if job_id not in self.registry:
            raise KeyError(job_id)
        return JobHandle(self, job_id)

    # -- bucket plumbing ------------------------------------------------
    def _sig(self, job: PSJob) -> tuple:
        """The decision ARCHITECTURE: window length, sampling count, and
        DMM shape.  Deliberately width-free — mixed worker widths share
        one bucket via pad-to-bucket ragged dispatch (the per-job width
        and argmax floor ride the jit as traced operands).  Two jobs with
        different (z_dim, hidden) still cannot share a param stack."""
        return (job.cap, job.k_samples, job.z_dim, job.hidden)

    def _place(self, job: PSJob, window=None):
        """Insert a dmm-mode job into its architecture bucket, growing
        the bucket pad width if this job is the widest, and seeding its
        ring slot."""
        sig = self._sig(job)
        b = self._buckets.get(sig)
        if b is None:
            b = self._buckets[sig] = _Bucket(job.cap, job.k_samples)
        if job.width > b.n_pad:
            b.repack(job.width)
        rows = np.asarray(window, np.float64) if window is not None else None
        if rows is not None and rows.ndim != 2:
            raise ValueError(f"seed window must be (T, n), got {rows.shape}")
        if rows is not None and rows.shape[1] != job.width:
            raise ValueError(f"seed window width {rows.shape[1]} != "
                             f"job width {job.width}")
        ring, head, count = _seed_ring(
            rows if rows is not None else np.zeros((0, job.width)),
            job.cap, job.width, b.n_pad)
        b.rings = jnp.concatenate([b.rings, jnp.asarray(ring)[None]])
        b.heads = jnp.concatenate(
            [b.heads, jnp.asarray([head], jnp.int32)])
        job.slot = len(b.jobs)
        b.jobs.append(job)
        b.dirty()
        job.bucket_sig = sig
        job.count = count
        job.mode = "dmm"

    def _remove(self, job: PSJob):
        b = self._buckets[job.bucket_sig]
        i = job.slot
        keep = np.array([k for k in range(len(b.jobs)) if k != i])
        if keep.size:
            ka = jnp.asarray(keep)
            b.rings = b.rings[ka]
            b.heads = b.heads[ka]
        else:
            b.rings = b.rings[:0]
            b.heads = b.heads[:0]
        b.jobs.pop(i)
        for k, other in enumerate(b.jobs):
            other.slot = k
        b.dirty()
        sig, job.bucket_sig = job.bucket_sig, None
        job.slot = -1
        if not b.jobs:
            del self._buckets[sig]
            return
        widest = max(j.width for j in b.jobs)
        if widest < b.n_pad:
            b.repack(widest)

    # -- window diagnostics / checkpointing -----------------------------
    def window_array(self, job_id: str) -> np.ndarray:
        """The job's lag window, oldest row first (host copy, pad
        columns stripped).

        Raises ValueError while empty — the Trainer's checkpoint path
        relies on this to skip cold controllers."""
        self.flush()
        job = self.registry[job_id]
        if job.mode != "dmm":
            if not job.trace:
                raise ValueError("window is empty")
            return np.stack(job.trace[-job.cap:])
        if job.count == 0:
            raise ValueError("window is empty")
        b = self._buckets[job.bucket_sig]
        head = int(b.heads[job.slot])
        w = np.asarray(jnp.roll(b.rings[job.slot], -head,
                                axis=0))[:, :job.width]
        return w[-job.count:] if job.count < job.cap else w

    def seed_window(self, job_id: str, rows: np.ndarray):
        """Warm-start the job's window from recorded traces (checkpoint
        restore path)."""
        self.flush()
        job = self.registry[job_id]
        rows = np.asarray(rows, np.float64)
        if rows.shape[1] != job.width:
            raise ValueError(f"seed rows have width {rows.shape[1]}, "
                             f"job width is {job.width}")
        job.trace = (job.trace + [r for r in rows])[-self.history:]
        if job.mode != "dmm":
            for r in rows[-50:]:
                job.fallback.buf.append(np.asarray(r, np.float64))
            return
        b = self._buckets[job.bucket_sig]
        old = (np.asarray(self.window_array(job_id), np.float32)
               if job.count else np.zeros((0, job.width), np.float32))
        merged = np.concatenate([old, np.asarray(rows, np.float32)])
        ring, head, count = _seed_ring(merged, job.cap, job.width, b.n_pad)
        b.rings = b.rings.at[job.slot].set(jnp.asarray(ring))
        b.heads = b.heads.at[job.slot].set(head)
        job.count = min(job.count + rows.shape[0], job.cap)
        job.pending = None
        job.pending_pred = None

    def checkpoint_group(self, job_id: str) -> Dict[str, np.ndarray]:
        """The job's persistable controller state (``"ctl"``-group shape:
        width, members, step, window), under its registry group name."""
        job = self.registry[job_id]
        grp = {"n": np.int64(job.width),
               "members": np.asarray(job.members, np.int64),
               "step": np.int64(job.step)}
        try:
            grp["window"] = np.asarray(self.window_array(job_id), np.float64)
        except ValueError:
            pass
        return grp

    def checkpoint_groups(self) -> Dict[str, Dict[str, np.ndarray]]:
        return {self.registry[i].ckpt_group: self.checkpoint_group(i)
                for i in self.registry.ids()}

    # -- the decision path ----------------------------------------------
    # reprolint: hot-path
    def predict_cutoff(self, job_id: str) -> int:
        job = self.registry[job_id]
        if job.queued:
            self.flush()
        self._poll_refit(job)
        job.step += 1
        if job.mode == "fallback":
            job.fallback_steps += 1
            return min(job.fallback.predict_cutoff(), job.width)
        if not job.warmed_up:
            job.pending_pred = None
            return job.width
        if job.pending is None or job.pending[0] != job.step:
            # first decision after seeding/rejoin, or out-of-cadence
            # call: dispatch one now (prefetch() batches this for a
            # whole service set)
            self._decide_jobs([job], [job.step])
        _, row, out = job.pending
        job.pending = None
        host = self._out_host(out)
        # predictive moments come back as HOST rows (one shared fetch per
        # batched output, amortized over its jobs) so the next flush can
        # splice them straight into the packed upload
        job.pending_pred = (host["mu"][row], host["std"][row],
                            out["samples"], row)
        # reprolint: disable=host-sync-in-hot-path -- reads of the already-fetched host cache (the designated per-dispatch transfer lives in _out_host)
        job.last_iter = float(host["iter"][row])
        # reprolint: disable=host-sync-in-hot-path -- same host cache; int(cutoff) is the API's one designated sync
        return int(host["cutoff"][row])

    @staticmethod
    def _out_host(out: dict) -> dict:
        """Host view of one batched decision output, fetched ONCE per
        dispatch (cutoffs, moments and iter times for every job row in a
        single transfer) and cached on the output dict; the (K, n)
        sample clouds stay on device."""
        h = out.get("host")
        if h is None:
            # reprolint: disable=host-sync-in-hot-path -- THE designated fetch: one device_get per batched dispatch, amortized over every job row it served
            cut, mu, std, it = jax.device_get(
                (out["cutoff"], out["mu"], out["std"], out["iter"]))
            h = out["host"] = {"cutoff": np.asarray(cut),
                               "mu": np.asarray(mu),
                               "std": np.asarray(std),
                               "iter": np.asarray(it)}
        return h

    def prefetch(self, job_ids=None):
        """Batch the decide-only dispatch for every warmed job in
        ``job_ids`` (default: all) that has no decision in flight for its
        next step — one fused call per bucket instead of one per job."""
        ids = job_ids if job_ids is not None else self.registry.ids()
        jobs = [self.registry[i] for i in ids]
        need = [j for j in jobs
                if j.mode == "dmm" and j.warmed_up and not j.queued
                and (j.pending is None or j.pending[0] != j.step + 1)]
        by_bucket: Dict[tuple, list] = {}
        for j in need:
            by_bucket.setdefault(j.bucket_sig, []).append(j)
        for group in by_bucket.values():
            self._decide_jobs(group, [j.step + 1 for j in group])

    def _decide_jobs(self, jobs: List[PSJob], dsteps: List[int]):
        """Decide-only batched dispatch for same-bucket jobs.  ``dsteps``
        are the decision steps: the caller's current step when invoked
        from ``predict_cutoff`` (which already incremented), step+1 when
        prefetching."""
        b = self._buckets[jobs[0].bucket_sig]
        keys = jnp.asarray(C._prng_key_rows(
            [j.seed + d for j, d in zip(jobs, dsteps)]))
        params, scales, widths, los = b.stacked()
        slots = [j.slot for j in jobs]
        if slots == list(range(len(b.jobs))):
            cut, samp, mu, std, it = _full_decide(
                params, b.rings, b.heads, keys, scales, widths, los,
                k_samples=b.k_samples)
        else:
            idx = jnp.asarray(slots, jnp.int32)
            cut, samp, mu, std, it = _subset_decide(
                params, b.rings, b.heads, idx, keys, scales, widths, los,
                k_samples=b.k_samples)
        self.dispatches += 1
        out = {"cutoff": cut, "samples": samp, "mu": mu, "std": std,
               "iter": it}
        for row, (j, d) in enumerate(zip(jobs, dsteps)):
            j.pending = (d, row, out)

    def observe(self, job_id: str, times, finished_mask=None):
        job = self.registry[job_id]
        t = np.asarray(times, np.float64)
        if t.shape != (job.width,):
            raise ValueError(
                f"job {job_id!r}: observe got {t.shape[0]} runtimes at "
                f"width {job.width}; resize() before the resized step")
        mask = (np.ones(job.width, bool) if finished_mask is None
                else np.asarray(finished_mask, bool))
        if not mask.any():
            # no coherent cutoff time exists to impute anything at — the
            # old fall-through fed fully-censored times into the refit
            # trace as if observed; reject loudly instead (the
            # CutoffController/ElasticController convention)
            raise ValueError(
                f"job {job_id!r}: observe got an all-False finished_mask: "
                "a step with zero finished workers has no observed cutoff "
                "time to impute the censored entries at")
        # rolling imputed trace: refit training data (plain imputation at
        # the observed cutoff time, as ElasticController keeps it)
        row = np.where(mask, t, t[mask].max()) if not mask.all() else t
        job.trace = (job.trace + [row])[-self.history:]
        job.fresh += 1
        if job.mode == "fallback":
            job.fallback.observe(times, finished_mask)
            self._poll_refit(job)
            if job.refit_task is None:
                self._maybe_refit(job)
            return
        if job.queued:
            self.flush()        # one observation in flight per job, max
        t32 = t.astype(np.float32)
        # mirror CutoffController.observe's mode selection exactly: a
        # full-sync observation takes the plain append even when moments
        # are pending (cheaper, and equivalence-by-construction with the
        # single-job reference rather than by where-merge accident)
        cen = job.pending_pred is not None and not bool(mask.all())
        pred = (job.pending_pred[0], job.pending_pred[1]) if cen else None
        if job.pending_pred is not None:
            # moments stay valid for the queued imputation; the sample
            # cache does not survive the window change
            job.pending_pred = job.pending_pred[:2] + (None,
                                                       job.pending_pred[3])
        job.count = min(job.count + 1, job.cap)
        if job.warmed_up:
            self._queue.append({
                "job": job, "times": t32, "mask": mask, "cen": cen,
                "pred": pred, "dstep": job.step + 1, "istep": job.step})
            job.queued = True
        else:
            # warmup: plain append straight into the job's ring slot
            # (pad columns carry times 0 under a True mask, which the
            # plain imputation writes through as 0 — the decision never
            # reads them)
            b = self._buckets[job.bucket_sig]
            tp = np.zeros(b.n_pad, np.float32)
            tp[:job.width] = t32
            mp = np.ones(b.n_pad, bool)
            mp[:job.width] = mask
            obs = {"times": jnp.asarray(tp), "mask": jnp.asarray(mp)}
            ring, head = C._ring_append(b.rings[job.slot],
                                        b.heads[job.slot], obs, mode="plain")
            b.rings = b.rings.at[job.slot].set(ring)
            b.heads = b.heads.at[job.slot].set(head)

    def flush(self) -> int:
        """Dispatch every queued observation+decision: ONE vmapped fused
        call per architecture bucket — mixed widths AND mixed
        plain/censored modes all ride the same dispatch (traced width
        masks + traced censor flags).  Returns the dispatches issued."""
        if not self._queue:
            return 0
        # spans stamp host perf_counter edges around the (async) dispatch
        # calls; obs attrs are plain host ints already on the queue
        # entries, so instrumentation adds zero device syncs here
        tracer = self.obs.trace if self.obs is not None else None
        fspan = (tracer.span("ps.flush", track="ps", tick=self.ticks,
                             queued=len(self._queue))
                 if tracer is not None else nullcontext())
        with fspan:
            queue, self._queue = self._queue, []
            groups: Dict[tuple, list] = {}
            for e in queue:
                groups.setdefault(e["job"].bucket_sig, []).append(e)
            issued = 0
            for sig, entries in groups.items():
                b = self._buckets[sig]
                m, npd = len(entries), b.n_pad
                slots = [e["job"].slot for e in entries]
                gather = slots != list(range(len(b.jobs)))
                dspan = (tracer.span("ps.dispatch", track="ps", jobs=m,
                                     n_pad=npd, gather=gather)
                         if tracer is not None else nullcontext())
                with dspan:
                    # one packed upload:
                    # [times, mask, mu, std] + keys/steps/cen
                    pack = np.zeros((4, m, npd), np.float32)
                    pack[1] = 1.0   # pad columns read mask=True (write 0.0)
                    keys = np.empty((m, 4), np.uint32)
                    steps = np.empty((m,), np.uint32)
                    cen = np.empty((m,), bool)
                    for r, e in enumerate(entries):
                        w = e["job"].width
                        pack[0, r, :w] = e["times"]
                        pack[1, r, :w] = e["mask"]
                        if e["cen"]:
                            pack[2, r, :w] = e["pred"][0][:w]
                            pack[3, r, :w] = e["pred"][1][:w]
                        steps[r] = e["istep"]
                        cen[r] = e["cen"]
                    keys[:, :2] = C._prng_key_rows(
                        [e["job"].seed + e["dstep"] for e in entries])
                    keys[:, 2:] = C._prng_key_rows(
                        [e["job"].seed + 1_000_003 for e in entries])
                    params, scales, widths, los = b.stacked()
                    args = (jnp.asarray(pack), jnp.asarray(keys),
                            jnp.asarray(steps), jnp.asarray(cen),
                            scales, widths, los)
                    if not gather:
                        (b.rings, b.heads, cut, samp, mu, std, it) = (
                            _full_observe_decide(
                                params, b.rings, b.heads, *args,
                                k_samples=b.k_samples))
                    else:
                        idx = jnp.asarray(slots, jnp.int32)
                        (b.rings, b.heads, cut, samp, mu, std, it) = (
                            _subset_observe_decide(
                                params, b.rings, b.heads, idx, *args,
                                k_samples=b.k_samples))
                    issued += 1
                    out = {"cutoff": cut, "samples": samp, "mu": mu,
                           "std": std, "iter": it}
                    for row, e in enumerate(entries):
                        e["job"].pending = (e["dstep"], row, out)
                        e["job"].queued = False
        self.dispatches += issued
        self.ticks += 1
        return issued

    # -- diagnostics -----------------------------------------------------
    def predicted_iter_time(self, job_id: str) -> Optional[float]:
        """Posterior-predictive E[x_(c)] of the job's latest decision (raw
        seconds) — the shortest-predicted-step-first scheduler's key.
        None before the first warmed-up decision (and in fallback mode,
        where the analytic controller has no sample cloud)."""
        return self.registry[job_id].last_iter

    def predicted_order_stats(self, job_id: str):
        job = self.registry[job_id]
        if job.pending_pred is None or job.pending_pred[2] is None:
            return None
        samples = np.asarray(
            job.pending_pred[2][job.pending_pred[3]])[:, :job.width]
        return order_stats.mc_order_stats(samples)

    def predicted_samples(self, job_id: str):
        """DEVICE view of the job's latest predictive sample cloud,
        ``(K, n)`` with the bucket's pad columns sliced off — a lazy
        array reference, never a host fetch, so the obs quality layer
        can buffer it on the hot path and materialize it only at drain
        boundaries.  None when no sampled decision is pending (cold,
        fallback mode, or already consumed by a censored observe)."""
        job = self.registry[job_id]
        if job.pending_pred is None or job.pending_pred[2] is None:
            return None
        return job.pending_pred[2][job.pending_pred[3], :, :job.width]

    # -- elasticity ------------------------------------------------------
    def resize(self, job_id: str, n_workers: int, col_map=None,
               model: Optional[RuntimeModel] = None, members=None):
        """Per-job worker-set change, ElasticController protocol: remap
        the window (survivors column-exact), then either swap in a
        ``model`` fitted at the new width (job stays on the batched DMM
        path) or degrade to a warm-seeded Elfving fallback until the
        refit lands (``_maybe_refit``)."""
        self.flush()
        job = self.registry[job_id]
        n_new = int(n_workers)
        if (n_new == job.width and col_map is None and model is None
                and members is None):
            return          # idempotent: re-asserting the current width
                            # must not degrade a healthy DMM job
        if model is not None and model.n_workers != n_new:
            raise ValueError(
                f"resize({n_new}) got a RuntimeModel of width "
                f"{model.n_workers}; refit it for the new width first")
        rows = None
        if job.mode == "dmm" and job.count > 0:
            rows = self.window_array(job_id)
        if job.bucket_sig is not None:
            self._remove(job)
        if job.trace:
            job.trace = [r for r in C.remap_columns(
                np.stack(job.trace), n_new, col_map)]
        if rows is not None:
            rows = C.remap_columns(np.asarray(rows, np.float64), n_new,
                                   col_map)
        elif job.trace:
            rows = np.stack(job.trace[-job.cap:])
        job.width = n_new
        job.members = self._resized_members(job.members, n_new, col_map,
                                            members)
        job.resize_count += 1
        job.fresh = 0
        job.pending = None
        job.pending_pred = None
        job.last_iter = None
        # abandon any in-flight refit WITHOUT blocking on its ELBO fit:
        # the daemon thread keeps filling its orphaned result box, and
        # _poll_refit_task would discard it by generation anyway
        job.refit_task = None
        if model is not None:
            job.model = model
            self._place(job, rows)
            return
        job.model = None
        job.mode = "fallback"
        job.count = 0
        job.fallback = C.ElfvingController(
            n_new, warmup=self.fallback_warmup, min_frac=job.min_frac)
        for r in job.trace[-50:]:
            job.fallback.buf.append(np.asarray(r, np.float64))

    @staticmethod
    def _resized_members(old: np.ndarray, n_new: int, col_map,
                         members) -> np.ndarray:
        """GLOBAL worker ids across a resize.  Survivors keep their ids
        (via ``col_map``, the same remap the window uses); workers whose
        global id the caller didn't supply are marked ``-1`` — never
        silently renumbered, so the per-job checkpoint group's
        restore-by-global-id protocol stays sound."""
        if members is not None:
            members = np.asarray(members, int)
            if members.shape != (n_new,):
                raise ValueError(f"members must be ({n_new},), got "
                                 f"{members.shape}")
            return members
        if old.size == 0:
            # np.clip(cm, 0, old.size - 1) on an empty member array would
            # clip to index -1 (the LAST element of a non-empty array) —
            # there are no surviving ids to carry over, so demand them
            # explicitly instead of crashing or aliasing
            raise ValueError(
                f"resize({n_new}) from a width-0 member set has no "
                "surviving global worker ids to remap; pass members= "
                "explicitly")
        if col_map is None:
            col_map = np.concatenate([
                np.arange(min(old.size, n_new)),
                np.full(max(0, n_new - old.size), -1, int)])
        cm = np.asarray(col_map, int)
        return np.where(cm >= 0, old[np.clip(cm, 0, old.size - 1)], -1)

    # -- refit plumbing (ElasticController's task shape, per job) --------
    def _fit_model(self, job: PSJob, rows: np.ndarray, n: int,
                   seed: int) -> RuntimeModel:
        model = RuntimeModel(n_workers=n, lag=job.lag,
                             z_dim=job.z_dim, hidden=job.hidden)
        model.fit(rows, steps=self.refit_steps, batch=self.refit_batch,
                  seed=seed)
        return model

    def _maybe_refit(self, job: PSJob):
        # failed attempts back off: each demands twice the fresh rows
        need = self.refit_fresh * (2 ** job.refit_failures)
        if (job.fresh < need
                or len(job.trace) < job.cap + self.refit_batch):
            return
        # freeze width/seed now: a resize mid-fit must not retarget the
        # running fit (its result is discarded by generation anyway)
        rows = np.stack(job.trace)
        n = job.width
        seed = job.seed + job.resize_count + 1000 * job.refit_failures
        if self.obs is not None:
            self.obs.metrics.counter("ps.refits_started").inc()
        if self.refit_async:
            job.refit_task = C._spawn_refit(
                lambda: self._fit_model(job, rows, n, seed),
                job.resize_count)
        else:
            span = (self.obs.trace.span("ps.refit", track="ps",
                                        job=job.job_id, width=n)
                    if self.obs is not None else nullcontext())
            with span:
                model = self._fit_model(job, rows, n, seed)
            self._install_refit(job, model)

    def _poll_refit(self, job: PSJob):
        if job.refit_task is None:
            return
        done, model, err = C._poll_refit_task(job.refit_task,
                                              job.resize_count, job.width)
        if not done:
            return
        job.refit_task = None
        if err is not None:
            job.refit_failures += 1
            if self.obs is not None:
                self.obs.metrics.counter("ps.refit_failures").inc()
            if job.refit_failures > self.refit_retries:
                raise C.RefitError(
                    f"job {job.job_id!r}: DMM refit failed "
                    f"{job.refit_failures} times at width {job.width} "
                    f"(retry budget {self.refit_retries} spent); last "
                    f"error: {err!r}") from err
            print(f"job {job.job_id!r}: DMM refit failed ({err!r}); "
                  f"retrying after "
                  f"{self.refit_fresh * 2 ** job.refit_failures} fresh "
                  f"observations")
            job.fresh = 0
            return
        if model is not None and job.mode == "fallback":
            job.refit_failures = 0
            self._install_refit(job, model)

    def _install_refit(self, job: PSJob, model: RuntimeModel):
        job.model = model
        job.mode = "dmm"
        job.fallback = None
        self._place(job, np.stack(job.trace[-job.cap:]))
        if self.obs is not None:
            # host counter increment — _poll_refit reaches here from the
            # hot predict path, so no spans/fetches, just bookkeeping
            self.obs.metrics.counter("ps.refits_installed").inc()

    def wait_refits(self, job_ids=None):
        """Block until every in-flight async refit for ``job_ids``
        (default: all) has finished and, if still current, been
        installed.  Deterministic sync point for tests and benches — the
        tick path itself never blocks on a fit."""
        ids = job_ids if job_ids is not None else self.registry.ids()
        for i in ids:
            job = self.registry[i]
            if job.refit_task is not None:
                job.refit_task[0].join()
                self._poll_refit(job)


# ---------------------------------------------------------------------------
# Controller-protocol facade.
# ---------------------------------------------------------------------------


class JobHandle:
    """One job's controller-shaped view of the shared server.

    Implements the full controller protocol (`predict_cutoff`, `observe`,
    `resize`, `seed_window`, `window_array`, `predicted_order_stats`,
    `_step`), so a ``launch.train.Trainer`` drives the multi-tenant
    server without knowing it — including the checkpoint ``"ctl"`` group
    and the elastic ``_sync_membership`` path.
    """

    def __init__(self, server: PSServer, job_id: str):
        self.server = server
        self.job_id = job_id

    @property
    def job(self) -> PSJob:
        return self.server.registry[self.job_id]

    @property
    def n(self) -> int:
        return self.job.width

    @property
    def warmed_up(self) -> bool:
        return self.job.warmed_up

    @property
    def mode(self) -> str:
        return self.job.mode

    @property
    def _step(self) -> int:
        return self.job.step

    @_step.setter
    def _step(self, value: int):
        self.job.step = int(value)

    def predict_cutoff(self) -> int:
        return self.server.predict_cutoff(self.job_id)

    def observe(self, times, finished_mask=None):
        return self.server.observe(self.job_id, times, finished_mask)

    def resize(self, n_workers: int, col_map=None, model=None,
               members=None):
        return self.server.resize(self.job_id, n_workers, col_map=col_map,
                                  model=model, members=members)

    def seed_window(self, traces):
        return self.server.seed_window(self.job_id, traces)

    def window_array(self) -> np.ndarray:
        return self.server.window_array(self.job_id)

    def predicted_order_stats(self):
        return self.server.predicted_order_stats(self.job_id)

    def predicted_samples(self):
        return self.server.predicted_samples(self.job_id)

    def predicted_iter_time(self) -> Optional[float]:
        return self.server.predicted_iter_time(self.job_id)
