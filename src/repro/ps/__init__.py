"""repro.ps — the multi-tenant parameter-server subsystem.

One shared cluster, J concurrent training jobs, ONE device-resident
decision path: per-job lag windows live stacked in a (J, lag+1, n_max)
ring — mixed worker widths ride the same stack via in-jit traced width
masks — and every tick dispatches a single vmapped fused observe+decide
instead of J separate jits (src/repro/core/README.md has the full
ragged-dispatch contract).
"""
from repro.ps.scheduler import (JobView, PriorityScheduler,
                                RoundRobinScheduler, ShortestStepScheduler,
                                job_views, make_scheduler)
from repro.ps.server import JobHandle, JobRegistry, PSJob, PSServer

__all__ = [
    "JobHandle", "JobRegistry", "PSJob", "PSServer",
    "JobView", "RoundRobinScheduler", "PriorityScheduler",
    "ShortestStepScheduler", "job_views", "make_scheduler",
]
