"""Deep Markov Model of joint worker run-times (paper §3.1.2–3.1.3).

Generative model (Krishnan et al. 2017 "deep linear dynamical model"):

    z_t ~ N(G_theta(z_{t-1}), H_theta(z_{t-1}))
    x_t ~ N(I_theta(z_t),     J_theta(z_t))

with the gated transition

    G(z) = (1 - g) * Linear(z) + g * h,   g = MLP_2(z, ReLU, Sigmoid),
    h = MLP_2(z, ReLU, Identity),          H = MLP_1(ReLU(G), Softplus)

and emission I = MLP_2(z, Id, Id), J = MLP_2(I(z), ReLU, Softplus).
H/J parameterize standard deviations (Softplus > 0).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def _mlp_init(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": dense_init(ks[i], dims[i], dims[i + 1], dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]


def _mlp(params, x, acts):
    for p, a in zip(params, acts):
        x = x @ p["w"] + p["b"]
        x = a(x)
    return x


_ID = lambda x: x
_SOFTPLUS = jax.nn.softplus
_RELU = jax.nn.relu
_SIG = jax.nn.sigmoid
_TANH = jnp.tanh


def dmm_init(key, n_workers: int, z_dim: int = 32, hidden: int = 64):
    ks = jax.random.split(key, 6)
    return {
        "trans_lin": _mlp_init(ks[0], (z_dim, z_dim)),
        "trans_h": _mlp_init(ks[1], (z_dim, hidden, z_dim)),
        "trans_g": _mlp_init(ks[2], (z_dim, hidden, z_dim)),
        "trans_std": _mlp_init(ks[3], (z_dim, z_dim)),
        "emit_mu": _mlp_init(ks[4], (z_dim, hidden, n_workers)),
        "emit_std": _mlp_init(ks[5], (n_workers, n_workers)),
        "z0_mu": jnp.zeros((z_dim,)),
        "z0_logstd": jnp.zeros((z_dim,)),
    }


def transition(params, z):
    """p(z_t | z_{t-1}) -> (mu, std)."""
    lin = _mlp(params["trans_lin"], z, (_ID,))
    h = _mlp(params["trans_h"], z, (_RELU, _ID))
    g = _mlp(params["trans_g"], z, (_RELU, _SIG))
    mu = (1.0 - g) * lin + g * h
    std = _mlp(params["trans_std"], _RELU(mu), (_SOFTPLUS,)) + 1e-3
    return mu, std


def emission(params, z):
    """p(x_t | z_t) -> (mu, std) over the n_workers runtime vector."""
    mu = _mlp(params["emit_mu"], z, (_ID, _ID))
    std = _mlp(params["emit_std"], _RELU(mu), (_SOFTPLUS,)) + 1e-3
    return mu, std


def gaussian_logpdf(x, mu, std):
    z = (x - mu) / std
    return -0.5 * (z * z + 2.0 * jnp.log(std) + math.log(2.0 * math.pi))
