"""Amortized inference network (paper §3.1.3): structured left-right guide.

    q_phi(z_t | z_{t-1}, x_{T-l:T}) = N(mu_q, sigma_q)
    h_out   = 1/3 * (MLP_1(z_{t-1}, Tanh) + h_left[t] + h_right[t])
    h_left  = RNN(x_{T-l:t-1}, ReLU)   (forward pass)
    h_right = RNN(x_{t+1:T},  ReLU)    (backward pass)
    mu_q    = MLP_1(h_out, Identity);  sigma_q = MLP_1(mu_q, Softplus)

Sampling is sequential in t (q conditions on the sampled z_{t-1}) under a
lax.scan; the RNN sweeps are computed once per window.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.runtime_model.dmm import (_ID, _RELU, _SOFTPLUS, _TANH, _mlp,
                                          _mlp_init)
from repro.models.layers import dense_init


def guide_init(key, n_workers: int, z_dim: int = 32, hidden: int = 64):
    ks = jax.random.split(key, 7)
    def rnn(k):
        k1, k2 = jax.random.split(k)
        return {"wx": dense_init(k1, n_workers, hidden, jnp.float32),
                "wh": dense_init(k2, hidden, hidden, jnp.float32),
                "b": jnp.zeros((hidden,))}
    return {
        "rnn_left": rnn(ks[0]),
        "rnn_right": rnn(ks[1]),
        "z_proj": _mlp_init(ks[2], (z_dim, hidden)),
        "mu": _mlp_init(ks[3], (hidden, z_dim)),
        "std": _mlp_init(ks[4], (z_dim, z_dim)),
    }


def _rnn_sweep(p, xs):
    """xs: (T, B, n) -> hidden states (T, B, hidden), ReLU RNN."""
    def step(h, x):
        h = _RELU(x @ p["wx"] + h @ p["wh"] + p["b"])
        return h, h
    B = xs.shape[1]
    h0 = jnp.zeros((B, p["wh"].shape[0]))
    _, hs = jax.lax.scan(step, h0, xs)
    return hs


def _shifted_sweeps(guide_params, xt):
    """Both RNN sweeps over xt (T, B, n), shifted one step so that
    ``h_left[t]`` summarizes x_{<t} and ``h_right[t]`` summarizes x_{>t}
    (the structured left-right conditioning of §3.1.3).  Shared by the
    ELBO-path ``guide_sample`` and the decision-path
    ``guide_sample_broadcast``; returns (h_left, h_right), each
    (T, B, hidden)."""
    h_left_all = _rnn_sweep(guide_params["rnn_left"], xt)
    h_right_all = _rnn_sweep(guide_params["rnn_right"], xt[::-1])[::-1]
    zeros = jnp.zeros((1,) + h_left_all.shape[1:])
    h_left = jnp.concatenate([zeros, h_left_all[:-1]], axis=0)
    h_right = jnp.concatenate([h_right_all[1:], zeros], axis=0)
    return h_left, h_right


def guide_sample(guide_params, x_window, key, z0=None):
    """Sample a z trajectory for one window.

    x_window: (B, T, n) normalized runtimes.
    Returns (zs (B, T, zd), mus, stds) — everything needed for the ELBO.
    """
    B, T, n = x_window.shape
    xt = jnp.moveaxis(x_window, 1, 0)             # (T, B, n)
    h_left, h_right = _shifted_sweeps(guide_params, xt)

    zd = guide_params["mu"][0]["w"].shape[1]
    if z0 is None:
        z0 = jnp.zeros((B, zd))
    keys = jax.random.split(key, T)

    def step(z_prev, inp):
        hl, hr, k = inp
        hz = _TANH(_mlp(guide_params["z_proj"], z_prev, (_ID,)))
        h_out = (hz + hl + hr) / 3.0
        mu = _mlp(guide_params["mu"], h_out, (_ID,))
        std = _mlp(guide_params["std"], mu, (_SOFTPLUS,)) + 1e-3
        z = mu + std * jax.random.normal(k, mu.shape)
        return z, (z, mu, std)

    _, (zs, mus, stds) = jax.lax.scan(step, z0, (h_left, h_right, keys))
    mv = lambda t: jnp.moveaxis(t, 0, 1)
    return mv(zs), mv(mus), mv(stds)


def guide_sample_broadcast(guide_params, x_window, key, k_samples: int):
    """K posterior samples of z_T for ONE window, sweeping the RNNs once.

    Equivalent to ``guide_sample`` on ``x_window`` broadcast to
    (k_samples, T, n), restructured for the parameter server's
    per-decision critical path:

      * the deterministic RNN sweeps produce identical rows for every
        sample there, so they run at B=1 and only the z-chain (which
        conditions on the sampled z_{t-1}) carries the K batch — removes
        the K× sweep compute;
      * the per-step normals are one batched threefry (same bits as
        ``normal(keys[t], (K, zd))`` per step);
      * the z-chain folds the mu and std projections into one matmul via
        the precomputed ``[W_mu | W_mu @ W_std]`` concatenation —
        sequential-loop ops are what dominate this path on real hardware,
        not FLOPs.  The reassociation perturbs samples at f32 rounding
        scale (~1e-6) relative to ``guide_sample``; the controller
        equivalence suite pins that down.

    RNG layout (split(key, T), one (K, zd) normal per step) matches
    ``guide_sample`` draw for draw.

    x_window: (T, n) normalized runtimes.  Returns z_T: (k_samples, zd).
    """
    T, n = x_window.shape
    xt = x_window[:, None, :]                     # (T, 1, n)
    h_left, h_right = _shifted_sweeps(guide_params, xt)
    # only the sum enters h_out, so precompute it once for the window
    h_sum = h_left + h_right

    zd = guide_params["mu"][0]["w"].shape[1]
    keys = jax.random.split(key, T)
    eps = jax.vmap(lambda k: jax.random.normal(k, (k_samples, zd)))(keys)

    wz, bz = guide_params["z_proj"][0]["w"], guide_params["z_proj"][0]["b"]
    wm, bm = guide_params["mu"][0]["w"], guide_params["mu"][0]["b"]
    ws, bs = guide_params["std"][0]["w"], guide_params["std"][0]["b"]
    w_cat = jnp.concatenate([wm, wm @ ws], axis=1)   # (hidden, 2*zd)
    b_cat = jnp.concatenate([bm, bm @ ws + bs])

    z0 = jnp.zeros((k_samples, zd))

    def step(z_prev, inp):
        hs, e = inp                               # hs: (1, hidden)
        h_out = (_TANH(z_prev @ wz + bz) + hs) / 3.0
        ms = h_out @ w_cat + b_cat                # [mu | std_pre]
        mu, sp = ms[:, :zd], ms[:, zd:]
        z = mu + (_SOFTPLUS(sp) + 1e-3) * e
        return z, None

    z_T, _ = jax.lax.scan(step, z0, (h_sum, eps))
    return z_T
