"""RuntimeModel: ELBO training + real-time posterior-predictive inference.

Implements the paper's Eq. 5 approximation: sample z_{T-l:T} trajectories
from the guide, push the last-step marginal through the transition and
emission to obtain K Monte-Carlo samples of the next joint runtime vector
x_{T+1} — fast enough for the parameter server's inner loop.

Observations are normalized by 2x the mean of the first lag window (paper
§3.1.3) so one trained model transfers across network/batch-size scales.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.cutoff import order_stats
from repro.core.runtime_model import dmm as D
from repro.core.runtime_model import guide as G


# ---------------------------------------------------------------------------
# Width-stable per-column RNG.
#
# A block draw like ``normal(key, (K, n))`` consumes the counter stream in
# row-major order, so the SAME key at width n and width n_pad > n yields
# different values in the shared columns — a padded bucket job could never
# reproduce its standalone controller's samples.  Folding the column index
# into the key makes column i a function of (key, i) alone: computing at any
# padded width reproduces the width-n draws in columns [:n] bit-for-bit.
# This is the RNG contract the ragged dispatch's parity guarantee rests on;
# every width-shaped draw on the decision/observe path routes through these.
# ---------------------------------------------------------------------------


def _colwise_keys(key, n: int):
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))


def colwise_uniform(key, n: int):
    """(n,) uniforms in [0, 1); entry i depends only on (key, i)."""
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(_colwise_keys(key, n))


def colwise_normal(key, rows: int, n: int):
    """(rows, n) standard normals; column i depends only on (key, i)."""
    return jax.vmap(lambda k: jax.random.normal(k, (rows,)),
                    out_axes=1)(_colwise_keys(key, n))


@dataclass
class RuntimeModel:
    n_workers: int
    lag: int = 20
    z_dim: int = 32
    hidden: int = 64
    params: dict = field(default=None, repr=False)
    norm_scale: float = 1.0

    # ------------------------------------------------------------------
    def init(self, seed: int = 0):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        self.params = {
            "dmm": D.dmm_init(k1, self.n_workers, self.z_dim, self.hidden),
            "guide": G.guide_init(k2, self.n_workers, self.z_dim,
                                  self.hidden),
        }
        return self

    # ------------------------------------------------------------------
    @staticmethod
    @functools.partial(jax.jit, static_argnames=())
    def _elbo(params, x, key):
        """x: (B, T, n) normalized windows. Single-sample ELBO."""
        zs, mus, stds = G.guide_sample(params["guide"], x, key)
        dmm = params["dmm"]
        B, T, n = x.shape
        # log p(x_t | z_t)
        emu, estd = D.emission(dmm, zs)
        lpx = jnp.sum(D.gaussian_logpdf(x, emu, estd), axis=(1, 2))
        # log p(z_t | z_{t-1}) (z_0 prior from learned z0)
        z_prev = jnp.concatenate(
            [jnp.broadcast_to(dmm["z0_mu"], (B, 1, zs.shape[-1])),
             zs[:, :-1]], axis=1)
        tmu, tstd = D.transition(dmm, z_prev)
        lpz = jnp.sum(D.gaussian_logpdf(zs, tmu, tstd), axis=(1, 2))
        # log q(z_t | ...)
        lqz = jnp.sum(D.gaussian_logpdf(zs, mus, stds), axis=(1, 2))
        return jnp.mean(lpx + lpz - lqz)

    def elbo(self, x, key):
        return self._elbo(self.params, x, key)

    # ------------------------------------------------------------------
    def fit(self, traces: np.ndarray, *, steps: int = 800, batch: int = 16,
            lr: float = 3e-3, seed: int = 0, verbose: bool = False,
            clip: float = 5.0):
        """traces: (T_total, n) raw runtimes from the instrumented cluster."""
        traces = np.asarray(traces, np.float32)
        assert traces.shape[1] == self.n_workers
        self.norm_scale = float(2.0 * traces[: self.lag + 1].mean())
        xs = traces / self.norm_scale
        T = self.lag + 1
        n_windows = xs.shape[0] - T
        if n_windows < 1:
            raise ValueError("trace too short for the lag window")
        windows = np.stack([xs[i:i + T] for i in range(n_windows)])

        if self.params is None:
            self.init(seed)
        opt = optim.clip_by_global_norm(optim.adam(lr), clip)
        state = opt.init(self.params)
        params = self.params

        @jax.jit
        def step_fn(params, state, batch_x, key):
            loss, grads = jax.value_and_grad(
                lambda p: -self._elbo(p, batch_x, key))(params)
            ups, state = opt.update(grads, state, params)
            return optim.apply_updates(params, ups), state, loss

        rng = np.random.default_rng(seed)
        key = jax.random.PRNGKey(seed + 1)
        losses = []
        for i in range(steps):
            idx = rng.integers(0, n_windows, size=min(batch, n_windows))
            key, sub = jax.random.split(key)
            params, state, loss = step_fn(params, state,
                                          jnp.asarray(windows[idx]), sub)
            losses.append(float(loss))
            if verbose and i % 100 == 0:
                print(f"  elbo step {i}: -elbo={float(loss):.3f}")
        self.params = params
        return losses

    # ------------------------------------------------------------------
    @staticmethod
    @functools.partial(jax.jit, static_argnames=("k_samples",))
    def _predict(params, window_norm, key, k_samples: int):
        """window_norm: (T, n) -> K samples of x_{T+1} plus (mu, std)."""
        x = jnp.broadcast_to(window_norm[None], (k_samples,)
                             + window_norm.shape)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        zs, _, _ = G.guide_sample(params["guide"], x, k1)
        z_T = zs[:, -1]                                   # (K, zd)
        tmu, tstd = D.transition(params["dmm"], z_T)
        z_next = tmu + tstd * jax.random.normal(k2, tmu.shape)
        emu, estd = D.emission(params["dmm"], z_next)     # (K, n)
        x_next = emu + estd * colwise_normal(k3, k_samples, emu.shape[1])
        return x_next, emu, estd

    def predict_next(self, window: np.ndarray, k_samples: int = 64,
                     seed: int = 0):
        """window: (lag+1, n) raw runtimes.

        Returns (samples (K, n), mu (K, n), std (K, n)) in RAW time units.
        """
        w = jnp.asarray(window, jnp.float32) / self.norm_scale
        key = jax.random.PRNGKey(seed)
        s, mu, std = self._predict(self.params, w, key, k_samples)
        return (np.asarray(s) * self.norm_scale,
                np.asarray(mu) * self.norm_scale,
                np.asarray(std) * self.norm_scale)

    # ------------------------------------------------------------------
    # Fused device-resident decision (controller hot path).
    # ------------------------------------------------------------------
    @staticmethod
    def _decide_core(params, ring, head, key, norm_scale, k_samples: int,
                     lo, width=None):
        """guide → transition → emission → sample → sort → argmax → moments
        over the device-resident ring buffer — the trace-level decision
        body that ``controller._fused_observe_decide`` jits (together with
        the deferred ring append).

        ring: (lag+1, n) raw f32 runtime rows, ``head`` (traced int32) the
        index of the OLDEST row; the window never round-trips to the host.
        RNG layout mirrors ``_predict`` (split(key, 4), k1/k2/k3) so the
        samples match the host reference path draw for draw.

        Every operand is either traced data or a job-independent static
        (``k_samples``), so the whole body vmaps over a leading JOB
        axis — ``controller._batched_observe_decide_ragged`` stacks J
        jobs' (params, ring, head, key, norm_scale, width, lo) and runs
        this once per tick for the multi-tenant parameter server
        (``repro.ps``).

        ``width=None`` (the single-job path) keeps ``lo`` a static int
        and the column count n as-is.  A TRACED ``width`` enables the
        ragged mode: the ring is n_pad columns wide, columns >= width are
        padding — they are zeroed out of the guide's input, their samples
        forced to +inf (the bitonic sort pushes them past every real
        order statistic, where the masked argmax in
        ``order_stats.cutoff_and_iter_ragged_jax`` cannot pick them) and
        ``lo`` is traced per job.  With zero-padded params
        (``stack_models_padded``) and the column-wise RNG above, a padded
        job computes the same decision its standalone width-n controller
        would.

        Returns (cutoff int32 scalar, samples (K, n) raw,
        pred_mu (n,), pred_std (n,) — the aggregated predictive moments the
        censored-imputation step needs — and pred_iter, the
        posterior-predictive E[x_(c)] wall time of the decided step, which
        the multi-job scheduler ranks by).
        """
        window = jnp.roll(ring, -head, axis=0) / norm_scale
        n = ring.shape[1]
        if width is not None:
            colm = jnp.arange(n) < width
            window = jnp.where(colm[None, :], window, 0.0)
        k1, k2, k3, _ = jax.random.split(key, 4)
        z_T = G.guide_sample_broadcast(params["guide"], window, k1, k_samples)
        tmu, tstd = D.transition(params["dmm"], z_T)
        z_next = tmu + tstd * jax.random.normal(k2, tmu.shape)
        emu, estd = D.emission(params["dmm"], z_next)     # (K, n)
        x_next = emu + estd * colwise_normal(k3, k_samples, n)
        samples = x_next * norm_scale
        if width is None:
            cutoff, pred_iter = order_stats.cutoff_and_iter_jax(samples, lo)
        else:
            samples = jnp.where(colm[None, :], samples, jnp.inf)
            cutoff, pred_iter = order_stats.cutoff_and_iter_ragged_jax(
                samples, lo, width)
        pred_mu = jnp.mean(emu, axis=0) * norm_scale
        # mixture-variance law over the K mixture components:
        # Var = E[std^2] + Var[mu] (E[std]^2 under-disperses the tail)
        pred_std = jnp.sqrt(jnp.mean(estd ** 2, axis=0)
                            + jnp.var(emu, axis=0)) * norm_scale
        return cutoff, samples, pred_mu, pred_std, pred_iter


def stack_models(models) -> Tuple[dict, jnp.ndarray]:
    """Stack J same-architecture RuntimeModels for the vmapped decision.

    Returns (stacked params pytree with a leading (J,) job axis,
    norm_scales (J,) f32).  All models must share (n_workers, lag, z_dim,
    hidden) — the job axis batches DECISIONS, it does not pad shapes; the
    multi-tenant server buckets jobs by shape before stacking.
    """
    if not models:
        raise ValueError("stack_models needs at least one model")
    shape = (models[0].n_workers, models[0].lag, models[0].z_dim,
             models[0].hidden)
    for m in models[1:]:
        got = (m.n_workers, m.lag, m.z_dim, m.hidden)
        if got != shape:
            raise ValueError(f"cannot stack RuntimeModels of shapes "
                             f"{shape} and {got}")
    params = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[m.params for m in models])
    scales = jnp.asarray([m.norm_scale for m in models], jnp.float32)
    return params, scales


def _pad_width_params(params, n: int, n_pad: int):
    """Zero-pad the width-shaped parameter leaves from n to n_pad workers.

    The width appears in exactly four places (everything else is
    (z_dim, hidden)-shaped and width-free): the emission mean head's last
    layer (hidden, n) + bias, the emission std layer (n, n) + bias — padded
    on BOTH axes — and the guide RNNs' input projections (n, hidden),
    padded on the input axis.  The pads are structural, not inferred by
    matching dim == n, which would misfire whenever n equals ``hidden``.

    Zero pads leave the real columns' math unchanged (zero input rows add
    nothing to any matmul) and keep the padded columns finite
    (emission std = softplus(0) + 1e-3), so downstream masking is about
    CORRECTNESS of the argmax, never about NaN containment.
    """
    if n == n_pad:
        return params
    d = n_pad - n
    pad_last = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, d)])
    pad_first = lambda a: jnp.pad(a, [(0, d)] + [(0, 0)] * (a.ndim - 1))
    dmm = dict(params["dmm"])
    emit_mu = [dict(l) for l in dmm["emit_mu"]]
    emit_mu[-1] = {"w": pad_last(emit_mu[-1]["w"]),
                   "b": pad_last(emit_mu[-1]["b"])}
    dmm["emit_mu"] = emit_mu
    emit_std = [dict(l) for l in dmm["emit_std"]]
    emit_std[0] = {"w": pad_last(pad_first(emit_std[0]["w"])),
                   "b": pad_last(emit_std[0]["b"])}
    dmm["emit_std"] = emit_std
    guide = dict(params["guide"])
    for name in ("rnn_left", "rnn_right"):
        rnn = dict(guide[name])
        rnn["wx"] = pad_first(rnn["wx"])
        guide[name] = rnn
    return {"dmm": dmm, "guide": guide}


def stack_models_padded(models, n_pad: int) -> Tuple[dict, jnp.ndarray]:
    """Ragged twin of ``stack_models``: stack J RuntimeModels whose worker
    widths may differ, zero-padding every width-shaped leaf to ``n_pad``
    columns (``_pad_width_params``).  Architectures (lag, z_dim, hidden)
    must still match — only the worker axis pads.  Used with the traced
    ``width`` mode of ``RuntimeModel._decide_core``; for a bucket whose
    jobs all share ``n_pad`` this is element-for-element ``stack_models``.
    """
    if not models:
        raise ValueError("stack_models_padded needs at least one model")
    arch = (models[0].lag, models[0].z_dim, models[0].hidden)
    for m in models[1:]:
        got = (m.lag, m.z_dim, m.hidden)
        if got != arch:
            raise ValueError(f"cannot stack RuntimeModels of architectures "
                             f"{arch} and {got}")
    for m in models:
        if m.n_workers > n_pad:
            raise ValueError(f"model width {m.n_workers} exceeds the bucket "
                             f"pad width {n_pad}")
    padded = [_pad_width_params(m.params, m.n_workers, n_pad)
              for m in models]
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)
    scales = jnp.asarray([m.norm_scale for m in models], jnp.float32)
    return params, scales

