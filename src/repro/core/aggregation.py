"""Masked gradient aggregation — the cutoff update on an SPMD mesh.

The paper's production variant (§4.3): the parameter server broadcasts the
participant list as a bit array; dropped workers zero their gradients; the
ring all-reduce runs over the full array; the update divides by c.

Two equivalent implementations:

1. ``example_weights`` — production path: per-example weights w (1 for
   examples on included DP shards, 0 otherwise) folded into the loss,
   ``loss = sum(w*ce)/sum(w)``.  The gradient all-reduce GSPMD already emits
   then implements Alg. 1 line 29 exactly, with zero extra collectives.
2. ``masked_psum_mean`` — explicit shard_map bit-array + psum over
   per-worker gradients, used by tests to prove (1) is equivalent and as
   the reference semantics.  ``psum_mean`` is the full-sync baseline with
   the identical reduction order (so all-ones-mask comparisons can demand
   bitwise equality).  ``masked_mean_local`` is the in-process (no mesh)
   form of the same combine; ``kernels.ops.masked_aggregate_tree`` fuses
   it into one HBM pass on TPU.

The layout-aware entry points live in ``repro.dist.collectives``; this
module stays mesh-explicit so it can be tested against hand-built meshes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist import compat  # noqa: F401  (installs jax.shard_map on 0.4.x)


def example_weights(mask: np.ndarray, global_batch: int) -> np.ndarray:
    """Expand a per-worker bit array to per-example weights.

    mask: (n_workers,) 0/1 — worker j owns the j-th contiguous slice of the
    global batch (matching the DP sharding of the batch dimension).
    """
    mask = np.asarray(mask, np.float32)
    n = mask.shape[0]
    assert global_batch % n == 0, (global_batch, n)
    return np.repeat(mask, global_batch // n)


def _bc(bit, leaf):
    return bit.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


def masked_mean_local(grads, mask_bit):
    """In-process reference combine: sum_w bit_w g_w / max(sum bit, 1).

    The no-mesh counterpart of ``masked_psum_mean`` — same math, same
    clamp, over the leading worker dim of each leaf.  This is the oracle
    the Pallas host-combine kernel (``kernels.masked_grad_agg``) is
    checked against, and the LOCAL path of
    ``dist.collectives.masked_grad_mean``.
    """
    bit = jnp.asarray(mask_bit)
    c = jnp.maximum(jnp.sum(bit.astype(jnp.float32)), 1.0)
    return jax.tree.map(
        lambda l: jnp.sum(l * _bc(bit, l), axis=0) / c.astype(l.dtype),
        grads)


def _worker_reduce(grads, mask_bit, mesh, dp_axes, *, apply_mask: bool):
    """Shared shard_map body: psum over ``dp_axes`` of per-worker grads.

    grads: pytree whose leaves carry a leading worker dim (n_workers, ...) —
    worker w's own gradient in slice w, n_workers == prod(dp axis sizes).
    mask_bit: (n_workers,) float.  The worker dim is sharded over the dp
    axes, summed locally, psum'd globally, and dropped from the result
    (replicated everywhere), divided by c = psum(bit) (or n for the plain
    mean, via an all-ones bit with identical op order).
    """
    axes = tuple(dp_axes)

    def body(bit, *leaves):
        c = jax.lax.psum(jnp.sum(bit), axes)
        outs = []
        for l in leaves:
            if apply_mask:
                w = bit.reshape((-1,) + (1,) * (l.ndim - 1)).astype(l.dtype)
                part = jnp.sum(l * w, axis=0)
            else:
                part = jnp.sum(l, axis=0)
            outs.append(jax.lax.psum(part, axes)
                        / jnp.maximum(c, 1.0).astype(l.dtype))
        return tuple(outs)

    flat, tree = jax.tree.flatten(grads)
    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(axes),) + tuple(
            P(axes, *([None] * (l.ndim - 1))) for l in flat),
        out_specs=tuple(P(*([None] * (l.ndim - 1))) for l in flat),
    )(jnp.asarray(mask_bit, jnp.float32), *flat)
    return jax.tree.unflatten(tree, list(out))


def masked_psum_mean(grads, mask_bit, mesh, dp_axes):
    """Reference bit-array aggregation: g = psum(bit * g_w) / psum(bit).

    See ``_worker_reduce`` for the contract; a masked-out worker's gradient
    is multiplied by 0.0 before the psum, so it has exactly zero influence.
    """
    return _worker_reduce(grads, mask_bit, mesh, dp_axes, apply_mask=True)


def psum_mean(grads, mesh, dp_axes):
    """Full-sync mean over the worker dim: g = psum(sum_w g_w) / n, with
    the same reduction order as ``masked_psum_mean``."""
    n = jax.tree.leaves(grads)[0].shape[0]
    ones = jnp.ones((n,), jnp.float32)
    return _worker_reduce(grads, ones, mesh, dp_axes, apply_mask=False)
