"""Masked gradient aggregation — the cutoff update on an SPMD mesh.

The paper's production variant (§4.3): the parameter server broadcasts the
participant list as a bit array; dropped workers zero their gradients; the
ring all-reduce runs over the full array; the update divides by c.

Two equivalent implementations:

1. ``example_weights`` — production path: per-example weights w (1 for
   examples on included DP shards, 0 otherwise) folded into the loss,
   ``loss = sum(w*ce)/sum(w)``.  The gradient all-reduce GSPMD already emits
   then implements Alg. 1 line 29 exactly, with zero extra collectives.
2. ``masked_psum_mean`` — explicit shard_map bit-array + psum, used by tests
   to prove (1) is equivalent and as the reference semantics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def example_weights(mask: np.ndarray, global_batch: int) -> np.ndarray:
    """Expand a per-worker bit array to per-example weights.

    mask: (n_workers,) 0/1 — worker j owns the j-th contiguous slice of the
    global batch (matching the DP sharding of the batch dimension).
    """
    mask = np.asarray(mask, np.float32)
    n = mask.shape[0]
    assert global_batch % n == 0, (global_batch, n)
    return np.repeat(mask, global_batch // n)


def masked_psum_mean(grads, mask_bit, mesh, dp_axes):
    """Reference bit-array aggregation: g = psum(bit * g_local) / psum(bit).

    grads: pytree of LOCAL per-shard gradients (already averaged within the
    shard); mask_bit: (dp_size,) float, one entry per DP shard.
    """
    axes = tuple(dp_axes)

    def body(bit, *leaves):
        c = jax.lax.psum(bit, axes)
        outs = [jax.lax.psum(l * bit, axes) / jnp.maximum(c, 1.0)
                for l in leaves]
        return tuple(outs)

    flat, tree = jax.tree.flatten(grads)
    out = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(axes),) + tuple(P(*([None] * l.ndim)) for l in flat),
        out_specs=tuple(P(*([None] * l.ndim)) for l in flat),
    )(mask_bit, *flat)
    return jax.tree.unflatten(tree, list(out))
