"""Cutoff controllers — the parameter-server decision logic (paper Alg. 1).

Each controller implements::

    c = ctl.predict_cutoff()            # before the step (line 23)
    ctl.observe(times, finished_mask)   # after the step (lines 25-26)

where ``times`` are per-worker runtimes for the finished workers (entries for
dropped workers are ignored) and ``finished_mask`` marks who reported.

Controllers:
  * CutoffController  — the paper's method: DMM + amortized inference,
    MC order statistics, censored imputation.  Two backends:
    ``backend="device"`` (default, production) keeps the lag window in a
    device-resident ring buffer; ``observe`` dispatches ONE fused jit
    (``_fused_observe_decide``: censored-imputation append + guide →
    transition → emission → sample → sort → argmax → predictive moments)
    that overlaps the workers' compute, and ``predict_cutoff`` only
    materializes the int32 — the single host/device sync per step.
    ``backend="numpy"`` is the float64 host reference the device path is
    checked against (tests/test_controller_device.py).
  * ElfvingController — the analytic iid-normal "order" baseline (Eq. 3).
  * StaticCutoffController — Chen et al. (2016) fixed cutoff.
  * FullSyncController — waits for everyone.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cutoff import censoring, elfving, order_stats
from repro.core.runtime_model.api import RuntimeModel


class FullSyncController:
    def __init__(self, n_workers: int):
        self.n = n_workers

    def predict_cutoff(self) -> int:
        return self.n

    def observe(self, times, finished_mask=None):
        pass


class StaticCutoffController(FullSyncController):
    """Chen et al. (2016): fixed c < n for the whole run."""

    def __init__(self, n_workers: int, cutoff: Optional[int] = None,
                 drop_frac: float = 0.06):
        super().__init__(n_workers)
        self.c = cutoff if cutoff is not None else max(
            1, int(round(n_workers * (1 - drop_frac))))

    def predict_cutoff(self) -> int:
        return self.c


class ElfvingController(FullSyncController):
    """Analytic normality baseline: running (mu, sigma) -> Eq. 3 cutoff."""

    def __init__(self, n_workers: int, warmup: int = 5,
                 min_frac: float = 0.5):
        super().__init__(n_workers)
        self.buf: list = []
        self.warmup = warmup
        self.min_frac = min_frac

    def predict_cutoff(self) -> int:
        if len(self.buf) < self.warmup:
            return self.n
        data = np.concatenate(self.buf[-50:])
        return elfving.elfving_cutoff(self.n, float(data.mean()),
                                      float(data.std()), self.min_frac)

    def observe(self, times, finished_mask=None):
        t = np.asarray(times, np.float64)
        if finished_mask is not None:
            t = t[np.asarray(finished_mask, bool)]
        self.buf.append(t)


# ---------------------------------------------------------------------------
# Device-resident ring-buffer primitives (jitted once, shared by every
# CutoffController instance — shapes key the jit cache).
# ---------------------------------------------------------------------------


def _append_core(ring, head, obs, mode: str):
    """Trace-level ring append; ``mode`` picks the imputation.

    "plain": censored entries take the observed cutoff time (warmup
    fallback, and the full-sync case).  "censored": fused truncated-normal
    imputation (paper §4.2) — the uniform draw, the inverse-CDF, the
    where-merge and the ring write all stay on device.
    """
    times, mask = obs["times"], obs["mask"]
    cutoff_time = jnp.max(jnp.where(mask, times, -jnp.inf))
    if mode == "censored":
        u = jax.random.uniform(obs["key"], times.shape)
        row = censoring.impute_censored_jax(times, mask, obs["mu"],
                                            obs["std"], cutoff_time, u)
    else:
        row = jnp.where(mask, times, cutoff_time)
    return ring.at[head].set(row), (head + 1) % ring.shape[0]


@functools.partial(jax.jit, static_argnames=("mode",))
def _ring_append(ring, head, obs, *, mode: str):
    return _append_core(ring, head, obs, mode)


@functools.partial(jax.jit, static_argnames=("mode", "k_samples", "lo"))
def _fused_observe_decide(params, ring, head, obs, key, norm_scale, *,
                          mode: str, k_samples: int, lo: int):
    """ONE jit call for a whole controller iteration on the hot path:
    flush the deferred observation (imputation included) into the ring,
    then run the full decision (guide → transition → emission → sample →
    sort → argmax → predictive moments) on the updated window.  The host
    uploads one (n,) row + mask and fetches one int32 per SGD step."""
    if mode != "none":
        ring, head = _append_core(ring, head, obs, mode)
    cutoff, samples, pred_mu, pred_std = RuntimeModel._decide_core(
        params, ring, head, key, norm_scale, k_samples, lo)
    return ring, head, cutoff, samples, pred_mu, pred_std


@functools.partial(jax.jit, static_argnames=("n",))
def _impute_uniforms(key, n: int):
    return jax.random.uniform(key, (n,))


def _impute_key(seed: int, step: int):
    """The per-step key both backends draw imputation uniforms from.

    Offset so it can never collide with the prediction keys
    (``PRNGKey(seed + step)``)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed + 1_000_003), step)


@dataclass
class CutoffController:
    """The paper's dynamic controller (DMM + amortized inference).

    Keeps the lag-l window of (imputed) runtime vectors; each iteration:
      1. predict K samples of the next joint runtime vector (Eq. 5),
      2. c* = argmax_c E[c / x_(c)]  (throughput-optimal cutoff),
      3. after the step, impute censored runtimes from the predictive
         distribution left-truncated at the observed cutoff time (§4.2).

    ``backend="device"`` (default): the window lives in a (lag+1, n) f32
    device ring buffer; ``observe`` uploads one (n,) row and dispatches
    the fused append+decide jit for the next step, and ``predict_cutoff``
    materializes a single int32.  ``backend="numpy"``: the float64 host
    reference.  Both
    consume the same jax-derived uniform stream for imputation, so their
    cutoff sequences are identical and their windows agree to f32 precision
    on seeded runs.
    """
    model: RuntimeModel
    k_samples: int = 64
    min_frac: float = 0.5
    seed: int = 0
    backend: str = "device"

    _window: list = field(default_factory=list)       # numpy backend
    _ring: Optional[jax.Array] = None                 # device backend
    _head: Optional[jax.Array] = None
    _count: int = 0
    _pending_pred: Optional[tuple] = None
    _pending_decision: Optional[tuple] = None         # (step, c, s, mu, std)
    _step: int = 0

    def __post_init__(self):
        if self.backend not in ("device", "numpy"):
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def n(self) -> int:
        return self.model.n_workers

    @property
    def _cap(self) -> int:
        return self.model.lag + 1

    @property
    def warmed_up(self) -> bool:
        if self.backend == "numpy":
            return len(self._window) >= self._cap
        return self._count >= self._cap

    # -- window plumbing ------------------------------------------------
    def _ensure_ring(self):
        if self._ring is None:
            self._ring = jnp.zeros((self._cap, self.n), jnp.float32)
            self._head = jnp.zeros((), jnp.int32)

    def window_array(self) -> np.ndarray:
        """The current lag window, oldest row first, as a numpy array."""
        if self.backend == "numpy":
            return np.stack(self._window[-self._cap:])
        self._ensure_ring()
        w = np.asarray(jnp.roll(self._ring, -self._head, axis=0))
        return w[-self._count:] if self._count < self._cap else w

    def seed_window(self, traces: np.ndarray):
        """Warm-start the lag window from recorded traces."""
        rows = np.asarray(traces)[-self._cap:]
        if self.backend == "numpy":
            for row in rows:
                self._window.append(np.asarray(row, np.float64))
            return
        self._ensure_ring()
        self._pending_decision = None
        full = jnp.ones((self.n,), bool)
        for row in rows:
            obs = {"times": jnp.asarray(row, jnp.float32), "mask": full}
            self._ring, self._head = _ring_append(self._ring, self._head,
                                                  obs, mode="plain")
            self._count = min(self._count + 1, self._cap)

    def _dispatch_decision(self, obs, mode: str, step: int):
        """Issue the fused observe+decide for ``step`` (async dispatch —
        nothing blocks until the cutoff scalar is read)."""
        lo = order_stats.min_frac_floor(self.n, self.min_frac)
        (self._ring, self._head, cutoff, samples, pred_mu,
         pred_std) = _fused_observe_decide(
            self.model.params, self._ring, self._head, obs,
            jax.random.PRNGKey(self.seed + step),
            jnp.float32(self.model.norm_scale), mode=mode,
            k_samples=self.k_samples, lo=lo)
        self._pending_decision = (step, cutoff, samples, pred_mu, pred_std)

    # -- decision -------------------------------------------------------
    def predict_cutoff(self) -> int:
        self._step += 1
        if not self.warmed_up:
            self._pending_pred = None
            return self.n
        if self.backend == "numpy":
            w = np.stack(self._window[-self._cap:])
            samples, mu, std = self.model.predict_next(
                w, self.k_samples, seed=self.seed + self._step)
            # per-worker predictive moments (for censoring) from MC samples
            self._pending_pred = (
                mu.mean(axis=0),
                np.sqrt(std.mean(axis=0) ** 2 + mu.var(axis=0)),
                samples)
            return order_stats.optimal_cutoff(samples, self.min_frac)
        if (self._pending_decision is None
                or self._pending_decision[0] != self._step):
            # no decision in flight for this step (first decision after
            # warmup/seeding, or out-of-cadence call): dispatch one now
            self._dispatch_decision(None, "none", self._step)
        _, cutoff, samples, pred_mu, pred_std = self._pending_decision
        self._pending_decision = None
        self._pending_pred = (pred_mu, pred_std, samples)
        # the ONLY host/device sync on the decision path: one int32
        return int(cutoff)

    def predicted_order_stats(self):
        """(mean, std) of predicted order statistics for the next step.

        Reuses the samples already drawn by the preceding
        ``predict_cutoff`` (cached on ``_pending_pred``) so diagnostics
        never double the inference cost.  ``observe`` invalidates the
        sample cache (the window changed), so a call after it falls back
        to a fresh prediction over the updated window — the pre-cache
        behavior.
        """
        if not self.warmed_up:
            return None
        if self._pending_pred is not None and self._pending_pred[2] is not None:
            samples = np.asarray(self._pending_pred[2])
        else:
            w = self.window_array()
            samples, _, _ = self.model.predict_next(
                w, self.k_samples, seed=self.seed + self._step)
        return order_stats.mc_order_stats(samples)

    # -- observation ----------------------------------------------------
    def observe(self, times, finished_mask=None):
        if self.backend == "numpy":
            return self._observe_numpy(times, finished_mask)
        self._ensure_ring()
        t = jnp.asarray(np.asarray(times, np.float32))
        mask = (jnp.ones(t.shape, bool) if finished_mask is None
                else jnp.asarray(np.asarray(finished_mask, bool)))
        all_finished = finished_mask is None or bool(np.all(finished_mask))
        if self._pending_pred is None or all_finished:
            # full sync, or warmup before any prediction exists
            obs, mode = {"times": t, "mask": mask}, "plain"
        else:
            pred_mu, pred_std, _ = self._pending_pred
            obs = {"times": t, "mask": mask, "mu": pred_mu, "std": pred_std,
                   "key": _impute_key(self.seed, self._step)}
            mode = "censored"
        if self._pending_pred is not None:
            # the moments stay valid for a repeated observe; the sample
            # cache does not survive a window change
            self._pending_pred = self._pending_pred[:2] + (None,)
        self._count = min(self._count + 1, self._cap)
        if self.warmed_up:
            # pipeline: fuse this append (imputation included) with the
            # NEXT step's decision and dispatch it now — the PS inference
            # runs while the workers compute, so the next predict_cutoff
            # only fetches a scalar (paper §1: the controller must decide
            # faster than the workers step)
            self._dispatch_decision(obs, mode, self._step + 1)
        else:
            self._ring, self._head = _ring_append(self._ring, self._head,
                                                  obs, mode=mode)

    def _observe_numpy(self, times, finished_mask=None):
        t = np.asarray(times, np.float64)
        if self._pending_pred is not None:
            # moments stay valid for a repeated observe; the sample cache
            # does not survive a window change
            self._pending_pred = self._pending_pred[:2] + (None,)
        if finished_mask is None or bool(np.all(finished_mask)):
            self._window.append(t)
            return
        mask = np.asarray(finished_mask, bool)
        cutoff_time = float(t[mask].max())
        if self._pending_pred is None:
            # warmup fallback: impute with the max observed time
            imputed = np.where(mask, t, cutoff_time)
        else:
            mu, std = self._pending_pred[0], self._pending_pred[1]
            u = np.asarray(_impute_uniforms(
                _impute_key(self.seed, self._step), t.shape[0]), np.float64)
            imputed = censoring.impute_censored(t, mask, mu, std,
                                                cutoff_time, u=u)
        self._window.append(imputed)
