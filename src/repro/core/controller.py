"""Cutoff controllers — the parameter-server decision logic (paper Alg. 1).

Each controller implements::

    c = ctl.predict_cutoff()            # before the step (line 23)
    ctl.observe(times, finished_mask)   # after the step (lines 25-26)

where ``times`` are per-worker runtimes for the finished workers (entries for
dropped workers are ignored) and ``finished_mask`` marks who reported.

Controllers:
  * CutoffController  — the paper's method: DMM + amortized inference,
    MC order statistics, censored imputation.  Two backends:
    ``backend="device"`` (default, production) keeps the lag window in a
    device-resident ring buffer; ``observe`` dispatches ONE fused jit
    (``_fused_observe_decide``: censored-imputation append + guide →
    transition → emission → sample → sort → argmax → predictive moments)
    that overlaps the workers' compute, and ``predict_cutoff`` only
    materializes the int32 — the single host/device sync per step.
    ``backend="numpy"`` is the float64 host reference the device path is
    checked against (tests/test_controller_device.py).
  * ElfvingController — the analytic iid-normal "order" baseline (Eq. 3).
  * StaticCutoffController — Chen et al. (2016) fixed cutoff.
  * FullSyncController — waits for everyone.
  * ElasticController — membership-elastic wrapper: DMM decisions while
    the cluster shape matches the fitted model; across a ``resize`` it
    remaps the window (``remap_columns``), falls back to Elfving, and
    refits the DMM on the surviving window (src/repro/core/README.md
    has the full elastic contract).

Every controller implements ``resize(n_workers, col_map=None, model=None,
members=None)`` for elastic worker membership; observation width is
strict after it.  ``members`` carries the GLOBAL worker ids of the new
set — width-only controllers ignore it, the multi-tenant ``ps.JobHandle``
records it in the job registry (its checkpoint groups restore by global
id).
"""
from __future__ import annotations

import functools
import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cutoff import censoring, elfving, order_stats
from repro.core.runtime_model.api import (RuntimeModel, colwise_uniform)


class FullSyncController:
    def __init__(self, n_workers: int):
        self.n = n_workers

    def predict_cutoff(self) -> int:
        return self.n

    def observe(self, times, finished_mask=None):
        pass

    def resize(self, n_workers: int, col_map=None, model=None,
               members=None):
        """Elastic membership change: track the new worker count
        (width-only controllers ignore the global ``members`` ids)."""
        self.n = int(n_workers)


class StaticCutoffController(FullSyncController):
    """Chen et al. (2016): fixed c < n for the whole run."""

    def __init__(self, n_workers: int, cutoff: Optional[int] = None,
                 drop_frac: float = 0.06):
        super().__init__(n_workers)
        self.drop_frac = drop_frac
        self._cutoff = cutoff        # the configured cutoff, never clamped
        self.c = cutoff if cutoff is not None else max(
            1, int(round(n_workers * (1 - drop_frac))))

    def predict_cutoff(self) -> int:
        return self.c

    def resize(self, n_workers: int, col_map=None, model=None,
               members=None):
        super().resize(n_workers, col_map, model, members)
        if self._cutoff is not None:
            # clamp to the live width but keep the configured value, so a
            # transient shrink doesn't permanently lower the baseline
            self.c = min(self._cutoff, self.n)
        else:
            self.c = max(1, int(round(self.n * (1 - self.drop_frac))))


class FirstKController(FullSyncController):
    """Chen et al. (2016) backup-workers baseline: accept the first
    ``n - b`` gradient arrivals BY COUNT, where ``b`` backup workers are
    provisioned to absorb stragglers.

    The distinction from :class:`StaticCutoffController` is the
    parameterization: the backup COUNT is fixed capacity (Chen et al.
    provision b extra machines), so a resize keeps ``b`` constant and the
    cutoff moves with the live width — shrink a 32-worker job to 24 and a
    4-backup config still accepts the first 20, not ``24 * (1 - 4/32)``.
    Count-based acceptance never consults the runtime distribution, which
    is exactly the error–runtime trade-off the paper's DMM controller
    beats (tests/test_controllers.py races it on wall-clock-to-loss).
    """

    def __init__(self, n_workers: int, backup: Optional[int] = None,
                 backup_frac: float = 0.04):
        super().__init__(n_workers)
        self.backup = (int(backup) if backup is not None
                       else max(1, int(round(n_workers * backup_frac))))

    def predict_cutoff(self) -> int:
        return max(1, self.n - self.backup)

    # resize: FullSyncController already tracks the live width; the backup
    # count deliberately stays fixed (it is provisioned capacity).


class ElfvingController(FullSyncController):
    """Analytic normality baseline: running (mu, sigma) -> Eq. 3 cutoff."""

    def __init__(self, n_workers: int, warmup: int = 5,
                 min_frac: float = 0.5):
        super().__init__(n_workers)
        self.buf: list = []
        self.warmup = warmup
        self.min_frac = min_frac

    def predict_cutoff(self) -> int:
        if len(self.buf) < self.warmup:
            return self.n
        data = np.concatenate(self.buf[-50:])
        return elfving.elfving_cutoff(self.n, float(data.mean()),
                                      float(data.std()), self.min_frac)

    def observe(self, times, finished_mask=None):
        t = np.asarray(times, np.float64)
        if finished_mask is not None:
            m = np.asarray(finished_mask, bool)
            if not m.any():
                raise ValueError(
                    "observe got an all-False finished_mask: a step with "
                    "zero finished workers has no observed cutoff time to "
                    "impute the censored entries at")
            if not m.all():
                # keeping only finished workers' times would give the
                # running (mu, sigma) survivorship bias once cutoffs
                # engage (the sample never contains a slow tail), drifting
                # the Eq. 3 cutoff optimistic.  Impute censored entries at
                # the observed cutoff time — a lower bound on their true
                # runtime, and the analytic analogue of §4.2's truncation.
                t = np.where(m, t, t[m].max())
        self.buf.append(t)


# ---------------------------------------------------------------------------
# Straggler-policy frontier: what a dropped worker contributes.
#
# The paper's controllers above all share ONE straggler policy — discard:
# a worker outside the cutoff contributes nothing and its mask bit is 0.
# The related work shows discard is one point on an error–runtime
# frontier; the two wrappers below implement the other two points the
# frontier bench races (benchmarks/frontier_bench.py), reusing any of the
# controllers above for the CUTOFF decision and changing only what the
# dropped workers contribute.  src/repro/core/README.md has the policy
# contract table.
# ---------------------------------------------------------------------------


class _PolicyWrapper:
    """Delegating base for straggler-policy wrappers: the inner controller
    owns the cutoff decision, the observe window, and the elastic resize
    protocol; the wrapper changes only the contribution semantics."""

    def __init__(self, inner):
        self.inner = inner

    @property
    def n(self) -> int:
        return self.inner.n

    def predict_cutoff(self) -> int:
        return self.inner.predict_cutoff()

    def observe(self, times, finished_mask=None):
        return self.inner.observe(times, finished_mask)

    def resize(self, n_workers: int, col_map=None, model=None,
               members=None):
        return self.inner.resize(n_workers, col_map=col_map, model=model,
                                 members=members)

    def predicted_order_stats(self):
        fn = getattr(self.inner, "predicted_order_stats", None)
        return fn() if fn is not None else None

    def predicted_samples(self):
        fn = getattr(self.inner, "predicted_samples", None)
        return fn() if fn is not None else None

    def window_array(self) -> np.ndarray:
        fn = getattr(self.inner, "window_array", None)
        if fn is None:
            # same contract as an empty CutoffController window: the
            # checkpoint path skips controllers with nothing to persist
            raise ValueError("inner controller keeps no window")
        return fn()

    def seed_window(self, traces: np.ndarray):
        fn = getattr(self.inner, "seed_window", None)
        if fn is not None:
            return fn(traces)


class AnytimeController(_PolicyWrapper):
    """Anytime SGD (Ferdinand & Draper): stragglers contribute PARTIAL
    gradient sums at the cutoff instead of being discarded.

    The inner controller still picks the cutoff c; the cutoff time is the
    c-th fastest worker's runtime as before.  But where the discard policy
    hands the aggregation a 0/1 bit array, :meth:`contribution` returns a
    per-worker f32 vector: a worker that completed ``k`` of its
    ``n_micro`` grad-accum microbatches by the cutoff time contributes its
    partial sum with weight ``k / n_micro``
    (``cluster.simulator.microbatch_progress``).  Finishers contribute
    exactly 1.0 (tie-consistent with the bit array), so with
    ``n_micro=1`` — or a cluster whose stragglers never complete a single
    microbatch by the cutoff — the vector reduces to the discard bit
    array bit-for-bit.

    The runtime model's view is unchanged: a straggler's full-step
    runtime is still censored at the cutoff time (it shipped a partial
    sum, not a completion time), so ``observe`` keeps the discard
    policy's finished mask.
    """

    def __init__(self, inner, n_micro: int = 1):
        super().__init__(inner)
        if n_micro < 1:
            raise ValueError(f"n_micro must be >= 1, got {n_micro}")
        self.n_micro = int(n_micro)

    def contribution(self, times, c: int) -> np.ndarray:
        """Per-worker f32 contribution vector for a step decided at
        cutoff ``c``: 1.0 for the c finishers, the completed-microbatch
        fraction at the cutoff time for everyone else."""
        from repro.cluster.simulator import microbatch_progress
        times = np.asarray(times, np.float64)
        order = np.argsort(times, kind="stable")
        cutoff_time = float(times[order[c - 1]])
        contrib = microbatch_progress(times, cutoff_time,
                                      self.n_micro).astype(np.float32)
        contrib[order[:c]] = 1.0       # finishers, exactly (tie-consistent)
        return contrib


class StaleReuseController(_PolicyWrapper):
    """Stale-gradient reuse (Dutta et al.): a dropped worker's LATE
    gradient is not thrown away — the Trainer buffers it and folds it
    into the NEXT step with a staleness-decayed weight.

    The wrapper itself only carries the policy knob: ``stale_decay`` is
    the weight a one-step-stale gradient enters the next step's masked
    mean with (relative to a fresh gradient's 1.0).  The Trainer detects
    the attribute, routes the step's dropped-gradient mean back into the
    next step's batch, and the ``stale_reuse=True`` train step does the
    fold in-jit (``launch.train.make_train_step``) — mask_agg="psum"
    only, since the fold needs per-worker gradients.  ``stale_decay=0``
    is exactly the discard policy (the fold multiplies by 0.0 and the
    parameters match bit-for-bit — tests/test_frontier.py).
    """

    def __init__(self, inner, decay: float = 0.5):
        super().__init__(inner)
        if not 0.0 <= decay <= 1.0:
            raise ValueError(f"decay must be in [0, 1], got {decay}")
        self.stale_decay = float(decay)


# ---------------------------------------------------------------------------
# Elastic membership: window remapping across worker-set changes.
# ---------------------------------------------------------------------------


def remap_columns(rows: np.ndarray, n_new: int,
                  col_map: Optional[np.ndarray] = None) -> np.ndarray:
    """Remap (T, n_old) worker-indexed rows onto a resized worker set.

    ``col_map`` is (n_new,) of old column indices — survivors carry their
    runtime series over column-exactly — with ``-1`` marking NEW workers,
    whose column is seeded row-by-row from the cluster mean of the
    surviving columns (the moment-matched prior before the new worker has
    reported anything).  Default: identity prefix (old worker i -> new
    column i, extra columns new).
    """
    rows = np.asarray(rows)
    if rows.ndim != 2:
        raise ValueError(f"rows must be (T, n), got {rows.shape}")
    n_old = rows.shape[1]
    if col_map is None:
        col_map = np.concatenate([
            np.arange(min(n_old, n_new)),
            np.full(max(0, n_new - n_old), -1, int)])
    col_map = np.asarray(col_map, int)
    if col_map.shape != (n_new,):
        raise ValueError(f"col_map must be ({n_new},), got {col_map.shape}")
    if np.any(col_map >= n_old):
        raise ValueError(f"col_map references old columns >= {n_old}")
    surv = col_map[col_map >= 0]
    fill = (rows[:, surv].mean(axis=1) if surv.size
            else rows.mean(axis=1))
    out = np.where((col_map >= 0)[None, :],
                   rows[:, np.clip(col_map, 0, n_old - 1)],
                   fill[:, None])
    return out.astype(rows.dtype)


# ---------------------------------------------------------------------------
# Device-resident ring-buffer primitives (jitted once, shared by every
# CutoffController instance — shapes key the jit cache).
# ---------------------------------------------------------------------------


def _append_core(ring, head, obs, mode: str):
    """Trace-level ring append; ``mode`` picks the imputation.

    "plain": censored entries take the observed cutoff time (warmup
    fallback, and the full-sync case).  "censored": fused truncated-normal
    imputation (paper §4.2) — the uniform draw, the inverse-CDF, the
    where-merge and the ring write all stay on device.
    """
    times, mask = obs["times"], obs["mask"]
    cutoff_time = jnp.max(jnp.where(mask, times, -jnp.inf))
    if mode == "censored":
        u = colwise_uniform(obs["key"], times.shape[0])
        row = censoring.impute_censored_jax(times, mask, obs["mu"],
                                            obs["std"], cutoff_time, u)
    else:
        row = jnp.where(mask, times, cutoff_time)
    return ring.at[head].set(row), (head + 1) % ring.shape[0]


def _ragged_append_core(ring, head, obs):
    """Ragged twin of :func:`_append_core` with the imputation mode
    TRACED: ``obs["cen"]`` (a per-job bool scalar) selects the censored or
    plain row in-jit, so a mixed plain/censored job set still shares one
    vmapped dispatch.  Both rows are computed — cheap elementwise work —
    and padded columns (mask False, garbage moments) land finite values
    that the decision's column mask never reads."""
    times, mask = obs["times"], obs["mask"]
    cutoff_time = jnp.max(jnp.where(mask, times, -jnp.inf))
    u = colwise_uniform(obs["key"], times.shape[0])
    crow = censoring.impute_censored_jax(times, mask, obs["mu"],
                                         obs["std"], cutoff_time, u)
    prow = jnp.where(mask, times, cutoff_time)
    row = jnp.where(obs["cen"], crow, prow)
    return ring.at[head].set(row), (head + 1) % ring.shape[0]


@functools.partial(jax.jit, static_argnames=("mode",))
def _ring_append(ring, head, obs, *, mode: str):
    return _append_core(ring, head, obs, mode)


def _observe_decide_core(params, ring, head, obs, key, norm_scale,
                         mode: str, k_samples: int, lo: int):
    """Trace-level body of one whole controller iteration: flush the
    deferred observation (imputation included) into the ring, then run the
    full decision (guide → transition → emission → sample → sort → argmax
    → predictive moments) on the updated window.  Jitted directly for the
    single-job hot path (:func:`_fused_observe_decide`) and vmapped over a
    leading JOB axis for the multi-tenant batched path
    (:func:`_batched_observe_decide`)."""
    if mode != "none":
        ring, head = _append_core(ring, head, obs, mode)
    (cutoff, samples, pred_mu, pred_std,
     pred_iter) = RuntimeModel._decide_core(
        params, ring, head, key, norm_scale, k_samples, lo)
    return ring, head, cutoff, samples, pred_mu, pred_std, pred_iter


# reprolint: disable=static-argnum-width -- `lo` is static by design on the single-job path: it changes only on resize (rare), and keeping it static lets XLA fold the cutoff floor; the ragged multi-job path traces it
@functools.partial(jax.jit, static_argnames=("mode", "k_samples", "lo"))
def _fused_observe_decide(params, ring, head, obs, key, norm_scale, *,
                          mode: str, k_samples: int, lo: int):
    """ONE jit call for a whole controller iteration on the hot path: the
    host uploads one (n,) row + mask and fetches one int32 per SGD step."""
    return _observe_decide_core(params, ring, head, obs, key, norm_scale,
                                mode, k_samples, lo)


def _ragged_observe_decide_core(params, ring, head, obs, key, norm_scale,
                                width, lo, k_samples: int):
    """One whole RAGGED controller iteration: traced-mode append
    (:func:`_ragged_append_core`), then the traced-width decision
    (``RuntimeModel._decide_core(width=...)``)."""
    ring, head = _ragged_append_core(ring, head, obs)
    (cutoff, samples, pred_mu, pred_std,
     pred_iter) = RuntimeModel._decide_core(
        params, ring, head, key, norm_scale, k_samples, lo, width=width)
    return ring, head, cutoff, samples, pred_mu, pred_std, pred_iter


@functools.partial(jax.jit, static_argnames=("k_samples",))
def _batched_observe_decide_ragged(params, rings, heads, obs, keys,
                                   norm_scales, widths, los, *,
                                   k_samples: int):
    """ONE jit call for J whole controller iterations (the multi-tenant
    parameter server's tick), jobs of MIXED widths included: every
    operand carries a leading (J,) job axis — zero-padded stacked params
    (``stack_models_padded``), the (J, lag+1, n_pad) ring stack, per-job
    heads, packed observation rows/masks/moments, per-job PRNG keys,
    norm scales, TRACED widths and argmax floors, and per-job traced
    censor flags inside ``obs``.  The only static is ``k_samples``, so
    one compiled program serves every job mix of a bucket and dispatch
    cost is paid once per tick instead of once per job (or per width
    group).  Per-job cutoffs come back as one (J,) int32 vector."""
    def one(p, r, h, o, k, s, w, lo):
        return _ragged_observe_decide_core(p, r, h, o, k, s, w, lo,
                                           k_samples)

    return jax.vmap(one)(params, rings, heads, obs, keys, norm_scales,
                         widths, los)


@functools.partial(jax.jit, static_argnames=("k_samples",))
def _batched_decide_ragged(params, rings, heads, keys, norm_scales,
                           widths, los, *, k_samples: int):
    """Decide-only twin of :func:`_batched_observe_decide_ragged`: used
    to prefetch the first post-seeding decision for a batch of jobs in
    one dispatch."""
    def one(p, r, h, k, s, w, lo):
        return RuntimeModel._decide_core(p, r, h, k, s, k_samples, lo,
                                         width=w)

    return jax.vmap(one)(params, rings, heads, keys, norm_scales, widths,
                         los)


# reprolint: disable=static-argnum-width -- `n` sizes the OUTPUT of a host-side helper for the numpy reference backend; it is not on the device hot path and must match the reference draw count exactly
@functools.partial(jax.jit, static_argnames=("n",))
def _impute_uniforms(key, n: int):
    # column-wise so the numpy reference backend draws the SAME uniforms
    # the device append path does at any padded width (api.colwise_uniform)
    return colwise_uniform(key, n)


def _impute_key(seed: int, step: int):
    """The per-step key both backends draw imputation uniforms from.

    Offset so it can never collide with the prediction keys
    (``PRNGKey(seed + step)``)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed + 1_000_003), step)


def _prng_key_rows(seeds) -> np.ndarray:
    """(J, 2) uint32 HOST array, row j bit-identical to
    ``jax.random.PRNGKey(seeds[j])`` under the default threefry impl.

    The numpy core of :func:`stacked_prng_keys`, kept host-side so the
    server's flush can splice decide and impute keys into one packed
    upload without touching the device."""
    seeds = np.asarray(list(seeds), np.uint64)
    out = np.empty((seeds.shape[0], 2), np.uint32)
    # with x64 disabled (this repo's default) PRNGKey truncates the seed
    # to its low 32 bits and the high word is 0
    if jax.config.jax_enable_x64:
        out[:, 0] = (seeds >> np.uint64(32)).astype(np.uint32)
    else:
        out[:, 0] = 0
    out[:, 1] = (seeds & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return out


def stacked_prng_keys(seeds) -> jax.Array:
    """(J, 2) uint32 key stack, row j bit-identical to
    ``jax.random.PRNGKey(seeds[j])`` under the default threefry impl.

    Built host-side in one shot so a J-job tick costs ONE upload instead
    of J ``PRNGKey`` dispatches (the dispatch overhead the batched
    decision exists to amortize).  ``tests/test_ps_server.py`` pins the
    bit-level equivalence."""
    return jnp.asarray(_prng_key_rows(seeds))


@jax.jit
def _batched_impute_keys(base_keys, steps):
    """vmap of ``fold_in`` — row j equals ``_impute_key(seed_j, step_j)``
    when ``base_keys[j] == PRNGKey(seed_j + 1_000_003)``."""
    return jax.vmap(jax.random.fold_in)(base_keys, steps)


@dataclass
class CutoffController:
    """The paper's dynamic controller (DMM + amortized inference).

    Keeps the lag-l window of (imputed) runtime vectors; each iteration:
      1. predict K samples of the next joint runtime vector (Eq. 5),
      2. c* = argmax_c E[c / x_(c)]  (throughput-optimal cutoff),
      3. after the step, impute censored runtimes from the predictive
         distribution left-truncated at the observed cutoff time (§4.2).

    ``backend="device"`` (default): the window lives in a (lag+1, n) f32
    device ring buffer; ``observe`` uploads one (n,) row and dispatches
    the fused append+decide jit for the next step, and ``predict_cutoff``
    materializes a single int32.  ``backend="numpy"``: the float64 host
    reference.  Both
    consume the same jax-derived uniform stream for imputation, so their
    cutoff sequences are identical and their windows agree to f32 precision
    on seeded runs.
    """
    model: RuntimeModel
    k_samples: int = 64
    min_frac: float = 0.5
    seed: int = 0
    backend: str = "device"

    _window: list = field(default_factory=list)       # numpy backend
    _ring: Optional[jax.Array] = None                 # device backend
    _head: Optional[jax.Array] = None
    _count: int = 0
    _pending_pred: Optional[tuple] = None
    _pending_decision: Optional[tuple] = None   # (step, c, s, mu, std, it)
    _last_iter: Optional[object] = None         # E[x_(c)] of last decision
    _step: int = 0

    def __post_init__(self):
        if self.backend not in ("device", "numpy"):
            raise ValueError(f"unknown backend {self.backend!r}")

    @property
    def n(self) -> int:
        return self.model.n_workers

    @property
    def _cap(self) -> int:
        return self.model.lag + 1

    @property
    def warmed_up(self) -> bool:
        if self.backend == "numpy":
            return len(self._window) >= self._cap
        return self._count >= self._cap

    # -- window plumbing ------------------------------------------------
    def _ensure_ring(self):
        if self._ring is None:
            self._ring = jnp.zeros((self._cap, self.n), jnp.float32)
            self._head = jnp.zeros((), jnp.int32)

    def window_array(self) -> np.ndarray:
        """The current lag window, oldest row first, as a numpy array.

        Raises ValueError while the window is empty (both backends — the
        checkpoint path relies on this to skip cold controllers rather
        than persist an all-zeros ring).
        """
        if self.backend == "numpy":
            if not self._window:
                raise ValueError("window is empty")
            return np.stack(self._window[-self._cap:])
        self._ensure_ring()
        if self._count == 0:
            raise ValueError("window is empty")
        w = np.asarray(jnp.roll(self._ring, -self._head, axis=0))
        return w[-self._count:] if self._count < self._cap else w

    def seed_window(self, traces: np.ndarray):
        """Warm-start the lag window from recorded traces.

        Device backend: built host-side and uploaded in ONE transfer —
        bit-identical to ``_ring_append`` with ``mode="plain"`` and a
        full mask (which writes the f32 rows verbatim), without paying
        up to lag+1 tiny dispatches per seeded controller (the cost that
        dominates large-J benchmark setup)."""
        rows = np.asarray(traces)[-self._cap:]
        if self.backend == "numpy":
            for row in rows:
                self._window.append(np.asarray(row, np.float64))
            return
        self._ensure_ring()
        self._pending_decision = None
        merged = np.asarray(rows, np.float32)
        if self._count:
            merged = np.concatenate(
                [np.asarray(self.window_array(), np.float32), merged])
        merged = merged[-self._cap:]
        m = merged.shape[0]
        ring = np.zeros((self._cap, self.n), np.float32)
        ring[:m] = merged
        self._ring = jnp.asarray(ring)
        self._head = jnp.asarray(m % self._cap, jnp.int32)
        self._count = min(self._count + rows.shape[0], self._cap)

    def resize(self, n_workers: int, col_map=None,
               model: Optional[RuntimeModel] = None, members=None):
        """Remap the lag window across a worker-set change.

        Survivor columns (``col_map`` entries >= 0) move column-exactly
        into the resized ring; NEW workers' columns are seeded from the
        per-row cluster mean of the survivors (:func:`remap_columns`).
        ``model`` must be a :class:`RuntimeModel` of the NEW width — the
        DMM's emission layer is shaped by n_workers, so a resize without a
        refit model cannot decide.  Callers that need a degraded mode
        while the refit runs should drive the resize through
        :class:`ElasticController` instead.
        """
        n_new = int(n_workers)
        model = model if model is not None else self.model
        if model.n_workers != n_new:
            raise ValueError(
                f"resize({n_new}) needs a RuntimeModel of that width, got "
                f"n_workers={model.n_workers}; refit first or drive the "
                f"resize through ElasticController")
        have_rows = (len(self._window) > 0 if self.backend == "numpy"
                     else self._count > 0)
        rows = self.window_array() if have_rows else None
        self.model = model
        self._pending_decision = None
        self._pending_pred = None
        self._last_iter = None
        if self.backend == "numpy":
            self._window = []
            if rows is not None:
                remapped = remap_columns(np.asarray(rows, np.float64), n_new,
                                         col_map)
                self._window = [row for row in remapped]
            return
        self._ring = None
        self._head = None
        self._count = 0
        self._ensure_ring()
        if rows is not None:
            self.seed_window(remap_columns(rows, n_new, col_map))

    def _dispatch_decision(self, obs, mode: str, step: int):
        """Issue the fused observe+decide for ``step`` (async dispatch —
        nothing blocks until the cutoff scalar is read)."""
        lo = order_stats.min_frac_floor(self.n, self.min_frac)
        (self._ring, self._head, cutoff, samples, pred_mu, pred_std,
         pred_iter) = _fused_observe_decide(
            self.model.params, self._ring, self._head, obs,
            jax.random.PRNGKey(self.seed + step),
            jnp.float32(self.model.norm_scale), mode=mode,
            k_samples=self.k_samples, lo=lo)
        self._pending_decision = (step, cutoff, samples, pred_mu, pred_std,
                                  pred_iter)

    # -- decision -------------------------------------------------------
    def predict_cutoff(self) -> int:
        self._step += 1
        if not self.warmed_up:
            self._pending_pred = None
            return self.n
        if self.backend == "numpy":
            w = np.stack(self._window[-self._cap:])
            samples, mu, std = self.model.predict_next(
                w, self.k_samples, seed=self.seed + self._step)
            # per-worker predictive moments (for censoring) from MC samples:
            # the K draws form a Gaussian mixture, so the variance is
            # E[std^2] + Var[mu] (mixture-variance law) — NOT E[std]^2,
            # which under-disperses the censored imputation
            self._pending_pred = (
                mu.mean(axis=0),
                np.sqrt(np.mean(std ** 2, axis=0) + mu.var(axis=0)),
                samples)
            c = order_stats.optimal_cutoff(samples, self.min_frac)
            # lazy: the extra sort only runs if a scheduler actually asks
            self._last_iter = ("lazy", samples, c)
            return c
        if (self._pending_decision is None
                or self._pending_decision[0] != self._step):
            # no decision in flight for this step (first decision after
            # warmup/seeding, or out-of-cadence call): dispatch one now
            self._dispatch_decision(None, "none", self._step)
        (_, cutoff, samples, pred_mu, pred_std,
         pred_iter) = self._pending_decision
        self._pending_decision = None
        self._pending_pred = (pred_mu, pred_std, samples)
        self._last_iter = pred_iter          # device scalar, fetched lazily
        # the ONLY host/device sync on the decision path: one int32
        return int(cutoff)

    def predicted_samples(self):
        """The predictive sample cloud (K, n) behind the decision just
        made — a LAZY peek for the obs decision-quality layer: the device
        backend returns the device array unfetched (the obs drain
        materializes it in batch), the numpy backend its host samples.
        None before warmup and after ``observe`` consumed the cache."""
        if self._pending_pred is None:
            return None
        return self._pending_pred[2]

    def predicted_iter_time(self):
        """Posterior-predictive E[x_(c)] of the step just decided (raw
        seconds) — what the multi-tenant scheduler ranks jobs by; None
        before the first warmed-up decision.  The device backend gets it
        free out of the fused decision's shared sort; the numpy backend
        computes it here, on demand."""
        if self._last_iter is None:
            return None
        if isinstance(self._last_iter, tuple):
            _, samples, c = self._last_iter
            self._last_iter = float(
                np.sort(samples, axis=1)[:, c - 1].mean())
        return float(self._last_iter)

    def predicted_order_stats(self):
        """(mean, std) of predicted order statistics for the next step.

        Reuses the samples already drawn by the preceding
        ``predict_cutoff`` (cached on ``_pending_pred``) so diagnostics
        never double the inference cost.  ``observe`` invalidates the
        sample cache (the window changed), so a call after it falls back
        to a fresh prediction over the updated window — the pre-cache
        behavior.
        """
        if not self.warmed_up:
            return None
        if self._pending_pred is not None and self._pending_pred[2] is not None:
            samples = np.asarray(self._pending_pred[2])
        else:
            w = self.window_array()
            samples, _, _ = self.model.predict_next(
                w, self.k_samples, seed=self.seed + self._step)
        return order_stats.mc_order_stats(samples)

    # -- observation ----------------------------------------------------
    def observe(self, times, finished_mask=None):
        if finished_mask is not None and not bool(np.any(finished_mask)):
            # no coherent cutoff time exists: the device path would
            # silently impute at max(where(False, ..)) = -inf and poison
            # the ring — reject loudly on both backends instead
            raise ValueError(
                "observe got an all-False finished_mask: a step with zero "
                "finished workers has no observed cutoff time to impute "
                "the censored entries at")
        if self.backend == "numpy":
            return self._observe_numpy(times, finished_mask)
        self._ensure_ring()
        t = jnp.asarray(np.asarray(times, np.float32))
        mask = (jnp.ones(t.shape, bool) if finished_mask is None
                else jnp.asarray(np.asarray(finished_mask, bool)))
        all_finished = finished_mask is None or bool(np.all(finished_mask))
        if self._pending_pred is None or all_finished:
            # full sync, or warmup before any prediction exists
            obs, mode = {"times": t, "mask": mask}, "plain"
        else:
            pred_mu, pred_std, _ = self._pending_pred
            obs = {"times": t, "mask": mask, "mu": pred_mu, "std": pred_std,
                   "key": _impute_key(self.seed, self._step)}
            mode = "censored"
        if self._pending_pred is not None:
            # the moments stay valid for a repeated observe; the sample
            # cache does not survive a window change
            self._pending_pred = self._pending_pred[:2] + (None,)
        self._count = min(self._count + 1, self._cap)
        if self.warmed_up:
            # pipeline: fuse this append (imputation included) with the
            # NEXT step's decision and dispatch it now — the PS inference
            # runs while the workers compute, so the next predict_cutoff
            # only fetches a scalar (paper §1: the controller must decide
            # faster than the workers step)
            self._dispatch_decision(obs, mode, self._step + 1)
        else:
            self._ring, self._head = _ring_append(self._ring, self._head,
                                                  obs, mode=mode)

    def _observe_numpy(self, times, finished_mask=None):
        t = np.asarray(times, np.float64)
        if self._pending_pred is not None:
            # moments stay valid for a repeated observe; the sample cache
            # does not survive a window change
            self._pending_pred = self._pending_pred[:2] + (None,)
        # every read uses only the last lag+1 rows; drop the dead history
        # (the device backend's ring is O(lag+1) by construction)
        del self._window[:-self._cap]
        if finished_mask is None or bool(np.all(finished_mask)):
            self._window.append(t)
            return
        mask = np.asarray(finished_mask, bool)
        cutoff_time = float(t[mask].max())
        if self._pending_pred is None:
            # warmup fallback: impute with the max observed time
            imputed = np.where(mask, t, cutoff_time)
        else:
            mu, std = self._pending_pred[0], self._pending_pred[1]
            # reprolint: disable=host-sync-in-hot-path -- numpy REFERENCE backend: this whole method is the host-side equivalence twin, not the device dispatch path
            u = np.asarray(_impute_uniforms(
                _impute_key(self.seed, self._step), t.shape[0]), np.float64)
            imputed = censoring.impute_censored(t, mask, mu, std,
                                                cutoff_time, u=u)
        self._window.append(imputed)


# ---------------------------------------------------------------------------
# Elastic membership: DMM controller + analytic fallback + refit.
# ---------------------------------------------------------------------------


class RefitError(RuntimeError):
    """An async DMM refit raised, and the retry budget is spent.

    Raised from the POLL (``predict_cutoff`` / ``observe``), not lost on
    the worker thread: the owner keeps serving decisions through its
    fallback while one seeded retry is in flight, and only escalates
    when the retry fails too — a silently-dead refit would pin the
    controller on the fallback forever and nobody would know why.
    """


def _spawn_refit(fit_fn, gen: int) -> tuple:
    """Start a DMM refit on a daemon thread.

    Returns the ``(thread, result_box, generation)`` refit-task triple
    shared by :class:`ElasticController` and the multi-tenant
    ``ps.PSServer``: the thread fills ``result_box["model"]`` when the
    ELBO fit finishes — or ``result_box["error"]`` when it RAISES (the
    exception is captured, never swallowed; :func:`_poll_refit_task`
    hands it back to the owner's poll) — and the generation tag (the
    owner's resize count at spawn time) lets :func:`_poll_refit_task`
    discard results that a later resize made stale.  Dropping the triple
    abandons the fit without ever blocking a decision tick on
    ``model.fit``.
    """
    box: dict = {}

    def work():
        try:
            box["model"] = fit_fn()
        except BaseException as e:         # surfaced by the poll
            box["error"] = e

    thread = threading.Thread(target=work, daemon=True)
    task = (thread, box, gen)
    thread.start()
    return task


def _poll_refit_task(task: tuple, gen: int, width: int):
    """Non-blocking poll of a :func:`_spawn_refit` triple.

    Returns ``(done, model, error)``: ``(False, None, None)`` while the
    fit thread is still running; ``(True, model, None)`` once it
    finished AND the result is still current (generation matches and the
    fitted width is the owner's width); ``(True, None, exc)`` when the
    fit RAISED and the failure is still current (a stale failure is as
    dead as a stale result); ``(True, None, None)`` for a
    finished-but-stale fit, which is discarded, never installed.
    """
    thread, box, task_gen = task
    if thread.is_alive():
        return False, None, None
    thread.join()
    if task_gen != gen:
        return True, None, None
    error = box.get("error")
    if error is not None:
        return True, None, error
    model = box.get("model")
    if model is None or model.n_workers != width:
        return True, None, None
    return True, model, None


class ElasticController:
    """Membership-elastic cutoff controller (DMM + Elfving fallback + refit).

    Wraps the paper's :class:`CutoffController` for clusters whose worker
    set changes mid-run (rack loss, preemption, node return).  While the
    cluster shape matches the fitted :class:`RuntimeModel` it delegates
    every decision to the DMM controller.  Across a :meth:`resize` it:

      1. remaps its window/trace onto the new worker set — survivors
         column-exact, new workers seeded from the cluster-mean moments
         (:func:`remap_columns`);
      2. falls back to the analytic :class:`ElfvingController`
         (warm-seeded from the remapped window, so Eq. 3 decisions start
         immediately) — the degraded mode the elastic launch story
         narrates (``launch/elastic.py``);
      3. refits the DMM at the new width from the surviving window once
         ``refit_fresh`` post-resize observations have arrived
         (synchronously by default; ``refit_async=True`` runs the ELBO
         fit on a worker thread and swaps the DMM back in on completion),
         then resumes DMM decisions with the window it kept warm.

    The controller also keeps a rolling imputed trace (plain imputation at
    the observed cutoff time) as refit training data; ``window_array`` /
    ``seed_window`` expose its lag-window tail so checkpoints can persist
    and warm-restore straggler prediction across restarts and resizes.
    """

    def __init__(self, model: RuntimeModel, *, k_samples: int = 64,
                 min_frac: float = 0.5, seed: int = 0,
                 backend: str = "device", history: int = 512,
                 refit_steps: int = 150, refit_batch: int = 8,
                 refit_fresh: int = 4, refit_async: bool = False,
                 fallback_warmup: int = 3, refit_retries: int = 1):
        self.k_samples = k_samples
        self.min_frac = min_frac
        self.seed = seed
        self.backend = backend
        self.history = history
        self.refit_steps = refit_steps
        self.refit_batch = refit_batch
        self.refit_fresh = refit_fresh
        self.refit_async = refit_async
        self.fallback_warmup = fallback_warmup
        self.refit_retries = refit_retries
        self._refit_failures = 0          # consecutive failed async fits
        # architecture template for refits (widths change, shapes don't)
        self._lag = model.lag
        self._z_dim = model.z_dim
        self._hidden = model.hidden
        self._n = model.n_workers
        self._trace: list = []            # imputed full rows, rolling
        self._fresh = 0                   # post-resize observations
        self._resize_count = 0
        # async refit in flight: (thread, result_box, resize generation)
        self._refit_job: Optional[tuple] = None
        self.fallback_steps = 0           # observes served by the fallback
        self._dmm: Optional[CutoffController] = None
        self._fallback = ElfvingController(self._n,
                                           warmup=fallback_warmup,
                                           min_frac=min_frac)
        self._install_dmm(model)

    # -- bookkeeping ----------------------------------------------------
    @property
    def n(self) -> int:
        return self._n

    @property
    def mode(self) -> str:
        """"dmm" when the fitted controller decides, "fallback" while a
        resize awaits its refit."""
        return "dmm" if self._dmm is not None else "fallback"

    @property
    def warmed_up(self) -> bool:
        return len(self._trace) >= self._lag + 1

    def _install_dmm(self, model: RuntimeModel):
        assert model.n_workers == self._n, (model.n_workers, self._n)
        ctl = CutoffController(
            model, k_samples=self.k_samples, min_frac=self.min_frac,
            seed=self.seed + 101 * self._resize_count, backend=self.backend)
        rows = self._trace[-(self._lag + 1):]
        if rows:
            ctl.seed_window(np.stack(rows))
        self._dmm = ctl

    def _active(self):
        return self._dmm if self._dmm is not None else self._fallback

    # -- window persistence (checkpoint contract) -----------------------
    def window_array(self) -> np.ndarray:
        """The lag-window tail of the imputed trace, oldest row first."""
        return np.stack(self._trace[-(self._lag + 1):])

    def seed_window(self, traces: np.ndarray):
        """Warm-start from recorded rows at the CURRENT width."""
        rows = [np.asarray(r, np.float64) for r in np.asarray(traces)]
        if rows and rows[0].shape != (self._n,):
            raise ValueError(f"seed rows have width {rows[0].shape}, "
                             f"controller width is {self._n}")
        self._trace = (self._trace + rows)[-self.history:]
        for r in rows[-50:]:
            self._fallback.buf.append(r)
        if self._dmm is not None:
            self._dmm.seed_window(np.stack(self._trace[-(self._lag + 1):]))

    # -- decision / observation -----------------------------------------
    def predict_cutoff(self) -> int:
        self._poll_refit()
        return self._active().predict_cutoff()

    def predicted_order_stats(self):
        if self._dmm is not None:
            return self._dmm.predicted_order_stats()
        return None

    def predicted_samples(self):
        if self._dmm is not None:
            return self._dmm.predicted_samples()
        return None

    def observe(self, times, finished_mask=None):
        t = np.asarray(times, np.float64)
        if t.shape != (self._n,):
            raise ValueError(
                f"observe got {t.shape[0]} runtimes at width {self._n}; "
                f"call resize() before observing the resized step")
        row = t
        if finished_mask is not None:
            m = np.asarray(finished_mask, bool)
            if not m.any():
                raise ValueError(
                    "observe got an all-False finished_mask: a step with "
                    "zero finished workers has no observed cutoff time to "
                    "impute the trace row at")
            if not m.all():
                # plain imputation at the observed cutoff time is enough
                # for refit TRAINING data; the active DMM still runs the
                # truncated-normal imputation for its own window
                row = np.where(m, t, t[m].max())
        self._trace = (self._trace + [row])[-self.history:]
        if self._dmm is None:
            self.fallback_steps += 1
        self._active().observe(times, finished_mask)
        self._fresh += 1
        self._poll_refit()
        if self._dmm is None and self._refit_job is None:
            self._maybe_refit()

    # -- resize protocol -------------------------------------------------
    def resize(self, n_workers: int, col_map=None,
               model: Optional[RuntimeModel] = None, members=None):
        """Worker-set change: remap, fall back, schedule the refit.

        ``col_map`` as in :func:`remap_columns`.  If ``model`` (already
        fitted at the new width) is supplied, the DMM controller resumes
        immediately; otherwise decisions route through the Elfving
        fallback until the refit lands.
        """
        n_new = int(n_workers)
        if model is not None and model.n_workers != n_new:
            raise ValueError(
                f"resize({n_new}) got a RuntimeModel of width "
                f"{model.n_workers}; refit it for the new width first")
        if n_new == self._n and col_map is None and model is None:
            return
        # abandon any in-flight refit WITHOUT blocking on its ELBO fit:
        # the daemon thread keeps filling its orphaned result box, and
        # _poll_refit discards it by generation
        self._refit_job = None
        if self._trace:
            rows = remap_columns(np.stack(self._trace), n_new, col_map)
            self._trace = [row for row in rows]
        self._n = n_new
        self._resize_count += 1
        self._fresh = 0
        self._dmm = None
        self._fallback = ElfvingController(n_new,
                                           warmup=self.fallback_warmup,
                                           min_frac=self.min_frac)
        for r in self._trace[-50:]:
            self._fallback.buf.append(r)
        if model is not None:
            self._install_dmm(model)

    # -- refit plumbing --------------------------------------------------
    def _enough_rows(self) -> bool:
        # RuntimeModel.fit needs strictly more than lag+1 rows; demand a
        # small margin so the first refit windows aren't degenerate
        return len(self._trace) >= self._lag + 1 + self.refit_batch

    def _maybe_refit(self):
        # failed attempts back the respawn off exponentially: each one
        # demands twice the fresh observations before the next try
        need = self.refit_fresh * (2 ** self._refit_failures)
        if self._fresh < need or not self._enough_rows():
            return
        # freeze width/seed now: a resize mid-fit must not retarget the
        # running fit (its result is discarded by generation anyway)
        rows = np.stack(self._trace)
        n = self._n
        seed = self.seed + self._resize_count + 1000 * self._refit_failures
        if self.refit_async:
            self._refit_job = _spawn_refit(
                lambda: self._fit_model(rows, n, seed), self._resize_count)
        else:
            self._install_dmm(self._fit_model(rows, n, seed))

    def _poll_refit(self):
        if self._refit_job is None:
            return
        # a resize since the fit started makes the result stale (wrong
        # membership, possibly even the wrong width) — _poll_refit_task
        # drops it by generation/width
        done, model, err = _poll_refit_task(self._refit_job,
                                            self._resize_count, self._n)
        if not done:
            return
        self._refit_job = None
        if err is not None:
            self._refit_failures += 1
            if self._refit_failures > self.refit_retries:
                raise RefitError(
                    f"DMM refit failed {self._refit_failures} times at "
                    f"width {self._n} (retry budget {self.refit_retries} "
                    f"spent); last error: {err!r}") from err
            # log + retry: stay on the fallback, reschedule with backoff
            print(f"DMM refit failed ({err!r}); retrying after "
                  f"{self.refit_fresh * 2 ** self._refit_failures} fresh "
                  f"observations")
            self._fresh = 0
            return
        if model is not None:
            self._refit_failures = 0
            self._install_dmm(model)

    def _fit_model(self, rows: np.ndarray, n: int,
                   seed: int) -> RuntimeModel:
        model = RuntimeModel(n_workers=n, lag=self._lag,
                             z_dim=self._z_dim, hidden=self._hidden)
        model.fit(rows, steps=self.refit_steps, batch=self.refit_batch,
                  seed=seed)
        return model
