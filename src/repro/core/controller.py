"""Cutoff controllers — the parameter-server decision logic (paper Alg. 1).

Each controller implements::

    c = ctl.predict_cutoff()            # before the step (line 23)
    ctl.observe(times, finished_mask)   # after the step (lines 25-26)

where ``times`` are per-worker runtimes for the finished workers (entries for
dropped workers are ignored) and ``finished_mask`` marks who reported.

Controllers:
  * CutoffController  — the paper's method: DMM + amortized inference,
    MC order statistics, censored imputation.
  * ElfvingController — the analytic iid-normal "order" baseline (Eq. 3).
  * StaticCutoffController — Chen et al. (2016) fixed cutoff.
  * FullSyncController — waits for everyone.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cutoff import censoring, elfving, order_stats
from repro.core.runtime_model.api import RuntimeModel


class FullSyncController:
    def __init__(self, n_workers: int):
        self.n = n_workers

    def predict_cutoff(self) -> int:
        return self.n

    def observe(self, times, finished_mask=None):
        pass


class StaticCutoffController(FullSyncController):
    """Chen et al. (2016): fixed c < n for the whole run."""

    def __init__(self, n_workers: int, cutoff: Optional[int] = None,
                 drop_frac: float = 0.06):
        super().__init__(n_workers)
        self.c = cutoff if cutoff is not None else max(
            1, int(round(n_workers * (1 - drop_frac))))

    def predict_cutoff(self) -> int:
        return self.c


class ElfvingController(FullSyncController):
    """Analytic normality baseline: running (mu, sigma) -> Eq. 3 cutoff."""

    def __init__(self, n_workers: int, warmup: int = 5,
                 min_frac: float = 0.5):
        super().__init__(n_workers)
        self.buf: list = []
        self.warmup = warmup
        self.min_frac = min_frac

    def predict_cutoff(self) -> int:
        if len(self.buf) < self.warmup:
            return self.n
        data = np.concatenate(self.buf[-50:])
        return elfving.elfving_cutoff(self.n, float(data.mean()),
                                      float(data.std()), self.min_frac)

    def observe(self, times, finished_mask=None):
        t = np.asarray(times, np.float64)
        if finished_mask is not None:
            t = t[np.asarray(finished_mask, bool)]
        self.buf.append(t)


@dataclass
class CutoffController:
    """The paper's dynamic controller (DMM + amortized inference).

    Keeps the lag-l window of (imputed) runtime vectors; each iteration:
      1. predict K samples of the next joint runtime vector (Eq. 5),
      2. c* = argmax_c E[c / x_(c)]  (throughput-optimal cutoff),
      3. after the step, impute censored runtimes from the predictive
         distribution left-truncated at the observed cutoff time (§4.2).
    """
    model: RuntimeModel
    k_samples: int = 64
    min_frac: float = 0.5
    seed: int = 0

    _window: list = field(default_factory=list)
    _pending_pred: Optional[tuple] = None
    _step: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    @property
    def n(self) -> int:
        return self.model.n_workers

    @property
    def warmed_up(self) -> bool:
        return len(self._window) >= self.model.lag + 1

    def seed_window(self, traces: np.ndarray):
        """Warm-start the lag window from recorded traces."""
        for row in np.asarray(traces)[-(self.model.lag + 1):]:
            self._window.append(np.asarray(row, np.float64))

    def predict_cutoff(self) -> int:
        self._step += 1
        if not self.warmed_up:
            self._pending_pred = None
            return self.n
        w = np.stack(self._window[-(self.model.lag + 1):])
        samples, mu, std = self.model.predict_next(
            w, self.k_samples, seed=self.seed + self._step)
        # per-worker predictive moments (for censoring) from the MC samples
        self._pending_pred = (
            mu.mean(axis=0),
            np.sqrt(std.mean(axis=0) ** 2 + mu.var(axis=0)))
        return order_stats.optimal_cutoff(samples, self.min_frac)

    def predicted_order_stats(self):
        """(mean, std) of predicted order statistics for the next step."""
        if not self.warmed_up:
            return None
        w = np.stack(self._window[-(self.model.lag + 1):])
        samples, _, _ = self.model.predict_next(
            w, self.k_samples, seed=self.seed + self._step)
        return order_stats.mc_order_stats(samples)

    def observe(self, times, finished_mask=None):
        t = np.asarray(times, np.float64)
        if finished_mask is None or bool(np.all(finished_mask)):
            self._window.append(t)
            return
        mask = np.asarray(finished_mask, bool)
        cutoff_time = float(t[mask].max())
        if self._pending_pred is None:
            # warmup fallback: impute with the max observed time
            imputed = np.where(mask, t, cutoff_time)
        else:
            mu, std = self._pending_pred
            imputed = censoring.impute_censored(t, mask, mu, std,
                                                cutoff_time, self._rng)
        self._window.append(imputed)
