"""Normal CDF / inverse-CDF: float64 numpy reference + float32 jax twins.

jax on this host truncates to f32; Acklam's rational approximation for the
inverse normal CDF is accurate to ~1.15e-9 in f64 which matches the paper's
printed figures (E[max] = 2.1063 at n=158).  The ``*_jax`` twins run the
same rational approximation in f32 inside jitted device code (controller
hot path); they agree with the numpy reference to f32 precision away from
the extreme tails.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from math import erf


_A = [-3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
      1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00]
_B = [-5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
      6.680131188771972e+01, -1.328068155288572e+01]
_C = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
      -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00]
_D = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
      3.754408661907416e+00]


def ndtri(p):
    """Inverse standard normal CDF (vectorized, float64)."""
    p = np.asarray(p, np.float64)
    out = np.empty_like(p)
    plow, phigh = 0.02425, 1 - 0.02425

    lo = p < plow
    hi = p > phigh
    mid = ~(lo | hi)

    q = np.sqrt(-2 * np.log(np.where(lo, p, 0.5)))
    out_lo = ((((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4])
               * q + _C[5])
              / ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1))
    q = p - 0.5
    r = q * q
    out_mid = ((((((_A[0] * r + _A[1]) * r + _A[2]) * r + _A[3]) * r + _A[4])
                * r + _A[5]) * q
               / (((((_B[0] * r + _B[1]) * r + _B[2]) * r + _B[3]) * r
                   + _B[4]) * r + 1))
    q = np.sqrt(-2 * np.log(np.where(hi, 1 - p, 0.5)))
    out_hi = -((((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4])
                * q + _C[5])
               / ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1))
    out = np.where(lo, out_lo, np.where(hi, out_hi, out_mid))
    return out


def ndtr(x):
    """Standard normal CDF (vectorized, float64)."""
    x = np.asarray(x, np.float64)
    return 0.5 * (1.0 + np.vectorize(erf)(x / np.sqrt(2.0)))


# ---------------------------------------------------------------------------
# jax twins (f32, jit-safe) — the controller's device-resident decision path.
# ---------------------------------------------------------------------------


def ndtri_jax(p):
    """Inverse standard normal CDF, Acklam's approximation in jnp.

    Same branch structure as :func:`ndtri`; callers must keep ``p`` inside
    (0, 1) — in f32 that means clipping at ~1e-7 from either end, not the
    reference's 1e-12 (which rounds to 0/1 in f32).
    """
    p = jnp.asarray(p)
    plow, phigh = 0.02425, 1 - 0.02425

    lo = p < plow
    hi = p > phigh

    q = jnp.sqrt(-2.0 * jnp.log(jnp.where(lo, p, 0.5)))
    out_lo = ((((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4])
               * q + _C[5])
              / ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1))
    q = p - 0.5
    r = q * q
    out_mid = ((((((_A[0] * r + _A[1]) * r + _A[2]) * r + _A[3]) * r + _A[4])
                * r + _A[5]) * q
               / (((((_B[0] * r + _B[1]) * r + _B[2]) * r + _B[3]) * r
                   + _B[4]) * r + 1))
    q = jnp.sqrt(-2.0 * jnp.log(jnp.where(hi, 1.0 - p, 0.5)))
    out_hi = -((((((_C[0] * q + _C[1]) * q + _C[2]) * q + _C[3]) * q + _C[4])
                * q + _C[5])
               / ((((_D[0] * q + _D[1]) * q + _D[2]) * q + _D[3]) * q + 1))
    return jnp.where(lo, out_lo, jnp.where(hi, out_hi, out_mid))


def ndtr_jax(x):
    """Standard normal CDF in jnp (lax erf)."""
    x = jnp.asarray(x)
    return 0.5 * (1.0 + jax.lax.erf(x / jnp.sqrt(jnp.asarray(2.0, x.dtype))))
