"""Throughput-optimal cutoff from Monte-Carlo order statistics (paper §3).

Throughput of waiting for the fastest c of n workers:  Omega(c) = c / x_(c),
where x_(c) is the c-th order statistic of the joint runtime vector.  Given K
predictive samples of the next runtime vector, sort each, average Omega per
cutoff, argmax.

Two implementations live side by side: the float64 numpy reference (host
path, easy to audit against the paper) and jit-safe ``*_jax`` twins that run
the identical sort → curve → argmax logic in f32 on device — the fused
controller decision (``controller._fused_observe_decide`` →
``RuntimeModel._decide_core``) calls those so the whole decision is one jit
with only the scalar cutoff fetched to the host.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cutoff.eps import OMEGA_FLOOR


def min_frac_floor(n: int, min_frac: float) -> int:
    """The smallest 0-based index the argmax may pick: c >= min_frac * n.

    Clamped so min_frac=1.0 degenerates to full sync instead of an empty
    argmax.  Shared by the numpy and jax cutoff implementations so the two
    paths can never disagree on the search window.
    """
    return min(int(np.ceil(min_frac * n)), n - 1)


def mc_order_stats(samples: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """samples: (K, n) -> (mean (n,), std (n,)) of each order statistic."""
    s = np.sort(np.asarray(samples), axis=1)
    return s.mean(axis=0), s.std(axis=0)


def throughput_curve(samples: np.ndarray) -> np.ndarray:
    """E[Omega(c)] for c = 1..n, from MC samples (K, n)."""
    s = np.sort(np.asarray(samples), axis=1)
    c = np.arange(1, s.shape[1] + 1, dtype=np.float64)
    return (c[None, :] / np.maximum(s, OMEGA_FLOOR)).mean(axis=0)


def optimal_cutoff(samples: np.ndarray, min_frac: float = 0.0) -> int:
    """argmax_c E[Omega(c)]; optionally restrict c >= min_frac * n.

    min_frac=0 reproduces the paper exactly; a floor (e.g. 0.5) bounds the
    gradient-noise increase when the model predicts an extreme tail.
    """
    omega = throughput_curve(samples)
    n = omega.shape[0]
    lo = min_frac_floor(n, min_frac)
    c = int(np.argmax(omega[lo:]) + lo) + 1
    return min(c, n)


# ---------------------------------------------------------------------------
# jax twins (f32, jit-safe).
# ---------------------------------------------------------------------------


def sorted_rows_jax(x) -> jnp.ndarray:
    """Ascending per-row sort via a bitonic network.

    XLA's generic comparator sort is pathologically slow on CPU (tens of
    ms for a (256, 1024) batch); the bitonic network is O(n log^2 n)
    compare-exchanges expressed as static gathers + elementwise min/max,
    which every backend executes well.  The output VALUES are exactly the
    sorted multiset — bit-identical to ``np.sort`` — which is all the
    order-statistics math needs (ties carry no identity here).
    """
    K, n = x.shape
    m = 1 << max(n - 1, 0).bit_length()
    if m != n:
        x = jnp.pad(x, ((0, 0), (0, m - n)), constant_values=jnp.inf)
    # jnp (not np) index math: numpy constants would be staged into the
    # jaxpr through device_put eqns, which the jaxpr auditor rejects on
    # this path; as traced int ops XLA constant-folds them identically
    idx = jnp.arange(m)
    ksz = 2
    while ksz <= m:
        j = ksz // 2
        while j >= 1:
            partner = idx ^ j
            take_min = (idx < partner) == ((idx & ksz) == 0)
            xp = x[:, partner]
            x = jnp.where(take_min[None, :], jnp.minimum(x, xp),
                          jnp.maximum(x, xp))
            j //= 2
        ksz *= 2
    return x[:, :n]


def mc_order_stats_jax(samples) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """samples: (K, n) -> (mean (n,), std (n,)) of each order statistic."""
    s = sorted_rows_jax(samples)
    return jnp.mean(s, axis=0), jnp.std(s, axis=0)


def throughput_curve_jax(samples) -> jnp.ndarray:
    """E[Omega(c)] for c = 1..n, from MC samples (K, n)."""
    s = sorted_rows_jax(samples)
    c = jnp.arange(1, s.shape[1] + 1, dtype=samples.dtype)
    return jnp.mean(c[None, :] / jnp.maximum(s, OMEGA_FLOOR), axis=0)


def _cutoff_from_sorted(s, lo: int) -> jnp.ndarray:
    """Throughput argmax over PRE-SORTED samples (K, n), 0-based floor
    ``lo``.  The one copy of the omega/argmax math every jax cutoff entry
    point shares — bit-identity between the single-job and batched
    decision paths is structural, not by parallel edit."""
    n = s.shape[1]
    cs = jnp.arange(1, n + 1, dtype=s.dtype)
    omega = jnp.mean(cs[None, :] / jnp.maximum(s, OMEGA_FLOOR), axis=0)
    c = jnp.argmax(omega[lo:]) + lo + 1
    return jnp.minimum(c, n).astype(jnp.int32)


def optimal_cutoff_jax_from_floor(samples, lo: int) -> jnp.ndarray:
    """Throughput argmax restricted to 0-based floor ``lo`` (static int)."""
    return _cutoff_from_sorted(sorted_rows_jax(samples), lo)


def cutoff_and_iter_jax(samples, lo: int):
    """(optimal cutoff, E[x_(c)] at that cutoff) from ONE shared sort.

    The cutoff is bit-identical to ``optimal_cutoff_jax_from_floor``
    (same ``_cutoff_from_sorted`` body); the second output is the
    posterior-predictive iteration wall time under the decision — what a
    multi-tenant scheduler ranks jobs by (shortest-predicted-step-first)
    without a second inference pass.
    """
    s = sorted_rows_jax(samples)
    c = _cutoff_from_sorted(s, lo)
    pred_iter = jnp.mean(jnp.take(s, c - 1, axis=1))
    return c, pred_iter


def _cutoff_from_sorted_ragged(s, lo, n_real) -> jnp.ndarray:
    """Throughput argmax over PRE-SORTED samples (K, n_pad) whose last
    ``n_pad - n_real`` columns are +inf padding.

    ``lo`` and ``n_real`` are TRACED int32 scalars, so one compiled
    program serves every job width in a ragged bucket.  For
    ``n_real == n_pad`` the masked argmax scans exactly the omega values
    ``_cutoff_from_sorted`` scans (padding contributes omega = c/inf = 0
    outside the mask), so full-width jobs keep the static path's answer.
    """
    n = s.shape[1]
    cs = jnp.arange(1, n + 1, dtype=s.dtype)
    omega = jnp.mean(cs[None, :] / jnp.maximum(s, OMEGA_FLOOR), axis=0)
    i = jnp.arange(n)
    valid = (i >= lo) & (i < n_real)
    c = jnp.argmax(jnp.where(valid, omega, -jnp.inf)) + 1
    return jnp.minimum(c, n_real).astype(jnp.int32)


def cutoff_and_iter_ragged_jax(samples, lo, n_real):
    """Ragged twin of ``cutoff_and_iter_jax``: samples (K, n_pad) with
    +inf in the padded columns, traced floor ``lo`` and real width
    ``n_real``.  The shared bitonic sort pushes the +inf pads to the top
    columns, so order statistics of the real workers land in columns
    [0, n_real) exactly as in a width-n_real sort."""
    s = sorted_rows_jax(samples)
    c = _cutoff_from_sorted_ragged(s, lo, n_real)
    pred_iter = jnp.mean(jnp.take(s, c - 1, axis=1))
    return c, pred_iter


def optimal_cutoff_jax(samples, min_frac: float = 0.0) -> jnp.ndarray:
    """argmax_c E[Omega(c)] as a traced int32 scalar (1-based cutoff).

    ``min_frac`` must be a static python float (it shapes the argmax
    window); everything else traces, so the whole decision jits.
    """
    return optimal_cutoff_jax_from_floor(
        samples, min_frac_floor(samples.shape[1], min_frac))


def oracle_cutoff(actual: np.ndarray) -> int:
    """Best cutoff in hindsight for one observed runtime vector (n,)."""
    s = np.sort(np.asarray(actual))
    c = np.arange(1, s.shape[0] + 1, dtype=np.float64)
    return int(np.argmax(c / np.maximum(s, OMEGA_FLOOR))) + 1


def iter_time(actual: np.ndarray, c: int) -> float:
    """Wall-clock of one SGD iteration when waiting for the fastest c."""
    return float(np.sort(np.asarray(actual))[c - 1])
