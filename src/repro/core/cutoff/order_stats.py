"""Throughput-optimal cutoff from Monte-Carlo order statistics (paper §3).

Throughput of waiting for the fastest c of n workers:  Omega(c) = c / x_(c),
where x_(c) is the c-th order statistic of the joint runtime vector.  Given K
predictive samples of the next runtime vector, sort each, average Omega per
cutoff, argmax.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def mc_order_stats(samples: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """samples: (K, n) -> (mean (n,), std (n,)) of each order statistic."""
    s = np.sort(np.asarray(samples), axis=1)
    return s.mean(axis=0), s.std(axis=0)


def throughput_curve(samples: np.ndarray) -> np.ndarray:
    """E[Omega(c)] for c = 1..n, from MC samples (K, n)."""
    s = np.sort(np.asarray(samples), axis=1)
    c = np.arange(1, s.shape[1] + 1, dtype=np.float64)
    return (c[None, :] / np.maximum(s, 1e-9)).mean(axis=0)


def optimal_cutoff(samples: np.ndarray, min_frac: float = 0.0) -> int:
    """argmax_c E[Omega(c)]; optionally restrict c >= min_frac * n.

    min_frac=0 reproduces the paper exactly; a floor (e.g. 0.5) bounds the
    gradient-noise increase when the model predicts an extreme tail.
    """
    omega = throughput_curve(samples)
    n = omega.shape[0]
    # clamp so min_frac=1.0 degenerates to full sync instead of an empty
    # argmax
    lo = min(int(np.ceil(min_frac * n)), n - 1)
    c = int(np.argmax(omega[lo:]) + lo) + 1
    return min(c, n)


def oracle_cutoff(actual: np.ndarray) -> int:
    """Best cutoff in hindsight for one observed runtime vector (n,)."""
    s = np.sort(np.asarray(actual))
    c = np.arange(1, s.shape[0] + 1, dtype=np.float64)
    return int(np.argmax(c / np.maximum(s, 1e-9))) + 1


def iter_time(actual: np.ndarray, c: int) -> float:
    """Wall-clock of one SGD iteration when waiting for the fastest c."""
    return float(np.sort(np.asarray(actual))[c - 1])
