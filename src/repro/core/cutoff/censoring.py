"""Censored run-time imputation (paper §4.2).

Workers dropped at the cutoff never report their runtimes; the guide RNN was
trained on fully-observed vectors, so missing entries are imputed by sampling
each worker's predictive distribution left-truncated at the observed cutoff
time x_(c):

    p(x | x > x_c) = p(x) / int_{x_c}^inf p(x) dx

Sampling via inverse-CDF on the truncated normal.
"""
from __future__ import annotations

import numpy as np

from repro.core.cutoff._normal import ndtr as _ndtr, ndtri as _ndtri




def truncated_normal_sample(mu, sigma, lower, rng) -> np.ndarray:
    """Sample x ~ N(mu, sigma^2) | x > lower (elementwise).

    Far in the right tail (lower >> mu) the CDF saturates and the
    inverse-CDF draw degenerates, so the result is clamped at ``lower`` —
    the correct limit of the truncated distribution as its mass above the
    bound vanishes.
    """
    mu = np.asarray(mu, np.float64)
    lower = np.asarray(lower, np.float64)
    sigma = np.maximum(np.asarray(sigma, np.float64), 1e-9)
    a = _ndtr((lower - mu) / sigma)
    a = np.clip(a, 0.0, 1.0 - 1e-9)
    u = a + (1.0 - a) * rng.uniform(size=mu.shape)
    return np.maximum(mu + sigma * _ndtri(np.clip(u, 1e-12, 1 - 1e-12)),
                      lower)


def impute_censored(observed: np.ndarray, finished_mask: np.ndarray,
                    pred_mu: np.ndarray, pred_std: np.ndarray,
                    cutoff_time: float, rng) -> np.ndarray:
    """Fill unobserved worker runtimes with truncated predictive samples.

    observed: (n,) runtimes (garbage where ~finished_mask);
    pred_mu/pred_std: (n,) per-worker predictive moments for THIS iteration.
    """
    imputed = truncated_normal_sample(pred_mu, pred_std,
                                      np.full_like(pred_mu, cutoff_time), rng)
    return np.where(finished_mask, observed, imputed)
