"""Censored run-time imputation (paper §4.2).

Workers dropped at the cutoff never report their runtimes; the guide RNN was
trained on fully-observed vectors, so missing entries are imputed by sampling
each worker's predictive distribution left-truncated at the observed cutoff
time x_(c):

    p(x | x > x_c) = p(x) / int_{x_c}^inf p(x) dx

Sampling via inverse-CDF on the truncated normal.

The numpy reference runs in f64 on the host; ``truncated_normal_sample_jax``
is the f32 twin the device-resident controller fuses into its jitted observe
path.  Both accept pre-drawn uniforms ``u`` so the two paths can consume the
SAME random stream — that is what lets the device/numpy equivalence suite
demand identical cutoff sequences while the imputed values only differ at
f32 precision.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.cutoff._normal import (ndtr as _ndtr, ndtr_jax as _ndtr_jax,
                                       ndtri as _ndtri,
                                       ndtri_jax as _ndtri_jax)
from repro.core.cutoff.eps import CDF_CLIP, SIGMA_FLOOR, U_CLIP_LO


# Both the f64 reference and the f32 device sampler clip the truncation CDF
# and the effective uniform at the SAME epsilons, shared (with the
# rationale) in ``repro.core.cutoff.eps`` — this caps the inverse-CDF at
# the 1-CDF_CLIP quantile (~4.75 sigma above the bound) so the two paths
# sample the same distribution and the device/numpy equivalence suite can
# hold them together even through far-tail draws.
_CDF_CLIP = CDF_CLIP
_U_CLIP_LO = U_CLIP_LO


def truncated_normal_sample(mu, sigma, lower, rng=None, u=None) -> np.ndarray:
    """Sample x ~ N(mu, sigma^2) | x > lower (elementwise).

    Far in the right tail (lower >> mu) the CDF saturates and the
    inverse-CDF draw degenerates, so the result is clamped at ``lower`` —
    the correct limit of the truncated distribution as its mass above the
    bound vanishes.

    Uniforms come from ``u`` when given (shared-stream mode; shape of
    ``mu``), otherwise from ``rng.uniform``.
    """
    mu = np.asarray(mu, np.float64)
    lower = np.asarray(lower, np.float64)
    sigma = np.maximum(np.asarray(sigma, np.float64), SIGMA_FLOOR)
    a = _ndtr((lower - mu) / sigma)
    a = np.clip(a, 0.0, 1.0 - _CDF_CLIP)
    if u is None:
        u = rng.uniform(size=mu.shape)
    u = a + (1.0 - a) * np.asarray(u, np.float64)
    return np.maximum(
        mu + sigma * _ndtri(np.clip(u, _U_CLIP_LO, 1 - _CDF_CLIP)), lower)


def impute_censored(observed: np.ndarray, finished_mask: np.ndarray,
                    pred_mu: np.ndarray, pred_std: np.ndarray,
                    cutoff_time: float, rng=None, u=None) -> np.ndarray:
    """Fill unobserved worker runtimes with truncated predictive samples.

    observed: (n,) runtimes (garbage where ~finished_mask);
    pred_mu/pred_std: (n,) per-worker predictive moments for THIS iteration.
    """
    imputed = truncated_normal_sample(pred_mu, pred_std,
                                      np.full_like(pred_mu, cutoff_time),
                                      rng, u=u)
    return np.where(finished_mask, observed, imputed)


# ---------------------------------------------------------------------------
# jax twins (f32, jit-safe) — fused into the controller's observe path.
# ---------------------------------------------------------------------------


def truncated_normal_sample_jax(mu, sigma, lower, u) -> jnp.ndarray:
    """f32 twin of :func:`truncated_normal_sample` with explicit uniforms.

    Identical clip epsilons to the reference (module constants above), so
    both paths sample the same capped-tail distribution; residual
    differences are f32 arithmetic only.
    """
    sigma = jnp.maximum(sigma, SIGMA_FLOOR)
    a = _ndtr_jax((lower - mu) / sigma)
    a = jnp.clip(a, 0.0, 1.0 - _CDF_CLIP)
    uu = a + (1.0 - a) * u
    x = mu + sigma * _ndtri_jax(jnp.clip(uu, _U_CLIP_LO, 1.0 - _CDF_CLIP))
    return jnp.maximum(x, lower)


def impute_censored_jax(observed, finished_mask, pred_mu, pred_std,
                        cutoff_time, u) -> jnp.ndarray:
    """jax twin of :func:`impute_censored` (``cutoff_time`` may be traced)."""
    imputed = truncated_normal_sample_jax(
        pred_mu, pred_std, jnp.broadcast_to(cutoff_time, pred_mu.shape), u)
    return jnp.where(finished_mask, observed, imputed)
