"""Shared numeric guards for the f64-numpy / f32-jax cutoff twins.

Every clip and epsilon the paired backend implementations
(``order_stats.throughput_curve`` / ``throughput_curve_jax``,
``censoring.truncated_normal_sample`` / ``truncated_normal_sample_jax``,
...) apply lives HERE, once, backend-neutral — so the two distributions
can never drift apart through an edit to one twin.  The
``twin-epsilon-drift`` lint rule (``repro.analysis``) rejects inline
float literals inside twin bodies; route any new guard through this
module.

Values are load-bearing for seeded-parity suites: do not retune without
re-running the controller equivalence tests.
"""

#: floor under a sorted runtime before it divides a throughput count —
#: keeps Omega(c) = c / x_(c) finite at a (degenerate) zero runtime.
OMEGA_FLOOR = 1e-9

#: floor under a predictive std before truncated-normal sampling; a
#: collapsed (zero-variance) predictive still inverts cleanly.
SIGMA_FLOOR = 1e-9

#: keep the truncation CDF strictly below 1 so the inverse-CDF stays
#: finite in f32 — tighter clips (1e-9/1e-12) round to exactly 1.0f and
#: the f32 twin would emit inf where the f64 reference does not.
CDF_CLIP = 1e-6

#: floor on the imputation uniform before inverse-CDF (u=0 maps to
#: -inf); asymmetric with CDF_CLIP on purpose — the low tail is safe in
#: f32 down to 1e-7.
U_CLIP_LO = 1e-7
