"""Analytic iid-normal order statistics — Elfving (1947)/Royston (1982).

    E[x_(j)] ~= mu + Phi^{-1}((j - pi/8) / (n - pi/4 + 1)) * sigma

This is the paper's "order" baseline (Eq. 3).  Paper validation (§4.1):
n=158, mu=1.057, sigma=0.393  =>  E[x_(158)] ~= 2.1063.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.cutoff._normal import ndtr as _ndtr, ndtri as _ndtri



def expected_order_stats(n: int, mu: float, sigma: float) -> np.ndarray:
    """E[x_(j)] for j = 1..n under iid N(mu, sigma^2)."""
    j = np.arange(1, n + 1, dtype=np.float64)
    alpha = math.pi / 8.0
    p = (j - alpha) / (n - 2 * alpha + 1.0)
    return mu + _ndtri(p) * sigma


def expected_max(n: int, mu: float, sigma: float) -> float:
    return float(expected_order_stats(n, mu, sigma)[-1])


def expected_idle_fraction(n: int, mu: float, sigma: float) -> float:
    """Mean idle time per worker under full sync ~= E[x_(n)] - E[x_(n/2)]
    (paper Eq. 2)."""
    e = expected_order_stats(n, mu, sigma)
    return float(e[-1] - e[n // 2 - 1])


def elfving_cutoff(n: int, mu: float, sigma: float,
                   min_frac: float = 0.5) -> int:
    """Throughput-optimal cutoff under the iid-normality assumption.

    min_frac guards the degenerate low-c region: with mu/sigma ratios typical
    of runtime data, E[x_(1)] approaches 0 under the (wrong) normal model and
    Omega(1) explodes; real systems never drop more than half the batch.
    """
    e = np.maximum(expected_order_stats(n, mu, sigma), 1e-9)
    c = np.arange(1, n + 1, dtype=np.float64)
    lo = int(np.ceil(min_frac * n)) - 1
    return int(np.argmax((c / e)[lo:])) + lo + 1


def exact_order_stat_mean(n: int, j: int, mu: float = 0.0,
                          sigma: float = 1.0) -> float:
    """E[x_(j)] by numerical quadrature of the exact density (paper §3.1.1):

        E = Z(n,j) * int x phi(x) Phi(x)^{j-1} (1-Phi(x))^{n-j} dx

    The paper's printed 2.1063 for (n=158, mu=1.057, sigma=0.393) matches
    this exact integral; the Elfving approximation gives 2.1047.
    """
    from math import lgamma
    x = np.linspace(-12.0, 12.0, 48_001)
    cdf = _ndtr(x)
    logpdf = -0.5 * x * x - 0.5 * math.log(2 * math.pi)
    logz = lgamma(n + 1) - lgamma(j) - lgamma(n - j + 1)
    with np.errstate(divide="ignore"):
        logw = (logz + logpdf + (j - 1) * np.log(np.clip(cdf, 1e-300, None))
                + (n - j) * np.log(np.clip(1 - cdf, 1e-300, None)))
    w = np.exp(logw)
    e = np.trapezoid(x * w, x) / max(np.trapezoid(w, x), 1e-300)
    return mu + sigma * float(e)
