"""The chief: launch workers, watch heartbeats, kill/restart/evict.

The :class:`Supervisor` owns one :class:`HeartbeatMonitor` and one
worker pool and advances both on a single logical clock (one ``tick``
per SGD step in simulated drills, one poll interval in subprocess
mode).  Per tick it:

  1. lets the pool apply any due (seeded) faults and deliver the
     heartbeats that actually arrived;
  2. applies the deadlines: a worker that misses ``dead_after`` ticks is
     DEAD — if its process is still alive (a hang) the supervisor KILLS
     it first, then schedules a restart;
  3. launches due restarts with capped exponential backoff + seeded
     jitter (``base * 2^failures``, capped, + U{0..jitter}); an
     incarnation that dies on arrival burns a failure, and a worker
     that fails ``flap_limit`` restarts is evicted permanently;
  4. publishes the new membership (alive + suspect) — the SAME
     global-id set a scripted ``ChurnSim`` would have produced, which
     :class:`SupervisedTimer` feeds into the unchanged
     ``Trainer.resize`` / ``ElasticController`` / ``PSServer`` paths.

Two pools share the protocol (``worker_ids`` / ``pump`` / ``start`` /
``kill`` / ``is_alive_process``):

  * :class:`SimWorkerPool` — logical-clock workers over a
    ``cluster.simulator.OverlaySim``; fully deterministic, tier-1 fast.
  * :class:`ProcWorkerPool` — real OS processes running
    ``python -m repro.controlplane.worker``; heartbeats arrive through
    per-worker sidecar JSONL files, restarts spawn real incarnations
    that recover warm from the ``"ctl"`` checkpoint group by GLOBAL
    worker id.  ``scripts/ci.sh --drill`` exercises kill -9 against it.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
from contextlib import nullcontext
from typing import Dict, List, Optional

import numpy as np

from repro.controlplane.events import Event, EventLog
from repro.controlplane.faults import FaultInjector
from repro.controlplane.heartbeat import DEAD, HeartbeatMonitor


# ---------------------------------------------------------------------------
# Worker pools.
# ---------------------------------------------------------------------------


class SimWorkerPool:
    """Deterministic thread-free workers on the supervisor's clock.

    Every ``up`` worker heartbeats every tick.  Faults (via a seeded
    :class:`~repro.controlplane.faults.FaultInjector`) flip workers to
    ``crashed`` (no beats, runtime stalled) or ``hung`` (no beats,
    runtime stalled, process still alive — must be killed), or apply a
    bounded ``slowdown`` (beats keep flowing; the cutoff controller owns
    that case).  Runtime effects land on the shared
    :class:`~repro.cluster.simulator.OverlaySim`, so the training loop
    sees exactly the stalls the control plane is reasoning about.
    """

    def __init__(self, overlay, injector: Optional[FaultInjector] = None,
                 *, ckpt_dir: Optional[str] = None):
        self.overlay = overlay
        self.injector = injector
        self.ckpt_dir = ckpt_dir
        self.status: Dict[int, str] = {w: "up" for w
                                       in range(overlay.n_workers)}
        self._slow_until: Dict[int, int] = {}

    def worker_ids(self) -> List[int]:
        return sorted(self.status)

    def healthy_count(self, members) -> int:
        return sum(1 for w in members if self.status[int(w)] == "up")

    def _apply_fault(self, f, tick: int, log: EventLog):
        log.emit(tick, "fault", f.worker, fault=f.kind)
        if f.kind == "crash":
            self.status[f.worker] = "crashed"
            self.overlay.stall(f.worker)
        elif f.kind == "hang":
            self.status[f.worker] = "hung"
            self.overlay.stall(f.worker)
        elif f.kind == "slowdown":
            self.overlay.slow(f.worker, f.factor)
            self._slow_until[f.worker] = tick + f.duration
        elif f.kind == "corrupt_ckpt" and self.ckpt_dir:
            path = self.injector.corrupt_checkpoint(self.ckpt_dir, f.group)
            log.emit(tick, "fault", None, fault="corrupt_ckpt",
                     path=path or "")
        # flaky_restart only arms the injector's budget

    def pump(self, tick: int, monitor: HeartbeatMonitor, log: EventLog):
        if self.injector is not None:
            for f in self.injector.fire(tick):
                self._apply_fault(f, tick, log)
        for w, until in list(self._slow_until.items()):
            if tick >= until:
                self.overlay.slow(w, 1.0)
                del self._slow_until[w]
        for w in self.worker_ids():
            if self.status[w] == "up" and w in monitor._tracks:
                monitor.beat(w, tick)

    def is_alive_process(self, wid: int) -> bool:
        return self.status[wid] == "hung"

    def kill(self, wid: int):
        self.status[wid] = "crashed"
        self.overlay.stall(wid)

    def start(self, wid: int, attempt: int, tick: int,
              log: EventLog) -> bool:
        if (self.injector is not None
                and self.injector.restart_should_fail(wid)):
            return False
        self.status[wid] = "up"
        self.overlay.stall(wid, False)
        self.overlay.slow(wid, 1.0)
        self._slow_until.pop(wid, None)
        if self.ckpt_dir:
            self._emit_recover(wid, tick, log)
        return True

    def _emit_recover(self, wid: int, tick: int, log: EventLog):
        """Warm recovery by GLOBAL worker id: the restarted worker reads
        the ``"ctl"`` checkpoint group and reports which step it resumed
        from and whether its own id was in the saved membership."""
        from repro.checkpoint import store
        try:
            step = store.latest_valid_step(self.ckpt_dir)
            grp = (store.restore_group(self.ckpt_dir, "ctl", step=step)
                   if step is not None else None)
        except Exception:
            grp = None
        if grp is None:
            return
        members = np.asarray(grp["members"], int)
        log.emit(tick, "recover", wid, step=int(grp["step"]),
                 warm=bool(wid in members))


class ProcWorkerPool:
    """Real subprocess workers (``python -m repro.controlplane.worker``).

    Heartbeats and worker-side events arrive through per-worker sidecar
    JSONL files under ``run_dir`` (``hb_<wid>.jsonl`` /
    ``ev_<wid>.jsonl``); ``pump`` reads the new lines each tick, beats
    the monitor once per tick with fresh lines, and re-emits worker
    events (e.g. warm ``recover``) into the supervisor's log.  Faults
    are injected from OUTSIDE (the drill sends a real ``kill -9``,
    drops a hang flag file, or lets the injector fail spawns), so the
    pool only manages lifecycle.
    """

    def __init__(self, n_workers: int, run_dir: str, *,
                 period: float = 0.05,
                 ckpt_dir: Optional[str] = None,
                 injector: Optional[FaultInjector] = None):
        self.n = int(n_workers)
        self.run_dir = run_dir
        self.period = period
        self.ckpt_dir = ckpt_dir
        self.injector = injector
        os.makedirs(run_dir, exist_ok=True)
        self.procs: Dict[int, subprocess.Popen] = {}
        self._offsets: Dict[str, int] = {}

    def worker_ids(self) -> List[int]:
        return list(range(self.n))

    def healthy_count(self, members) -> int:
        return sum(1 for w in members if self.proc_running(int(w)))

    # -- lifecycle ------------------------------------------------------
    def _spawn(self, wid: int, fail: bool = False) -> subprocess.Popen:
        args = [sys.executable, "-m", "repro.controlplane.worker",
                "--wid", str(wid), "--dir", self.run_dir,
                "--period", str(self.period)]
        if self.ckpt_dir:
            args += ["--ckpt", self.ckpt_dir]
        if fail:
            args += ["--fail"]
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), "..", "..")
        env["PYTHONPATH"] = (os.path.abspath(src)
                             + os.pathsep + env.get("PYTHONPATH", ""))
        env.setdefault("JAX_PLATFORMS", "cpu")
        p = subprocess.Popen(args, env=env,
                             stdout=subprocess.DEVNULL,
                             stderr=subprocess.DEVNULL)
        self.procs[wid] = p
        return p

    def launch_all(self):
        for w in self.worker_ids():
            self._spawn(w)

    def proc_running(self, wid: int) -> bool:
        p = self.procs.get(wid)
        return p is not None and p.poll() is None

    def is_alive_process(self, wid: int) -> bool:
        return self.proc_running(wid)

    def kill(self, wid: int):
        p = self.procs.get(wid)
        if p is not None and p.poll() is None:
            p.kill()
            p.wait()
        # a fresh incarnation must not inherit a stale hang flag
        flag = os.path.join(self.run_dir, f"hang_{wid}")
        if os.path.exists(flag):
            os.remove(flag)

    def start(self, wid: int, attempt: int, tick: int,
              log: EventLog) -> bool:
        fail = (self.injector is not None
                and self.injector.restart_should_fail(wid))
        p = self._spawn(wid, fail=fail)
        if fail:
            # the incarnation exits on arrival; reap it so the failure
            # is a real observed process exit, not an oracle
            rc = p.wait(timeout=60)
            return rc == 0
        return True

    # -- fault hooks for drills ----------------------------------------
    def sigkill(self, wid: int):
        """kill -9 the worker's live incarnation (the drill's crash)."""
        p = self.procs.get(wid)
        if p is not None and p.poll() is None:
            os.kill(p.pid, signal.SIGKILL)
            p.wait()

    def hang(self, wid: int):
        """Drop the hang flag: the worker spins alive but stops beating."""
        with open(os.path.join(self.run_dir, f"hang_{wid}"), "w") as f:
            f.write("hang\n")

    # -- heartbeat plumbing --------------------------------------------
    def _new_lines(self, name: str) -> List[str]:
        path = os.path.join(self.run_dir, name)
        if not os.path.exists(path):
            return []
        pos = self._offsets.get(name, 0)
        with open(path) as f:
            f.seek(pos)
            chunk = f.read()
        nl = chunk.rfind("\n")
        if nl < 0:
            return []
        self._offsets[name] = pos + nl + 1
        return [ln for ln in chunk[:nl].split("\n") if ln.strip()]

    def pump(self, tick: int, monitor: HeartbeatMonitor, log: EventLog):
        for w in self.worker_ids():
            if w in monitor._tracks and self._new_lines(f"hb_{w}.jsonl"):
                monitor.beat(w, tick)
            for ln in self._new_lines(f"ev_{w}.jsonl"):
                ev = Event.from_json(ln)
                log.emit(tick, ev.kind, ev.worker, **ev.data)

    def shutdown(self):
        with open(os.path.join(self.run_dir, "stop"), "w") as f:
            f.write("stop\n")
        for p in self.procs.values():
            if p.poll() is None:
                try:
                    p.wait(timeout=5)
                except subprocess.TimeoutExpired:
                    p.kill()
                    p.wait()


# ---------------------------------------------------------------------------
# The chief.
# ---------------------------------------------------------------------------


class Supervisor:
    """Heartbeat-driven membership + restart policy over a worker pool."""

    def __init__(self, pool, *, suspect_after: int = 2, dead_after: int = 4,
                 grace: int = 0, restart_base: int = 2,
                 restart_cap: int = 16, restart_jitter: int = 0,
                 flap_limit: int = 3, seed: int = 0,
                 log: Optional[EventLog] = None, start_tick: int = 0,
                 obs=None):
        self.pool = pool
        # optional repro.obs.ObsRun: tick spans are host perf_counter
        # edges + host counters only — tick() is a lint hot root, and
        # nothing here ever touches a device value
        self.obs = obs
        self.log = log if log is not None else EventLog()
        self.monitor = HeartbeatMonitor(
            pool.worker_ids(), suspect_after=suspect_after,
            dead_after=dead_after, grace=grace, log=self.log,
            start_tick=start_tick)
        self.restart_base = int(restart_base)
        self.restart_cap = int(restart_cap)
        self.restart_jitter = int(restart_jitter)
        self.flap_limit = int(flap_limit)
        self._rng = np.random.default_rng(seed)
        self._restarts: Dict[int, dict] = {}
        self.evicted: set = set()
        self._members = self.monitor.members()
        self.log.emit(start_tick, "run", n=len(self._members),
                      phase="start")

    # -- queries --------------------------------------------------------
    def membership(self) -> np.ndarray:
        """Global worker ids currently holding a lease, ascending."""
        return self._members

    # -- the clock ------------------------------------------------------
    def tick(self, tick: int) -> bool:
        """One control-plane step; returns True if membership changed."""
        tick = int(tick)
        span = (self.obs.trace.span("supervisor.tick", track="controlplane",
                                    tick=tick)
                if self.obs is not None else nullcontext())
        with span:
            self.pool.pump(tick, self.monitor, self.log)
            for wid, _old, new in self.monitor.advance(tick):
                if new == DEAD:
                    self._on_dead(wid, tick)
            self._advance_restarts(tick)
            m = self.monitor.members()
            changed = not np.array_equal(m, self._members)
            if changed:
                self.log.emit(tick, "membership", n=len(m),
                              members=[int(w) for w in m])
                self._members = m
            if self.obs is not None:
                self.obs.metrics.counter("supervisor.ticks").inc()
                if changed:
                    self.obs.metrics.counter(
                        "supervisor.membership_changes").inc()
        return changed

    # -- restart policy -------------------------------------------------
    def _backoff(self, failures: int) -> int:
        base = min(self.restart_cap, self.restart_base * 2 ** failures)
        jitter = (int(self._rng.integers(0, self.restart_jitter + 1))
                  if self.restart_jitter else 0)
        return base + jitter

    def _on_dead(self, wid: int, tick: int):
        if self.pool.is_alive_process(wid):
            # a hang: the incarnation is alive but silent — kill it so
            # the restart below doesn't double-run the worker
            self.pool.kill(wid)
            self.log.emit(tick, "kill", wid, reason="hung")
        rec = self._restarts.get(wid, {"attempt": 0, "failures": 0})
        self._schedule(wid, tick, rec)

    def _schedule(self, wid: int, tick: int, rec: dict):
        rec["eta"] = tick + self._backoff(rec["failures"])
        self._restarts[wid] = rec

    def _advance_restarts(self, tick: int):
        for wid in sorted(self._restarts):
            rec = self._restarts[wid]
            if tick < rec["eta"]:
                continue
            rec["attempt"] += 1
            ok = self.pool.start(wid, rec["attempt"], tick, self.log)
            if ok:
                self.log.emit(tick, "restart", wid,
                              attempt=rec["attempt"],
                              failures=rec["failures"])
                self.monitor.admit(wid, tick)
                del self._restarts[wid]
                continue
            rec["failures"] += 1
            self.log.emit(tick, "restart_failed", wid,
                          attempt=rec["attempt"],
                          failures=rec["failures"])
            if rec["failures"] >= self.flap_limit:
                self.monitor.remove(wid)
                self.evicted.add(wid)
                self.log.emit(tick, "evict", wid,
                              failures=rec["failures"])
                del self._restarts[wid]
            else:
                self._schedule(wid, tick, rec)


class SupervisedTimer:
    """ChurnSim-shaped Trainer timer driven by LIVE detection.

    Implements the elastic timer protocol (``n_workers`` /
    ``active_ids`` / ``step``) over the supervisor's current membership
    and the fault overlay's runtimes — the drop-in replacement for a
    scripted ``ChurnSim`` that makes the whole existing elastic path
    (``Trainer._sync_membership`` -> ``resize`` -> controller remap) run
    off detected reality.  Drive ``supervisor.tick(t)`` BEFORE the
    trainer's step ``t`` (the ``ChurnSim`` convention: membership
    changes land before the resized step's runtimes are drawn).
    """

    def __init__(self, overlay, supervisor: Supervisor):
        self.overlay = overlay
        self.sup = supervisor

    @property
    def n_workers(self) -> int:
        return int(self.sup.membership().size)

    @property
    def active_ids(self) -> np.ndarray:
        return self.sup.membership()

    @property
    def t(self) -> int:
        return self.overlay.t

    def step(self) -> np.ndarray:
        row = self.overlay.step()
        return row[self.sup.membership()]


# ---------------------------------------------------------------------------
# Post-mortem: operational stats out of an event stream.
# ---------------------------------------------------------------------------


def drill_report(events) -> dict:
    """Detection/recovery stats from an event list (log or JSONL replay).

    Returns per-incident records and the aggregate the bench gates on:
    ``detection`` (fault tick -> dead tick, in ticks), ``recovery``
    (dead tick -> rejoin tick), ``evictions``, ``restarts`` (incl.
    failed attempts).  Faults that never produce a detection (e.g.
    slowdowns — the cutoff controller's case) are reported with
    ``detected: False``.

    Aggregation runs on the obs metrics registry (host collectors:
    ``Series``/``Counter``/``LabelSet``), which stores values at their
    original types — so the report is bit-identical to the historical
    ad-hoc dict accounting (``BENCH_controlplane.json`` pins this).
    """
    # lazy import: controlplane is imported by obs's event layer
    from repro.obs.metrics import MetricsRegistry
    faults = [e for e in events
              if e.kind == "fault" and e.worker is not None
              and e.data.get("fault") in ("crash", "hang")]
    deads = [e for e in events if e.kind == "dead"]
    rejoins = [e for e in events
               if e.kind == "rejoin" and not e.data.get("false_alarm")]
    incidents = []
    for f in faults:
        dead = next((d for d in deads
                     if d.worker == f.worker and d.tick >= f.tick), None)
        rej = (next((r for r in rejoins
                     if r.worker == f.worker and r.tick >= dead.tick),
                    None) if dead else None)
        incidents.append({
            "worker": f.worker, "kind": f.data.get("fault"),
            "fault_tick": f.tick, "detected": dead is not None,
            "dead_tick": dead.tick if dead else None,
            "detection_ticks": (dead.tick - f.tick) if dead else None,
            "rejoin_tick": rej.tick if rej else None,
            "recovery_ticks": (rej.tick - dead.tick)
            if (dead and rej) else None,
        })
    reg = MetricsRegistry()
    det = reg.series("detection_ticks")
    rec = reg.series("recovery_ticks")
    for i in incidents:
        if i["detected"]:
            det.observe(i["detection_ticks"])
        if i["recovery_ticks"] is not None:
            rec.observe(i["recovery_ticks"])
    for e in events:
        if e.kind == "restart":
            reg.counter("restarts").inc()
        elif e.kind == "restart_failed":
            reg.counter("failed_restarts").inc()
        elif e.kind == "evict":
            reg.labels("evicted").add(e.worker)
    return {
        "incidents": incidents,
        "n_faults": len(faults),
        "n_detected": det.count,
        "max_detection_ticks": det.max(),
        "mean_detection_ticks": det.mean(),
        "mean_recovery_ticks": rec.mean(),
        "restarts": reg.counter("restarts").value,
        "failed_restarts": reg.counter("failed_restarts").value,
        "evicted": reg.labels("evicted").values(),
    }
