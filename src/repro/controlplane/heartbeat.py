"""Deadline-driven per-worker liveness state machine.

Each tracked worker is ``alive``, ``suspect``, or ``dead``, judged purely
by ticks since its last heartbeat::

    alive   --[> suspect_after ticks silent]-->  suspect
    suspect --[> dead_after    ticks silent]-->  dead
    suspect --[beat]-->                          alive       (false alarm)
    dead    --[admit()]-->                       alive       (rejoin)

Determinism contract (what the property tests pin):

  * a worker whose last beat was at tick ``b`` is NEVER dead at any tick
    ``t <= b + dead_after`` — and if ``advance`` is called every tick, it
    is declared dead at EXACTLY ``b + dead_after + 1``: detection latency
    is the heartbeat deadline + 1 tick, never more;
  * ``admit`` always re-admits a dead worker (the flap limit lives in the
    supervisor, not here) and restarts its deadline clock;
  * transitions are emitted to the event log in tick order.

A worker that has never beaten since ``admit`` gets ``grace`` extra
silent ticks before deadlines apply — subprocess incarnations pay an
interpreter-startup cost far above the steady-state heartbeat period,
and a monitor without grace would declare every fresh worker dead on
arrival.  ``grace=0`` (default) keeps simulated drills exact.

Membership: ``members()`` is the not-dead tracked set (alive + suspect —
a suspect worker still holds its lease; only a detection removes it),
which is exactly what ``ChurnSim`` would have scripted and what
``Trainer.resize`` / ``ElasticController`` / ``PSServer`` consume
unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.controlplane.events import EventLog

ALIVE, SUSPECT, DEAD = "alive", "suspect", "dead"


@dataclass
class WorkerTrack:
    wid: int
    state: str
    last_beat: int          # tick of the last heartbeat (or admit)
    admitted: int           # tick of the last admit
    beaten_since_admit: bool = False


class HeartbeatMonitor:
    def __init__(self, workers, *, suspect_after: int = 2,
                 dead_after: int = 4, grace: int = 0,
                 log: Optional[EventLog] = None,
                 log_heartbeats: bool = False, start_tick: int = 0):
        if not 0 < suspect_after < dead_after:
            raise ValueError(
                f"need 0 < suspect_after < dead_after, got "
                f"{suspect_after} / {dead_after}")
        self.suspect_after = int(suspect_after)
        self.dead_after = int(dead_after)
        self.grace = int(grace)
        self.log = log if log is not None else EventLog()
        self.log_heartbeats = log_heartbeats
        self._tracks: Dict[int, WorkerTrack] = {}
        for w in workers:
            self._tracks[int(w)] = WorkerTrack(
                wid=int(w), state=ALIVE, last_beat=int(start_tick),
                admitted=int(start_tick))

    # -- queries --------------------------------------------------------
    def state(self, wid: int) -> str:
        return self._tracks[wid].state

    def members(self) -> np.ndarray:
        """Global ids currently holding a lease (alive + suspect)."""
        return np.array(sorted(t.wid for t in self._tracks.values()
                               if t.state != DEAD), int)

    def tracked(self) -> np.ndarray:
        return np.array(sorted(self._tracks), int)

    # -- transitions ----------------------------------------------------
    def beat(self, wid: int, tick: int):
        """A heartbeat arrived.  Dead workers' late beats are dropped —
        once detection has fired the membership already shrank, and the
        worker must come back through the supervisor's restart path
        (``admit``), not sneak back in."""
        t = self._tracks[wid]
        if t.state == DEAD:
            return
        t.last_beat = int(tick)
        t.beaten_since_admit = True
        if t.state == SUSPECT:
            t.state = ALIVE
            self.log.emit(tick, "rejoin", wid, false_alarm=True)
        if self.log_heartbeats:
            self.log.emit(tick, "heartbeat", wid)

    def advance(self, tick: int) -> List[Tuple[int, str, str]]:
        """Apply deadlines at ``tick``; returns [(wid, old, new), ...]."""
        tick = int(tick)
        out: List[Tuple[int, str, str]] = []
        for t in sorted(self._tracks.values(), key=lambda x: x.wid):
            if t.state == DEAD:
                continue
            silent = tick - t.last_beat
            dead_line = self.dead_after
            suspect_line = self.suspect_after
            if not t.beaten_since_admit:
                dead_line = max(dead_line, self.grace)
                suspect_line = max(suspect_line, self.grace)
            if silent > dead_line:
                old, t.state = t.state, DEAD
                self.log.emit(tick, "dead", t.wid, last_beat=t.last_beat,
                              silent_ticks=silent)
                out.append((t.wid, old, DEAD))
            elif silent > suspect_line and t.state == ALIVE:
                t.state = SUSPECT
                self.log.emit(tick, "suspect", t.wid,
                              last_beat=t.last_beat, silent_ticks=silent)
                out.append((t.wid, ALIVE, SUSPECT))
        return out

    def admit(self, wid: int, tick: int):
        """(Re-)admit a worker: a completed restart, or a brand-new id.
        Resets the deadline clock; grace applies until its first beat."""
        wid, tick = int(wid), int(tick)
        prev = self._tracks.get(wid)
        self._tracks[wid] = WorkerTrack(wid=wid, state=ALIVE,
                                        last_beat=tick, admitted=tick)
        if prev is not None and prev.state == DEAD:
            self.log.emit(tick, "rejoin", wid)

    def remove(self, wid: int):
        """Stop tracking (permanent eviction — the supervisor logs it)."""
        self._tracks.pop(int(wid), None)
