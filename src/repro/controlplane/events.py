"""Structured control-plane event stream.

One JSONL line per event, append-only, so benches and dashboards can
*follow a live run* (``tail_events``) and post-mortems can replay it
(``read_events``).  Events carry a monotone ``seq``, the supervisor's
logical ``tick``, a wall-clock stamp, the event ``kind``, an optional
global ``worker`` id, and kind-specific payload fields.

The writer keeps an in-memory list too (``EventLog.events``), so
single-process drivers never need a file; multi-process drills give each
worker its own sidecar file and let the supervisor merge (appends of one
short line are atomic enough on POSIX, but we never rely on that — the
reader tolerates a trailing partial line from a crashed writer).

Kinds (the full schema table lives in ``controlplane/README.md``):

  ``heartbeat``      a worker reported in (high-volume; logging optional)
  ``suspect``        deadline half-missed: alive -> suspect
  ``dead``           deadline missed: suspect -> dead (detection!)
  ``rejoin``         a restarted worker re-admitted: dead -> alive
  ``membership``     the active set changed (what Trainer.resize consumes)
  ``restart``        a new worker incarnation launched (attempt k)
  ``restart_failed`` the incarnation died on arrival (flaky restart)
  ``evict``          flap limit hit: worker permanently removed
  ``kill``           supervisor killed a hung-but-live worker
  ``recover``        a worker/chief resumed warm from a checkpoint
  ``fault``          the (seeded) injector fired a fault
  ``decision``       a cutoff decision (optional, high-volume)
  ``run``            run-level marker (start/stop/summary)
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional

EVENT_KINDS = (
    "heartbeat", "suspect", "dead", "rejoin", "membership", "restart",
    "restart_failed", "evict", "kill", "recover", "fault",
    "decision",
    "run",
)


@dataclass(frozen=True)
class Event:
    seq: int
    tick: int
    kind: str
    worker: Optional[int] = None
    wall: float = 0.0
    data: dict = field(default_factory=dict)

    def to_json(self) -> str:
        rec = {"seq": self.seq, "tick": self.tick, "kind": self.kind,
               "wall": round(self.wall, 6)}
        if self.worker is not None:
            rec["worker"] = self.worker
        rec.update(self.data)
        return json.dumps(rec, sort_keys=True)

    @staticmethod
    def from_json(line: str) -> "Event":
        rec = json.loads(line)
        data = {k: v for k, v in rec.items()
                if k not in ("seq", "tick", "kind", "wall", "worker")}
        return Event(seq=int(rec["seq"]), tick=int(rec["tick"]),
                     kind=rec["kind"], worker=rec.get("worker"),
                     wall=float(rec.get("wall", 0.0)), data=data)


class EventLog:
    """Append-only event sink: in-memory list + optional JSONL file.

    ``emit`` assigns a monotone ``seq`` and enforces tick monotonicity —
    the control plane is a single logical clock, and an out-of-order
    tick is a driver bug the stream's consumers (the drill assertions,
    the bench latency math) must be able to rule out.

    ``KINDS`` is the kind registry ``emit`` validates against.
    Subclasses with their own vocabulary (``repro.obs.trace.ObsLog``)
    override it and inherit the seq/tick/JSONL machinery unchanged; the
    ``event-kind-drift`` lint rule walks every registry it knows about.
    """

    KINDS = EVENT_KINDS

    def __init__(self, path: Optional[str] = None, *,
                 clock: Callable[[], float] = time.time):
        self.path = path
        self.events: List[Event] = []
        self._seq = 0
        self._last_tick: Optional[int] = None
        self._clock = clock
        self._fh = open(path, "a", buffering=1) if path else None

    def emit(self, tick: int, kind: str, worker: Optional[int] = None,
             **data) -> Event:
        kinds = type(self).KINDS
        if kind not in kinds:
            raise ValueError(f"unknown event kind {kind!r} "
                             f"(want one of {kinds})")
        tick = int(tick)
        if self._last_tick is not None and tick < self._last_tick:
            raise ValueError(
                f"event tick went backwards: {tick} after {self._last_tick}"
                f" (the control plane runs on one monotone logical clock)")
        self._last_tick = tick
        ev = Event(seq=self._seq, tick=tick, kind=kind, worker=worker,
                   wall=self._clock(), data=dict(data))
        self._seq += 1
        self.events.append(ev)
        if self._fh is not None:
            self._fh.write(ev.to_json() + "\n")
        return ev

    def of_kind(self, *kinds: str) -> List[Event]:
        return [e for e in self.events if e.kind in kinds]

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_events(path: str) -> List[Event]:
    """Parse a whole JSONL event file; a trailing partial line (crashed
    writer) is ignored, a malformed FULL line raises."""
    out: List[Event] = []
    with open(path) as f:
        content = f.read()
    for i, line in enumerate(content.split("\n")):
        if not line.strip():
            continue
        complete = content.endswith("\n") or i < content.count("\n")
        try:
            out.append(Event.from_json(line))
        except (json.JSONDecodeError, KeyError):
            if complete:
                raise
            # partial trailing line: the writer died mid-append
    return out


def tail_events(path: str, *, poll: float = 0.05,
                stop: Optional[Callable[[], bool]] = None,
                timeout: Optional[float] = None) -> Iterator[Event]:
    """Follow a (possibly still-growing) JSONL event file.

    Yields each complete event exactly once, in file order.  Partial
    lines are buffered until their newline arrives.  Terminates when
    ``stop()`` returns True AND the file is drained, or after
    ``timeout`` seconds without a new event.
    """
    buf = ""
    last_new = time.monotonic()
    # open lazily: the writer may not have created the file yet
    fh = None
    try:
        while True:
            if fh is None:
                if os.path.exists(path):
                    fh = open(path)
                else:
                    time.sleep(poll)
                    if timeout and time.monotonic() - last_new > timeout:
                        return
                    continue
            chunk = fh.read()
            if chunk:
                buf += chunk
                last_new = time.monotonic()
                while "\n" in buf:
                    line, buf = buf.split("\n", 1)
                    if line.strip():
                        yield Event.from_json(line)
                continue
            if stop is not None and stop():
                return
            if timeout and time.monotonic() - last_new > timeout:
                return
            time.sleep(poll)
    finally:
        if fh is not None:
            fh.close()
