"""Subprocess worker payload: heartbeat until told otherwise.

``python -m repro.controlplane.worker --wid N --dir RUNDIR --period S``

The loop appends one JSON line per heartbeat to ``RUNDIR/hb_N.jsonl``
and worker-side events to ``RUNDIR/ev_N.jsonl`` (the supervisor's
:class:`~repro.controlplane.supervisor.ProcWorkerPool` tails both).
Control surface, all file-based so a drill can poke it from outside:

  ``RUNDIR/hang_N``   exists -> stop heartbeating but STAY ALIVE (the
                      supervisor must notice the silence and kill -9 us);
  ``RUNDIR/stop``     exists -> exit 0 cleanly (drill teardown);
  ``--fail``          exit 1 immediately (a flaky restart incarnation).

With ``--ckpt DIR`` the worker opens the checkpoint store on startup
and emits a ``recover`` event naming the step it warm-started from and
whether its OWN global id was in the saved membership — the drill's
proof that restore is by global worker id, not by rank.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _append(path: str, rec: dict):
    with open(path, "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
        f.flush()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--wid", type=int, required=True)
    ap.add_argument("--dir", required=True)
    ap.add_argument("--period", type=float, default=0.05)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--fail", action="store_true")
    args = ap.parse_args(argv)

    if args.fail:
        return 1

    wid = args.wid
    hb = os.path.join(args.dir, f"hb_{wid}.jsonl")
    ev = os.path.join(args.dir, f"ev_{wid}.jsonl")
    hang_flag = os.path.join(args.dir, f"hang_{wid}")
    stop_flag = os.path.join(args.dir, "stop")

    if args.ckpt:
        try:
            from repro.checkpoint import store
            step = store.latest_valid_step(args.ckpt)
            grp = (store.restore_group(args.ckpt, "ctl", step=step)
                   if step is not None else None)
        except Exception:
            grp = None
        if grp is not None:
            members = [int(w) for w in grp["members"]]
            _append(ev, {"seq": 0, "tick": 0, "kind": "recover",
                         "worker": wid, "wall": time.time(),
                         "step": int(grp["step"]),
                         "warm": wid in members})

    n = 0
    while True:
        if os.path.exists(stop_flag):
            return 0
        if not os.path.exists(hang_flag):
            _append(hb, {"wid": wid, "n": n, "wall": time.time()})
            n += 1
        time.sleep(args.period)


if __name__ == "__main__":
    sys.exit(main())
