"""Control plane: supervision that turns simulated elasticity into
detected, recovered reality.

The training stack below this package (Trainer / ElasticController /
PSServer) already survives membership changes — but everything that
*drives* a membership change is a pre-scripted ``ChurnSim`` schedule.
This package adds the production layer the shifu ``ssgd_monitor``
exemplar sketches: a chief that detects worker failure from missed
heartbeats and recovers from checkpoints, instead of being told.

  * :mod:`repro.controlplane.events`    — structured JSONL event stream
    (heartbeats, suspicions, membership, restarts, recoveries) with a
    tailing reader;
  * :mod:`repro.controlplane.heartbeat` — deadline-driven per-worker
    ``alive -> suspect -> dead`` state machine (with rejoin);
  * :mod:`repro.controlplane.faults`    — seeded, composable fault plans
    (crash / hang / slowdown / checkpoint corruption / flaky restart)
    so every drill is reproducible;
  * :mod:`repro.controlplane.supervisor` — the chief: launches workers
    (threads for tier-1 speed, subprocesses for the real drill), watches
    heartbeats, kills hung workers, restarts crashed ones with capped
    exponential backoff + jitter, evicts flapping ones, and feeds the
    resulting membership into the UNCHANGED elastic training paths;
  * :mod:`repro.controlplane.worker`    — the subprocess worker payload
    (heartbeat emitter + warm ``"ctl"``-checkpoint recovery by global
    worker id).

``src/repro/controlplane/README.md`` holds the full contract
(state-machine table, restart policy, event schema).
"""
from repro.controlplane.events import (Event, EventLog, read_events,
                                       tail_events)
from repro.controlplane.faults import Fault, FaultInjector, FaultPlan
from repro.controlplane.heartbeat import (ALIVE, DEAD, SUSPECT,
                                          HeartbeatMonitor)
from repro.controlplane.supervisor import (ProcWorkerPool, SimWorkerPool,
                                           SupervisedTimer, Supervisor,
                                           drill_report)

__all__ = [
    "Event", "EventLog", "read_events", "tail_events",
    "Fault", "FaultPlan", "FaultInjector",
    "ALIVE", "SUSPECT", "DEAD", "HeartbeatMonitor",
    "Supervisor", "SimWorkerPool", "ProcWorkerPool", "SupervisedTimer",
    "drill_report",
]
