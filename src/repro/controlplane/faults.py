"""Seeded, composable fault injection — every drill is reproducible.

A :class:`FaultPlan` is an explicit list of :class:`Fault` records (or a
seeded random "storm"); a :class:`FaultInjector` hands them out by tick
and tracks the stateful budgets (how many restart attempts a flaky
worker still fails).  The injector never touches the cluster itself —
the supervisor's worker pool applies ``crash``/``hang``/``slowdown``,
and ``corrupt_ckpt`` mutates bytes on disk — so the same plan drives
the thread-simulated pool, the subprocess pool, and the no-supervisor
baseline identically.

Fault kinds:

  ``crash``          the worker dies: no process, no heartbeats, and its
                     step never completes (runtime -> STALL) until a
                     restart lands;
  ``hang``           live process, no heartbeats, no progress — the
                     nasty one: the supervisor must KILL it before a
                     restart (a crashed process is already gone);
  ``slowdown``       runtimes multiplied by ``factor`` for ``duration``
                     ticks (heartbeats keep flowing — this is the
                     cutoff controller's job, not the supervisor's);
  ``flaky_restart``  the NEXT ``fails`` restart attempts of ``worker``
                     exit on arrival (drives backoff + the flap limit);
  ``corrupt_ckpt``   flip bytes in the latest checkpoint step's group
                     file (recovery must fall back one step).
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

FAULT_KINDS = ("crash", "hang", "slowdown", "flaky_restart",
               "corrupt_ckpt")


@dataclass(frozen=True)
class Fault:
    at: int                      # tick the fault fires
    kind: str
    worker: Optional[int] = None  # None only for corrupt_ckpt
    factor: float = 4.0          # slowdown multiplier
    duration: int = 20           # slowdown ticks
    fails: int = 1               # flaky_restart: failed attempts
    group: Optional[str] = None  # corrupt_ckpt: group file (None: any)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(want one of {FAULT_KINDS})")
        if self.worker is None and self.kind != "corrupt_ckpt":
            raise ValueError(f"{self.kind} fault needs a worker id")


@dataclass
class FaultPlan:
    faults: List[Fault] = field(default_factory=list)

    def __post_init__(self):
        self.faults = sorted(self.faults, key=lambda f: (f.at, f.kind,
                                                         -1 if f.worker is
                                                         None else f.worker))

    def at_tick(self, tick: int) -> List[Fault]:
        return [f for f in self.faults if f.at == tick]

    @property
    def horizon(self) -> int:
        return max((f.at for f in self.faults), default=0)

    @classmethod
    def storm(cls, n_workers: int, n_faults: int, horizon: int, *,
              seed: int = 0,
              kinds: Sequence[str] = ("crash", "hang", "slowdown"),
              min_gap: int = 3) -> "FaultPlan":
        """A seeded random fault storm: ``n_faults`` faults over
        ``horizon`` ticks, at most one per worker (a storm is about
        breadth; stacking two faults on one worker just shadows the
        first), spaced at least ``min_gap`` ticks apart so detection
        windows don't trivially collapse into one membership event."""
        rng = np.random.default_rng(seed)
        if n_faults > n_workers:
            raise ValueError(f"storm wants {n_faults} faults over only "
                             f"{n_workers} workers (one fault per worker)")
        workers = rng.choice(n_workers, size=n_faults, replace=False)
        lo = max(1, horizon - min_gap * n_faults)
        starts = np.sort(rng.integers(1, max(2, lo), size=n_faults))
        starts = starts + np.arange(n_faults) * min_gap
        faults = [
            Fault(at=int(t), kind=str(rng.choice(list(kinds))),
                  worker=int(w),
                  factor=float(rng.uniform(2.0, 6.0)),
                  duration=int(rng.integers(5, 25)))
            for t, w in zip(starts, workers)]
        return cls(faults)


class FaultInjector:
    """Stateful dispenser for one run of a plan.

    ``fire(tick)`` returns the faults due at ``tick`` (each exactly
    once) and arms the flaky-restart budgets; the worker pool asks
    ``restart_should_fail(wid)`` at each restart attempt, which burns
    one unit of budget per call.
    """

    def __init__(self, plan: FaultPlan, *, seed: int = 0):
        self.plan = plan
        self.rng = np.random.default_rng(seed)
        self._fired: set = set()
        self._flaky_budget: Dict[int, int] = {}

    def fire(self, tick: int) -> List[Fault]:
        due = []
        for f in self.plan.at_tick(tick):
            key = (f.at, f.kind, f.worker)
            if key in self._fired:
                continue
            self._fired.add(key)
            if f.kind == "flaky_restart":
                self._flaky_budget[f.worker] = (
                    self._flaky_budget.get(f.worker, 0) + f.fails)
            due.append(f)
        return due

    def restart_should_fail(self, wid: int) -> bool:
        left = self._flaky_budget.get(wid, 0)
        if left > 0:
            self._flaky_budget[wid] = left - 1
            return True
        return False

    # -- checkpoint corruption -----------------------------------------
    def corrupt_checkpoint(self, ckpt_dir: str,
                           group: Optional[str] = None) -> Optional[str]:
        """Flip bytes in the LATEST step's ``<group>.npz`` (seeded
        offsets).  Returns the corrupted path, or None if there is no
        checkpoint to corrupt.  The recovery contract under test: the
        restore path must detect the damage (checksums), name the bad
        group, and fall back to the previous step.
        """
        from repro.checkpoint import store
        step = store.latest_step(ckpt_dir)
        if step is None:
            return None
        d = os.path.join(ckpt_dir, f"step_{step:010d}")
        names = sorted(n for n in os.listdir(d) if n.endswith(".npz"))
        if group is not None:
            names = [n for n in names if n == f"{group}.npz"]
        if not names:
            return None
        path = os.path.join(d, names[int(self.rng.integers(len(names)))])
        size = os.path.getsize(path)
        # reprolint: disable=nonatomic-checkpoint-write -- deliberate corruption: this injector exists to flip bits in published checkpoints so recovery drills exercise the crc32 path
        with open(path, "r+b") as f:
            for _ in range(8):
                off = int(self.rng.integers(0, max(1, size)))
                f.seek(off)
                b = f.read(1)
                f.seek(off)
                f.write(bytes([b[0] ^ 0xFF]) if b else b"\x00")
        return path
