"""Decision-quality layer: score every cutoff decision against hindsight.

``QualityController`` rides the same delegation protocol as the
straggler-policy wrappers (``core.controller._PolicyWrapper``), so ANY
of the six frontier policies — dmm, sync, static, firstk, anytime,
stale — can be wrapped and reports the SAME record schema.  On the hot
path it only *buffers references*: the cutoff just decided, the realized
times row, and a lazy handle to the predictive sample cloud the inner
controller already drew (``predicted_samples`` — a device array the
wrapper never fetches).  All arithmetic happens at drain time
(:meth:`DecisionRecorder.flush`), where the sample clouds are
materialized in one batch alongside the Trainer's own metric drain.

Per-decision record (``decisions.jsonl``, kind ``decision``):

======================= ====================================================
``policy, step, n``     attribution
``c``                   the cutoff actually used (mask popcount)
``iter_time``           realized x_(c): the slowest included worker
``oracle_c``            hindsight-optimal cutoff (``order_stats.oracle_cutoff``)
``regret``              relative throughput regret vs the oracle, in [0, 1]
``idle_frac``           included workers' wait for x_(c), as a fraction of
                        the c * x_(c) worker-seconds the step paid for
``discard_frac``        1 - (sum of contributions) / n — what the straggler
                        policy threw away (0 under full sync; partial under
                        anytime, which contributes microbatch fractions)
``pred_iter``           E[x_(c)] under the predictive samples (None for
                        sample-less policies: sync / static / firstk)
``residual``            pred_iter - iter_time (None without samples)
``cov50, cov90``        realized x_(c) inside the empirical 50% / 90%
                        predictive interval of x_(c) (None without samples)
======================= ====================================================

Calibration then falls out as frequencies: a well-calibrated DMM has
cov50 ≈ 0.5 and cov90 ≈ 0.9 over a run (``report.calibration_report``).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.controller import _PolicyWrapper
from repro.core.cutoff import order_stats
from repro.obs.trace import ObsLog

_EPS = 1e-12


def score_decision(entry: dict) -> dict:
    """Score one buffered decision; ``entry["samples"]`` must already be
    host-resident (``DecisionRecorder.flush`` batches the fetch)."""
    times = np.asarray(entry["times"], np.float64)
    mask = np.asarray(entry["mask"], bool)
    n = int(times.shape[0])
    c_used = int(mask.sum())
    iter_time = float(times[mask].max())
    contrib_fn = entry.get("contrib_fn")
    if contrib_fn is not None:
        contrib = np.asarray(contrib_fn(times, c_used), np.float64)
    else:
        contrib = mask.astype(np.float64)
    idle = float(np.sum(iter_time - times[mask])
                 / max(c_used * iter_time, _EPS))
    discard = float(1.0 - contrib.sum() / n)
    c_star = order_stats.oracle_cutoff(times)
    tp = c_used / max(iter_time, _EPS)
    tp_star = c_star / max(order_stats.iter_time(times, c_star), _EPS)
    regret = float(max(0.0, (tp_star - tp) / max(tp_star, _EPS)))
    rec = {"policy": entry["policy"], "step": entry["step"], "n": n,
           "c": c_used, "iter_time": iter_time, "oracle_c": c_star,
           "regret": regret, "idle_frac": idle, "discard_frac": discard,
           "pred_iter": None, "residual": None, "cov50": None,
           "cov90": None}
    samples = entry.get("samples")
    if samples is not None:
        s = np.sort(np.asarray(samples, np.float64), axis=1)
        col = s[:, min(c_used, s.shape[1]) - 1]   # K draws of x_(c)
        lo50, hi50 = np.quantile(col, [0.25, 0.75])
        lo90, hi90 = np.quantile(col, [0.05, 0.95])
        rec["pred_iter"] = float(col.mean())
        rec["residual"] = float(col.mean() - iter_time)
        rec["cov50"] = bool(lo50 <= iter_time <= hi50)
        rec["cov90"] = bool(lo90 <= iter_time <= hi90)
    return rec


class DecisionRecorder:
    """Buffers decision entries on the hot path, scores them at drain.

    ``record`` appends a dict and returns — no device access, no numpy
    math.  ``flush`` materializes every pending sample cloud (the drain
    boundary's batched host fetch), scores, appends to ``records``, and
    streams each record to ``decisions.jsonl`` when a log is attached."""

    def __init__(self, log: Optional[ObsLog] = None):
        self._pending: List[dict] = []
        self.records: List[dict] = []
        self._log = log

    def record(self, entry: dict):
        self._pending.append(entry)

    def flush(self) -> List[dict]:
        batch, self._pending = self._pending, []
        fresh = []
        for entry in batch:
            s = entry.get("samples")
            if s is not None and not isinstance(s, np.ndarray):
                entry["samples"] = np.asarray(s)   # drain-boundary fetch
            rec = score_decision(entry)
            fresh.append(rec)
            if self._log is not None:
                self._log.emit(self._log.autotick(), "decision", **rec)
        self.records.extend(fresh)
        return fresh


class QualityController(_PolicyWrapper):
    """Observing wrapper: delegates every decision to ``inner`` and
    buffers (c, times, samples-handle) pairs for drain-time scoring.

    Transparency contract (pinned by the obs bit-exactness tests): the
    wrapped controller makes byte-identical decisions — the wrapper
    consumes no randomness, mutates no inner state, and reads the sample
    cloud through ``predicted_samples`` (a lazy peek).  Unknown
    attributes forward to ``inner``, so the Trainer's duck-typed policy
    probes (``contribution``, ``stale_decay``, ``mode``, ``_step``) see
    the wrapped policy unchanged."""

    def __init__(self, inner, recorder: DecisionRecorder,
                 policy: str = "policy"):
        super().__init__(inner)
        self._recorder = recorder
        self.policy = policy
        self._pending: Optional[dict] = None
        self._decisions = 0

    def __getattr__(self, name):
        if name == "inner":            # guard: not set yet during __init__
            raise AttributeError(name)
        return getattr(self.inner, name)

    @property
    def _step(self):
        return self.inner._step        # AttributeError when inner has none

    @_step.setter
    def _step(self, v):
        self.inner._step = v

    def predict_cutoff(self) -> int:
        c = self.inner.predict_cutoff()
        self._decisions += 1
        peek = getattr(self.inner, "predicted_samples", None)
        samples = peek() if peek is not None else None
        self._pending = {"step": self._decisions, "c": int(c),
                         "samples": samples}
        return c

    def observe(self, times, finished_mask=None):
        p, self._pending = self._pending, None
        if p is not None:
            t = np.array(times, np.float64, copy=True)
            mask = (np.ones(t.shape, bool) if finished_mask is None
                    else np.array(finished_mask, bool, copy=True))
            p.update(policy=self.policy, times=t, mask=mask,
                     contrib_fn=getattr(self.inner, "contribution", None))
            self._recorder.record(p)
        return self.inner.observe(times, finished_mask)
