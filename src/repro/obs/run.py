"""ObsRun: one run's telemetry — streams, registry, tracer, recorder.

An ``ObsRun`` is the single object drivers attach (``Trainer(obs=...)``,
``PSServer(obs=...)``, ``Supervisor(obs=...)``).  With ``dir=None``
everything stays in memory (benches read ``obs.steps.records``
directly); with a directory, four JSONL streams are written with the
``controlplane.events`` conventions:

  ``spans.jsonl``      tracer spans           (kind ``span``)
  ``steps.jsonl``      trainer step records   (kind ``step``)
  ``decisions.jsonl``  scored cutoff decisions (kind ``decision``)
  ``metrics.jsonl``    drained device collectors + run markers
                       (kinds ``metrics`` / ``run``)

``drain`` is the only point that touches the device (see
``obs/metrics.py``); drivers call it where they already batch-fetch —
the Trainer's ``metrics_every`` boundary — and ``close`` drains one
final time and ends the streams.
"""
from __future__ import annotations

import os
from typing import List, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry
from repro.obs.quality import DecisionRecorder, QualityController
from repro.obs.trace import ObsLog, Tracer


class StepStream:
    """The run's step trajectory: ONE recorder shared by every consumer.

    The Trainer forwards each history record here as it drains (loss
    already host-resident), so benches and launch drivers read
    `(clock, loss)` trajectories from ``obs.steps`` instead of
    re-threading their own lists — ``launch.train.clock_to_loss``
    accepts this object directly via its ``records`` attribute."""

    def __init__(self, log: Optional[ObsLog] = None):
        self.records: List[dict] = []
        self._log = log

    def on_step(self, rec: dict, job: Optional[str] = None):
        self.records.append(rec)
        if self._log is not None:
            data = {k: rec[k] for k in
                    ("step", "clock", "c", "n", "iter_time", "loss")
                    if k in rec}
            if job is not None:
                data["job"] = job
            self._log.emit(self._log.autotick(), "step", **data)

    def __len__(self) -> int:
        return len(self.records)

    def losses(self) -> list:
        return [r["loss"] for r in self.records]

    def final_loss(self, window: int = 3) -> float:
        """Mean loss over the last ``window`` steps (the bench target)."""
        if not self.records:
            raise ValueError("step stream is empty")
        return float(np.mean([r["loss"] for r in self.records[-window:]]))

    def total_clock(self) -> float:
        if not self.records:
            raise ValueError("step stream is empty")
        return float(self.records[-1]["clock"])


class ObsRun:
    """Everything one run records; see the module docstring."""

    def __init__(self, dir: Optional[str] = None):
        self.dir = dir
        if dir is not None:
            os.makedirs(dir, exist_ok=True)

        def _log(fname: str) -> ObsLog:
            return ObsLog(os.path.join(dir, fname) if dir else None)

        self._span_log = _log("spans.jsonl")
        self._step_log = _log("steps.jsonl")
        self._dec_log = _log("decisions.jsonl")
        self._meta_log = _log("metrics.jsonl")
        self.trace = Tracer(log=self._span_log)
        self.steps = StepStream(log=self._step_log)
        self.metrics = MetricsRegistry()
        self.decisions = DecisionRecorder(log=self._dec_log)
        self._closed = False
        self._meta_log.emit(self._meta_log.autotick(), "run", phase="start")

    def wrap(self, controller, policy: str = "policy") -> QualityController:
        """Wrap any controller for decision-quality scoring; the wrapped
        controller's decisions are bit-identical to the bare one's."""
        return QualityController(controller, self.decisions, policy)

    def drain(self):
        """Score pending decisions and fetch fresh device collectors —
        the run's ONLY device reads.  Call at metrics boundaries."""
        self.decisions.flush()
        for payload in self.metrics.drain():
            self._meta_log.emit(self._meta_log.autotick(), "metrics",
                                **payload)

    def close(self):
        if self._closed:
            return
        self._closed = True
        self.drain()
        self._meta_log.emit(self._meta_log.autotick(), "run", phase="end",
                            summary=self.metrics.summary())
        for log in (self._span_log, self._step_log, self._dec_log,
                    self._meta_log):
            log.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
