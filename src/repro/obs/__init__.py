"""repro.obs — the zero-sync telemetry spine.

Device-side metric rings, host-edge span tracing, and decision-quality
scoring for every cutoff policy; see ``src/repro/obs/README.md`` for
the contracts (ring drain rules, span schema, calibration definitions).
"""
from repro.obs.metrics import (Counter, Gauge, LabelSet, MetricHistogram,
                               MetricRing, MetricsRegistry, Series)
from repro.obs.quality import (DecisionRecorder, QualityController,
                               score_decision)
from repro.obs.run import ObsRun, StepStream
from repro.obs.trace import OBS_KINDS, ObsLog, Tracer, chrome_trace

__all__ = [
    "Counter", "Gauge", "LabelSet", "MetricHistogram", "MetricRing",
    "MetricsRegistry", "Series", "DecisionRecorder", "QualityController",
    "score_decision", "ObsRun", "StepStream", "OBS_KINDS", "ObsLog",
    "Tracer", "chrome_trace",
]
