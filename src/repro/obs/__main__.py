"""CLI: render a recorded run's timeline + calibration report.

  PYTHONPATH=src python -m repro.obs OBS_DIR [--chrome trace.json]

Reads only the JSONL artifacts an ``--obs-dir`` run wrote; ``--chrome``
additionally exports the span stream as Chrome-trace/Perfetto JSON
(open in ``chrome://tracing`` or https://ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import report as R


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    ap.add_argument("obs_dir", help="directory an --obs-dir run wrote")
    ap.add_argument("--chrome", metavar="OUT.json", default=None,
                    help="also export spans as a Chrome-trace JSON file")
    args = ap.parse_args(argv)

    run = R.load_run(args.obs_dir)
    if not any(run.values()):
        print(f"no obs streams found under {args.obs_dir}",
              file=sys.stderr)
        return 1
    print(R.render(run))
    if args.chrome:
        doc = R.run_chrome_trace(run)
        with open(args.chrome, "w") as f:
            json.dump(doc, f)
        print(f"\nchrome trace -> {args.chrome} "
              f"({len(doc['traceEvents'])} events)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
