"""Render a recorded run: timeline + calibration report from artifacts.

Everything here reads the JSONL streams an :class:`~repro.obs.ObsRun`
wrote — no live objects, no device — via the torn-tail-tolerant
``controlplane.events.read_events`` reader, so a crashed run's artifacts
still render.  ``python -m repro.obs <dir>`` is the CLI front.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

import numpy as np

from repro.controlplane.events import Event, read_events
from repro.obs.trace import chrome_trace

STREAMS = ("spans", "steps", "decisions", "metrics")


def load_run(dir: str) -> Dict[str, List[Event]]:
    """Read every stream present under ``dir`` (absent files -> [])."""
    out: Dict[str, List[Event]] = {}
    for stream in STREAMS:
        path = os.path.join(dir, f"{stream}.jsonl")
        out[stream] = read_events(path) if os.path.exists(path) else []
    return out


def _records(events_or_dicts) -> List[dict]:
    return [e.data if isinstance(e, Event) else e for e in events_or_dicts]


def calibration_report(decisions) -> Dict[str, dict]:
    """Per-policy decision-quality aggregates from ``decision`` records.

    Coverage rates are frequencies of the per-step booleans — a
    calibrated predictive distribution shows ``coverage50`` ≈ 0.5 and
    ``coverage90`` ≈ 0.9; policies without samples (sync/static/firstk)
    report ``None`` there but still report regret/idle/discard, which is
    the frontier comparison the CLI renders."""
    by_policy: Dict[str, List[dict]] = {}
    for r in _records(decisions):
        by_policy.setdefault(r["policy"], []).append(r)
    out: Dict[str, dict] = {}
    for policy, recs in sorted(by_policy.items()):
        scored = [r for r in recs if r.get("cov50") is not None]
        mean = lambda key, rs: (float(np.mean([r[key] for r in rs]))
                                if rs else None)
        frac = lambda key: (float(np.mean([bool(r[key]) for r in scored]))
                            if scored else None)
        out[policy] = {
            "decisions": len(recs),
            "scored": len(scored),
            "mean_regret": mean("regret", recs),
            "mean_idle_frac": mean("idle_frac", recs),
            "mean_discard_frac": mean("discard_frac", recs),
            "mean_abs_residual": (float(np.mean(
                [abs(r["residual"]) for r in scored])) if scored else None),
            "coverage50": frac("cov50"),
            "coverage90": frac("cov90"),
        }
    return out


def timeline_summary(spans) -> List[dict]:
    """Aggregate span records per (track, name): count, total/mean µs."""
    agg: Dict[tuple, dict] = {}
    for s in _records(spans):
        key = (s.get("track", "main"), s["name"])
        a = agg.setdefault(key, {"track": key[0], "name": key[1],
                                 "count": 0, "total_us": 0.0,
                                 "depth": s.get("depth", 1)})
        a["count"] += 1
        a["total_us"] += float(s["dur_us"])
    rows = sorted(agg.values(), key=lambda a: (a["track"], -a["total_us"]))
    for a in rows:
        a["mean_us"] = a["total_us"] / a["count"]
    return rows


def run_chrome_trace(run: Dict[str, List[Event]]) -> dict:
    return chrome_trace(_records(run["spans"]))


def _fmt(v, pat="{:.3f}") -> str:
    return "-" if v is None else pat.format(v)


def render(run: Dict[str, List[Event]]) -> str:
    """The CLI's text report: where the time went, then how well the
    decisions were made."""
    lines: List[str] = []
    steps = _records(run["steps"])
    lines.append(f"== run: {len(steps)} step records, "
                 f"{len(run['spans'])} spans, "
                 f"{len(run['decisions'])} decisions ==")
    if steps:
        first, last = steps[0], steps[-1]
        lines.append(f"   loss {first['loss']:.4f} -> {last['loss']:.4f} "
                     f"over {last['clock']:.1f}s simulated clock")

    rows = timeline_summary(run["spans"])
    if rows:
        lines.append("\n-- timeline (per span, by total time) --")
        lines.append(f"{'track':<12} {'span':<28} {'count':>6} "
                     f"{'total ms':>10} {'mean us':>10}")
        for a in rows:
            pad = "  " * (max(int(a["depth"]), 1) - 1)
            lines.append(f"{a['track']:<12} {pad + a['name']:<28} "
                         f"{a['count']:>6} {a['total_us'] / 1e3:>10.2f} "
                         f"{a['mean_us']:>10.1f}")

    cal = calibration_report(run["decisions"])
    if cal:
        lines.append("\n-- decision quality (per policy) --")
        lines.append(f"{'policy':<10} {'steps':>6} {'regret':>8} "
                     f"{'idle':>7} {'discard':>8} {'|resid|':>8} "
                     f"{'cov50':>6} {'cov90':>6}")
        for policy, r in cal.items():
            lines.append(
                f"{policy:<10} {r['decisions']:>6} "
                f"{_fmt(r['mean_regret']):>8} "
                f"{_fmt(r['mean_idle_frac']):>7} "
                f"{_fmt(r['mean_discard_frac']):>8} "
                f"{_fmt(r['mean_abs_residual']):>8} "
                f"{_fmt(r['coverage50'], '{:.2f}'):>6} "
                f"{_fmt(r['coverage90'], '{:.2f}'):>6}")
        lines.append("(calibrated predictive quantiles: cov50 ~ 0.50, "
                     "cov90 ~ 0.90)")

    mets = [e for e in run["metrics"] if e.kind == "metrics"]
    if mets:
        lines.append("\n-- drained device collectors --")
        for e in mets:
            d = e.data
            if d.get("collector") == "ring":
                lines.append(f"ring {d['name']}: {len(d['rows'])} rows "
                             f"({d['pushed']} pushed, "
                             f"{d['dropped']} dropped)")
            else:
                lines.append(f"histogram {d['name']}: "
                             f"{sum(d['counts']):.0f} samples")
    return "\n".join(lines)
