"""Metrics registry: device-resident rings that never sync the hot path.

Two halves, split by WHERE the value lives:

* **Device collectors** (:class:`MetricRing`, :class:`MetricHistogram`)
  accumulate in-jit.  ``push``/``add`` dispatch ONE donated jit that
  scatter-writes into a fixed f32 buffer — the same pattern as the
  controller's lag-window ring (``core.controller._ring_append``): the
  value being recorded may be a lazy device scalar straight out of
  ``train_step`` and it is never materialized on the host.  The buffers
  come back only at :meth:`MetricsRegistry.drain` — the ``metrics_every``
  boundary where the Trainer already batch-fetches its loss scalars.
* **Host collectors** (:class:`Counter`, :class:`Gauge`, :class:`Series`,
  :class:`LabelSet`) are plain-python bookkeeping (``+=`` on ints) and
  are therefore safe inside reprolint hot roots (``Supervisor.tick``,
  ``PSServer.flush``): they can never introduce a device sync because
  they never touch a device value.

Ring drain contract (pinned by ``tests/test_obs.py``):

* rows come back OLDEST-FIRST, exactly the rows pushed since the last
  drain;
* a ring that overflowed between drains drops the OLDEST rows — the ring
  keeps the most recent ``cap`` — and the drain payload counts what it
  dropped (``dropped``), so truncation is never silent;
* ``drain`` is the only operation that reads a device buffer.  ``push``
  is fire-and-forget and a counter of pushes is kept on the host, which
  is how ``dropped`` is computed without a sync.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _ring_push(ring, head, vals):
    """ONE dispatch per recorded row: stack the (possibly lazy device)
    scalars in-jit and scatter-write them at the ring head.  ``ring`` and
    ``head`` are donated — pushing re-uses the buffer it replaces, and the
    jaxpr audit (``ANALYSIS.json`` entry ``obs_ring_push``) pins that the
    lowering stays transfer-free with the aliasing effective."""
    row = jnp.stack([jnp.asarray(v, jnp.float32) for v in vals])
    return ring.at[head].set(row), (head + 1) % ring.shape[0]


@functools.partial(jax.jit, donate_argnums=(0,))
def _hist_add(counts, edges, x):
    """Scatter-add one sample into a fixed-edge histogram, in-jit."""
    i = jnp.searchsorted(edges, jnp.asarray(x, jnp.float32))
    return counts.at[i].add(1.0)


class MetricRing:
    """A (cap, k) f32 device ring of metric rows; see the module
    docstring for the drain contract."""

    def __init__(self, name: str, columns: Sequence[str], cap: int = 256):
        if cap < 1:
            raise ValueError(f"ring cap must be >= 1, got {cap}")
        self.name = name
        self.columns = tuple(columns)
        self.cap = int(cap)
        self._ring = jnp.zeros((self.cap, len(self.columns)), jnp.float32)
        self._head = jnp.zeros((), jnp.int32)
        self._pushed = 0          # host-side, so drain never syncs to count
        self._drained = 0

    def push(self, vals):
        """Record one row (tuple matching ``columns``).  Values may be
        lazy device scalars; nothing is fetched."""
        if len(vals) != len(self.columns):
            raise ValueError(f"ring {self.name!r} wants "
                             f"{len(self.columns)} values, got {len(vals)}")
        self._ring, self._head = _ring_push(self._ring, self._head,
                                            tuple(vals))
        self._pushed += 1

    @property
    def pushed(self) -> int:
        return self._pushed

    def drain(self) -> Optional[dict]:
        """Fetch the rows pushed since the last drain (oldest first).

        Returns ``None`` when nothing was pushed.  Overflow drops the
        oldest rows and reports how many (``dropped``)."""
        fresh = self._pushed - self._drained
        if fresh == 0:
            return None
        dropped = max(0, fresh - self.cap)
        take = fresh - dropped
        w = np.asarray(self._ring)
        head = int(np.asarray(self._head))
        rows = np.roll(w, -head, axis=0)[self.cap - take:]
        self._drained = self._pushed
        return {"name": self.name, "columns": list(self.columns),
                "rows": rows.tolist(), "pushed": self._pushed,
                "dropped": dropped}


class MetricHistogram:
    """Fixed-edge f32 histogram accumulated on device by scatter-add."""

    def __init__(self, name: str, edges: Sequence[float]):
        self.name = name
        self._edges = jnp.asarray(np.asarray(edges, np.float32))
        self._counts = jnp.zeros(len(edges) + 1, jnp.float32)
        self._added = 0
        self._drained = 0

    def add(self, x):
        self._counts = _hist_add(self._counts, self._edges, x)
        self._added += 1

    def drain(self) -> Optional[dict]:
        if self._added == self._drained:
            return None
        self._drained = self._added
        return {"name": self.name,
                "edges": np.asarray(self._edges).tolist(),
                "counts": np.asarray(self._counts).tolist(),
                "added": self._added}


class Counter:
    """Host-side monotone counter (safe in lint hot roots)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, by: int = 1):
        self.value += by


class Gauge:
    """Host-side last-value gauge."""

    def __init__(self, name: str):
        self.name = name
        self.value = None

    def set(self, v):
        self.value = v


class Series:
    """Host-side value list with summary stats.

    Values are stored as given (ints stay ints), so aggregates like
    ``max`` round-trip bit-identically through JSON — the property
    ``Supervisor.drill_report`` relies on to keep
    ``BENCH_controlplane.json`` stable."""

    def __init__(self, name: str):
        self.name = name
        self.values: list = []

    def observe(self, v):
        self.values.append(v)

    @property
    def count(self) -> int:
        return len(self.values)

    def max(self):
        return max(self.values) if self.values else None

    def mean(self):
        return sum(self.values) / len(self.values) if self.values else None


class LabelSet:
    """Host-side set of labels (e.g. evicted worker ids)."""

    def __init__(self, name: str):
        self.name = name
        self._seen: set = set()

    def add(self, label):
        self._seen.add(label)

    def values(self) -> list:
        return sorted(self._seen)


class MetricsRegistry:
    """Get-or-create registry over every collector kind.

    One registry per :class:`~repro.obs.ObsRun`; the run drains the
    device collectors at ``metrics_every`` boundaries and serializes the
    payloads to the ``metrics.jsonl`` stream."""

    def __init__(self):
        self._rings: Dict[str, MetricRing] = {}
        self._hists: Dict[str, MetricHistogram] = {}
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._series: Dict[str, Series] = {}
        self._labels: Dict[str, LabelSet] = {}

    def ring(self, name: str, columns: Sequence[str],
             cap: int = 256) -> MetricRing:
        r = self._rings.get(name)
        if r is None:
            r = self._rings[name] = MetricRing(name, columns, cap)
        elif r.columns != tuple(columns):
            raise ValueError(f"ring {name!r} re-registered with different "
                             f"columns {tuple(columns)} != {r.columns}")
        return r

    def histogram(self, name: str,
                  edges: Sequence[float]) -> MetricHistogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = MetricHistogram(name, edges)
        return h

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def series(self, name: str) -> Series:
        s = self._series.get(name)
        if s is None:
            s = self._series[name] = Series(name)
        return s

    def labels(self, name: str) -> LabelSet:
        l = self._labels.get(name)
        if l is None:
            l = self._labels[name] = LabelSet(name)
        return l

    def drain(self) -> List[dict]:
        """Fetch every device collector with fresh data (the ONLY reader
        of device buffers — call at metrics boundaries, never per step)."""
        out = []
        for r in self._rings.values():
            p = r.drain()
            if p is not None:
                out.append(dict(p, collector="ring"))
        for h in self._hists.values():
            p = h.drain()
            if p is not None:
                out.append(dict(p, collector="histogram"))
        return out

    def summary(self) -> dict:
        """Host-only snapshot (no device fetch): counters, gauges, series
        stats, label sets, and per-ring push/drain accounting."""
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "gauges": {n: g.value for n, g in self._gauges.items()},
            "series": {n: {"count": s.count, "max": s.max(),
                           "mean": s.mean()}
                       for n, s in self._series.items()},
            "labels": {n: l.values() for n, l in self._labels.items()},
            "rings": {n: {"pushed": r.pushed, "cap": r.cap}
                      for n, r in self._rings.items()},
        }
