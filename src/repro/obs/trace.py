"""Span tracer + obs event streams (the telemetry wire format).

``ObsLog`` subclasses ``controlplane.events.EventLog`` — same
append-only JSONL lines, same strictly-monotone ``seq``, same
torn-tail-tolerant reader (``controlplane.events.read_events``) — with
its own kind vocabulary (``OBS_KINDS``, walked by the
``event-kind-drift`` lint rule alongside ``EVENT_KINDS``).  The one
semantic difference: obs streams are written by several components whose
logical clocks interleave (three trainers behind one PS, a supervisor
beside a trainer), so the event ``tick`` is a per-stream monotone record
index (``ObsLog.autotick``) and the COMPONENT clock (SGD step, PS tick,
job id) travels in the payload.

Spans are host-edge timestamps only: ``time.perf_counter()`` at enter
and exit, nothing else — a span around a jit dispatch measures dispatch
(the async-dispatch cost model the repo optimizes for), never inserts a
``block_until_ready``.  Nesting is lexical (a context manager), depth is
recorded, and :func:`chrome_trace` renders the stream as Chrome
``chrome://tracing`` / Perfetto "X" (complete) events with one thread
row per ``track``.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import List, Optional

from repro.controlplane.events import EventLog

OBS_KINDS = (
    "run",        # run-level marker: start / end + registry summary
    "span",       # one completed tracer span (host perf_counter edges)
    "step",       # one trainer step record (the obs step stream)
    "decision",   # one scored cutoff decision (quality layer)
    "metrics",    # one drained device collector payload
)


class ObsLog(EventLog):
    """An ``EventLog`` speaking the obs vocabulary.

    ``autotick`` hands out the per-stream monotone tick; callers pass it
    straight to ``emit`` so the inherited monotonicity check holds by
    construction while component clocks ride in the payload."""

    KINDS = OBS_KINDS

    def __init__(self, path: Optional[str] = None, *, clock=time.time):
        super().__init__(path, clock=clock)
        self._auto = 0

    def autotick(self) -> int:
        t = self._auto
        self._auto += 1
        return t


class Tracer:
    """Nested spans with tick/step/job attribution.

    ``span`` is a context manager; enter/exit take ``perf_counter``
    stamps on the host and the completed span (name, offset ``ts_us``
    from tracer start, ``dur_us``, nesting ``depth``, a ``track`` for
    timeline rows, plus any attribution kwargs under a nested ``attrs``
    dict — nested so component clocks named ``tick``/``step`` can never
    collide with the EventLog wire fields) lands in ``self.spans`` and —
    when a log is attached — on the ``spans.jsonl`` stream.
    """

    def __init__(self, log: Optional[ObsLog] = None):
        self._t0 = time.perf_counter()
        self._depth = 0
        self._log = log
        self.spans: List[dict] = []

    @contextmanager
    def span(self, name: str, *, track: str = "main", **attrs):
        self._depth += 1
        depth = self._depth
        t0 = time.perf_counter()
        try:
            yield
        finally:
            t1 = time.perf_counter()
            self._depth -= 1
            rec = {"name": name, "track": track,
                   "ts_us": (t0 - self._t0) * 1e6,
                   "dur_us": (t1 - t0) * 1e6, "depth": depth,
                   "attrs": attrs}
            self.spans.append(rec)
            if self._log is not None:
                self._log.emit(self._log.autotick(), "span", **rec)


def chrome_trace(spans) -> dict:
    """Render span records (dicts or ``Event.data`` payloads) as a
    Chrome-trace / Perfetto JSON document.

    Every span becomes a ``ph: "X"`` complete event; tracks map to
    thread rows (with ``thread_name`` metadata) so the viewer nests
    spans by time containment per track — the tick→dispatch→drain
    waterfall."""
    tracks: dict = {}
    events = []
    for s in spans:
        track = s.get("track", "main")
        tid = tracks.setdefault(track, len(tracks))
        args = dict(s.get("attrs") or {}, depth=s.get("depth", 1))
        events.append({"name": s["name"], "ph": "X", "pid": 0, "tid": tid,
                       "ts": s["ts_us"], "dur": s["dur_us"], "args": args})
    meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": tid,
             "args": {"name": track}} for track, tid in tracks.items()]
    # stable render: metadata first, then spans in start order
    events.sort(key=lambda e: (e["ts"], -e["dur"]))
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}
