"""Fault-tolerant checkpointing.

Properties needed at 1000+ node scale, implemented here:
  * atomic publish — write to ``<dir>/tmp.<step>`` then ``os.rename``; a
    crash mid-save can never corrupt the latest checkpoint;
  * keep-N retention;
  * mesh-shape-agnostic — arrays are saved in LOGICAL (unsharded) form; on
    restore they are device_put with whatever shardings the (possibly
    resized) mesh prescribes → elastic restart;
  * async save — serialization happens on a worker thread off the train loop;
  * full training state — params, optimizer state, step/clock meta, and the
    cutoff controller's lag window + worker membership (the Trainer writes
    them as the flat ``"ctl"`` group; ``restore_group`` reads it back so
    straggler prediction resumes warm across restarts and elastic resizes —
    data pipelines are seeded by step and carry no mutable state).

Format: a directory per step holding one .npz per top-level group plus a
msgpack manifest of the pytree structure.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         keep: int = 3) -> str:
    """Synchronous atomic save.  state: dict of pytrees / plain values.

    Re-saving an EXISTING step (a warm-restarted run re-checkpoints the
    step it restored at) must stay atomic too: the old dir is first
    renamed aside to ``stale.<step>`` and only removed after the new dir
    is published, so there is no instant at which ``step_<step>`` is
    missing or partial — a crash anywhere leaves either the old or the
    new checkpoint fully in place.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    stale = os.path.join(ckpt_dir, f"stale.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    for leftover in (tmp, stale):    # debris from an earlier crash
        if os.path.exists(leftover):
            shutil.rmtree(leftover)

    os.makedirs(tmp)
    manifest = {"step": step, "groups": {}}
    for name, tree in state.items():
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, f"{name}.npz"), **flat)
        manifest["groups"][name] = {
            "treedef": str(_treedef_of(tree)),
            "keys": sorted(flat.keys()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        os.rename(final, stale)  # atomic: old stays restorable until...
    os.rename(tmp, final)        # ...the new one is published
    if os.path.exists(stale):
        shutil.rmtree(stale)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore_group(ckpt_dir: str, name: str,
                  step: Optional[int] = None
                  ) -> Optional[Dict[str, np.ndarray]]:
    """Load one flat group, or None when the group (or step) is absent.

    Groups saved as flat dicts of arrays round-trip here without an
    example tree.  The Trainer's controller window/membership group
    (``"ctl"``) uses this: checkpoints written before the group existed
    simply lack the file, and restore degrades to a cold controller.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    path = os.path.join(ckpt_dir, f"step_{step:010d}", f"{name}.npz")
    if not os.path.exists(path):
        return None
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def restore(ckpt_dir: str, example_state: Dict[str, Any],
            step: Optional[int] = None,
            shardings: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Restore into the structure of ``example_state``.

    shardings: optional dict name -> pytree of NamedShardings (matching the
    possibly-resized mesh) — arrays are device_put accordingly (elastic
    restart path).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    out = {}
    for name, tree in example_state.items():
        with np.load(os.path.join(d, f"{name}.npz")) as z:
            flat = {k: z[k] for k in z.files}
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        new_leaves = []
        for path, leaf in leaves_with_paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            new_leaves.append(arr)
        restored = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings and name in shardings and shardings[name] is not None:
            restored = jax.device_put(restored, shardings[name])
        out[name] = restored
    return out


class AsyncCheckpointer:
    """Off-thread saver: ``save()`` returns immediately; ``wait()`` joins."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state: Dict[str, Any]):
        self.wait()
        # materialize on host before handing to the thread
        state_np = {k: jax.tree.map(np.asarray, v) for k, v in state.items()}
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, state_np, self.keep),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
