"""Fault-tolerant checkpointing.

Properties needed at 1000+ node scale, implemented here:
  * atomic publish — write to ``<dir>/tmp.<step>`` then ``os.rename``; a
    crash mid-save can never corrupt the latest checkpoint;
  * keep-N retention;
  * mesh-shape-agnostic — arrays are saved in LOGICAL (unsharded) form; on
    restore they are device_put with whatever shardings the (possibly
    resized) mesh prescribes → elastic restart;
  * async save — serialization happens on a worker thread off the train loop;
  * full training state — params, optimizer state, step/clock meta, and the
    cutoff controller's lag window + worker membership (the Trainer writes
    them as the flat ``"ctl"`` group; ``restore_group`` reads it back so
    straggler prediction resumes warm across restarts and elastic resizes —
    data pipelines are seeded by step and carry no mutable state).

Format: a directory per step holding one .npz per top-level group plus a
msgpack manifest of the pytree structure.

Crash-window recovery: the publish sequence for re-saving an existing
step is ``rename(final, stale)`` then ``rename(tmp, final)`` then
``rmtree(stale)``.  A crash between the two renames leaves NO
``step_<step>`` dir — only a complete ``tmp.<step>`` and the old
``stale.<step>``.  :func:`recover` (run on every open: ``save`` /
``latest_step`` / ``restore`` / ``restore_group``) repairs every such
window: a COMPLETE tmp (manifest present) is promoted to final, else the
stale dir is renamed back; debris is only deleted once a final dir for
that step exists.  Single writer assumed (the ``AsyncCheckpointer``
serializes saves; recovery runs on open, before any writer).

Integrity: the manifest records a CRC-32 per group file.  ``restore`` /
``restore_group`` verify before deserializing and raise
:class:`CheckpointError` naming the bad group; ``latest_valid_step``
walks steps newest-first to the first fully-verifying one, which is how
supervisor recovery falls back past a corrupted latest step.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint failed validation (corrupt, truncated, or missing)."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def _crc32_of(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc
            crc = zlib.crc32(chunk, crc)


def _tmp_complete(tmp: str) -> bool:
    """A tmp dir is complete iff its manifest exists — the manifest is
    written LAST, so its presence certifies every group file landed."""
    return os.path.exists(os.path.join(tmp, "manifest.json"))


def recover(ckpt_dir: str):
    """Repair the publish crash windows; idempotent, run on every open.

    For each step with leftover ``tmp.<step>`` / ``stale.<step>`` dirs:

      * ``step_<step>`` exists -> the publish completed; tmp/stale are
        debris from before/after the renames — delete them;
      * no final, COMPLETE tmp -> the crash hit between
        ``rename(final, stale)`` and ``rename(tmp, final)`` (or just
        before the first rename on a fresh step): finish the publish —
        promote tmp to final, then drop the stale copy;
      * no final, incomplete tmp, stale present -> the save died
        mid-write after parking the old dir: put the old checkpoint
        back (``rename(stale, final)``) and drop the partial tmp;
      * incomplete tmp alone -> a fresh-step save died mid-write; the
        previous step is still the latest — just drop the partial tmp.

    Without this, the NEXT save of the same step would delete both dirs
    as debris and the step (sometimes the only copy) would be lost.
    """
    if not os.path.isdir(ckpt_dir):
        return
    steps = set()
    for d in os.listdir(ckpt_dir):
        if d.startswith("tmp.") or d.startswith("stale."):
            steps.add(int(d.split(".", 1)[1]))
    for step in sorted(steps):
        tmp = os.path.join(ckpt_dir, f"tmp.{step}")
        stale = os.path.join(ckpt_dir, f"stale.{step}")
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if not os.path.exists(final):
            if _tmp_complete(tmp):
                os.rename(tmp, final)
            elif os.path.exists(stale):
                os.rename(stale, final)
        for leftover in (tmp, stale):
            if os.path.exists(leftover):
                shutil.rmtree(leftover)


def save(ckpt_dir: str, step: int, state: Dict[str, Any],
         keep: int = 3) -> str:
    """Synchronous atomic save.  state: dict of pytrees / plain values.

    Re-saving an EXISTING step (a warm-restarted run re-checkpoints the
    step it restored at) must stay atomic too: the old dir is first
    renamed aside to ``stale.<step>`` and only removed after the new dir
    is published, so there is no instant at which ``step_<step>`` is
    missing or partial — a crash anywhere leaves either the old or the
    new checkpoint fully in place (:func:`recover` finishes interrupted
    publishes before this save touches anything).
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    recover(ckpt_dir)            # promote, don't delete, crashed publishes
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    stale = os.path.join(ckpt_dir, f"stale.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")

    os.makedirs(tmp)
    manifest = {"step": step, "groups": {}}
    for name, tree in state.items():
        flat = _flatten(tree)
        path = os.path.join(tmp, f"{name}.npz")
        np.savez(path, **flat)
        manifest["groups"][name] = {
            "treedef": str(_treedef_of(tree)),
            "keys": sorted(flat.keys()),
            "crc32": _crc32_of(path),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        os.rename(final, stale)  # atomic: old stays restorable until...
    os.rename(tmp, final)        # ...the new one is published
    if os.path.exists(stale):
        shutil.rmtree(stale)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    """All published steps, ascending (after crash-window recovery)."""
    if not os.path.isdir(ckpt_dir):
        return []
    recover(ckpt_dir)
    return sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                  if d.startswith("step_"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def _read_manifest(ckpt_dir: str, step: int) -> dict:
    path = os.path.join(ckpt_dir, f"step_{step:010d}", "manifest.json")
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise CheckpointError(
            f"checkpoint step {step} in {ckpt_dir} has no manifest "
            f"(truncated save?)") from None
    except json.JSONDecodeError as e:
        raise CheckpointError(
            f"checkpoint step {step} in {ckpt_dir}: manifest is not valid "
            f"JSON ({e})") from None


def _verify_group(ckpt_dir: str, step: int, name: str, manifest: dict):
    """Checksum one group file against the manifest; raises
    :class:`CheckpointError` NAMING the bad group on any mismatch.
    Manifests from before checksums existed (no ``crc32`` field) pass."""
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    path = os.path.join(d, f"{name}.npz")
    if not os.path.exists(path):
        raise CheckpointError(
            f"checkpoint step {step} group {name!r}: file missing "
            f"({path})")
    want = manifest.get("groups", {}).get(name, {}).get("crc32")
    if want is None:
        return
    got = _crc32_of(path)
    if got != want:
        raise CheckpointError(
            f"checkpoint step {step} group {name!r} is corrupt: "
            f"crc32 {got:#010x} != manifest {want:#010x} ({path})")


def verify_step(ckpt_dir: str, step: int):
    """Validate every group of one step; raises CheckpointError."""
    manifest = _read_manifest(ckpt_dir, step)
    for name in sorted(manifest.get("groups", {})):
        _verify_group(ckpt_dir, step, name, manifest)


def latest_valid_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose every group verifies — the recovery anchor.

    Walks newest-first past corrupt/truncated steps, so a supervisor
    warm-restarting after a torn or bit-flipped latest checkpoint lands
    on the most recent GOOD one instead of dying."""
    for step in reversed(list_steps(ckpt_dir)):
        try:
            verify_step(ckpt_dir, step)
            return step
        except CheckpointError:
            continue
    return None


def restore_group(ckpt_dir: str, name: str,
                  step: Optional[int] = None
                  ) -> Optional[Dict[str, np.ndarray]]:
    """Load one flat group, or None when the group (or step) is absent.

    Groups saved as flat dicts of arrays round-trip here without an
    example tree.  The Trainer's controller window/membership group
    (``"ctl"``) uses this: checkpoints written before the group existed
    simply lack the file, and restore degrades to a cold controller.
    Present-but-corrupt groups raise :class:`CheckpointError` instead of
    silently seeding the controller with garbage.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    path = os.path.join(ckpt_dir, f"step_{step:010d}", f"{name}.npz")
    if not os.path.exists(path):
        return None
    _verify_group(ckpt_dir, step, name, _read_manifest(ckpt_dir, step))
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def restore(ckpt_dir: str, example_state: Dict[str, Any],
            step: Optional[int] = None,
            shardings: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Restore into the structure of ``example_state``.

    shardings: optional dict name -> pytree of NamedShardings (matching the
    possibly-resized mesh) — arrays are device_put accordingly (elastic
    restart path).
    """
    recover(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    manifest = _read_manifest(ckpt_dir, step)
    out = {}
    for name, tree in example_state.items():
        _verify_group(ckpt_dir, step, name, manifest)
        try:
            with np.load(os.path.join(d, f"{name}.npz")) as z:
                flat = {k: z[k] for k in z.files}
        except Exception as e:
            raise CheckpointError(
                f"checkpoint step {step} group {name!r} failed to "
                f"deserialize: {e}") from e
        leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
        new_leaves = []
        for path, leaf in leaves_with_paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = flat[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            new_leaves.append(arr)
        restored = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings and name in shardings and shardings[name] is not None:
            restored = jax.device_put(restored, shardings[name])
        out[name] = restored
    return out


class AsyncCheckpointer:
    """Off-thread saver: ``save()`` returns immediately; ``wait()`` joins."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, state: Dict[str, Any]):
        self.wait()
        # materialize on host before handing to the thread
        state_np = {k: jax.tree.map(np.asarray, v) for k, v in state.items()}
        self._thread = threading.Thread(
            target=save, args=(self.ckpt_dir, step, state_np, self.keep),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
