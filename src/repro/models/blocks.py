"""Layer blocks for every assigned architecture family.

Contract: ``apply_block(cfg, spec, params, x, ctx, cache) -> (x, cache', aux)``
  * train:   cache None -> None
  * prefill: cache None -> freshly built cache
  * decode:  cache in   -> updated cache
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


@dataclass(frozen=True)
class LayerSpec:
    kind: str      # attn_mlp | attn_moe | mlstm | slstm | hybrid | enc | dec
    window: int = 0  # 0 = full attention


class Ctx(NamedTuple):
    mode: str                      # train | prefill | decode
    positions: Any                 # (B,S) or (3,B,S) int32
    pos: Any = None                # decode: scalar cache write position
    encoder_out: Any = None        # whisper cross-attention source (B,Se,D)


def _round128(x: float) -> int:
    return max(16, int(-(-x // 16) * 16)) if x < 128 else int(-(-x // 128) * 128)


def slstm_ff_dim(cfg) -> int:
    return _round128(cfg.d_model * 4 / 3)


# ---------------------------------------------------------------------------
# Attention sublayer (shared).
# ---------------------------------------------------------------------------


def _attn_sublayer(cfg, p, x, ctx, cache, *, window: int, causal: bool = True,
                   rope: bool = True):
    B, Sx, _ = x.shape
    if ctx.mode == "decode":
        q = x @ p["wq"]
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bq" in p:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        # gather feature-sharded projections to full heads (tiny at S=1)
        q = shd.act(q, "dp", None, None)
        k = shd.act(k, "dp", None, None)
        v = shd.act(v, "dp", None, None)
        q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        k = k.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = L.rms_head_norm(q, p["q_norm"], cfg.norm_eps)
            k = L.rms_head_norm(k, p["k_norm"], cfg.norm_eps)
        if rope:
            q, k = L.apply_rope(cfg, q, k, ctx.positions)
        y, ck, cv = A.attn_decode(q, k, v, cache["k"], cache["v"], ctx.pos,
                                  window=window,
                                  softcap=cfg.attn_logit_softcap)
        cache = dict(cache, k=ck, v=cv)
    else:
        q, k, v = A.project_qkv(cfg, p, x, ctx.positions, rope=rope)
        qpos = ctx.positions[0] if ctx.positions.ndim == 3 else ctx.positions
        y = A.attention_sp(q, k, v, qpos, causal=causal, window=window,
                           softcap=cfg.attn_logit_softcap)
        if ctx.mode == "prefill":
            cache = {"k": k, "v": v}
    y = y.reshape(B, Sx, cfg.qkv_dim)
    y = y @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y, cache


def _cross_attn_sublayer(cfg, p, x, ctx, cache):
    """Whisper cross-attention: keys/values from the encoder output."""
    B, Sx, _ = x.shape
    if ctx.mode == "decode":
        ck, cv = cache["ck"], cache["cv"]
        q = (x @ p["wq"] + p.get("bq", 0.0))
        q = shd.act(q, "dp", None, None)
        q = q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        kpos = jnp.arange(ck.shape[1])
        y = A.attn_core(q, ck, cv, jnp.full((B, 1), ck.shape[1] - 1), kpos,
                        causal=False, window=0)
    else:
        enc = ctx.encoder_out
        # project q from x, k/v from encoder output
        q = (x @ p["wq"] + (p["bq"] if "bq" in p else 0.0)).reshape(
            B, Sx, cfg.n_heads, cfg.head_dim)
        k = (enc @ p["wk"] + (p["bk"] if "bk" in p else 0.0)).reshape(
            B, enc.shape[1], cfg.n_kv_heads, cfg.head_dim)
        v = (enc @ p["wv"] + (p["bv"] if "bv" in p else 0.0)).reshape(
            B, enc.shape[1], cfg.n_kv_heads, cfg.head_dim)
        qpos = ctx.positions[0] if ctx.positions.ndim == 3 else ctx.positions
        y = A.attention_sp(q, k, v, qpos, causal=False, window=0)
        if ctx.mode == "prefill":
            cache = dict(cache or {}, ck=k, cv=v)
    y = y.reshape(B, Sx, cfg.qkv_dim) @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y, cache


# ---------------------------------------------------------------------------
# Mamba sublayer (hymba) — Mamba-2/SSD form, per-head scalar decay.
# ---------------------------------------------------------------------------


def mamba_init(cfg, key, dtype):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h = cfg.n_heads
    n = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": L.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, di),
                                     dtype=jnp.float32) * 0.2).astype(dtype),
        "conv_b": L.zeros((di,), dtype),
        "w_bc": L.dense_init(ks[2], di, 2 * n, dtype),
        "w_dt": L.dense_init(ks[3], di, h, dtype),
        "dt_bias": jnp.full((h,), -2.0, dtype),
        "a_log": jnp.zeros((h,), dtype),
        "d_skip": L.ones((h,), dtype),
        "w_out_m": L.dense_init(ks[4], di, d, dtype),
    }


def mamba_apply(cfg, p, x, ctx, cache):
    B, Sx, d = x.shape
    di = cfg.ssm_expand * d
    h = cfg.n_heads
    hd = di // h
    n = cfg.ssm_state
    xz = x @ p["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    if ctx.mode == "decode":
        xs = shd.act(xs, "dp", None, None)
        z = shd.act(z, "dp", None, None)
        conv_in = jnp.concatenate([cache["conv"], xs], axis=1)
        xc = sum(conv_in[:, j:j + 1] * p["conv_w"][j]
                 for j in range(cfg.ssm_conv_width)) + p["conv_b"]
        new_conv = conv_in[:, 1:]
    else:
        xc = S.causal_conv1d(xs, p["conv_w"], p["conv_b"])
        new_conv = None
    xc = jax.nn.silu(xc)
    bc = xc @ p["w_bc"]
    b_, c_ = jnp.split(bc, 2, axis=-1)                    # (B,S,N) each
    dt = jax.nn.softplus(xc @ p["w_dt"] + p["dt_bias"])   # (B,S,h)
    g = (-dt * jnp.exp(p["a_log"].astype(jnp.float32))[None, None, :])
    i = jnp.log(dt + 1e-9)
    v = xs.reshape(B, Sx, h, hd)
    k = jnp.broadcast_to(b_[:, :, None, :], (B, Sx, h, n))
    q = jnp.broadcast_to(c_[:, :, None, :], (B, Sx, h, n))
    if ctx.mode == "decode":
        y, st = S.recurrence_step(cache["state"], q[:, 0], k[:, 0], v[:, 0],
                                  g[:, 0], i[:, 0], normalize=False,
                                  scale=1.0)
        y = y[:, None]
        cache = dict(cache, state=st, conv=new_conv)
    else:
        y, st = S.linear_recurrence(q, k, v, g, i, normalize=False,
                                    scale=1.0)
        if ctx.mode == "prefill":
            tail = shd.act(xs, "dp", None, None)[:, -(cfg.ssm_conv_width - 1):]
            cache = {"state": st, "conv": tail}
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * v.astype(jnp.float32)
    y = y.reshape(B, Sx, di).astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out_m"], cache


# ---------------------------------------------------------------------------
# Block kinds.
# ---------------------------------------------------------------------------


def init_block(cfg, key, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: dict = {}
    if spec.kind in ("attn_mlp", "attn_moe", "enc", "dec", "hybrid"):
        p["norm1"] = L.norm_init(cfg, d, dtype)
        p["attn"] = A.attn_init(cfg, ks[0], dtype)
        p["norm2"] = L.norm_init(cfg, d, dtype)
    if spec.kind == "attn_mlp" or spec.kind == "enc" or spec.kind == "hybrid":
        dff = cfg.d_ff
        p["mlp"] = L.mlp_init(cfg, ks[1], d, dff, dtype)
    if spec.kind == "attn_moe":
        p["moe"] = M.moe_init(cfg, ks[1], dtype)
    if spec.kind == "dec":
        p["norm_cross"] = L.norm_init(cfg, d, dtype)
        p["cross"] = A.attn_init(cfg, ks[2], dtype)
        p["mlp"] = L.mlp_init(cfg, ks[3], d, cfg.d_ff, dtype)
    if spec.kind == "hybrid":
        p["mamba"] = mamba_init(cfg, ks[4], dtype)
        p["branch_norm_attn"] = {"scale": L.ones((d,), dtype)}
        p["branch_norm_ssm"] = {"scale": L.ones((d,), dtype)}
    if spec.kind == "mlstm":
        di = cfg.ssm_expand * d
        kk = jax.random.split(ks[5], 7)
        p["norm1"] = L.norm_init(cfg, d, dtype)
        p["w_in"] = L.dense_init(kk[0], d, 2 * di, dtype)
        p["conv_w"] = (jax.random.normal(kk[1], (cfg.ssm_conv_width, di),
                                         dtype=jnp.float32) * 0.2).astype(dtype)
        p["conv_b"] = L.zeros((di,), dtype)
        p["wq"] = L.dense_init(kk[2], di, di, dtype)
        p["wk"] = L.dense_init(kk[3], di, di, dtype)
        p["wv"] = L.dense_init(kk[4], di, di, dtype)
        p["w_gates"] = L.dense_init(kk[5], di, 2 * cfg.n_heads, dtype)
        p["b_gates"] = jnp.concatenate([
            jnp.zeros((cfg.n_heads,), dtype),
            jnp.full((cfg.n_heads,), 3.0, dtype)])  # forget-gate bias high
        p["head_norm"] = {"scale": L.ones((di,), dtype)}
        p["w_out"] = L.dense_init(kk[6], di, d, dtype)
    if spec.kind == "slstm":
        p["norm1"] = L.norm_init(cfg, d, dtype)
        p["slstm"] = S.slstm_init(ks[6], d, cfg.n_heads, dtype)
        p["w_out"] = L.dense_init(ks[7], d, d, dtype)
        p["norm2"] = L.norm_init(cfg, d, dtype)
        p["mlp"] = L.mlp_init(cfg, ks[1], d, slstm_ff_dim(cfg), dtype)
    # deepseek first dense layer: attn + dense mlp with dense_d_ff
    if spec.kind == "attn_dense":
        p["norm1"] = L.norm_init(cfg, d, dtype)
        p["attn"] = A.attn_init(cfg, ks[0], dtype)
        p["norm2"] = L.norm_init(cfg, d, dtype)
        p["mlp"] = L.mlp_init(cfg, ks[1], d, cfg.dense_d_ff or cfg.d_ff, dtype)
    return p


def apply_block(cfg, spec: LayerSpec, p, x, ctx: Ctx, cache):
    aux = jnp.float32(0.0)
    kind = spec.kind
    if kind in ("attn_mlp", "attn_moe", "attn_dense", "enc", "dec"):
        pa = shd.use_weight(p["attn"])
        h = L.apply_norm(cfg, p["norm1"], x)
        rope = cfg.rope_theta != 0.0
        causal = kind != "enc"
        attn_cache = cache.get("attn") if cache else None
        y, attn_cache = _attn_sublayer(cfg, pa, h, ctx, attn_cache,
                                       window=spec.window, causal=causal,
                                       rope=rope)
        x = x + shd.act(y, "dp", "sp", None)
        new_cache = {"attn": attn_cache} if attn_cache is not None else None
        if kind == "dec":
            pc = shd.use_weight(p["cross"])
            h = L.apply_norm(cfg, p["norm_cross"], x)
            cross_cache = cache.get("cross") if cache else None
            y, cross_cache = _cross_attn_sublayer(cfg, pc, h, ctx, cross_cache)
            x = x + shd.act(y, "dp", "sp", None)
            if cross_cache is not None:
                new_cache = dict(new_cache or {}, cross=cross_cache)
        h = L.apply_norm(cfg, p["norm2"], x)
        if kind == "attn_moe":
            y, aux = M.moe_apply(cfg, p["moe"], h)
        else:
            y = L.apply_mlp(cfg, p["mlp"], h)
        x = x + shd.act(y, "dp", "sp", None)
        return x, new_cache, aux

    if kind == "hybrid":
        h = L.apply_norm(cfg, p["norm1"], x)
        pa = shd.use_weight(p["attn"])
        attn_cache = cache.get("attn") if cache else None
        ya, attn_cache = _attn_sublayer(cfg, pa, h, ctx, attn_cache,
                                        window=spec.window)
        pm = shd.use_weight(p["mamba"])
        mamba_cache = cache.get("mamba") if cache else None
        ym, mamba_cache = mamba_apply(cfg, pm, h, ctx, mamba_cache)
        ya = L.apply_norm(cfg, p["branch_norm_attn"], ya)
        ym = L.apply_norm(cfg, p["branch_norm_ssm"], ym)
        x = x + shd.act(0.5 * (ya + ym), "dp", "sp", None)
        h = L.apply_norm(cfg, p["norm2"], x)
        x = x + shd.act(L.apply_mlp(cfg, p["mlp"], h), "dp", "sp", None)
        new_cache = None
        if attn_cache is not None or mamba_cache is not None:
            new_cache = {"attn": attn_cache, "mamba": mamba_cache}
        return x, new_cache, aux

    if kind == "mlstm":
        pu = shd.use_weight(p)
        B, Sx, d = x.shape
        di = cfg.ssm_expand * d
        h0 = L.apply_norm(cfg, pu["norm1"], x)
        xz = h0 @ pu["w_in"]
        xs, z = jnp.split(xz, 2, axis=-1)
        if ctx.mode == "decode":
            xs = shd.act(xs, "dp", None, None)
            z = shd.act(z, "dp", None, None)
            conv_in = jnp.concatenate([cache["conv"], xs], axis=1)
            xc = sum(conv_in[:, j:j + 1] * pu["conv_w"][j]
                     for j in range(cfg.ssm_conv_width)) + pu["conv_b"]
            new_conv = conv_in[:, 1:]
        else:
            xc = S.causal_conv1d(xs, pu["conv_w"], pu["conv_b"])
            new_conv = None
        xc = jax.nn.silu(xc)
        nh = cfg.n_heads
        hd = di // nh
        q = (xc @ pu["wq"]).reshape(B, Sx, nh, hd)
        k = (xc @ pu["wk"]).reshape(B, Sx, nh, hd)
        v = (xs @ pu["wv"]).reshape(B, Sx, nh, hd)
        gates = xc @ pu["w_gates"] + pu["b_gates"]
        i_pre, f_pre = jnp.split(gates, 2, axis=-1)        # (B,S,nh)
        g = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
        ig = i_pre.astype(jnp.float32)
        if ctx.mode == "decode":
            y, st = S.recurrence_step(cache["state"], q[:, 0], k[:, 0],
                                      v[:, 0], g[:, 0], ig[:, 0],
                                      normalize=True)
            y = y[:, None]
            cache = dict(cache, state=st, conv=new_conv)
            new_cache = cache
        else:
            y, st = S.linear_recurrence(q, k, v, g, ig, normalize=True)
            new_cache = None
            if ctx.mode == "prefill":
                tail = shd.act(xs, "dp", None, None)[
                    :, -(cfg.ssm_conv_width - 1):]
                new_cache = {"state": st, "conv": tail}
        y = y.reshape(B, Sx, di).astype(x.dtype)
        y = L.rms_head_norm(y.reshape(B, Sx, nh, hd),
                            pu["head_norm"]["scale"].reshape(nh, hd),
                            cfg.norm_eps).reshape(B, Sx, di)
        y = y * jax.nn.silu(z)
        x = x + shd.act(y @ pu["w_out"], "dp", "sp", None)
        return x, new_cache, aux

    if kind == "slstm":
        h0 = L.apply_norm(cfg, p["norm1"], x)
        state = cache.get("state") if cache else None
        if ctx.mode == "decode":
            y, st = S.slstm_apply(p["slstm"], h0, cfg.n_heads,
                                  init_state=state)
            new_cache = dict(cache, state=st)
        else:
            y, st = S.slstm_apply(p["slstm"], h0, cfg.n_heads)
            new_cache = {"state": st} if ctx.mode == "prefill" else None
        pw = shd.use_weight(p["w_out"])
        x = x + shd.act(y @ pw, "dp", "sp", None)
        h1 = L.apply_norm(cfg, p["norm2"], x)
        x = x + shd.act(L.apply_mlp(cfg, p["mlp"], h1), "dp", "sp", None)
        return x, new_cache, aux

    raise ValueError(f"unknown layer kind {kind!r}")


# ---------------------------------------------------------------------------
# Cache shape structs (for dry-run decode lowering).
# ---------------------------------------------------------------------------


def cache_struct(cfg, spec: LayerSpec, batch: int, cache_len: int, dtype):
    """Abstract cache shapes for one layer (decode entry point)."""
    hd = cfg.head_dim
    out = {}
    if spec.kind in ("attn_mlp", "attn_moe", "attn_dense", "dec", "hybrid"):
        out["attn"] = {
            "k": jax.ShapeDtypeStruct((batch, cache_len, cfg.n_kv_heads, hd),
                                      dtype),
            "v": jax.ShapeDtypeStruct((batch, cache_len, cfg.n_kv_heads, hd),
                                      dtype),
        }
    if spec.kind == "dec":
        out["cross"] = {
            "ck": jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq_len, cfg.n_kv_heads, hd), dtype),
            "cv": jax.ShapeDtypeStruct(
                (batch, cfg.encoder_seq_len, cfg.n_kv_heads, hd), dtype),
        }
    if spec.kind == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        h = cfg.n_heads
        out["mamba"] = {
            "state": S.ScanState(
                loga=jax.ShapeDtypeStruct((batch, h), jnp.float32),
                m=jax.ShapeDtypeStruct((batch, h), jnp.float32),
                C=jax.ShapeDtypeStruct((batch, h, cfg.ssm_state, di // h),
                                       jnp.float32),
                n=jax.ShapeDtypeStruct((batch, h, cfg.ssm_state), jnp.float32)),
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.ssm_conv_width - 1, di), dtype),
        }
    if spec.kind == "mlstm":
        di = cfg.ssm_expand * cfg.d_model
        h = cfg.n_heads
        hd_i = di // h
        out = {
            "state": S.ScanState(
                loga=jax.ShapeDtypeStruct((batch, h), jnp.float32),
                m=jax.ShapeDtypeStruct((batch, h), jnp.float32),
                C=jax.ShapeDtypeStruct((batch, h, hd_i, hd_i), jnp.float32),
                n=jax.ShapeDtypeStruct((batch, h, hd_i), jnp.float32)),
            "conv": jax.ShapeDtypeStruct(
                (batch, cfg.ssm_conv_width - 1, di), dtype),
        }
    if spec.kind == "slstm":
        h = cfg.n_heads
        hd_h = cfg.d_model // h
        z = jax.ShapeDtypeStruct((batch, h, hd_h), jnp.float32)
        out = {"state": (z, z, z, z)}
    return out
