"""Model assembly: layer segments, init, train/prefill/decode entry points.

Layers are grouped into *segments*: (pattern of LayerSpecs, repeats).  A
segment with repeats > 1 runs under ``jax.lax.scan`` over parameters stacked
on a leading repeats dim (small HLO, fast compile, per-iteration remat) —
e.g. gemma3's "LLLLLG" pattern becomes one scan of 8 repeats whose body holds
6 layer applications.  Irregular layouts (hymba's global layers {0,15,31})
fall back to run-length segments.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import layers as L
from repro.models.blocks import Ctx, LayerSpec, apply_block, cache_struct, init_block


@dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeats: int


# ---------------------------------------------------------------------------
# Layer specs & segments.
# ---------------------------------------------------------------------------


def layer_specs(cfg) -> List[LayerSpec]:
    specs = []
    for i in range(cfg.n_layers):
        if cfg.family == "moe":
            kind = "attn_dense" if i < cfg.first_dense_layers else "attn_moe"
        elif cfg.family == "ssm":
            kind = ("slstm" if cfg.slstm_every and
                    (i % cfg.slstm_every == cfg.slstm_every - 1) else "mlstm")
        elif cfg.family == "hybrid":
            kind = "hybrid"
        elif cfg.is_encoder_decoder:
            kind = "dec"
        else:
            kind = "attn_mlp"
        window = 0
        if kind in ("attn_mlp", "attn_moe", "attn_dense", "hybrid"):
            if cfg.attn_kind(i) == "L" and cfg.sliding_window:
                window = cfg.sliding_window
        specs.append(LayerSpec(kind=kind, window=window))
    return specs


def encoder_layer_specs(cfg) -> List[LayerSpec]:
    return [LayerSpec(kind="enc", window=0)
            for _ in range(cfg.n_encoder_layers)]


def build_segments(specs: Sequence[LayerSpec]) -> List[Segment]:
    n = len(specs)
    # try cyclic grouping with the smallest period
    for period in range(1, min(12, n) + 1):
        if n % period:
            continue
        if all(specs[i] == specs[i % period] for i in range(n)):
            return [Segment(tuple(specs[:period]), n // period)]
    # run-length fallback
    segs: List[Segment] = []
    i = 0
    while i < n:
        j = i
        while j < n and specs[j] == specs[i]:
            j += 1
        segs.append(Segment((specs[i],), j - i))
        i = j
    return segs


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_segment(cfg, key, seg: Segment, dtype):
    def init_pattern(k):
        ks = jax.random.split(k, len(seg.pattern))
        return [init_block(cfg, ks[i], spec, dtype)
                for i, spec in enumerate(seg.pattern)]

    if seg.repeats == 1:
        return init_pattern(key)
    keys = jax.random.split(key, seg.repeats)
    return jax.vmap(init_pattern)(keys)


def init_model(cfg, key, dtype=None):
    dtype = dtype or _dtype(cfg)
    keys = jax.random.split(key, 8)
    segs = build_segments(layer_specs(cfg))
    params = {
        "embed": {"table": (jax.random.normal(
            keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)},
        "segments": [init_segment(cfg, k, s, dtype)
                     for k, s in zip(jax.random.split(keys[1], len(segs)),
                                     segs)],
        "final_norm": L.norm_init(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": L.dense_init(
            keys[2], cfg.d_model, cfg.vocab_size, dtype)}
    if cfg.is_encoder_decoder:
        esegs = build_segments(encoder_layer_specs(cfg))
        params["encoder"] = {
            "segments": [init_segment(cfg, k, s, dtype)
                         for k, s in zip(
                             jax.random.split(keys[3], len(esegs)), esegs)],
            "final_norm": L.norm_init(cfg, cfg.d_model, dtype),
            "pos_table": (jax.random.normal(
                keys[4], (cfg.encoder_seq_len, cfg.d_model), jnp.float32)
                * 0.02).astype(dtype),
        }
        params["dec_pos_table"] = (jax.random.normal(
            keys[5], (32_768, cfg.d_model), jnp.float32) * 0.02).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# Segment runner.
# ---------------------------------------------------------------------------


def _norm_cache(c):
    return () if c is None else c


def run_segments(cfg, seg_params, segs, x, ctx: Ctx, caches=None,
                 remat: bool = True):
    """Returns (x, new_caches, aux_total)."""
    aux_total = jnp.float32(0.0)
    new_caches = []
    for si, seg in enumerate(segs):
        sp = seg_params[si]
        sc = caches[si] if caches is not None else None
        if seg.repeats == 1:
            ncs = []
            for pi, spec in enumerate(seg.pattern):
                cin = sc[pi] if sc is not None else None

                def call(p_, x_, spec=spec, cin=cin):
                    return apply_block(cfg, spec, p_, x_, ctx, cin)

                if remat and ctx.mode == "train":
                    call = jax.checkpoint(call, prevent_cse=False)
                x, c, a = call(sp[pi], x)
                aux_total = aux_total + a
                ncs.append(_norm_cache(c))
            new_caches.append(ncs)
        else:
            def body(carry, xs):
                x_c, aux_c = carry
                p_sl, c_sl = xs
                outs = []
                for pi, spec in enumerate(seg.pattern):
                    cin = c_sl[pi] if c_sl is not None else None
                    cin = None if cin == () else cin
                    x_c, c, a = apply_block(cfg, spec, p_sl[pi], x_c, ctx, cin)
                    aux_c = aux_c + a
                    outs.append(_norm_cache(c))
                return (x_c, aux_c), outs

            fn = body
            if remat and ctx.mode == "train":
                fn = jax.checkpoint(body, prevent_cse=False)
            xs = (sp, sc if sc is not None
                  else [() for _ in seg.pattern])
            (x, aux_total), ncs = jax.lax.scan(fn, (x, aux_total), xs)
            new_caches.append(ncs)
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Embedding / head.
# ---------------------------------------------------------------------------


def embed_tokens(cfg, params, tokens, batch=None):
    lay = shd.layout()
    table = params["embed"]["table"]
    V = cfg.vocab_size
    if (lay.mesh is not None and lay.mode == "decode_tp"
            and lay.model_axis is not None and V % lay.n_shards == 0):
        # vocab-parallel lookup: mask + psum (keeps the table sharded)
        m_ax = lay.model_axis
        dp = lay.dp_for(tokens.shape[0])
        v_loc = V // lay.n_shards

        def body(tab_l, ids):
            lo = jax.lax.axis_index(m_ax) * v_loc
            rel = jnp.clip(ids - lo, 0, v_loc - 1)
            vals = jnp.take(tab_l, rel, axis=0)
            ok = ((ids >= lo) & (ids < lo + v_loc))[..., None]
            return jax.lax.psum(jnp.where(ok, vals, 0), m_ax)

        x = jax.shard_map(body, mesh=lay.mesh,
                          in_specs=(P(m_ax), P(dp)),
                          out_specs=P(dp))(table, tokens)
    else:
        table = shd.use_weight(table)
        x = jnp.take(table, tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if batch is not None and "patch_embeds" in batch:
        x = jnp.where(batch["image_mask"][..., None],
                      batch["patch_embeds"].astype(x.dtype), x)
    return x


def lm_logits(cfg, params, x):
    if cfg.tie_embeddings:
        w = shd.use_weight(params["embed"]["table"])  # (V, D)
        return x @ w.T.astype(x.dtype)
    w = shd.use_weight(params["lm_head"]["w"])        # (D, V)
    return x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# Entry points.
# ---------------------------------------------------------------------------


def _run_encoder(cfg, params, frames):
    enc = params["encoder"]
    pos = jnp.arange(frames.shape[1])
    x = frames.astype(_dtype(cfg)) + enc["pos_table"][None, pos]
    x = shd.act(x, "dp", "sp", None)
    segs = build_segments(encoder_layer_specs(cfg))
    ctx = Ctx(mode="train", positions=jnp.broadcast_to(
        pos[None], frames.shape[:2]))
    x, _, _ = run_segments(cfg, enc["segments"], segs, x, ctx, remat=True)
    return L.apply_norm(cfg, enc["final_norm"], x)


def forward(cfg, params, batch, mode: str = "train", caches=None,
            pos=None, remat: bool = True, head: bool = True):
    """Unified forward.

    batch keys: tokens (B,S), positions ((B,S) or (3,B,S)); optional
    patch_embeds/image_mask (vlm), frames (audio).  decode: S == 1 and
    ``pos``/``caches`` are given.
    Returns (logits, new_caches, aux) — or the final-norm hidden instead of
    logits when ``head=False`` (the fused ring-CE path applies its own head).
    """
    tokens = batch["tokens"]
    positions = batch["positions"]
    x = embed_tokens(cfg, params, tokens, batch)
    if cfg.is_encoder_decoder:
        qpos = positions[0] if positions.ndim == 3 else positions
        x = x + jnp.take(params["dec_pos_table"], qpos, axis=0)
    if mode == "decode":
        x = shd.act(x, "dp", None, None)
    else:
        x = shd.act(x, "dp", "sp", None)

    encoder_out = None
    if cfg.is_encoder_decoder and mode != "decode":
        encoder_out = _run_encoder(cfg, params, batch["frames"])

    ctx = Ctx(mode=mode, positions=positions, pos=pos,
              encoder_out=encoder_out)
    segs = build_segments(layer_specs(cfg))
    x, new_caches, aux = run_segments(cfg, params["segments"], segs, x, ctx,
                                      caches=caches, remat=remat)
    x = L.apply_norm(cfg, params["final_norm"], x)
    if not head:
        return x, new_caches, aux
    if mode == "prefill":
        # serving only needs the last position's logits: slice BEFORE the
        # head so the (B, S, V) logits tensor never materializes
        x = shd.act(x[:, -1:], "dp", None, None)
    logits = lm_logits(cfg, params, x)
    return logits, new_caches, aux


def ring_ce_sum(cfg, params, x, labels, weights=None):
    """Vocab-ring fused cross-entropy (beyond-paper §Perf optimization).

    x: (B, S, D) final hidden, sequence-sharded over "model"; the head
    weight stays VOCAB-SHARDED and its blocks circulate the ring
    (collective-permute) while each shard streams its sequence chunk through
    running (max, sum-exp, label-logit) accumulators — neither the gathered
    (V, D) table nor any (B, S, V) logits tensor ever materializes.

    Returns sum of weighted token CE (replicated scalar).
    """
    lay = shd.layout()
    tied = cfg.tie_embeddings
    w = params["embed"]["table"] if tied else params["lm_head"]["w"]
    if lay.mesh is None or lay.mode != "train_sp" or lay.model_axis is None:
        logits = lm_logits(cfg, params, x)
        return _ce_sum_dense(logits, labels, weights)
    m_ax = lay.model_axis
    tp = lay.n_shards
    dp = lay.dp if lay.dp else None
    V = cfg.vocab_size
    v_loc = V // tp
    perm = [(s, (s - 1) % tp) for s in range(tp)]

    def body(x_l, w_l, lab_l, wt_l):
        idx = jax.lax.axis_index(m_ax)
        B_l, S_l, D = x_l.shape
        xf = x_l.reshape(-1, D)
        labf = lab_l.reshape(-1)
        T = xf.shape[0]
        m_run = jnp.full((T,), -1e30, jnp.float32)
        s_run = jnp.zeros((T,), jnp.float32)
        ll = jnp.zeros((T,), jnp.float32)
        blk = w_l
        for r in range(tp):
            off = ((idx + r) % tp) * v_loc
            wb = blk if tied else blk.T           # (v_loc, D)
            logits = jax.lax.dot_general(
                xf, wb, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
            s_run = (s_run * jnp.exp(m_run - m_new)
                     + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=-1))
            m_run = m_new
            rel = labf - off
            inr = (rel >= 0) & (rel < v_loc)
            pick = jnp.take_along_axis(
                logits, jnp.clip(rel, 0, v_loc - 1)[:, None], axis=1)[:, 0]
            ll = jnp.where(inr, pick, ll)
            if r < tp - 1:
                blk = jax.lax.ppermute(blk, m_ax, perm)
        ce = (m_run + jnp.log(jnp.maximum(s_run, 1e-30))) - ll
        if wt_l is not None:
            wt = jnp.broadcast_to(wt_l.astype(jnp.float32)[:, None],
                                  (B_l, S_l)).reshape(-1)
            ce = ce * wt
        axes = tuple(lay.dp) + (m_ax,) if lay.dp else (m_ax,)
        return jax.lax.psum(jnp.sum(ce), axes)

    w_spec = P(m_ax) if tied else P(None, m_ax)
    if weights is None:
        fn = lambda a, b, c: body(a, b, c, None)
        return jax.shard_map(fn, mesh=lay.mesh,
                             in_specs=(P(dp, m_ax), w_spec, P(dp, m_ax)),
                             out_specs=P())(x, w, labels)
    return jax.shard_map(body, mesh=lay.mesh,
                         in_specs=(P(dp, m_ax), w_spec, P(dp, m_ax), P(dp)),
                         out_specs=P())(x, w, labels, weights)


def chunked_ce_sum(cfg, params, x, labels, weights, vchunk: int):
    """Vocab-chunked fused CE for the local / train_fsdp layouts.

    Streams the head in (D, vchunk) slices with running (max, sum-exp,
    label-logit) accumulators — the (T, V) logits tensor never materializes
    (peak extra memory = one (T, vchunk) fp32 tile + the gathered head).
    """
    tied = cfg.tie_embeddings
    w = params["embed"]["table"] if tied else params["lm_head"]["w"]
    w = shd.use_weight(w)
    B, S, D = x.shape
    V = cfg.vocab_size
    nch = -(-V // vchunk)
    xf = x.reshape(-1, D)
    labf = labels.reshape(-1)
    T = xf.shape[0]

    def body(carry, i):
        m_run, s_run, ll = carry
        off = i * vchunk
        if tied:
            w_c = jax.lax.dynamic_slice(w, (off, 0), (vchunk, D))
            logits = jax.lax.dot_general(xf, w_c, (((1,), (1,)), ((), ())),
                                         preferred_element_type=jnp.float32)
        else:
            w_c = jax.lax.dynamic_slice(w, (0, off), (D, vchunk))
            logits = jax.lax.dot_general(xf, w_c, (((1,), (0,)), ((), ())),
                                         preferred_element_type=jnp.float32)
        # mask pad columns when vchunk does not divide V
        col = off + jnp.arange(vchunk)
        logits = jnp.where(col[None, :] < V, logits, -1e30)
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
        s_run = (s_run * jnp.exp(m_run - m_new)
                 + jnp.sum(jnp.exp(logits - m_new[:, None]), axis=-1))
        rel = labf - off
        inr = (rel >= 0) & (rel < vchunk)
        pick = jnp.take_along_axis(
            logits, jnp.clip(rel, 0, vchunk - 1)[:, None], axis=1)[:, 0]
        ll = jnp.where(inr, pick, ll)
        return (m_new, s_run, ll), None

    init = (jnp.full((T,), -1e30, jnp.float32), jnp.zeros((T,), jnp.float32),
            jnp.zeros((T,), jnp.float32))
    (m_run, s_run, ll), _ = jax.lax.scan(body, init, jnp.arange(nch))
    ce = (m_run + jnp.log(jnp.maximum(s_run, 1e-30))) - ll
    if weights is not None:
        wt = jnp.broadcast_to(weights.astype(jnp.float32)[:, None],
                              (B, S)).reshape(-1)
        ce = ce * wt
    return jnp.sum(ce)


def _ce_sum_dense(logits, labels, weights=None):
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - ll
    if weights is not None:
        ce = ce * jnp.broadcast_to(
            weights.astype(jnp.float32)[:, None], ce.shape)
    return jnp.sum(ce)


def cross_entropy(logits, labels, weights=None):
    """Mean CE with optional per-example/token weights (the cutoff mask).

    Implements the paper's Alg.1 line 29 normalization: sum(w * ce) / sum(w)
    — i.e. the update averages over *included* workers only.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - ll
    if weights is None:
        return jnp.mean(ce)
    w = jnp.broadcast_to(weights.astype(jnp.float32).reshape(
        weights.shape + (1,) * (ce.ndim - weights.ndim)), ce.shape)
    return jnp.sum(w * ce) / jnp.maximum(jnp.sum(w), 1e-6)


def train_loss(cfg, params, batch, aux_coef: float = 0.01):
    logits, _, aux = forward(cfg, params, batch, mode="train")
    loss = cross_entropy(logits, batch["labels"], batch.get("weights"))
    return loss + aux_coef * aux, {"ce": loss, "aux": aux}


def prefill(cfg, params, batch):
    logits, caches, _ = forward(cfg, params, batch, mode="prefill",
                                remat=False)
    return logits[:, -1], caches


def decode_step(cfg, params, tokens, pos, caches, positions=None):
    """tokens: (B,1); pos: scalar int32 cache length so far."""
    B = tokens.shape[0]
    if positions is None:
        positions = jnp.full((B, 1), pos, jnp.int32)
    batch = {"tokens": tokens, "positions": positions}
    logits, caches, _ = forward(cfg, params, batch, mode="decode",
                                caches=caches, pos=pos, remat=False)
    return logits, caches


def pad_caches(caches, target_len: int):
    """Grow attention KV caches (leaves named k/v) to ``target_len`` slots."""

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("k", "v") and hasattr(v, "ndim"):
                    ax = v.ndim - 3
                    pad = [(0, 0)] * v.ndim
                    pad[ax] = (0, target_len - v.shape[ax])
                    out[k] = jnp.pad(v, pad)
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            t = [walk(v) for v in node]
            if hasattr(node, "_fields"):   # NamedTuple (e.g. ScanState)
                return type(node)(*t)
            return tuple(t) if isinstance(node, tuple) else t
        return node

    return walk(caches)


# ---------------------------------------------------------------------------
# Cache structs + shardings (for AOT decode lowering).
# ---------------------------------------------------------------------------


def cache_structs(cfg, batch: int, cache_len: int, dtype=None):
    dtype = dtype or _dtype(cfg)
    segs = build_segments(layer_specs(cfg))
    out = []
    for seg in segs:
        per_pos = [cache_struct(cfg, spec, batch, cache_len, dtype)
                   for spec in seg.pattern]
        if seg.repeats > 1:
            per_pos = [jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((seg.repeats,) + s.shape,
                                               s.dtype), c) for c in per_pos]
        out.append(per_pos)
    return out


def cache_pspec(path_leaf_name: str, shape, lay, stacked: bool):
    """PartitionSpec for a cache leaf in decode_tp layout."""
    if lay.mesh is None or lay.model_axis is None:
        return P()
    m = lay.model_axis
    off = 1 if stacked else 0
    tp = lay.mesh.shape[m]
    dims: list = [None] * len(shape)
    dp_dim = off  # batch dim
    if lay.dp and shape[dp_dim] % max(lay.dp_size, 1) == 0:
        dims[dp_dim] = lay.dp
    name = path_leaf_name

    def try_put(i):
        if shape[i] % tp == 0:
            dims[i] = m

    if name in ("k", "v", "ck", "cv"):
        try_put(off + 1)          # sequence dim
    elif name == "C":
        try_put(off + 2)          # dq dim
    elif name == "n":
        try_put(off + 2)
    elif name == "conv":
        try_put(off + 2)          # channel dim
    return P(*dims)
