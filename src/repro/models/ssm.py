"""SSM cores: chunked linear recurrences (mLSTM / Mamba-SSD) and sLSTM.

The shared machinery is a *stabilized linear recurrence over chunk states*

    S_t = exp(g_t) * S_{t-1} + exp(i_t) * k_t v_t^T

computed in chunkwise-parallel form: quadratic (attention-like) math inside a
chunk, a tiny sequential scan over chunk states, and — when the sequence is
sharded over the "model" axis (train_sp layout) — a distributed exclusive
prefix across shards (all_gather of per-shard summaries + log-depth local
combine).  This is the TPU-native adaptation of GPU selective-scan kernels:
chunk-local matmuls feed the MXU, and only (h, dq, dv) chunk states cross
chunk/shard boundaries.

Hardware-adaptation note (DESIGN.md §4): Hymba's Mamba heads use per-*head*
scalar decay (Mamba-2/SSD form) rather than per-channel (Mamba-1) so the
intra-chunk math is head-wise matmuls.  mLSTM follows the xLSTM chunkwise
formulation with max-stabilizers and the |den| >= exp(-m) normalizer.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd

NEG = -1e30


class ScanState(NamedTuple):
    """Stabilized recurrence state: true_C = C * exp(m); loga = log of the
    total decay this state spans (identity: loga=0, m=NEG, C=n=0)."""
    loga: jnp.ndarray  # (..., h)
    m: jnp.ndarray     # (..., h)
    C: jnp.ndarray     # (..., h, dq, dv)
    n: jnp.ndarray     # (..., h, dq)


def _bc(s, x):
    return s.reshape(s.shape + (1,) * (x.ndim - s.ndim))


def state_identity(shape_hint: ScanState) -> ScanState:
    return ScanState(
        loga=jnp.zeros_like(shape_hint.loga),
        m=jnp.full_like(shape_hint.m, NEG),
        C=jnp.zeros_like(shape_hint.C),
        n=jnp.zeros_like(shape_hint.n))


def combine(s1: ScanState, s2: ScanState) -> ScanState:
    """Associative combine: apply s1's span, then s2's."""
    loga = s1.loga + s2.loga
    m = jnp.maximum(s1.m + s2.loga, s2.m)
    a1 = jnp.exp(s1.m + s2.loga - m)
    a2 = jnp.exp(s2.m - m)
    return ScanState(
        loga=loga, m=m,
        C=s1.C * _bc(a1, s1.C) + s2.C * _bc(a2, s2.C),
        n=s1.n * _bc(a1, s1.n) + s2.n * _bc(a2, s2.n))


# ---------------------------------------------------------------------------
# Chunk elements / outputs.
# ---------------------------------------------------------------------------


def _chunk_states(k, v, g, i) -> ScanState:
    """Per-chunk recurrence elements.

    k: (B, nc, c, h, dq); v: (B, nc, c, h, dv); g/i: (B, nc, c, h).
    """
    lg = jnp.cumsum(g, axis=2)
    tot = lg[:, :, -1]                        # (B, nc, h)
    w = tot[:, :, None] - lg + i              # carry-to-chunk-end log weight
    m_loc = jnp.max(w, axis=2)                # (B, nc, h)
    sc = jnp.exp(w - m_loc[:, :, None])
    C = jnp.einsum("bnch,bnchq,bnchv->bnhqv", sc, k, v)
    n = jnp.einsum("bnch,bnchq->bnhq", sc, k)
    return ScanState(loga=tot, m=m_loc, C=C, n=n)


def _chunk_outputs(q, k, v, g, i, ent: ScanState, *, normalize: bool,
                   scale: float):
    """Outputs for every position given the entering state of each chunk."""
    lg = jnp.cumsum(g, axis=2)                           # (B,nc,c,h)
    # intra-chunk log decay matrix D[t,s] = lg_t - lg_s + i_s (s <= t)
    D = (lg[:, :, :, None, :] - lg[:, :, None, :, :]
         + i[:, :, None, :, :])                          # (B,nc,t,s,h)
    c = q.shape[2]
    tri = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(tri[None, None, :, :, None], D, NEG)
    m_intra = jnp.max(D, axis=3)                         # (B,nc,t,h)
    lg_e = lg + ent.m[:, :, None, :]                     # inter log scale
    m_out = jnp.maximum(lg_e, m_intra)
    W = jnp.exp(D - m_out[:, :, :, None, :])             # (B,nc,t,s,h)
    dot = jnp.einsum("bnthq,bnshq->bntsh",
                     q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    WS = W * dot
    num = jnp.einsum("bntsh,bnshv->bnthv", WS, v.astype(jnp.float32))
    den = jnp.sum(WS, axis=3)                            # (B,nc,t,h)
    sc_e = jnp.exp(lg_e - m_out)                         # (B,nc,t,h)
    qC = jnp.einsum("bnthq,bnhqv->bnthv",
                    q.astype(jnp.float32), ent.C) * scale
    qn = jnp.einsum("bnthq,bnhq->bnth",
                    q.astype(jnp.float32), ent.n) * scale
    num = num + sc_e[..., None] * qC
    den = den + sc_e * qn
    if normalize:
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_out))
        return num / den[..., None]
    return num


def _local_scan(elems: ScanState):
    """Sequential scan over the chunk dim; returns (entering, final)."""
    ident = jax.tree.map(lambda t: t[:, 0], state_identity(elems))

    def step(carry, e):
        return combine(carry, e), carry

    el = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), elems)
    if shd.unrolled():
        nc = jax.tree.leaves(el)[0].shape[0]
        carry, outs = ident, []
        for i in range(nc):
            carry, prev = step(carry, jax.tree.map(lambda t: t[i], el))
            outs.append(prev)
        entering = jax.tree.map(lambda *ts: jnp.stack(ts, 0), *outs)
        final = carry
    else:
        final, entering = jax.lax.scan(step, ident, ScanState(*el))
    entering = jax.tree.map(lambda t: jnp.moveaxis(t, 0, 1), entering)
    return ScanState(*entering), final


def linear_recurrence(q, k, v, g, i, *, chunk: int = 128,
                      normalize: bool, scale: Optional[float] = None,
                      init_state: Optional[ScanState] = None):
    """Chunked linear recurrence over (B, S, h, d*) inputs.

    Returns (y (B,S,h,dv) fp32, final_state).  Sequence-sharding over the
    "model" axis is handled with a distributed exclusive prefix.
    """
    B, S, h, dq = q.shape
    dv = v.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dq)
    lay = shd.layout()
    sharded = (lay.mesh is not None and lay.mode == "train_sp"
               and lay.model_axis is not None)

    def run_local(q, k, v, g, i, tp_idx, n_tp):
        B_l, S_l = q.shape[0], q.shape[1]
        c = chunk if S_l % chunk == 0 and S_l > chunk else S_l
        nc = S_l // c
        rs = lambda t, d: t.reshape(B_l, nc, c, h, d)
        qc, kc, vc = rs(q, dq), rs(k, dq), rs(v, dv)
        gc = g.reshape(B_l, nc, c, h).astype(jnp.float32)
        ic = i.reshape(B_l, nc, c, h).astype(jnp.float32)
        elems = _chunk_states(kc.astype(jnp.float32), vc.astype(jnp.float32),
                              gc, ic)
        entering, final = _local_scan(elems)
        if n_tp > 1:
            gathered = jax.tree.map(
                lambda t: jax.lax.all_gather(t, lay.model_axis), final)
            prefix = jax.tree.map(lambda t: t[0],
                                  state_identity(ScanState(*gathered)))
            for s in range(n_tp - 1):
                cand = combine(prefix, jax.tree.map(lambda t: t[s], gathered))
                take = s < tp_idx
                prefix = jax.tree.map(
                    lambda a, b: jnp.where(take, b, a), prefix, cand)
            entering = combine(
                jax.tree.map(lambda t: t[:, None], prefix), entering)
            final = combine(prefix, final)
            # replicate the global final across shards
            is_last = (tp_idx == n_tp - 1).astype(jnp.float32)
            final = jax.tree.map(
                lambda t: jax.lax.psum(t * is_last, lay.model_axis), final)
        if init_state is not None:
            entering = combine(
                jax.tree.map(lambda t: t[:, None], init_state), entering)
            final = combine(init_state, final)
        y = _chunk_outputs(qc, kc, vc, gc, ic, entering,
                           normalize=normalize, scale=scale)
        return y.reshape(B_l, S_l, h, dv), final

    if not sharded:
        return run_local(q, k, v, g, i, jnp.int32(0), 1)

    m_ax = lay.model_axis
    dp = lay.dp if lay.dp else None
    n_tp = lay.n_shards

    def body(q, k, v, g, i):
        idx = jax.lax.axis_index(m_ax)
        return run_local(q, k, v, g, i, idx, n_tp)

    return jax.shard_map(
        body, mesh=lay.mesh,
        in_specs=(P(dp, m_ax), P(dp, m_ax), P(dp, m_ax), P(dp, m_ax),
                  P(dp, m_ax)),
        out_specs=(P(dp, m_ax), P(dp)),
    )(q, k, v, g, i)


def recurrence_step(state: ScanState, q, k, v, g, i, *, normalize: bool,
                    scale: Optional[float] = None):
    """Single-token decode update.  q/k: (B,h,dq); v: (B,h,dv); g/i: (B,h)."""
    dq = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(dq)
    elem = ScanState(
        loga=g.astype(jnp.float32), m=i.astype(jnp.float32),
        C=jnp.einsum("bhq,bhv->bhqv", k.astype(jnp.float32),
                     v.astype(jnp.float32)),
        n=k.astype(jnp.float32))
    new = combine(state, elem)
    num = jnp.einsum("bhq,bhqv->bhv", q.astype(jnp.float32), new.C) * scale
    if normalize:
        den = jnp.einsum("bhq,bhq->bh", q.astype(jnp.float32), new.n) * scale
        den = jnp.maximum(jnp.abs(den), jnp.exp(-new.m))
        return num / den[..., None], new
    return num, new


# ---------------------------------------------------------------------------
# Causal depthwise conv (with cross-shard halo under train_sp).
# ---------------------------------------------------------------------------


def causal_conv1d(x, w, b=None, *, init_state=None):
    """x: (B, S, C); w: (cw, C) depthwise; left-pads with zeros (or
    ``init_state`` (B, cw-1, C) during decode/chunked prefill)."""
    cw = w.shape[0]
    lay = shd.layout()
    sharded = (lay.mesh is not None and lay.mode == "train_sp"
               and lay.model_axis is not None)

    def conv_local(x_l, left):
        xp = jnp.concatenate([left, x_l], axis=1)
        y = sum(xp[:, j:j + x_l.shape[1]] * w[j] for j in range(cw))
        return y + (b if b is not None else 0.0)

    if not sharded:
        left = (init_state if init_state is not None
                else jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype))
        return conv_local(x, left)

    m_ax = lay.model_axis
    dp = lay.dp if lay.dp else None
    n_tp = lay.n_shards

    def body(x_l):
        idx = jax.lax.axis_index(m_ax)
        tail = x_l[:, -(cw - 1):]
        left = jax.lax.ppermute(
            tail, m_ax, [(s, s + 1) for s in range(n_tp - 1)])
        left = jnp.where(idx == 0, jnp.zeros_like(left), left)
        return conv_local(x_l, left)

    return jax.shard_map(body, mesh=lay.mesh, in_specs=P(dp, m_ax),
                         out_specs=P(dp, m_ax))(x)


# ---------------------------------------------------------------------------
# sLSTM (strictly sequential; xLSTM scalar-memory cell).
# ---------------------------------------------------------------------------


def slstm_init(key, d: int, n_heads: int, dtype):
    hd = d // n_heads
    ks = jax.random.split(key, 3)
    scale = 1.0 / math.sqrt(d)
    w = (jax.random.normal(ks[0], (d, 4 * d), dtype=jnp.float32)
         * scale).astype(dtype)
    r = (jax.random.normal(ks[1], (4, n_heads, hd, hd), dtype=jnp.float32)
         * (1.0 / math.sqrt(hd))).astype(dtype)
    bias = jnp.zeros((4 * d,), dtype)
    return {"w": w, "r": r, "bias": bias}


def slstm_apply(params, x, n_heads: int, *, init_state=None):
    """x: (B, S, D).  Returns (h (B,S,D), final_state).

    Under train_sp the sequence is gathered (sLSTM is non-associative), the
    scan runs replicated, and each shard keeps its local slice — documented
    replicated compute for the 1-in-8 sLSTM blocks of xlstm.
    """
    B, S, D = x.shape
    hd = D // n_heads
    p = shd.use_weight(params)
    pre = x @ p["w"] + p["bias"]                      # (B,S,4D)
    lay = shd.layout()
    sharded = (lay.mesh is not None and lay.mode == "train_sp"
               and lay.model_axis is not None)
    if sharded:
        pre = shd.act(pre, "dp", None, None)          # gather sequence

    def scan_full(pre_full, state0):
        def step(carry, z_t):
            c, n, h, m = carry
            zi, zf, zz, zo = jnp.split(
                z_t + jnp.einsum("bkh,gkhj->bgkj", h, p["r"].astype(
                    jnp.float32)).reshape(z_t.shape[0], -1), 4, axis=-1)
            rs = lambda t: t.reshape(t.shape[0], n_heads, hd)
            zi, zf, zz, zo = rs(zi), rs(zf), rs(zz), rs(zo)
            logf = jax.nn.log_sigmoid(zf)
            m_new = jnp.maximum(logf + m, zi)
            fp = jnp.exp(logf + m - m_new)
            ip = jnp.exp(zi - m_new)
            c_new = fp * c + ip * jnp.tanh(zz)
            n_new = fp * n + ip
            h_new = jax.nn.sigmoid(zo) * c_new / jnp.maximum(n_new, 1.0)
            return (c_new, n_new, h_new, m_new), h_new

        if state0 is None:
            z = jnp.zeros((pre_full.shape[0], n_heads, hd), jnp.float32)
            state0 = (z, z, z, jnp.full((pre_full.shape[0], n_heads, hd),
                                        NEG, jnp.float32))
        final, hs = jax.lax.scan(step, state0,
                                 jnp.moveaxis(pre_full, 1, 0).astype(
                                     jnp.float32))
        hs = jnp.moveaxis(hs, 0, 1).reshape(pre_full.shape[0], -1, D)
        return hs.astype(x.dtype), final

    h_full, final = scan_full(pre, init_state)
    if sharded:
        h_full = shd.act(h_full, "dp", "sp", None)    # back to local slice
    return h_full, final
