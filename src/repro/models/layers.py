"""Core pure-JAX layers: inits, norms, MLPs, RoPE (std / partial / M-RoPE)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist import sharding as shd


# ---------------------------------------------------------------------------
# Init helpers.
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
            ).astype(dtype)


def zeros(shape, dtype):
    return jnp.zeros(shape, dtype=dtype)


def ones(shape, dtype):
    return jnp.ones(shape, dtype=dtype)


# ---------------------------------------------------------------------------
# Norms.
# ---------------------------------------------------------------------------


def norm_init(cfg, d: int, dtype):
    if cfg.norm == "layernorm":
        return {"scale": ones((d,), dtype), "bias": zeros((d,), dtype)}
    return {"scale": ones((d,), dtype)}


def apply_norm(cfg, params, x):
    p = shd.use_weight(params)
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(x, scale, eps):
    """Per-head RMS norm (gemma3 qk-norm); x: (..., hd)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLPs.
# ---------------------------------------------------------------------------


def mlp_init(cfg, key, d: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], d, d_ff, dtype)
        p["w_up"] = dense_init(ks[1], d, d_ff, dtype)
    else:
        p["w_up"] = dense_init(ks[1], d, d_ff, dtype)
        if cfg.mlp_bias:
            p["b_up"] = zeros((d_ff,), dtype)
    p["w_down"] = dense_init(ks[2], d_ff, d, dtype)
    if cfg.mlp_bias:
        p["b_down"] = zeros((d,), dtype)
    return p


def apply_mlp(cfg, params, x):
    p = shd.use_weight(params)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = x @ p["w_up"]
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h, approximate=True)
    h = shd.act(h, "dp", "sp", "tp")
    y = h @ p["w_down"]
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# RoPE: standard, partial-rotary, M-RoPE (qwen2-vl).
# ---------------------------------------------------------------------------


def _rope_cos_sin(positions, rot_dim: int, theta: float, dtype):
    """positions: (..., S) int -> cos/sin (..., S, rot_dim/2)."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def _rotate(x, cos, sin):
    """x: (B, S, H, rot_dim); cos/sin: (B, S, half) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(cfg, q, k, positions):
    """q: (B,S,H,hd); k: (B,S,KV,hd); positions: (B,S) or (3,B,S) for M-RoPE."""
    if cfg.rope_theta == 0.0:
        return q, k  # learned-absolute-position archs (whisper)
    hd = cfg.head_dim
    rot = int(hd * cfg.partial_rotary)
    rot -= rot % 2
    if cfg.mrope_sections:
        cos, sin = _mrope_cos_sin(cfg, positions, rot, q.dtype)
    else:
        if positions.ndim == 3:
            positions = positions[0]
        cos, sin = _rope_cos_sin(positions, rot, cfg.rope_theta, q.dtype)

    def rope_one(x):
        if rot == hd:
            return _rotate(x, cos, sin)
        xr = _rotate(x[..., :rot], cos, sin)
        return jnp.concatenate([xr, x[..., rot:]], axis=-1)

    return rope_one(q), rope_one(k)


def _mrope_cos_sin(cfg, positions, rot_dim: int, dtype):
    """M-RoPE: positions (3, B, S) = (t, h, w) streams; frequency f uses the
    stream its section assigns (sections are half-dim counts summing to
    rot_dim//2).  For pure-text positions all three streams coincide and
    M-RoPE reduces to standard RoPE.
    """
    if positions.ndim == 2:
        positions = jnp.broadcast_to(positions[None],
                                     (3,) + positions.shape)
    half = rot_dim // 2
    freqs = 1.0 / (cfg.rope_theta
                   ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (3, B, S, half)
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32)
        for i, s in enumerate(cfg.mrope_sections)])  # (half,)
    sel = jax.nn.one_hot(sec, 3, dtype=ang.dtype)  # (half, 3)
    ang = jnp.einsum("kbsf,fk->bsf", ang, sel)
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)
