"""Mixture-of-Experts FFN with expert parallelism.

train_sp: tokens are sequence-sharded over "model" and experts are sharded
over "model" (EP).  Dispatch is sort-based (stable argsort by expert id,
rank-within-expert via searchsorted, static capacity buffers) followed by a
``lax.all_to_all`` to the expert owners and the inverse a2a back — the
collective pattern real EP systems use (no dense one-hot dispatch einsums,
which would dominate HLO FLOPs).

decode_tp: tokens are replicated over "model"; each shard runs its local
experts densely over the (few) decode tokens, masked by routing weights, and
psums the combined output.

Aux (load-balance) loss is returned alongside the output.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import layers as L


def moe_init(cfg, key, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)

    def bank(k, din, dout, scale):
        return (jax.random.normal(k, (e, din, dout), dtype=jnp.float32)
                * scale).astype(dtype)

    p = {
        "router": L.dense_init(ks[0], d, e, dtype, scale=scale_in),
        "experts": {
            "w_gate": bank(ks[1], d, f, scale_in),
            "w_up": bank(ks[2], d, f, scale_in),
            "w_down": bank(ks[3], f, d, scale_out),
        },
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": L.dense_init(kk[0], d, fs, dtype),
            "w_up": L.dense_init(kk[1], d, fs, dtype),
            "w_down": L.dense_init(kk[2], fs, d, dtype),
        }
    return p


def _expert_ffn(bank, x):
    """bank leaves: (E_local, D, F)/(E_local, F, D); x: (E_local, T, D)."""
    h = jax.nn.silu(jnp.einsum("etd,edf->etf", x, bank["w_gate"]))
    h = h * jnp.einsum("etd,edf->etf", x, bank["w_up"])
    return jnp.einsum("etf,efd->etd", h, bank["w_down"])


def _route(cfg, router_w, x):
    """x: (..., D) -> (topk_w, topk_i, f_e, p_e).

    f_e = fraction of routed slots on expert e; p_e = mean router prob.
    The load-balance aux is E * sum_e f_e * p_e — when tokens are sharded,
    f_e/p_e must be pmean'd across shards *before* the product so the loss
    matches the unsharded computation exactly.
    """
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_i = jax.lax.top_k(probs, cfg.top_k)
    if cfg.router_scale:
        topk_w = topk_w / jnp.sum(topk_w, axis=-1, keepdims=True)
    e = cfg.n_experts
    f_e = jnp.mean(
        jnp.sum(jax.nn.one_hot(topk_i, e, dtype=jnp.float32), axis=-2),
        axis=tuple(range(topk_i.ndim - 1))) / cfg.top_k
    p_e = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return topk_w, topk_i, f_e, p_e


def _aux(cfg, f_e, p_e):
    return cfg.n_experts * jnp.sum(f_e * p_e)


def _dispatch_compute_combine(cfg, x_flat, topk_w, topk_i, bank,
                              tp: int, tp_idx, capacity: int):
    """Sort-based dispatch on one shard's tokens.

    x_flat: (N, D); topk_*: (N, k); bank leaves are the LOCAL expert slices
    (E_local, ...).  tp == 1 means no a2a (all experts local).
    """
    N, D = x_flat.shape
    k = cfg.top_k
    e_local = cfg.n_experts // tp
    C = capacity
    flat_e = topk_i.reshape(-1)
    flat_w = topk_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    rank = jnp.arange(N * k, dtype=jnp.int32) - jnp.searchsorted(
        se, se, side="left").astype(jnp.int32)
    keep = rank < C
    dest = se // e_local                     # owning shard
    slot = (se % e_local) * C + rank         # slot within that shard's buffer
    tok = flat_t[order]
    w_sorted = flat_w[order]

    send = jnp.zeros((tp, e_local * C, D), x_flat.dtype)
    send = send.at[dest, jnp.where(keep, slot, 0)].add(
        x_flat[tok] * keep[:, None].astype(x_flat.dtype), mode="drop")

    if tp > 1:
        recv = jax.lax.all_to_all(send, shd.layout().model_axis,
                                  split_axis=0, concat_axis=0)
    else:
        recv = send
    # (tp, E_local, C, D) -> (E_local, tp*C, D)
    grouped = recv.reshape(tp, e_local, C, D).transpose(1, 0, 2, 3)
    grouped = grouped.reshape(e_local, tp * C, D)
    out = _expert_ffn(bank, grouped)
    out = out.reshape(e_local, tp, C, D).transpose(1, 0, 2, 3)
    out = out.reshape(tp, e_local * C, D)
    if tp > 1:
        out = jax.lax.all_to_all(out, shd.layout().model_axis,
                                 split_axis=0, concat_axis=0)
    gathered = out[dest, slot]               # (N*k, D) in sorted space
    contrib = gathered * (w_sorted * keep).astype(x_flat.dtype)[:, None]
    y = jnp.zeros((N, D), x_flat.dtype).at[tok].add(contrib)
    return y


def capacity_for(cfg, n_tokens: int, factor: Optional[float] = None) -> int:
    from repro.perf.knobs import knobs
    if factor is None and knobs().moe_capacity_factor > 0:
        factor = knobs().moe_capacity_factor
    factor = factor if factor is not None else cfg.moe_capacity_factor
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts * factor))
    return max(8, -(-c // 8) * 8)  # round up to 8


def moe_apply(cfg, params, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) (seq-sharded under train_sp; replicated S=1 in decode).

    Returns (y, aux_loss).
    """
    lay = shd.layout()
    B, S, D = x.shape

    if lay.mesh is not None and lay.mode == "decode_tp" and lay.model_axis:
        return _moe_decode(cfg, params, x)

    sharded = (lay.mesh is not None and lay.mode == "train_sp"
               and lay.model_axis is not None)
    if not sharded:
        topk_w, topk_i, f_e, p_e = _route(cfg, params["router"], x)
        aux = _aux(cfg, f_e, p_e)
        C = capacity_for(cfg, B * S)
        y = _dispatch_compute_combine(
            cfg, x.reshape(-1, D), topk_w.reshape(-1, cfg.top_k),
            topk_i.reshape(-1, cfg.top_k), params["experts"], 1,
            jnp.int32(0), C)
        y = y.reshape(B, S, D)
    else:
        m_ax = lay.model_axis
        dp = lay.dp if lay.dp else None
        tp = lay.n_shards
        S_local = S // tp
        B_local = B // max(lay.dp_size, 1)
        C = capacity_for(cfg, B_local * S_local)

        def body(x_l, router_w, bank):
            tpi = jax.lax.axis_index(m_ax)
            topk_w, topk_i, f_e, p_e = _route(cfg, router_w, x_l)
            y = _dispatch_compute_combine(
                cfg, x_l.reshape(-1, D), topk_w.reshape(-1, cfg.top_k),
                topk_i.reshape(-1, cfg.top_k), bank, tp, tpi, C)
            axes = tuple(lay.dp) + (m_ax,)
            aux = _aux(cfg, jax.lax.pmean(f_e, axes),
                       jax.lax.pmean(p_e, axes))
            return y.reshape(x_l.shape), aux

        y, aux = jax.shard_map(
            body, mesh=lay.mesh,
            in_specs=(P(dp, m_ax), P(), P(m_ax)),
            out_specs=(P(dp, m_ax), P()),
        )(x, params["router"], params["experts"])

    if cfg.n_shared_experts:
        sp = shd.use_weight(params["shared"])
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        y = y + h @ sp["w_down"]
    return y, aux


def _moe_decode(cfg, params, x):
    """Decode path: tokens replicated, local experts densely masked + psum."""
    lay = shd.layout()
    m_ax = lay.model_axis
    B, S, D = x.shape
    dp = lay.dp_for(B)
    tp = lay.n_shards
    e_local = cfg.n_experts // tp

    def body(x_l, router_w, bank):
        tpi = jax.lax.axis_index(m_ax)
        xf = x_l.reshape(-1, D)                       # (T, D)
        topk_w, topk_i, f_e, p_e = _route(cfg, router_w, xf)
        w_dense = jnp.zeros((xf.shape[0], cfg.n_experts), jnp.float32)
        w_dense = w_dense.at[
            jnp.arange(xf.shape[0])[:, None], topk_i].set(topk_w)
        lo = tpi * e_local
        w_local = jax.lax.dynamic_slice_in_dim(w_dense, lo, e_local, axis=1)
        xt = jnp.broadcast_to(xf[None], (e_local,) + xf.shape)
        ye = _expert_ffn(bank, xt)                    # (E_local, T, D)
        y = jnp.einsum("te,etd->td", w_local.astype(x_l.dtype), ye)
        y = jax.lax.psum(y, m_ax)
        # tokens are replicated over "model" here, so f_e/p_e only vary
        # over the dp axes (if the batch is dp-sharded at all)
        if dp:
            f_m = jax.lax.pmean(f_e, tuple(dp))
            p_m = jax.lax.pmean(p_e, tuple(dp))
        else:
            f_m, p_m = f_e, p_e
        aux = _aux(cfg, f_m, p_m)
        return y.reshape(x_l.shape), aux

    y, aux = jax.shard_map(
        body, mesh=lay.mesh,
        in_specs=(P(dp), P(), P(m_ax)),
        out_specs=(P(dp), P()),
    )(x, params["router"], params["experts"])

    if cfg.n_shared_experts:
        sp = params["shared"]
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        y = y + h @ sp["w_down"]
    return y, aux
