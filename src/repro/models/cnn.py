"""The paper's own workload: a small 3-layer CNN classifier (MNIST-class).

Pure JAX (lax.conv_general_dilated); trained on the synthetic image task
(no dataset downloads in this container) for the Fig. 4 wall-clock
convergence reproduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def cnn_init(key, n_classes: int = 10):
    ks = jax.random.split(key, 4)
    w = lambda k, shape, fan: (jax.random.normal(k, shape, jnp.float32)
                               * (1.0 / jnp.sqrt(fan)))
    return {
        "c1": {"w": w(ks[0], (3, 3, 1, 16), 9), "b": jnp.zeros(16)},
        "c2": {"w": w(ks[1], (3, 3, 16, 32), 9 * 16), "b": jnp.zeros(32)},
        "c3": {"w": w(ks[2], (3, 3, 32, 32), 9 * 32), "b": jnp.zeros(32)},
        "fc": {"w": dense_init(ks[3], 7 * 7 * 32, n_classes, jnp.float32),
               "b": jnp.zeros(n_classes)},
    }


def _conv(x, p, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + p["b"])


def cnn_apply(params, x):
    """x: (B, 28, 28) -> logits (B, 10)."""
    h = x[..., None]
    h = _conv(h, params["c1"])
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")          # 14x14
    h = _conv(h, params["c2"])
    h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")          # 7x7
    h = _conv(h, params["c3"])
    h = h.reshape(h.shape[0], -1)
    return h @ params["fc"]["w"] + params["fc"]["b"]


def cnn_loss(params, x, y, weights=None):
    logits = cnn_apply(params, x)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    ce = lse - ll
    if weights is None:
        return jnp.mean(ce)
    w = weights.astype(jnp.float32)
    return jnp.sum(w * ce) / jnp.maximum(jnp.sum(w), 1e-6)
