"""Attention: projections + two distributed cores.

* ``attention_sp``  — train/prefill.  q stays sequence-sharded over the
  "model" axis; k/v are all-gathered (context parallelism).  Inside each
  shard the core is q-chunked (memory O(S·chunk)) and sliding-window layers
  slice only the needed KV span (FLOPs O(S·window)).
* ``attn_decode``   — single-token decode with the KV cache sequence-sharded
  over "model" and a flash-decoding (max/sum-exp psum) combine.

Both wrap the same pure-jnp local core ``attn_core`` which is also the
oracle contract implemented by the Pallas flash-attention kernel
(`repro.kernels.flash_attention`).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import layers as L
from repro.perf.knobs import knobs

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params.
# ---------------------------------------------------------------------------


def attn_init(cfg, key, dtype):
    d, qd, kvd = cfg.d_model, cfg.qkv_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], d, qd, dtype),
        "wk": L.dense_init(ks[1], d, kvd, dtype),
        "wv": L.dense_init(ks[2], d, kvd, dtype),
        "wo": L.dense_init(ks[3], qd, d, dtype),
    }
    if cfg.attn_bias:
        p["bq"] = L.zeros((qd,), dtype)
        p["bk"] = L.zeros((kvd,), dtype)
        p["bv"] = L.zeros((kvd,), dtype)
    if cfg.attn_out_bias:
        p["bo"] = L.zeros((d,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = L.ones((cfg.head_dim,), dtype)
        p["k_norm"] = L.ones((cfg.head_dim,), dtype)
    return p


def project_qkv(cfg, p, x, positions, *, rope: bool = True):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd), roped + qk-normed."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_head_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q, k = L.apply_rope(cfg, q, k, positions)
    return q, k, v


# ---------------------------------------------------------------------------
# Local core (oracle contract shared with the Pallas kernel).
# ---------------------------------------------------------------------------


def _scores_block(q, k, v, qpos, kpos, *, causal, window, softcap):
    """Dense attention on concrete blocks.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd); qpos: (B, Sq); kpos: (Sk,).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    sdt = jnp.bfloat16 if knobs().attn_scores_bf16 else jnp.float32
    qg = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=sdt)
    s = s * jnp.asarray(1.0 / float(hd) ** 0.5, sdt)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.ones((B, 1, 1, Sq, kpos.shape[0]), dtype=bool)
    kb = kpos[None, None, None, None, :]
    qb = qpos[:, None, None, :, None]
    if causal:
        mask = mask & (kb <= qb)
    if window > 0:
        mask = mask & (kb > qb - window)
    s = jnp.where(mask, s, jnp.asarray(NEG_INF if sdt == jnp.float32
                                       else -3e38, sdt))
    a = jax.nn.softmax(s, axis=-1)  # max-subtracted; bf16-safe under knob
    o = jnp.einsum("bkgqs,bskh->bqkgh", a.astype(v.dtype), v)
    return o.reshape(B, Sq, H, hd)


def attn_core(q, k, v, qpos, kpos, *, causal=True, window=0, softcap=0.0,
              q_chunk=None, slice_window=None):
    """Chunked local attention.

    Iterates q in chunks of ``q_chunk`` (memory O(Sq_chunk · Sk)); for
    sliding-window layers only the [chunk_start - window, chunk_end) KV span
    is touched (assumes row-uniform positions, which all our pipelines use).
    Knobs (repro.perf.knobs) supply the defaults — §Perf hillclimb levers.
    """
    kn = knobs()
    q_chunk = kn.q_chunk if q_chunk is None else q_chunk
    slice_window = kn.window_slice if slice_window is None else slice_window
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    qc = q_chunk if (Sq % q_chunk == 0 and Sq > q_chunk) else Sq
    n = Sq // qc
    if n == 1:
        return _scores_block(q, k, v, qpos, kpos, causal=causal,
                             window=window, softcap=softcap)

    qs = q.reshape(B, n, qc, H, hd).swapaxes(0, 1)
    qps = qpos.reshape(B, n, qc).swapaxes(0, 1)
    win_span = window + qc if window > 0 else 0
    use_slice = slice_window and window > 0 and win_span < Sk and causal

    def one(args):
        qi, qpi = args
        if use_slice:
            start = jnp.clip(qpi[0, 0] - window + 1, 0, Sk - win_span)
            ki = jax.lax.dynamic_slice_in_dim(k, start, win_span, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, win_span, axis=1)
            kpi = start + jnp.arange(win_span)
        else:
            ki, vi, kpi = k, v, kpos
        return _scores_block(qi, ki, vi, qpi, kpi, causal=causal,
                             window=window, softcap=softcap)

    if shd.unrolled():
        outs = [one((qs[i], qps[i])) for i in range(n)]
        out = jnp.stack(outs, axis=0)
    else:
        out = jax.lax.map(one, (qs, qps))
    return out.swapaxes(0, 1).reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# Train / prefill: sequence-parallel wrapper.
# ---------------------------------------------------------------------------


def attention_sp(q, k, v, qpos, *, causal=True, window=0, softcap=0.0,
                 q_chunk=None, kpos=None):
    """q sequence-sharded over "model"; k/v gathered to full sequence.

    kpos defaults to arange over the full (gathered) key length — correct for
    self-attention where keys span the whole global sequence.
    """
    lay = shd.layout()
    Sk = k.shape[1]
    if lay.mesh is None or lay.mode != "train_sp" or lay.model_axis is None:
        kp = kpos if kpos is not None else jnp.arange(Sk)
        return attn_core(q, k, v, qpos, kp, causal=causal, window=window,
                         softcap=softcap, q_chunk=q_chunk)

    m = lay.model_axis
    dp = lay.dp if lay.dp else None
    tp = lay.n_shards
    S_loc = Sk // tp

    if (knobs().attn_halo and causal and window > 0
            and -(-window // S_loc) < tp - 1):
        # HALO EXCHANGE (beyond-paper §Perf): a sliding-window layer only
        # attends ceil(W / S_loc) chunks back — collect those via ppermute
        # instead of all-gathering the full sequence.  Backward traffic
        # (the dKV reduction) shrinks to the same neighborhood.
        n_hops = -(-window // S_loc)

        def halo_body(q_l, k_l, v_l, qpos_l):
            idx = jax.lax.axis_index(m)
            parts_k, parts_v = [], []
            for h in range(n_hops, 0, -1):
                perm = [(s, s + h) for s in range(tp - h)]
                parts_k.append(jax.lax.ppermute(k_l, m, perm))
                parts_v.append(jax.lax.ppermute(v_l, m, perm))
            k_ext = jnp.concatenate(parts_k + [k_l], axis=1)
            v_ext = jnp.concatenate(parts_v + [v_l], axis=1)
            base = (idx - n_hops) * S_loc
            kp = base + jnp.arange((n_hops + 1) * S_loc)
            # non-received halo chunks are zeros; their kp < 0 masks them out
            kp = jnp.where(kp < 0, -(10 ** 9), kp)
            return attn_core(q_l, k_ext, v_ext, qpos_l, kp, causal=causal,
                             window=window, softcap=softcap,
                             q_chunk=q_chunk, slice_window=False)

        return jax.shard_map(
            halo_body, mesh=lay.mesh,
            in_specs=(P(dp, m), P(dp, m), P(dp, m), P(dp, m)),
            out_specs=P(dp, m),
        )(q, k, v, qpos)

    def body(q_l, k_f, v_f, qpos_l):
        kp = jnp.arange(k_f.shape[1])
        return attn_core(q_l, k_f, v_f, qpos_l, kp, causal=causal,
                         window=window, softcap=softcap, q_chunk=q_chunk)

    return jax.shard_map(
        body, mesh=lay.mesh,
        in_specs=(P(dp, m), P(dp), P(dp), P(dp, m)),
        out_specs=P(dp, m),
    )(q, k, v, qpos)


# ---------------------------------------------------------------------------
# Decode: sequence-sharded KV cache + flash-decoding combine.
# ---------------------------------------------------------------------------


def _decode_block(q, k_l, v_l, kpos, pos, *, window, softcap):
    """Partial attention stats over a local KV span.

    q: (B, H, hd); k_l/v_l: (B, L_l, KV, hd); kpos: (L_l,) global positions.
    Returns (m, l, o) partials for the flash combine.
    """
    B, H, hd = q.shape
    KV = k_l.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_l).astype(jnp.float32)
    s = s * (1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = kpos[None, None, None, :] <= pos
    if window > 0:
        valid = valid & (kpos[None, None, None, :] > pos - window)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1)                      # (B, KV, G)
    e = jnp.exp(s - m[..., None])
    l = jnp.sum(e, axis=-1)                      # (B, KV, G)
    o = jnp.einsum("bkgs,bskh->bkgh", e, v_l.astype(jnp.float32))
    return m, l, o


def attn_decode(q, k_new, v_new, cache_k, cache_v, pos, *, window=0,
                softcap=0.0):
    """One-token decode.

    q/k_new/v_new: (B, 1, {H|KV}, hd) replicated over "model";
    cache_{k,v}: (B, L, KV, hd), sequence-sharded over "model" in decode_tp.
    pos: scalar int32 — number of tokens already in the cache (the new token
    is written at index ``pos`` and attends over [0, pos]).
    Returns (y (B,1,H,hd), new_cache_k, new_cache_v).
    """
    lay = shd.layout()
    B, _, H, hd = q.shape

    if lay.mesh is None or lay.mode != "decode_tp" or lay.model_axis is None:
        ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, pos, axis=1)
        kpos = jnp.arange(ck.shape[1])
        m, l, o = _decode_block(q[:, 0], ck, cv, kpos, pos,
                                window=window, softcap=softcap)
        y = (o / l[..., None]).reshape(B, 1, H, hd).astype(q.dtype)
        return y, ck, cv

    m_ax = lay.model_axis
    dp = lay.dp_for(B)

    def body(q_f, kn, vn, ck_l, cv_l, pos_s):
        pos_s = pos_s[0] if pos_s.ndim else pos_s
        idx = jax.lax.axis_index(m_ax)
        L_l = ck_l.shape[1]
        lo = idx * L_l
        # write the new token into whichever shard owns position `pos`
        rel = jnp.clip(pos_s - lo, 0, L_l - 1)
        in_range = (pos_s >= lo) & (pos_s < lo + L_l)
        ck_u = jax.lax.dynamic_update_slice_in_dim(ck_l, kn, rel, axis=1)
        cv_u = jax.lax.dynamic_update_slice_in_dim(cv_l, vn, rel, axis=1)
        ck_l = jnp.where(in_range, ck_u, ck_l)
        cv_l = jnp.where(in_range, cv_u, cv_l)
        kpos = lo + jnp.arange(L_l)
        m, l, o = _decode_block(q_f[:, 0], ck_l, cv_l, kpos, pos_s,
                                window=window, softcap=softcap)
        m_g = jax.lax.pmax(m, m_ax)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, m_ax)
        o_g = jax.lax.psum(o * corr[..., None], m_ax)
        B_l = q_f.shape[0]
        y = (o_g / l_g[..., None]).reshape(B_l, 1, H, hd).astype(q_f.dtype)
        return y, ck_l, cv_l

    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1)
    return jax.shard_map(
        body, mesh=lay.mesh,
        in_specs=(P(dp), P(dp), P(dp), P(dp, m_ax), P(dp, m_ax), P()),
        out_specs=(P(dp), P(dp, m_ax), P(dp, m_ax)),
    )(q, k_new, v_new, cache_k, cache_v, pos_arr)
