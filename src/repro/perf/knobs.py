"""Performance knobs — the §Perf hillclimb levers.

Set per-experiment (dry-run CLI / hillclimb harness) via a contextvar so
model code stays clean.  Every knob defaults to the paper-faithful baseline.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Knobs:
    q_chunk: int = 256          # attention q-chunk rows
    window_slice: bool = True   # slice KV span for sliding-window layers
    ce_impl: str = "dense"      # dense | ring  (vocab-ring fused CE)
    ce_chunk: int = 0           # >0: vocab chunking within the ring step
    fsdp_gather: str = "wsc"    # wsc | shardmap (all_gather w/ reduce-
                                # scatter AD transpose; dim0-only sharding)
    moe_capacity_factor: float = 0.0  # >0 overrides the config value
    remat: bool = True
    attn_scores_bf16: bool = False  # softmax chain in bf16 (inference)
    attn_halo: bool = False   # sliding-window layers exchange KV halos via
                              # ppermute instead of all-gathering full seq


_current: contextvars.ContextVar[Knobs] = contextvars.ContextVar(
    "repro_knobs", default=Knobs())


def knobs() -> Knobs:
    return _current.get()


@contextlib.contextmanager
def use_knobs(**kw):
    tok = _current.set(replace(_current.get(), **kw))
    try:
        yield _current.get()
    finally:
        _current.reset(tok)
