"""Parse collective traffic + roofline terms from compiled HLO.

``cost_analysis`` gives FLOPs and HBM bytes but not collective bytes; those
are summed from the optimized (post-SPMD) HLO text: every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute result size.
Async pairs (-start/-done) are counted once via the -start op.
"""
from __future__ import annotations

import math
import re
from typing import Dict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind from HLO text."""
    out: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", s)
        if not m:
            continue
        rhs = m.group(1)
        for kind in COLLECTIVES:
            # match "<type> <kind>(" or "<type> <kind>-start("
            km = re.match(r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                          + kind + r"(-start)?\(", rhs)
            if km:
                out[kind] += _shape_bytes(km.group(1))
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e).
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip effective)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    t_c = flops_per_dev / PEAK_FLOPS_BF16
    t_m = bytes_per_dev / HBM_BW
    t_n = coll_bytes_per_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])[0]
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "bound": dom, "step_s_lower_bound": max(t_c, t_m, t_n)}
