"""Deterministic synthetic data pipelines.

The paper (§4.3) requires sampling mini-batches WITH REPLACEMENT rather than
pre-partitioning data onto workers: under cutoff SGD a persistently-slow
worker would otherwise never contribute its shard.  ``SyntheticTokens``
implements exactly that: every (step, worker) pair draws its sub-mini-batch
by seeded hash, so any worker's draw is reproducible regardless of which
workers were dropped — this is also what makes checkpoint/restart and
elastic resizing deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass
class SyntheticTokens:
    """Markov-chain token stream → (tokens, labels) batches.

    A fixed random transition structure gives a learnable distribution
    (loss decreases materially from uniform), unlike iid-uniform tokens.
    """
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 16  # successors per token

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.succ = rng.integers(0, self.vocab_size,
                                 size=(self.vocab_size, self.branch))

    def _gen(self, rng: np.random.Generator, n: int) -> np.ndarray:
        toks = np.empty((n, self.seq_len + 1), np.int64)
        cur = rng.integers(0, self.vocab_size, size=n)
        for t in range(self.seq_len + 1):
            toks[:, t] = cur
            pick = rng.integers(0, self.branch, size=n)
            cur = self.succ[cur, pick]
        return toks

    def batch(self, step: int, worker: Optional[int] = None,
              n_workers: int = 1) -> Dict[str, np.ndarray]:
        """Batch for (step, worker) — sampling with replacement by seed."""
        if worker is None:
            rng = np.random.default_rng((self.seed, step))
            n = self.global_batch
        else:
            if self.global_batch % n_workers != 0:
                raise ValueError(
                    f"global batch {self.global_batch} is not divisible by "
                    f"{n_workers} workers — per-worker draws would silently "
                    f"truncate and disagree with the worker=None full batch "
                    f"(pick a worker count that divides {self.global_batch},"
                    f" matching the Trainer's B % W check)")
            rng = np.random.default_rng((self.seed, step, worker))
            n = self.global_batch // n_workers
        toks = self._gen(rng, n)
        pos = np.broadcast_to(np.arange(self.seq_len), (n, self.seq_len))
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
                "positions": np.ascontiguousarray(pos.astype(np.int32))}

    def state(self) -> dict:
        return {"seed": self.seed}


@dataclass
class SyntheticImages:
    """Class-conditional Gaussian images (the MNIST stand-in: no network
    access in this container).  10 classes, 28x28, fixed class templates."""
    n_classes: int = 10
    side: int = 28
    noise: float = 0.35
    seed: int = 0
    n_train: int = 60_000
    n_valid: int = 10_000

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.templates = rng.normal(size=(self.n_classes, self.side,
                                          self.side)).astype(np.float32)
        # smooth the templates to make the task non-trivial but learnable
        for _ in range(2):
            t = self.templates
            self.templates = (t + np.roll(t, 1, 1) + np.roll(t, -1, 1)
                              + np.roll(t, 1, 2) + np.roll(t, -1, 2)) / 5.0

    def _make(self, rng, n):
        y = rng.integers(0, self.n_classes, size=n)
        x = self.templates[y] + self.noise * rng.normal(
            size=(n, self.side, self.side)).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)

    def batch(self, step: int, batch_size: int,
              worker: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed, step, 2**31 - 1 if worker is None else worker))
        return self._make(rng, batch_size)

    def valid_set(self) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, 10**9))
        return self._make(rng, self.n_valid)
