"""Architecture config system.

Every assigned architecture is a frozen :class:`ArchConfig`.  The full configs
are exercised only through the AOT dry-run (``launch/dryrun.py``); smoke tests
use ``cfg.reduced()`` which shrinks every scale knob while preserving the
family-specific structure (MoE routing, sliding-window pattern, hybrid heads,
enc-dec, ...).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned; identical for every LM-family arch).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- norm / mlp / attention flavour ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    mlp_bias: bool = False
    attn_bias: bool = False  # bias on qkv projections
    attn_out_bias: bool = False
    qk_norm: bool = False
    attn_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    partial_rotary: float = 1.0  # fraction of head_dim rotated
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) pairs
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)

    # --- layer pattern (sliding-window / global mix) ---
    sliding_window: int = 0  # 0 => full attention everywhere
    # pattern of attention kinds, cycled over layers: "L"=local(sliding), "G"=global
    layer_pattern: str = ""  # e.g. gemma3 "LLLLLG"; "" => all global
    global_layer_ids: Tuple[int, ...] = ()  # hymba-style explicit overrides

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0  # deepseek: layer 0 keeps a dense FFN
    dense_d_ff: int = 0  # d_ff used by those first dense layers
    router_scale: bool = False  # deepseek normalises top-k weights
    moe_capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    slstm_every: int = 0  # xlstm: every k-th block is an sLSTM block
    hybrid_parallel: bool = False  # hymba: attention and mamba heads in parallel

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1536  # padded whisper frame count (1500 -> 1536)

    # --- modality frontend stub ---
    frontend: str = "none"  # none | audio_frames | vision_patches

    # --- runtime ---
    dtype: str = "bfloat16"
    max_seq_len: int = 1_048_576
    subquadratic: bool = False  # eligible for long_500k
    source: str = ""  # provenance note

    # ------------------------------------------------------------------
    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def attn_kind(self, layer_id: int) -> str:
        """Return "G" (global/full) or "L" (local/sliding) for a layer."""
        if layer_id in self.global_layer_ids:
            return "G"
        if self.layer_pattern:
            return self.layer_pattern[layer_id % len(self.layer_pattern)]
        if self.sliding_window and not self.global_layer_ids:
            return "L"
        if self.sliding_window:
            return "L"
        return "G"

    def n_params(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, hd = self.d_model, self.head_dim
        attn = d * self.qkv_dim + 2 * d * self.kv_dim + self.qkv_dim * d
        if self.attn_bias:
            attn += self.qkv_dim + 2 * self.kv_dim
        per_layer = attn + 2 * d  # norms
        total = 0
        for i in range(self.n_layers):
            ff = per_layer
            if self.family == "moe" and i >= self.first_dense_layers:
                e_ff = self.moe_d_ff
                n_e = self.n_experts + self.n_shared_experts
                ff += n_e * 3 * d * e_ff + d * self.n_experts
            else:
                dff = self.dense_d_ff if (self.family == "moe" and self.dense_d_ff) else self.d_ff
                mult = 3 if self.mlp in ("swiglu", "geglu") else 2
                ff += mult * d * dff
            total += ff
        total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += d  # final norm
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: shared + top_k routed only)."""
        if self.family != "moe":
            return self.n_params()
        d = self.d_model
        dense = self.n_params()
        n_moe_layers = self.n_layers - self.first_dense_layers
        inactive = (self.n_experts - self.top_k) * 3 * d * self.moe_d_ff * n_moe_layers
        return dense - inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Smoke-test sized config preserving the family structure."""
        changes = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            dtype="float32",
            max_seq_len=512,
        )
        if self.family == "moe":
            changes.update(n_experts=8, top_k=min(self.top_k, 2), moe_d_ff=32,
                           n_shared_experts=min(self.n_shared_experts, 1),
                           first_dense_layers=min(self.first_dense_layers, 1),
                           dense_d_ff=128 if self.dense_d_ff else 0)
        if self.sliding_window:
            changes.update(sliding_window=8)
        if self.global_layer_ids:
            changes.update(global_layer_ids=(0, 2))
        if self.layer_pattern:
            # keep the same cyclic pattern but fewer layers
            changes.update(n_layers=len(self.layer_pattern))
        if self.slstm_every:
            changes.update(n_layers=4, slstm_every=4)
        if self.is_encoder_decoder:
            changes.update(n_encoder_layers=2, n_layers=2, encoder_seq_len=32)
        if self.ssm_state:
            changes.update(ssm_state=8)
        if self.mrope_sections:
            changes.update(mrope_sections=(2, 3, 3))  # sums to head_dim//2 = 8
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> Tuple[str, ...]:
    if not _REGISTRY:
        _load_all()
    return tuple(sorted(_REGISTRY))


def bench_tiny_config(name: str = "qwen2-0.5b") -> "ArchConfig":
    """A deliberately tiny LM so the PS decision path is a visible
    fraction of the train step — the regime the paper's 158-worker
    cluster runs in (sub-second steps, controller on the critical path).
    The one config the controller/elastic benches, demos, and the elastic
    acceptance tests all share.
    """
    import dataclasses

    cfg = get_config(name).reduced()
    return dataclasses.replace(cfg, n_layers=2, d_model=32, n_heads=2,
                               n_kv_heads=1, head_dim=16, d_ff=64,
                               vocab_size=256)


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        qwen2_vl_7b, deepseek_moe_16b, phi35_moe, stablelm_3b, gemma3_12b,
        starcoder2_3b, qwen2_05b, xlstm_350m, hymba_15b, whisper_base,
    )


def cells():
    """Yield every assigned (arch, shape) cell plus its run/skip decision."""
    for arch in all_archs():
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and not cfg.subquadratic:
                skip = "full-attention arch: long_500k requires sub-quadratic attention"
            yield cfg, shape, skip
