"""Whisper-base [arXiv:2212.04356; unverified].

Encoder-decoder; the conv frontend is a STUB — ``input_specs`` provides
precomputed frame embeddings (batch, 1536, d_model) (1500 mel frames padded
to 1536 for even sharding).  Decoder: self-attn (causal) + cross-attn.
Learned positions (no RoPE).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    norm="layernorm", norm_eps=1e-5, mlp="gelu", mlp_bias=True,
    attn_bias=True, attn_out_bias=True,
    rope_theta=0.0,  # 0 => learned absolute positions
    is_encoder_decoder=True, n_encoder_layers=6, encoder_seq_len=1536,
    frontend="audio_frames",
    source="arXiv:2212.04356; unverified",
))
