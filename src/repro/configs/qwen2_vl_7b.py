"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].

Vision frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings + an is-image mask; the backbone consumes a mixed embedding stream.
M-RoPE uses 3 position streams (t, h, w) with sections (16, 24, 24) half-dim
pairs (sums to head_dim/2 = 64).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    norm="rmsnorm", norm_eps=1e-6, mlp="swiglu",
    attn_bias=True, rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision_patches",
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-7B-Instruct",
))
