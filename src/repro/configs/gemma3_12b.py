"""Gemma-3-12B [hf:google/gemma-3-1b-pt scaled; unverified].

5:1 local:global sliding-window pattern (window 1024), GeGLU, qk-norm,
head_dim=256, 262k vocab, embeddings scaled by sqrt(d_model).
Layer pattern "LLLLLG" cycles over 48 layers = 8 repeats.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    norm="rmsnorm", norm_eps=1e-6, mlp="geglu",
    qk_norm=True, embed_scale=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
    sliding_window=1024, layer_pattern="LLLLLG",
    source="hf:google/gemma-3-12b-pt family; unverified",
))
