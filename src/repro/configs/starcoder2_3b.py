"""StarCoder2-3B [arXiv:2402.19173; hf:bigcode/starcoder2-3b].

GQA kv=2, LayerNorm, GELU MLP with bias, RoPE.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab_size=49152,
    norm="layernorm", norm_eps=1e-5, mlp="gelu", mlp_bias=True,
    attn_bias=True, attn_out_bias=True, rope_theta=999_999.44,
    source="arXiv:2402.19173; hf",
))
