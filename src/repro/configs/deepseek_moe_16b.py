"""DeepSeekMoE-16B [arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base].

Fine-grained MoE: 64 routed experts (top-6) + 2 shared experts, expert
d_ff=1408.  Layer 0 keeps a dense FFN with d_ff=10944 (first_k_dense_replace=1
in the HF config).  MHA (kv == heads == 16).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    norm="rmsnorm", norm_eps=1e-6, mlp="swiglu",
    rope_theta=10_000.0,
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense_layers=1, dense_d_ff=10944, router_scale=True,
    source="arXiv:2401.06066; hf",
))
