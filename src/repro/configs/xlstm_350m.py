"""xLSTM-350M [arXiv:2405.04517; unverified].

24 blocks at 7:1 mLSTM:sLSTM (every 8th block is sLSTM).  mLSTM: matrix
memory with exponential gating, chunkwise-parallel training form; sLSTM:
scalar memory, sequential lax.scan recurrence.  Sub-quadratic => runs
long_500k.  d_ff=0 per the assignment (block-internal up/down projections
use ssm_expand).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
    d_ff=0, vocab_size=50304,
    norm="rmsnorm", norm_eps=1e-6, mlp="swiglu",
    ssm_expand=2, slstm_every=8,
    subquadratic=True,
    source="arXiv:2405.04517; unverified",
))
