"""Hymba-1.5B [arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base].

Hybrid-head blocks: attention heads and Mamba(SSM) heads run in PARALLEL on
the same input; outputs are normalised and averaged.  Sliding-window
attention everywhere except global full-attention layers {0, 15, 31}.
Meta-tokens are stubbed (noted in DESIGN.md).  ssm_state=16.
Sub-quadratic (SWA + SSM; 3 global layers carry the long KV) => runs long_500k.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    norm="rmsnorm", norm_eps=1e-6, mlp="swiglu",
    sliding_window=1024, global_layer_ids=(0, 15, 31),
    ssm_state=16, ssm_expand=2, hybrid_parallel=True,
    subquadratic=True,
    source="arXiv:2411.13676; hf",
))
