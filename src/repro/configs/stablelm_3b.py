"""StableLM-3B (stablelm-2 family) [hf:stabilityai/stablelm-2-1_6b; unverified].

LayerNorm, partial rotary (25% of head_dim), MHA kv==heads.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab_size=50304,
    norm="layernorm", norm_eps=1e-5, mlp="swiglu",
    partial_rotary=0.25, rope_theta=10_000.0,
    source="hf:stabilityai/stablelm-2-1_6b; unverified",
))
