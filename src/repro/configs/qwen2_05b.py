"""Qwen2-0.5B [arXiv:2407.10671; hf:Qwen/Qwen2-0.5B].

GQA kv=2, QKV bias, tied embeddings.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151936,
    norm="rmsnorm", norm_eps=1e-6, mlp="swiglu",
    attn_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
    source="arXiv:2407.10671; hf",
))
