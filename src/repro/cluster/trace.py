"""Trace record/replay — the paper's 'instrument the cluster once' step."""
from __future__ import annotations

import os
from typing import Optional

import numpy as np


def save_trace(path: str, times: np.ndarray, meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, times=np.asarray(times, np.float32),
                        **{f"meta_{k}": v for k, v in (meta or {}).items()})


def load_trace(path: str) -> np.ndarray:
    with np.load(path) as z:
        return np.asarray(z["times"], np.float64)


class TraceReplay:
    """Replays a recorded trace with the ClusterSim interface."""

    def __init__(self, times: np.ndarray, loop: bool = True):
        self.times = np.asarray(times, np.float64)
        self.loop = loop
        self.t = 0
        self.n_workers = self.times.shape[1]

    def step(self) -> np.ndarray:
        if self.t >= len(self.times):
            if not self.loop:
                raise StopIteration
            self.t = 0
        out = self.times[self.t]
        self.t += 1
        return out

    def run(self, n_steps: int) -> np.ndarray:
        return np.stack([self.step() for _ in range(n_steps)])
