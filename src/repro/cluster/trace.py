"""Trace record/replay — the paper's 'instrument the cluster once' step."""
from __future__ import annotations

import os
from typing import List, Optional, Tuple, Union

import numpy as np


def save_trace(path: str, times: np.ndarray, meta: Optional[dict] = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez_compressed(path, times=np.asarray(times, np.float32),
                        **{f"meta_{k}": v for k, v in (meta or {}).items()})


def load_trace(path: str, with_meta: bool = False
               ) -> Union[np.ndarray, Tuple[np.ndarray, dict]]:
    """Load a recorded trace.

    ``with_meta=True`` also returns the ``meta_*`` entries ``save_trace``
    wrote (prefixes stripped, 0-d arrays unwrapped to python scalars) —
    previously these were silently dropped on load.
    """
    with np.load(path) as z:
        times = np.asarray(z["times"], np.float64)
        if not with_meta:
            return times
        meta = {}
        for k in z.files:
            if k.startswith("meta_"):
                v = z[k]
                meta[k[len("meta_"):]] = v.item() if v.ndim == 0 else v
        return times, meta


class TraceReplay:
    """Replays a recorded trace with the ClusterSim interface.

    ``times`` is one (T, n) array, or a list of such segments whose widths
    may differ — the recorded form of a run whose worker set changed
    (``ChurnSim``).  ``n_workers`` always reflects the width of the row the
    NEXT ``step()`` returns.  With ``loop=False`` an exhausted replay
    raises ``IndexError`` (a bare ``StopIteration`` — the old behavior —
    is swallowed silently inside generators and for-loops).
    """

    def __init__(self, times, loop: bool = True):
        if isinstance(times, (list, tuple)):
            segs = [np.asarray(t, np.float64) for t in times]
        else:
            segs = [np.asarray(times, np.float64)]
        if not segs or any(s.ndim != 2 or s.shape[0] == 0 for s in segs):
            raise ValueError("TraceReplay needs non-empty (T, n) segments")
        self.segments: List[np.ndarray] = segs
        # flat view for width-uniform traces (the common, recorded case)
        widths = {s.shape[1] for s in segs}
        self.times = (np.concatenate(segs) if len(widths) == 1 else None)
        self.loop = loop
        self.t = 0          # steps served so far (ClusterSim-compatible)
        self._seg = 0
        self._row = 0

    @property
    def n_workers(self) -> int:
        seg = min(self._seg, len(self.segments) - 1)
        return self.segments[seg].shape[1]

    def step(self) -> np.ndarray:
        if self._seg >= len(self.segments):
            raise IndexError(
                f"TraceReplay exhausted after {self.t} steps (loop=False)")
        seg = self.segments[self._seg]
        out = seg[self._row]
        self._row += 1
        if self._row >= seg.shape[0]:
            self._row = 0
            self._seg += 1
            if self._seg >= len(self.segments) and self.loop:
                self._seg = 0
        self.t += 1
        return out

    def run(self, n_steps: int) -> np.ndarray:
        return np.stack([self.step() for _ in range(n_steps)])
