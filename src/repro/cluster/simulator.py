"""Cluster run-time simulator.

Generates joint worker runtimes with the phenomenology the paper observes on
its real clusters (Fig. 2): machine-correlated slowdowns (workers share
nodes), time-correlated regimes (a slow node persisting for ~60 iterations,
then equilibrating), contention periods, and heavy-tailed per-worker
straggler spikes.  On real hardware the same interface is backed by
``time.monotonic()`` measurements per host; the simulator is the stand-in
the CPU-only container uses for end-to-end runs and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class Regime:
    name: str
    node_mult: np.ndarray      # (n_nodes,) multiplicative slowdown
    extra_noise: float = 0.0   # additional lognormal sigma


@dataclass
class ClusterSim:
    """Regime-switching, node-correlated runtime generator."""
    n_workers: int
    n_nodes: int = 4
    base_mean: float = 1.0
    worker_hetero: float = 0.15   # fixed per-worker speed spread
    noise_sigma: float = 0.07     # iid lognormal noise
    ar_rho: float = 0.9           # AR(1) node-load persistence
    ar_sigma: float = 0.05
    spike_prob: float = 0.015     # heavy-tail straggler probability
    spike_scale: float = 0.8
    regime_stay: float = 0.985    # Markov chain self-transition
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._rng = rng
        # node assignment: contiguous groups (like cores on a machine)
        sizes = np.full(self.n_nodes, self.n_workers // self.n_nodes)
        sizes[: self.n_workers % self.n_nodes] += 1
        self.node_of = np.repeat(np.arange(self.n_nodes), sizes)
        self.mu = self.base_mean * (
            1.0 + self.worker_hetero * (rng.uniform(size=self.n_workers)
                                        - 0.3))
        self.regimes = self._make_regimes()
        self._state = rng.integers(len(self.regimes))
        self._load = np.zeros(self.n_nodes)
        self.t = 0

    def _make_regimes(self) -> List[Regime]:
        ones = np.ones(self.n_nodes)
        regs = [Regime("uniform", ones.copy())]
        for k in range(self.n_nodes):
            m = ones.copy()
            m[k] = 1.9
            regs.append(Regime(f"slow_node_{k}", m))
        regs.append(Regime("contended", ones * 1.35, extra_noise=0.12))
        return regs

    # ------------------------------------------------------------------
    def step(self) -> np.ndarray:
        """One SGD iteration's joint runtimes (n_workers,)."""
        rng = self._rng
        if rng.uniform() > self.regime_stay:
            self._state = rng.integers(len(self.regimes))
        reg = self.regimes[self._state]
        self._load = (self.ar_rho * self._load
                      + self.ar_sigma * rng.standard_normal(self.n_nodes))
        node_factor = reg.node_mult * np.exp(self._load)
        sigma = self.noise_sigma + reg.extra_noise
        noise = np.exp(sigma * rng.standard_normal(self.n_workers)
                       - 0.5 * sigma ** 2)
        spikes = np.where(rng.uniform(size=self.n_workers) < self.spike_prob,
                          1.0 + rng.exponential(self.spike_scale,
                                                self.n_workers), 1.0)
        t = self.mu * node_factor[self.node_of] * noise * spikes
        self.t += 1
        return t

    def run(self, n_steps: int) -> np.ndarray:
        return np.stack([self.step() for _ in range(n_steps)])

    @property
    def regime_name(self) -> str:
        return self.regimes[self._state].name


# ---------------------------------------------------------------------------
# Churn layer: elastic worker membership on top of any runtime source.
# ---------------------------------------------------------------------------


@dataclass
class ChurnEvent:
    """One membership change, keyed on the base simulator's step count.

    ``kill`` / ``restore`` name GLOBAL worker ids (columns of the base
    sim); ``resize`` is a convenience target width — extra kills come off
    the highest active ids, restores come back lowest-id first.  The event
    fires BEFORE the runtimes of iteration ``step`` are drawn, so the
    step at which it fires already runs at the new width.
    """
    step: int
    kill: Tuple[int, ...] = ()
    restore: Tuple[int, ...] = ()
    resize: Optional[int] = None


class ChurnSim:
    """Membership schedule wrapped around a ClusterSim (or TraceReplay).

    The base simulator keeps generating FULL-width joint runtimes — the
    cluster's phenomenology (node regimes, AR load) is independent of which
    workers currently hold a lease — and ``step()`` returns only the active
    columns.  ``n_workers`` / ``active_ids`` reflect the membership of the
    NEXT ``step()`` (pending events are applied eagerly), so a driver can
    resize its plumbing before drawing the runtimes of the resized step.

    Survivor columns are therefore column-exact across a resize: worker j's
    runtime series is the same whether or not its neighbours were killed.
    """

    def __init__(self, base, events: List[ChurnEvent]):
        self.base = base
        self.events = sorted(events, key=lambda e: e.step)
        self._active = np.ones(base.n_workers, bool)
        self._ei = 0
        self._apply_pending()

    def _apply_pending(self):
        while (self._ei < len(self.events)
               and self.events[self._ei].step <= self.base.t):
            ev = self.events[self._ei]
            self._ei += 1
            if ev.kill:
                self._active[list(ev.kill)] = False
            if ev.restore:
                self._active[list(ev.restore)] = True
            if ev.resize is not None:
                n = int(ev.resize)
                if not 1 <= n <= self.base.n_workers:
                    raise ValueError(f"resize target {n} outside "
                                     f"[1, {self.base.n_workers}]")
                ids = np.flatnonzero(self._active)
                if n < ids.size:                  # kill highest active ids
                    self._active[ids[n:]] = False
                elif n > ids.size:                # restore lowest dead ids
                    dead = np.flatnonzero(~self._active)
                    self._active[dead[: n - ids.size]] = True

    @property
    def n_workers(self) -> int:
        self._apply_pending()
        return int(self._active.sum())

    @property
    def active_ids(self) -> np.ndarray:
        """Global worker ids of the active set, ascending."""
        self._apply_pending()
        return np.flatnonzero(self._active)

    @property
    def t(self) -> int:
        return self.base.t

    def step(self) -> np.ndarray:
        """Joint runtimes of the CURRENT active set ((n_active,))."""
        self._apply_pending()
        active = self._active.copy()
        return self.base.step()[active]

    def run(self, n_steps: int) -> List[np.ndarray]:
        """Rows may change width across events, so this returns a list."""
        return [self.step() for _ in range(n_steps)]


def resize_schedule(base, plan: List[Tuple[int, int]]) -> ChurnSim:
    """ChurnSim from a [(step, n_workers), ...] width plan."""
    return ChurnSim(base, [ChurnEvent(step=s, resize=n) for s, n in plan])


# ---------------------------------------------------------------------------
# Presets matching the paper's two clusters.
# ---------------------------------------------------------------------------


def paper_cluster_158(seed: int = 0, n_workers: int = 158) -> ClusterSim:
    """4 nodes x 40 Xeon cores, 1 PS + 1 spare => 158 workers (paper §4.1).

    Calibrated near the paper's measured moments (mean 1.057 s, std 0.393 s).
    ``n_workers`` scales the same phenomenology down for CPU-budget
    end-to-end tests (node count and per-worker moments unchanged).
    """
    return ClusterSim(n_workers=n_workers, n_nodes=4, base_mean=1.0,
                      worker_hetero=0.15, noise_sigma=0.07,
                      spike_prob=0.02, spike_scale=0.9, seed=seed)


def cray_xc40_2175(seed: int = 0) -> ClusterSim:
    """32 KNL nodes x 68 logical cores, minus the PS => 2175 workers."""
    return ClusterSim(n_workers=2175, n_nodes=32, base_mean=1.0,
                      worker_hetero=0.1, noise_sigma=0.05,
                      spike_prob=0.01, spike_scale=0.7,
                      regime_stay=0.99, seed=seed)


def tpu_pod_hosts(n_hosts: int = 64, seed: int = 0) -> ClusterSim:
    """Per-host step-time jitter for a TPU pod (input pipeline + DCN):
    weaker heterogeneity, rarer spikes — the regime the controller sees when
    driving the masked-psum cutoff on the production mesh."""
    return ClusterSim(n_workers=n_hosts, n_nodes=max(2, n_hosts // 16),
                      base_mean=1.0, worker_hetero=0.04, noise_sigma=0.03,
                      spike_prob=0.01, spike_scale=1.5, regime_stay=0.995,
                      seed=seed)
