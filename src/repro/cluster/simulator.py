"""Cluster run-time simulator.

Generates joint worker runtimes with the phenomenology the paper observes on
its real clusters (Fig. 2): machine-correlated slowdowns (workers share
nodes), time-correlated regimes (a slow node persisting for ~60 iterations,
then equilibrating), contention periods, and heavy-tailed per-worker
straggler spikes.  On real hardware the same interface is backed by
``time.monotonic()`` measurements per host; the simulator is the stand-in
the CPU-only container uses for end-to-end runs and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class Regime:
    name: str
    node_mult: np.ndarray      # (n_nodes,) multiplicative slowdown
    extra_noise: float = 0.0   # additional lognormal sigma


@dataclass
class ClusterSim:
    """Regime-switching, node-correlated runtime generator."""
    n_workers: int
    n_nodes: int = 4
    base_mean: float = 1.0
    worker_hetero: float = 0.15   # fixed per-worker speed spread
    noise_sigma: float = 0.07     # iid lognormal noise
    ar_rho: float = 0.9           # AR(1) node-load persistence
    ar_sigma: float = 0.05
    spike_prob: float = 0.015     # heavy-tail straggler probability
    spike_scale: float = 0.8
    regime_stay: float = 0.985    # Markov chain self-transition
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._rng = rng
        # node assignment: contiguous groups (like cores on a machine)
        sizes = np.full(self.n_nodes, self.n_workers // self.n_nodes)
        sizes[: self.n_workers % self.n_nodes] += 1
        self.node_of = np.repeat(np.arange(self.n_nodes), sizes)
        self.mu = self.base_mean * (
            1.0 + self.worker_hetero * (rng.uniform(size=self.n_workers)
                                        - 0.3))
        self.regimes = self._make_regimes()
        self._state = rng.integers(len(self.regimes))
        self._load = np.zeros(self.n_nodes)
        self.t = 0

    def _make_regimes(self) -> List[Regime]:
        ones = np.ones(self.n_nodes)
        regs = [Regime("uniform", ones.copy())]
        for k in range(self.n_nodes):
            m = ones.copy()
            m[k] = 1.9
            regs.append(Regime(f"slow_node_{k}", m))
        regs.append(Regime("contended", ones * 1.35, extra_noise=0.12))
        return regs

    # ------------------------------------------------------------------
    def step(self) -> np.ndarray:
        """One SGD iteration's joint runtimes (n_workers,)."""
        rng = self._rng
        if rng.uniform() > self.regime_stay:
            self._state = rng.integers(len(self.regimes))
        reg = self.regimes[self._state]
        self._load = (self.ar_rho * self._load
                      + self.ar_sigma * rng.standard_normal(self.n_nodes))
        node_factor = reg.node_mult * np.exp(self._load)
        sigma = self.noise_sigma + reg.extra_noise
        noise = np.exp(sigma * rng.standard_normal(self.n_workers)
                       - 0.5 * sigma ** 2)
        spikes = np.where(rng.uniform(size=self.n_workers) < self.spike_prob,
                          1.0 + rng.exponential(self.spike_scale,
                                                self.n_workers), 1.0)
        t = self.mu * node_factor[self.node_of] * noise * spikes
        self.t += 1
        return t

    def run(self, n_steps: int) -> np.ndarray:
        return np.stack([self.step() for _ in range(n_steps)])

    @property
    def regime_name(self) -> str:
        return self.regimes[self._state].name


# ---------------------------------------------------------------------------
# Progress query: partial work completed by a wall-clock deadline.
# ---------------------------------------------------------------------------


def microbatch_progress(times, t: float, n_micro: int) -> np.ndarray:
    """Fraction of ``n_micro`` microbatches each worker finishes by time ``t``.

    ``times`` are full-step runtimes (any width — a ClusterSim row, a
    ChurnSim active-set row, or a measured vector); a worker's microbatches
    are assumed uniform across its step, so worker w completes
    ``floor(n_micro * t / times[w])`` of them by the deadline, capped at
    ``n_micro``.  The returned fractions are exact multiples of
    ``1 / n_micro`` — the granularity anytime-SGD partial gradient sums
    actually come in (a worker cannot ship half a microbatch) — and a
    worker with ``times[w] <= t`` returns exactly 1.0.

    This is the query the :class:`~repro.core.controller.AnytimeController`
    turns a cutoff time into a per-worker f32 contribution vector with.
    """
    if n_micro < 1:
        raise ValueError(f"n_micro must be >= 1, got {n_micro}")
    times = np.asarray(times, np.float64)
    frac = np.clip(t / np.maximum(times, 1e-300), 0.0, 1.0)
    # the 1e-9 guard keeps an exact k/n_micro ratio from flooring to k-1
    return np.floor(frac * n_micro + 1e-9) / float(n_micro)


# ---------------------------------------------------------------------------
# Fault overlay: mutable per-worker stalls/slowdowns on any runtime source.
# ---------------------------------------------------------------------------


class OverlaySim:
    """Mutable fault overlay on a full-width runtime source.

    The control plane's live twin of the scripted :class:`ChurnSim`: a
    supervisor (or a drill script) toggles per-worker ``stall`` flags
    (crashed/hung workers never finish — their runtime becomes
    :data:`STALL` seconds) and ``slow`` multipliers mid-run, while the
    base simulator keeps generating the full-width joint phenomenology.
    Untouched columns are bit-identical to the base run, so a detected
    fault schedule can be replayed as a scripted one column-exactly.
    """

    STALL = 1e9

    def __init__(self, base):
        self.base = base
        n = base.n_workers
        self.mult = np.ones(n)
        self.stalled = np.zeros(n, bool)

    @property
    def n_workers(self) -> int:
        return self.base.n_workers

    @property
    def t(self) -> int:
        return self.base.t

    def stall(self, wid: int, on: bool = True):
        self.stalled[int(wid)] = bool(on)

    def slow(self, wid: int, factor: float = 1.0):
        if factor <= 0:
            raise ValueError(f"slowdown factor must be > 0, got {factor}")
        self.mult[int(wid)] = float(factor)

    def step(self) -> np.ndarray:
        row = np.asarray(self.base.step(), np.float64) * self.mult
        return np.where(self.stalled, self.STALL, row)

    def run(self, n_steps: int) -> np.ndarray:
        return np.stack([self.step() for _ in range(n_steps)])


# ---------------------------------------------------------------------------
# Churn layer: elastic worker membership on top of any runtime source.
# ---------------------------------------------------------------------------


@dataclass
class ChurnEvent:
    """One membership change, keyed on the base simulator's step count.

    ``kill`` / ``restore`` name GLOBAL worker ids (columns of the base
    sim); ``resize`` is a convenience target width — extra kills come off
    the highest active ids, restores come back lowest-id first.  The event
    fires BEFORE the runtimes of iteration ``step`` are drawn, so the
    step at which it fires already runs at the new width.
    """
    step: int
    kill: Tuple[int, ...] = ()
    restore: Tuple[int, ...] = ()
    resize: Optional[int] = None


class ChurnSim:
    """Membership schedule wrapped around a ClusterSim (or TraceReplay).

    The base simulator keeps generating FULL-width joint runtimes — the
    cluster's phenomenology (node regimes, AR load) is independent of which
    workers currently hold a lease — and ``step()`` returns only the active
    columns.  ``n_workers`` / ``active_ids`` reflect the membership of the
    NEXT ``step()`` (pending events are applied eagerly), so a driver can
    resize its plumbing before drawing the runtimes of the resized step.

    Survivor columns are therefore column-exact across a resize: worker j's
    runtime series is the same whether or not its neighbours were killed.
    """

    def __init__(self, base, events: List[ChurnEvent]):
        self.base = base
        self.events = sorted(events, key=lambda e: e.step)
        self._active = np.ones(base.n_workers, bool)
        self._ei = 0
        self._apply_pending()

    def _apply_pending(self):
        while (self._ei < len(self.events)
               and self.events[self._ei].step <= self.base.t):
            ev = self.events[self._ei]
            self._ei += 1
            if ev.kill:
                self._active[list(ev.kill)] = False
            if ev.restore:
                self._active[list(ev.restore)] = True
            if ev.resize is not None:
                n = int(ev.resize)
                if not 1 <= n <= self.base.n_workers:
                    raise ValueError(f"resize target {n} outside "
                                     f"[1, {self.base.n_workers}]")
                ids = np.flatnonzero(self._active)
                if n < ids.size:                  # kill highest active ids
                    self._active[ids[n:]] = False
                elif n > ids.size:                # restore lowest dead ids
                    dead = np.flatnonzero(~self._active)
                    self._active[dead[: n - ids.size]] = True

    @property
    def n_workers(self) -> int:
        self._apply_pending()
        return int(self._active.sum())

    @property
    def active_ids(self) -> np.ndarray:
        """Global worker ids of the active set, ascending."""
        self._apply_pending()
        return np.flatnonzero(self._active)

    @property
    def t(self) -> int:
        return self.base.t

    def step(self) -> np.ndarray:
        """Joint runtimes of the CURRENT active set ((n_active,))."""
        self._apply_pending()
        active = self._active.copy()
        return self.base.step()[active]

    def run(self, n_steps: int) -> List[np.ndarray]:
        """Rows may change width across events, so this returns a list."""
        return [self.step() for _ in range(n_steps)]


def resize_schedule(base, plan: List[Tuple[int, int]]) -> ChurnSim:
    """ChurnSim from a [(step, n_workers), ...] width plan."""
    return ChurnSim(base, [ChurnEvent(step=s, resize=n) for s, n in plan])


# ---------------------------------------------------------------------------
# Multi-tenant partitioning: J jobs share one cluster's workers.
# ---------------------------------------------------------------------------


def partition_ids(n_workers: int, n_jobs: int) -> List[np.ndarray]:
    """Contiguous near-equal partition of global worker ids over jobs
    (first ``n_workers % n_jobs`` partitions get the extra worker) —
    the same convention the node assignment uses."""
    if not 1 <= n_jobs <= n_workers:
        raise ValueError(f"cannot split {n_workers} workers into "
                         f"{n_jobs} jobs")
    sizes = np.full(n_jobs, n_workers // n_jobs)
    sizes[: n_workers % n_jobs] += 1
    bounds = np.concatenate([[0], np.cumsum(sizes)])
    return [np.arange(bounds[j], bounds[j + 1]) for j in range(n_jobs)]


class PartitionView:
    """One job's timer view of a :class:`PartitionedSim` partition.

    Implements the Trainer timer protocol (``n_workers`` /
    ``active_ids`` / ``step``) over the job's slice of the shared
    cluster.  Views advance independent cursors, so the multi-job
    scheduler can service jobs at different rates and each job's runtime
    series stays internally consistent; churn events apply at the VIEW's
    own step index (ChurnEvent semantics: the event fires before the
    runtimes of iteration ``step`` are drawn).
    """

    def __init__(self, parent: "PartitionedSim", ids: np.ndarray):
        self.parent = parent
        self.ids = np.asarray(ids, int)
        self.t = 0

    def _active_mask(self) -> np.ndarray:
        member = self.parent.membership_at(self.t)
        return member[self.ids]

    @property
    def n_workers(self) -> int:
        return int(self._active_mask().sum())

    @property
    def active_ids(self) -> np.ndarray:
        """Global worker ids of this partition's active set, ascending."""
        return self.ids[self._active_mask()]

    def step(self) -> np.ndarray:
        """Joint runtimes of the partition's CURRENT active set."""
        row = self.parent.row(self.t)
        out = row[self.active_ids]
        self.t += 1
        return out

    def run(self, n_steps: int) -> List[np.ndarray]:
        return [self.step() for _ in range(n_steps)]


class PartitionedSim:
    """Split one base cluster's workers among J concurrent jobs.

    The base simulator keeps generating FULL-width joint runtimes — node
    regimes and AR load are properties of the shared hardware, not of
    which job leases which worker — and each :class:`PartitionView`
    serves its partition's columns.  Rows are generated once and cached
    by step index, so every view of step ``i`` sees the SAME draw:
    worker j's runtime series is identical whether it is read by a
    multi-job driver or a single-tenant run (column-exactness, the
    ChurnSim invariant, extended across tenants).  Rows every registered
    view has moved past are pruned, so memory is bounded by the cursor
    SPREAD between jobs, not run length — and the spread itself is
    bounded by ``max_cache`` rows, so a pinned cursor (a starved or
    evicted job whose view stopped advancing) cannot grow the cache
    without bound; it gets a loud ``IndexError`` on its next read
    instead.  Create all views before stepping (a view opened after
    pruning raises the same way).

    ``events`` is a :class:`ChurnEvent` schedule over GLOBAL worker ids;
    a kill inside partition p shrinks job p's view (its Trainer resizes
    through the elastic protocol) and leaves every other job untouched.
    """

    def __init__(self, base, partitions: List[np.ndarray],
                 events: List[ChurnEvent] = (), max_cache: int = 4096):
        self.base = base
        self.max_cache = max_cache
        self.partitions = [np.asarray(p, int) for p in partitions]
        flat = np.concatenate(self.partitions) if self.partitions else \
            np.array([], int)
        if flat.size != np.unique(flat).size:
            raise ValueError("partitions overlap")
        if flat.size and (flat.min() < 0 or flat.max() >= base.n_workers):
            raise ValueError("partition ids outside the base cluster")
        for ev in events:
            if ev.resize is not None:
                raise ValueError(
                    "ChurnEvent.resize targets a global width; partitioned "
                    "schedules must kill/restore explicit worker ids")
        self.events = sorted(events, key=lambda e: e.step)
        self._rows: List[np.ndarray] = []
        self._row0 = 0                       # step index of _rows[0]
        self._members: dict = {}
        self._views: List[PartitionView] = []

    def _prune(self):
        """Drop cached rows/masks no registered view can read again —
        or, past ``max_cache``, rows only a pinned (stalled) view could."""
        if not self._views:
            return
        low = min(v.t for v in self._views)
        low = max(low, self._row0 + len(self._rows) - self.max_cache)
        while self._row0 < low:
            self._rows.pop(0)
            self._row0 += 1
        if len(self._members) > len(self.events) + 2:
            self._members = {i: m for i, m in self._members.items()
                             if i >= low}

    def row(self, i: int) -> np.ndarray:
        """The full-width joint runtimes of step ``i`` (cached)."""
        if i < self._row0:
            raise IndexError(
                f"row {i} was pruned (oldest cached: {self._row0}); "
                f"create every PartitionView before stepping")
        while len(self._rows) <= i - self._row0:
            self._rows.append(self.base.step())
            self._prune()
        return self._rows[i - self._row0]

    def membership_at(self, i: int) -> np.ndarray:
        """Global active mask after every event with ``step <= i``."""
        if i not in self._members:
            active = np.ones(self.base.n_workers, bool)
            for ev in self.events:
                if ev.step > i:
                    break
                if ev.kill:
                    active[list(ev.kill)] = False
                if ev.restore:
                    active[list(ev.restore)] = True
            self._members[i] = active
        return self._members[i]

    def view(self, job: int) -> PartitionView:
        v = PartitionView(self, self.partitions[job])
        self._views.append(v)
        return v

    def views(self) -> List[PartitionView]:
        return [self.view(j) for j in range(len(self.partitions))]


# ---------------------------------------------------------------------------
# Presets matching the paper's two clusters.
# ---------------------------------------------------------------------------


def paper_cluster_158(seed: int = 0, n_workers: int = 158) -> ClusterSim:
    """4 nodes x 40 Xeon cores, 1 PS + 1 spare => 158 workers (paper §4.1).

    Calibrated near the paper's measured moments (mean 1.057 s, std 0.393 s).
    ``n_workers`` scales the same phenomenology down for CPU-budget
    end-to-end tests (node count and per-worker moments unchanged).
    """
    return ClusterSim(n_workers=n_workers, n_nodes=4, base_mean=1.0,
                      worker_hetero=0.15, noise_sigma=0.07,
                      spike_prob=0.02, spike_scale=0.9, seed=seed)


def cray_xc40_2175(seed: int = 0) -> ClusterSim:
    """32 KNL nodes x 68 logical cores, minus the PS => 2175 workers."""
    return ClusterSim(n_workers=2175, n_nodes=32, base_mean=1.0,
                      worker_hetero=0.1, noise_sigma=0.05,
                      spike_prob=0.01, spike_scale=0.7,
                      regime_stay=0.99, seed=seed)


def tpu_pod_hosts(n_hosts: int = 64, seed: int = 0) -> ClusterSim:
    """Per-host step-time jitter for a TPU pod (input pipeline + DCN):
    weaker heterogeneity, rarer spikes — the regime the controller sees when
    driving the masked-psum cutoff on the production mesh."""
    return ClusterSim(n_workers=n_hosts, n_nodes=max(2, n_hosts // 16),
                      base_mean=1.0, worker_hetero=0.04, noise_sigma=0.03,
                      spike_prob=0.01, spike_scale=1.5, regime_stay=0.995,
                      seed=seed)
