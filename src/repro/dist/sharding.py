"""Layouts: how logical axes (dp / sp / tp) map onto mesh axes per mode.

Every model and launch module imports this as ``shd`` and programs against
one small surface:

  * ``Layout``          — frozen description of one execution mode on one
    mesh: which mesh axes carry data-parallel batch shards (``dp``), which
    single axis carries the model sharding (``model_axis``), and how the
    sequence (``seq_axis``) and feature (``tp_axis``) dims of activations
    are split in that mode.
  * ``LOCAL``           — the no-mesh layout: every helper below becomes a
    pure no-op, so the same model code runs eagerly on one CPU device.
  * ``make_layout``     — mode -> Layout.  Modes:
      - ``train_sp``:   batch over the dp axes, sequence over "model"
        (context/sequence parallelism), params ZeRO-3 over "model".
      - ``train_fsdp``: pure batch-parallel ZeRO-3 — batch over the WHOLE
        mesh, no sequence sharding, params still ZeRO-3 over "model".
      - ``decode_tp``:  batch over dp, features over "model" (tensor
        parallelism), KV caches sequence-sharded over "model".
  * ``use_layout`` / ``layout``      — contextvar holding the active layout
    (read at trace time, so ``with use_layout(lay)`` inside a jitted
    function body works).
  * ``unroll_loops`` / ``unrolled``  — ask inner loops (attention q-chunks,
    SSM chunk scans) to unroll instead of ``lax.scan``/``lax.map`` so XLA
    cost analysis counts every iteration (dry-run accounting).
  * ``act(x, dp, sp, tp)``           — activation sharding constraint for
    dims 0/1/2; each argument names a logical kind ("dp"/"sp"/"tp") or
    ``None`` to pin that dim replicated (== force a gather).
  * ``use_weight(tree)``             — FSDP use-site gather hint for
    ZeRO-3-sharded weights (identity under LOCAL and decode_tp).
  * ``named_sharding(tree, lay, stacked_paths=...)`` — NamedShardings for a
    parameter pytree (ZeRO-3 rules; ``stacked_paths`` marks subtrees whose
    leaves carry a leading ``lax.scan`` repeats dim).

See ``src/repro/dist/README.md`` for the LOCAL-vs-mesh contract.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import compat  # noqa: F401  (installs jax polyfills)

MODES = ("local", "train_sp", "train_fsdp", "decode_tp")


@dataclass(frozen=True)
class Layout:
    """One execution mode's logical-axis -> mesh-axis map."""
    mesh: Optional[Mesh] = None
    mode: str = "local"
    dp: Tuple[str, ...] = ()            # axes sharding the batch dim
    model_axis: Optional[str] = None    # the model axis (FSDP / SP / TP)
    seq_axis: Optional[str] = None      # axis sharding the sequence dim
    tp_axis: Optional[str] = None       # axis sharding feature dims

    @property
    def dp_size(self) -> int:
        """Number of data-parallel shards (1 under LOCAL)."""
        if self.mesh is None or not self.dp:
            return 1
        size = 1
        for a in self.dp:
            size *= self.mesh.shape[a]
        return size

    @property
    def n_shards(self) -> int:
        """Size of the model axis (1 under LOCAL)."""
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    def axis(self, kind: Optional[str]):
        """Logical kind -> mesh axis name(s): "dp" -> tuple (or None when
        empty), "sp"/"tp" -> single axis name or None, None -> None."""
        if kind is None:
            return None
        if kind == "dp":
            return self.dp if self.dp else None
        if kind == "sp":
            return self.seq_axis
        if kind == "tp":
            return self.tp_axis
        raise ValueError(f"unknown logical axis kind {kind!r}")

    def dp_for(self, batch_size: int):
        """dp axes if they divide ``batch_size``, else None (replicate)."""
        if not self.dp or batch_size % self.dp_size != 0:
            return None
        return self.dp


LOCAL = Layout()


def make_layout(mesh: Optional[Mesh], mode: str) -> Layout:
    """Build the Layout for ``mode`` on ``mesh``.

    The model axis is the mesh axis named "model" (last axis as fallback);
    every other axis is data-parallel ("pod" crosses DCN and only ever
    carries batch).  ``mesh=None`` returns LOCAL regardless of mode.
    """
    if mesh is None:
        return LOCAL
    if mode not in MODES or mode == "local":
        raise ValueError(f"unknown layout mode {mode!r} (want one of "
                         f"{MODES[1:]})")
    names = tuple(mesh.axis_names)
    model = "model" if "model" in names else names[-1]
    others = tuple(a for a in names if a != model)
    if mode == "train_sp":
        return Layout(mesh=mesh, mode=mode, dp=others, model_axis=model,
                      seq_axis=model, tp_axis=None)
    if mode == "train_fsdp":
        return Layout(mesh=mesh, mode=mode, dp=names, model_axis=model,
                      seq_axis=None, tp_axis=None)
    # decode_tp
    return Layout(mesh=mesh, mode=mode, dp=others, model_axis=model,
                  seq_axis=None, tp_axis=model)


# ---------------------------------------------------------------------------
# Active layout / unroll flags (contextvars: cheap, trace-time, re-entrant).
# ---------------------------------------------------------------------------


_layout_var: contextvars.ContextVar[Layout] = contextvars.ContextVar(
    "repro_layout", default=LOCAL)
_unroll_var: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_unroll", default=False)


def layout() -> Layout:
    """The active Layout (LOCAL when none was installed)."""
    return _layout_var.get()


@contextlib.contextmanager
def use_layout(lay: Layout):
    """Install ``lay`` as the active layout; restores the previous layout
    on exit (nesting-safe)."""
    tok = _layout_var.set(lay)
    try:
        yield lay
    finally:
        _layout_var.reset(tok)


def unrolled() -> bool:
    """True when inner loops should unroll (dry-run cost accounting)."""
    return _unroll_var.get()


@contextlib.contextmanager
def unroll_loops(flag: bool = True):
    """Unroll scan/map inner loops within the context (see ``unrolled``)."""
    tok = _unroll_var.set(flag)
    try:
        yield
    finally:
        _unroll_var.reset(tok)


# ---------------------------------------------------------------------------
# Activation constraints.
# ---------------------------------------------------------------------------


def _axes_size(lay: Layout, ax) -> int:
    axes = ax if isinstance(ax, tuple) else (ax,)
    size = 1
    for a in axes:
        size *= lay.mesh.shape[a]
    return size


def act(x, dp=None, sp=None, tp=None):
    """Sharding constraint for an activation's (batch, seq, feature) dims.

    ``dp``/``sp``/``tp`` name the logical kind for dims 0/1/2 (any of
    "dp"/"sp"/"tp", or None to pin the dim replicated — i.e. force XLA to
    gather it).  Dims past the first three stay unconstrained-replicated.
    A dim whose size does not divide its mesh axes falls back to
    replicated.  No-op under LOCAL.
    """
    lay = layout()
    if lay.mesh is None:
        return x
    kinds = (dp, sp, tp)
    spec = []
    for i in range(x.ndim):
        kind = kinds[i] if i < 3 else None
        ax = lay.axis(kind)
        if ax is not None and x.shape[i] % _axes_size(lay, ax) != 0:
            ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(lay.mesh, P(*spec)))


# ---------------------------------------------------------------------------
# Weight use-site hint (ZeRO-3 gather).
# ---------------------------------------------------------------------------


def use_weight(tree):
    """Mark ZeRO-3-sharded weights as gathered for use.

    LOCAL and decode_tp: identity (decode keeps weights TP-sharded and lets
    GSPMD partition the matmuls).  Train modes: by default a replicated
    sharding constraint ("wsc") — XLA inserts the use-site all-gather and
    transposes it to a reduce-scatter of the weight gradients; under
    ``knobs().fsdp_gather == "shardmap"`` an explicit shard_map all-gather
    over the model axis (dim-0-sharded leaves only) with the same
    reduce-scatter AD transpose.
    """
    lay = layout()
    if lay.mesh is None or lay.mode not in ("train_sp", "train_fsdp"):
        return tree
    from repro.perf.knobs import knobs  # local import: knobs has no deps
    impl = knobs().fsdp_gather
    mesh, m, tp = lay.mesh, lay.model_axis, lay.n_shards

    def gather(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim == 0:
            return leaf
        if (impl == "shardmap" and m is not None and tp > 1
                and leaf.shape[0] % tp == 0):
            def body(w_l):
                return jax.lax.all_gather(w_l, m, axis=0, tiled=True)
            return jax.shard_map(body, mesh=mesh, in_specs=P(m),
                                 out_specs=P())(leaf)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, P(*([None] * leaf.ndim))))

    return jax.tree.map(gather, tree)


# ---------------------------------------------------------------------------
# Parameter-tree shardings.
# ---------------------------------------------------------------------------


def _path_str(path) -> str:
    parts = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            parts.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            parts.append(str(e.idx))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            parts.append(str(e.name))
        else:  # FlattenedIndexKey and friends
            parts.append(str(getattr(e, "key", e)))
    return "/".join(parts)


def named_sharding(tree, lay: Layout, *, stacked_paths: Sequence[str] = ()):
    """NamedShardings for a parameter pytree under ``lay``.

    ZeRO-3 rule: each leaf is sharded over ``lay.model_axis`` on its first
    divisible dim — dim 0 normally, dim 1 for leaves under a
    ``stacked_paths`` prefix (their dim 0 is the ``lax.scan`` repeats dim
    and must stay whole per scan step).  ``decode_tp`` prefers the LAST dim
    (feature tensor-parallelism).  Leaves with no divisible dim replicate.
    Returns a tree of ``None`` when ``lay.mesh`` is None (LOCAL).
    """
    if lay.mesh is None:
        return jax.tree.map(lambda _: None, tree)
    m, tp = lay.model_axis, lay.n_shards
    stacked_paths = tuple(stacked_paths)

    def spec_for(path, leaf):
        ps = _path_str(path)
        stacked = any(ps == s or ps.startswith(s + "/")
                      for s in stacked_paths)
        nd = leaf.ndim
        spec = [None] * nd
        if m is not None:
            start = 1 if stacked else 0
            dims = list(range(start, nd))
            if lay.mode == "decode_tp":
                dims = dims[::-1]
            for i in dims:
                if leaf.shape[i] >= tp and leaf.shape[i] % tp == 0:
                    spec[i] = m
                    break
        return NamedSharding(lay.mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(spec_for, tree)
