"""Gated JAX compatibility polyfills.

The codebase is written against the current jax sharding API
(``jax.shard_map``, ``jax.set_mesh``, ``jax.make_mesh(axis_types=...)``).
Older jax releases (the 0.4.x line this container pins) expose the same
machinery under different entry points; this module backfills the gap so
the rest of the code can use the modern spellings unconditionally.  On a
new-enough jax every branch below is a no-op re-export.

Backfills:
  * ``jax.shard_map``  <- ``jax.experimental.shard_map.shard_map`` (with
    ``check_rep=False``: the old checker predates several collectives we
    use inside shard_map bodies — all_to_all, ppermute chains).
  * ``jax.set_mesh``   <- the ``Mesh`` context manager (activating the
    mesh; shardings in this repo always name their mesh explicitly, so the
    physical-mesh context is all callers need).
  * ``AxisType``       <- a stand-in enum; pre-0.5 meshes are implicitly
    "auto" so the value is only ever decorative there.
  * ``make_mesh``      <- drops the ``axis_types`` kwarg on old jax.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:
    class AxisType:  # minimal stand-in; old meshes are implicitly Auto
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def make_mesh(shape, axes, *, axis_types=None):
    """``jax.make_mesh`` that tolerates old jax (no ``axis_types``)."""
    shape, axes = tuple(shape), tuple(axes)
    types = axis_types if axis_types is not None else (
        (AxisType.Auto,) * len(axes))
    try:
        return jax.make_mesh(shape, axes, axis_types=types)
    except TypeError:  # jax 0.4.x: positional-only (shape, axes)
        return jax.make_mesh(shape, axes)


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f, *, mesh, in_specs, out_specs, **kw):
        kw.setdefault("check_rep", False)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)

    jax.shard_map = _compat_shard_map


try:  # pallas TPU params were renamed TPUCompilerParams -> CompilerParams
    import jax.experimental.pallas.tpu as _pltpu
    if not hasattr(_pltpu, "CompilerParams") and hasattr(
            _pltpu, "TPUCompilerParams"):
        _pltpu.CompilerParams = _pltpu.TPUCompilerParams
except ImportError:  # pallas not available on this install
    pass


if not hasattr(jax, "set_mesh"):
    @contextlib.contextmanager
    def _compat_set_mesh(mesh):
        with mesh:
            yield mesh

    jax.set_mesh = _compat_set_mesh
