"""repro.dist — mesh layouts, sharding constraints, and DP collectives.

``repro.dist.sharding`` (imported everywhere as ``shd``) is the single
source of truth for how logical axes (dp / sp / tp) map onto mesh axes in
each execution mode; ``repro.dist.collectives`` carries the cutoff-SGD
bit-array aggregation behind the same layout.  Importing this package also
installs the gated JAX compatibility polyfills (``repro.dist.compat``).
"""
from repro.dist import compat  # noqa: F401  (installs jax polyfills)
from repro.dist import collectives, sharding  # noqa: F401
