"""Data-parallel collectives behind the layout — the cutoff-SGD story.

The parameter-server decision (``core.controller``) produces a per-worker
bit array each step; this module is how that bit array meets the SPMD mesh:

  * ``example_weights``   — the PRODUCTION path (paper §4.3): expand the
    bit array to per-example weights folded into the loss.  The gradient
    all-reduce GSPMD already emits then implements the masked mean exactly,
    with zero extra collectives.  ``launch.train.Trainer`` uses this.
  * ``masked_grad_mean``  — the EXPLICIT path (``mask_agg="psum"`` in
    ``launch.train``): bit-array aggregation over per-worker gradients
    (leading worker dim).  Under LOCAL the stacked host combine goes
    through ``kernels.ops.masked_aggregate_tree`` (the Pallas
    masked_grad_agg kernel on TPU / interpret, pure jnp under the "xla"
    backend); under a mesh layout it is the shard_map psum of
    ``core.aggregation.masked_psum_mean`` over the layout's dp axes.
    Tests prove the two paths agree.
  * ``grad_mean``         — the full-sync baseline (all-ones mask) with
    identical reduction order, so masked-vs-plain comparisons can demand
    bitwise equality.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import sharding as shd

# NOTE: repro.core.aggregation is imported lazily inside the functions —
# it imports repro.dist.compat for the shard_map polyfill, so a module-level
# import here would be circular.


def example_weights(mask: np.ndarray, global_batch: int) -> np.ndarray:
    """Per-worker bit array -> per-example loss weights (production path)."""
    from repro.core import aggregation
    return aggregation.example_weights(mask, global_batch)


def masked_grad_mean(grads, mask_bit, lay: Optional[shd.Layout] = None):
    """Masked mean over per-worker gradients: sum_w bit_w g_w / sum_w bit_w.

    ``grads`` leaves carry a leading worker dim (n_workers, ...); under a
    mesh layout n_workers must equal the layout's dp_size and the psum runs
    over the dp axes.  Under LOCAL the same reduction happens in-process,
    through the kernel-backend dispatch of ``ops.masked_aggregate_tree``.
    The worker dim is dropped from the result.
    """
    lay = lay if lay is not None else shd.layout()
    if lay.mesh is None or not lay.dp:
        from repro.kernels import ops
        return ops.masked_aggregate_tree(grads, jnp.asarray(mask_bit))
    from repro.core import aggregation
    return aggregation.masked_psum_mean(grads, mask_bit, lay.mesh, lay.dp)


def grad_mean(grads, lay: Optional[shd.Layout] = None):
    """Full-sync mean over the worker dim (the all-ones-mask special case,
    with the same reduction order as ``masked_grad_mean``)."""
    n = jax.tree.leaves(grads)[0].shape[0]
    ones = jnp.ones((n,), jnp.float32)
    return masked_grad_mean(grads, ones, lay)
