"""Minimal batched serving engine: prefill + greedy/temperature decode.

Used by examples/serve_decode.py and the decode-shape smoke tests.  The
production mesh path reuses the same decode_step the dry-run lowers
(feature-TP + sequence-sharded KV); on CPU it runs the local layout.
"""
from __future__ import annotations

from contextlib import nullcontext
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclass
class ServeEngine:
    cfg: object
    params: object
    max_len: int = 512
    # optional repro.obs.ObsRun: prefill/decode/fetch spans stamp host
    # perf_counter edges around the (async) dispatches — they time
    # DISPATCH, never insert a block_until_ready
    obs: object = None

    def __post_init__(self):
        cfg = self.cfg

        def _prefill(params, batch):
            return M.prefill(cfg, params, batch)

        def _decode(params, tokens, pos, caches):
            return M.decode_step(cfg, params, tokens, pos, caches)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)

    # reprolint: hot-path
    def generate(self, tokens: np.ndarray, n_new: int,
                 temperature: float = 0.0, seed: int = 0,
                 frames: Optional[np.ndarray] = None) -> np.ndarray:
        """tokens: (B, S) prompt -> (B, n_new) generated ids."""
        B, S = tokens.shape
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))}
        if self.cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S))
        if self.cfg.is_encoder_decoder:
            batch["frames"] = (jnp.asarray(frames) if frames is not None
                               else jnp.zeros(
                (B, self.cfg.encoder_seq_len, self.cfg.d_model)))
        tracer = self.obs.trace if self.obs is not None else None

        def _span(name, **attrs):
            return (tracer.span(name, track="serving", **attrs)
                    if tracer is not None else nullcontext())

        with _span("serve.prefill", batch=B, seq=S):
            last_logits, caches = self._prefill(self.params, batch)
        caches = M.pad_caches(caches, S + n_new)
        key = jax.random.PRNGKey(seed)
        out = []
        nxt = self._sample(last_logits, temperature, key)
        with _span("serve.decode", batch=B, n_new=n_new):
            for t in range(n_new):
                # keep the loop transfer-free: collect DEVICE arrays so
                # each decode dispatch overlaps the previous step instead
                # of blocking on a per-token host copy
                out.append(nxt)
                logits, caches = self._decode(self.params, nxt[:, None],
                                              jnp.int32(S + t), caches)
                key, sub = jax.random.split(key)
                nxt = self._sample(logits[:, 0], temperature, sub)
        with _span("serve.fetch", batch=B, n_new=n_new):
            # reprolint: disable=host-sync-in-hot-path -- the ONE designated fetch: all n_new tokens come back in a single transfer after the loop has been fully enqueued
            return np.asarray(jnp.stack(out, axis=1))

    @staticmethod
    def _sample(logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)
