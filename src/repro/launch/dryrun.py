import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything else follows.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the right entry point is AOT-compiled against the production
mesh with ShapeDtypeStruct inputs (no allocation):

  train_4k    -> train_step (FSDP + sequence-parallel layout, grad accum)
  prefill_32k -> prefill     (same layout)
  decode_*    -> decode_step (feature-TP + sequence-sharded KV cache)

Outputs per cell: memory_analysis, cost_analysis, collective-bytes by kind,
roofline terms -> experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import base as cfgbase
from repro.dist import sharding as shd
from repro.launch import inputs as I
from repro.launch import train as T
from repro.launch.mesh import make_production_mesh
from repro.models import model as M

from repro.perf import hlo_stats

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def grad_accum_for(cfg, shape) -> int:
    """Microbatching so activations fit 16 GB/chip (hillclimb knob)."""
    n = cfg.n_params()
    if shape.name != "train_4k":
        return 1
    if n > 20e9:
        return 8
    if n > 8e9:
        return 4
    if n > 3e9:
        return 2
    return 1


def zero1_for(cfg) -> bool:
    return cfg.n_params() > 5e9


def lower_cell(cfg, shape, mesh, *, grad_accum=None, zero1=None,
               overrides=None, grads_only=False, layout="sp"):
    """Returns the jax ``Lowered`` for one cell.

    layout="fsdp": pure batch-parallel ZeRO-3 for train shapes whose
    global_batch divides the device count (single-pod train_4k); no
    sequence sharding, no KV gathers.  MoE archs keep "sp" (EP owns the
    model axis)."""
    overrides = overrides or {}
    kind = shape.kind
    if kind == "decode":
        mode = "decode_tp"
    elif layout == "fsdp" and kind == "train":
        assert cfg.family != "moe", "train_fsdp incompatible with EP"
        assert shape.global_batch % mesh.size == 0, (shape.global_batch,
                                                     mesh.size)
        mode = "train_fsdp"
    else:
        mode = "train_sp"
    lay = shd.make_layout(mesh, mode)
    key = jax.random.PRNGKey(0)

    with shd.use_layout(lay), jax.set_mesh(mesh):
        if kind == "train":
            ga = grad_accum if grad_accum is not None else grad_accum_for(
                cfg, shape)
            z1 = zero1 if zero1 is not None else zero1_for(cfg)
            opt = optim.adamw(optim.cosine_schedule(3e-4, 200, 10_000))
            params_abs = jax.eval_shape(lambda: M.init_model(cfg, key))
            pshard = shd.named_sharding(
                params_abs, lay, stacked_paths=T.stacked_paths_for(cfg))
            batch, bshard = I.input_specs(cfg, shape, lay)
            if grads_only:
                loss_fn = T.make_loss_fn(cfg)

                def gfn(params, batch):
                    B, S = batch["tokens"].shape
                    norm = jnp.asarray(B * S, jnp.float32)
                    return jax.grad(loss_fn, has_aux=True)(
                        params, batch, norm)

                jitted = jax.jit(gfn, in_shardings=(pshard, bshard),
                                 out_shardings=(pshard, None))
                return jitted.lower(params_abs, batch), {}
            step = T.make_train_step(cfg, opt, grad_accum=ga, **overrides)
            state_abs = T.abstract_state(cfg, opt, key)
            sshard = T.state_shardings(cfg, state_abs["params"], lay,
                                       zero1=z1)
            sshard["opt"] = {k: sshard["opt"][k]
                             for k in state_abs["opt"]}
            jitted = jax.jit(step, in_shardings=(sshard, bshard),
                             out_shardings=(sshard, None),
                             donate_argnums=(0,))
            return jitted.lower(state_abs, batch), {"grad_accum": ga,
                                                    "zero1": z1}
        if kind == "prefill":
            params_abs = jax.eval_shape(lambda: M.init_model(cfg, key))
            pshard = shd.named_sharding(
                params_abs, lay, stacked_paths=T.stacked_paths_for(cfg))
            batch, bshard = I.input_specs(cfg, shape, lay)

            def fn(params, batch):
                return M.prefill(cfg, params, batch)

            jitted = jax.jit(fn, in_shardings=(pshard, bshard))
            return jitted.lower(params_abs, batch), {}
        # decode
        params_abs = jax.eval_shape(lambda: M.init_model(cfg, key))
        pshard = shd.named_sharding(
            params_abs, lay, stacked_paths=T.stacked_paths_for(cfg))
        (batch, caches), (bshard, cshard) = I.input_specs(cfg, shape, lay)

        def fn(params, tokens, pos, caches, positions):
            return M.decode_step(cfg, params, tokens, pos, caches,
                                 positions=positions)

        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(
            fn,
            in_shardings=(pshard, bshard["tokens"], None, cshard,
                          bshard["positions"]),
            out_shardings=(None, cshard),
            donate_argnums=(3,))
        return jitted.lower(params_abs, batch["tokens"], pos_sds, caches,
                            batch["positions"]), {}


# ---------------------------------------------------------------------------
# Layer-wise cost accounting.
#
# XLA's cost_analysis counts while-loop (scan) bodies ONCE, not x trip-count,
# so the production graph (layers under lax.scan, q-chunks under lax.map)
# under-reports FLOPs/bytes/collectives.  We therefore measure, per distinct
# LayerSpec, a 1-layer fully-unrolled graph and a 0-layer base graph and
# combine:  total = ga * [grads(0L) + sum_spec count * (grads(1L)-grads(0L))]
#                 + [opt(0L) + sum_spec count * opt_delta(1L)]
# which is exact for homogeneous repeats.  sLSTM's sequential time scan gets
# an analytic FLOPs add-on (its recurrent matmuls live inside a length-S
# scan that cannot be unrolled).
# ---------------------------------------------------------------------------

import dataclasses

from repro.models.blocks import LayerSpec


def _single_layer_cfg(cfg, spec: LayerSpec, n: int = 1):
    ch = dict(n_layers=n, layer_pattern="", global_layer_ids=(),
              first_dense_layers=0, slstm_every=0, n_encoder_layers=0,
              sliding_window=0)
    if spec.kind == "attn_dense":
        ch.update(first_dense_layers=n)
    if spec.kind == "slstm":
        ch.update(slstm_every=1)
    if spec.kind == "enc":
        ch.update(n_layers=0, n_encoder_layers=n)
    if spec.window > 0:
        ch.update(sliding_window=spec.window)
    elif spec.kind == "hybrid":
        ch.update(global_layer_ids=tuple(range(n)),
                  sliding_window=cfg.sliding_window)
    return dataclasses.replace(cfg, **ch)


def _base_cfg(cfg):
    return dataclasses.replace(
        cfg, n_layers=0, n_encoder_layers=0, layer_pattern="",
        global_layer_ids=(), first_dense_layers=0, slstm_every=0)


def _cost_of(cfg, shape, mesh, **kw):
    with shd.unroll_loops():
        lowered, _ = lower_cell(cfg, shape, mesh, **kw)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = hlo_stats.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]),
            "coll_by_kind": {k: coll[k] for k in hlo_stats.COLLECTIVES}}


def _combine(a, scale_a, b=None, scale_b=0.0):
    out = {}
    for k in ("flops", "bytes", "coll"):
        out[k] = scale_a * a[k] + (scale_b * b[k] if b else 0.0)
    out["coll_by_kind"] = {
        k: scale_a * a["coll_by_kind"][k]
        + (scale_b * b["coll_by_kind"][k] if b else 0.0)
        for k in a["coll_by_kind"]}
    return out


def _slstm_extra_flops(cfg, shape, n_slstm: int, lay) -> float:
    """Analytic recurrent-matmul FLOPs hidden in the length-S sLSTM scan.

    Per step per sequence: 4 gates x (nh x hd x hd) matmul = 8*d*hd MACs.
    Train: fwd + remat fwd + bwd ~= 4x fwd.  Per-device: the scan is
    replicated over "model" (documented), sharded over batch only.
    """
    if n_slstm == 0:
        return 0.0
    d = cfg.d_model
    hd = d // cfg.n_heads
    S = shape.seq_len if shape.kind != "decode" else 1
    B = shape.global_batch
    fwd = 2.0 * S * B * 4 * d * hd
    mult = 4.0 if shape.kind == "train" else 1.0
    per_dev = fwd * mult * n_slstm / max(lay.dp_size, 1)
    return per_dev


def account_cell(cfg, shape, mesh, *, grad_accum=None, zero1=None,
                 layout="sp"):
    """Layer-wise accounted per-device costs for one cell."""
    kind = shape.kind
    ga = (grad_accum if grad_accum is not None
          else grad_accum_for(cfg, shape)) if kind == "train" else 1
    z1 = zero1 if zero1 is not None else zero1_for(cfg)
    # microbatch shape for the per-layer graphs
    mshape = dataclasses.replace(shape, global_batch=shape.global_batch // ga)

    specs = M.layer_specs(cfg)
    counts = {}
    for s in specs:
        counts[s] = counts.get(s, 0) + 1
    if cfg.is_encoder_decoder:
        for s in M.encoder_layer_specs(cfg):
            counts[s] = counts.get(s, 0) + 1

    base_cfg = _base_cfg(cfg)
    kw = dict(grad_accum=1, zero1=z1, layout=layout)
    if kind == "train":
        g0 = _cost_of(base_cfg, mshape, mesh, grads_only=True, **kw)
        t0 = _cost_of(base_cfg, mshape, mesh, **kw)
        opt0 = {k: (t0[k] - g0[k]) if k != "coll_by_kind" else {
            kk: t0["coll_by_kind"][kk] - g0["coll_by_kind"][kk]
            for kk in t0["coll_by_kind"]} for k in t0}
        total = _combine(g0, float(ga))
        total = _combine(total, 1.0, opt0, 1.0)
        for s, cnt in counts.items():
            c1 = _single_layer_cfg(cfg, s)
            g1 = _cost_of(c1, mshape, mesh, grads_only=True, **kw)
            t1 = _cost_of(c1, mshape, mesh, **kw)
            dg = {k: (g1[k] - g0[k]) if k != "coll_by_kind" else {
                kk: g1["coll_by_kind"][kk] - g0["coll_by_kind"][kk]
                for kk in g1["coll_by_kind"]} for k in g1}
            dopt = {k: ((t1[k] - g1[k]) - opt0[k]) if k != "coll_by_kind"
                    else {kk: (t1["coll_by_kind"][kk] - g1["coll_by_kind"][kk]
                               - opt0["coll_by_kind"][kk])
                          for kk in t1["coll_by_kind"]} for k in t1}
            total = _combine(total, 1.0, dg, float(ga * cnt))
            total = _combine(total, 1.0, dopt, float(cnt))
    else:
        b0 = _cost_of(base_cfg, mshape, mesh)
        total = _combine(b0, 1.0)
        for s, cnt in counts.items():
            c1 = _single_layer_cfg(cfg, s)
            b1 = _cost_of(c1, mshape, mesh)
            ds = {k: (b1[k] - b0[k]) if k != "coll_by_kind" else {
                kk: b1["coll_by_kind"][kk] - b0["coll_by_kind"][kk]
                for kk in b1["coll_by_kind"]} for k in b1}
            total = _combine(total, 1.0, ds, float(cnt))

    lay = shd.make_layout(mesh, "decode_tp" if kind == "decode"
                          else "train_sp")
    n_slstm = sum(cnt for s, cnt in counts.items() if s.kind == "slstm")
    total["flops"] += _slstm_extra_flops(cfg, shape, n_slstm, lay)
    total["grad_accum"] = ga
    return total


def run_cell(cfg, shape, mesh, mesh_name: str, out_dir: str,
             force: bool = False, **kw):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{cfg.name}__{shape.name}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    rec = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
           "n_devices": mesh.size}
    try:
        lowered, meta = lower_cell(cfg, shape, mesh, **kw)
        rec.update(meta)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # the assignment requires these printed: proves fit + feeds §Roofline
        print(f"--- {cfg.name} x {shape.name} x {mesh_name} ---")
        print("memory_analysis:", mem)
        print("cost_analysis:", {k: v for k, v in sorted(cost.items())
                                 if "bytes accessed" == k or k == "flops"
                                 or k == "optimal_seconds"})
        coll = hlo_stats.collective_bytes(compiled.as_text())
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        rec.update({
            "ok": True,
            "trace_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            # raw = production graph as XLA reports it (scan bodies counted
            # once -- kept for reference only)
            "raw_flops_per_device": flops,
            "raw_bytes_per_device": bytes_acc,
            "raw_collectives": coll,
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_live_est": (mem.argument_size_in_bytes
                                  + mem.output_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  - mem.alias_size_in_bytes),
            },
        })
        t3 = time.time()
        acc = account_cell(cfg, shape, mesh, **{
            k: v for k, v in kw.items() if k in ("grad_accum", "zero1")})
        rec.update({
            "accounting_s": round(time.time() - t3, 1),
            "flops_per_device": acc["flops"],
            "bytes_per_device": acc["bytes"],
            "collective_bytes_per_device": acc["coll"],
            "collectives_by_kind": acc["coll_by_kind"],
            "roofline": hlo_stats.roofline_terms(
                acc["flops"], acc["bytes"], acc["coll"]),
        })
        print(f"[OK]   {cfg.name:24s} {shape.name:12s} {mesh_name:10s} "
              f"compile={t2-t1:6.1f}s flops/dev={acc['flops']:.3e} "
              f"bound={rec['roofline']['bound']}")
    except Exception as e:  # noqa: BLE001
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[FAIL] {cfg.name:24s} {shape.name:12s} {mesh_name}: "
              f"{type(e).__name__}: {str(e)[:200]}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--zero1", type=int, default=None)
    args = ap.parse_args()
    out_dir = args.out or os.path.abspath(OUT_DIR)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    n_ok = n_fail = n_skip = 0
    for cfg, shape, skip in cfgbase.cells():
        if args.arch and cfg.name != args.arch:
            continue
        if args.shape and shape.name != args.shape:
            continue
        if skip:
            print(f"[SKIP] {cfg.name:24s} {shape.name:12s} -- {skip}")
            n_skip += 1
            continue
        for mesh_name, mesh in meshes:
            kw = {}
            if args.grad_accum is not None:
                kw["grad_accum"] = args.grad_accum
            if args.zero1 is not None:
                kw["zero1"] = bool(args.zero1)
            rec = run_cell(cfg, shape, mesh, mesh_name, out_dir,
                           force=args.force, **kw)
            if rec.get("ok"):
                n_ok += 1
            else:
                n_fail += 1
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed, "
          f"{n_skip} skipped (documented)")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
