"""Supervised training: live fault detection driving the elastic path.

The scripted fault-tolerance story (``ChurnSim`` membership schedules,
``launch.elastic``) assumed someone ELSE notices failures.  This driver
closes the loop: a :class:`~repro.controlplane.supervisor.Supervisor`
watches heartbeats, converts missed deadlines into the SAME membership
changes a ``ChurnSim`` would have scripted, restarts crashed workers
with capped backoff, and the existing ``Trainer.resize`` /
``ElasticController`` machinery consumes the detected reality unchanged.

Default mode runs a seeded fault storm end-to-end on this container:

  1. train with a supervisor + fault injector (one crash, one hang, one
     slowdown); the crash and hang are DETECTED by missed heartbeats —
     membership shrinks, the controller remaps, restarts bring the
     workers back warm;
  2. replay the event log as a SCRIPTED run (ChurnSim kills at the
     detection ticks, restores at the rejoin ticks, stalls over the
     undetected windows) and check the two loss trajectories match —
     detection-driven elasticity is a faithful stand-in for an oracle
     schedule;
  3. print the drill report (detection latency in ticks, restarts,
     evictions) off the structured event stream.

  PYTHONPATH=src python -m repro.launch.supervised [--steps N]
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.simulator import (ChurnEvent, ChurnSim, OverlaySim,
                                     paper_cluster_158)
from repro.controlplane.events import EventLog
from repro.controlplane.faults import FaultInjector, FaultPlan
from repro.controlplane.supervisor import (SimWorkerPool, SupervisedTimer,
                                           Supervisor, drill_report)


# ---------------------------------------------------------------------------
# Wiring: overlay + injector + supervisor + Trainer.
# ---------------------------------------------------------------------------


def build_supervised(n_workers: int, plan: Optional[FaultPlan] = None, *,
                     seed: int = 0, ckpt_dir: Optional[str] = None,
                     event_path: Optional[str] = None,
                     suspect_after: int = 2, dead_after: int = 4,
                     restart_base: int = 2, restart_cap: int = 16,
                     flap_limit: int = 3, obs=None):
    """The supervised stack minus the Trainer: (overlay, supervisor, timer).

    The overlay wraps a fresh paper-cluster sim; the injector (if a plan
    is given) drives the :class:`SimWorkerPool`.  Plug ``timer`` into a
    ``Trainer`` and call ``supervisor.tick(trainer.step)`` before every
    ``run(1)`` — :func:`run_supervised_trainer` does exactly that.
    """
    overlay = OverlaySim(paper_cluster_158(seed + 1, n_workers=n_workers))
    injector = FaultInjector(plan, seed=seed) if plan is not None else None
    pool = SimWorkerPool(overlay, injector, ckpt_dir=ckpt_dir)
    log = EventLog(event_path)
    sup = Supervisor(pool, suspect_after=suspect_after,
                     dead_after=dead_after, restart_base=restart_base,
                     restart_cap=restart_cap, flap_limit=flap_limit,
                     seed=seed, log=log, obs=obs)
    return overlay, sup, SupervisedTimer(overlay, sup)


def run_supervised_trainer(trainer, supervisor: Supervisor,
                           n_steps: int) -> list:
    """Drive trainer + supervisor on one logical clock.

    The supervisor ticks BEFORE each trainer step (the ChurnSim
    convention: membership changes land before the resized step's
    runtimes are drawn), so a worker declared dead at tick t is out of
    the aggregation from step t on.
    """
    for _ in range(n_steps):
        supervisor.tick(trainer.step)
        trainer.run(1)
    return trainer.history


# ---------------------------------------------------------------------------
# Scripted replay: the event log as a ChurnSim + stall schedule.
# ---------------------------------------------------------------------------


class _ScriptedFaults:
    """Replays stall/slow commands at fixed ticks on an OverlaySim —
    the deterministic twin of a supervised run's pool, for replay."""

    def __init__(self, overlay: OverlaySim,
                 commands: Dict[int, List[tuple]]):
        self.overlay = overlay
        self.commands = commands

    @property
    def n_workers(self) -> int:
        return self.overlay.n_workers

    @property
    def t(self) -> int:
        return self.overlay.t

    def step(self) -> np.ndarray:
        for op, wid, arg in self.commands.get(self.overlay.t, ()):
            if op == "stall":
                self.overlay.stall(wid, arg)
            else:
                self.overlay.slow(wid, arg)
        return self.overlay.step()


def scripted_equivalent(events, base) -> ChurnSim:
    """Rebuild a supervised run as a scripted timer from its event log.

    Detection-tick kills, rejoin-tick restores, and the fault/restart
    stall windows become an explicit schedule over a FRESH base sim with
    the same seed — stepping this timer reproduces the supervised run's
    active-set runtime rows column-exactly (the OverlaySim contract),
    which is what makes the equivalence drill a real assertion.
    """
    commands: Dict[int, List[tuple]] = {}

    def at(tick, cmd):
        commands.setdefault(int(tick), []).append(cmd)

    churn: List[ChurnEvent] = []
    for e in events:
        if e.kind == "fault" and e.worker is not None:
            if e.data.get("fault") in ("crash", "hang"):
                at(e.tick, ("stall", e.worker, True))
            elif e.data.get("fault") == "slowdown":
                at(e.tick, ("slow", e.worker, e.data.get("factor", 4.0)))
        elif e.kind == "dead":
            churn.append(ChurnEvent(step=e.tick, kill=(e.worker,)))
        elif e.kind == "restart":
            at(e.tick, ("stall", e.worker, False))
            at(e.tick, ("slow", e.worker, 1.0))
        elif e.kind == "rejoin" and not e.data.get("false_alarm"):
            churn.append(ChurnEvent(step=e.tick, restore=(e.worker,)))
    # slowdown expiry: the sim pool clears the multiplier duration ticks
    # after the fault fired
    for e in events:
        if e.kind == "fault" and e.data.get("fault") == "slowdown":
            at(e.tick + e.data.get("duration", 20),
               ("slow", e.worker, 1.0))
    return ChurnSim(_ScriptedFaults(OverlaySim(base), commands), churn)


# ---------------------------------------------------------------------------
# Default demo / drill.
# ---------------------------------------------------------------------------


def default_plan(n_workers: int, start: int = 12) -> FaultPlan:
    """The acceptance drill's storm: 1 crash, 1 hang (+ a flaky restart
    on the hung worker), 1 slowdown — firing after the Elfving warmup so
    detection windows never overlap a full-sync cutoff."""
    from repro.controlplane.faults import Fault
    w = list(range(n_workers))
    return FaultPlan([
        Fault(at=start, kind="crash", worker=w[-1]),
        Fault(at=start, kind="flaky_restart", worker=w[-2], fails=1),
        Fault(at=start + 8, kind="hang", worker=w[-2]),
        Fault(at=start + 16, kind="slowdown", worker=w[0], factor=4.0,
              duration=10),
    ])


def run_supervised(steps: int = 60, seed: int = 0, n_workers: int = 6,
                   verbose: bool = True, obs=None) -> dict:
    import jax

    from repro import optim
    from repro.configs.base import bench_tiny_config
    from repro.core.controller import ElfvingController
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, jit_train_step

    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    def init_fn():
        from repro.models import model as M
        params = M.init_model(cfg, jax.random.PRNGKey(seed))
        return {"params": params, "opt": opt.init(params)}

    def make_trainer(timer):
        # global_batch = lcm(1..6) * 2: every transient width divides it
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=60, seed=seed)
        tr = Trainer(cfg=cfg, step_fn=step_fn, data=data,
                     controller=ElfvingController(n_workers),
                     timer=timer, n_workers=timer.n_workers)
        return tr.restore_or_init(init_fn)

    plan = default_plan(n_workers)
    if verbose:
        print(f"=== supervised run: {n_workers} workers, seeded storm "
              f"({len(plan.faults)} faults) ===")
    overlay, sup, timer = build_supervised(n_workers, plan, seed=seed,
                                           obs=obs)
    tr = make_trainer(timer)
    if obs is not None:
        tr.obs = obs
    run_supervised_trainer(tr, sup, steps)
    report = drill_report(sup.log.events)
    if verbose:
        for i in report["incidents"]:
            print(f"  {i['kind']} on worker {i['worker']} at tick "
                  f"{i['fault_tick']}: detected={i['detected']} "
                  f"(+{i['detection_ticks']} ticks), rejoined at "
                  f"{i['rejoin_tick']}")
        print(f"  restarts={report['restarts']} "
              f"failed={report['failed_restarts']} "
              f"evicted={report['evicted']}")

    if verbose:
        print("=== scripted replay of the detected schedule ===")
    base2 = paper_cluster_158(seed + 1, n_workers=n_workers)
    tr2 = make_trainer(scripted_equivalent(sup.log.events, base2))
    tr2.run(steps)

    losses = np.array([h["loss"] for h in tr.history])
    losses2 = np.array([h["loss"] for h in tr2.history])
    match = bool(np.allclose(losses, losses2, rtol=1e-5, atol=1e-6))
    widths = [h["n"] for h in tr.history]
    if verbose:
        print(f"  widths seen: {sorted(set(widths))}; "
              f"loss trajectories match: {match}")
        print("\nsupervised fault-storm run OK" if match
              else "\nsupervised run DIVERGED from scripted replay")
    return {"history": tr.history, "scripted_history": tr2.history,
            "report": report, "events": sup.log.events, "match": match,
            "widths": widths, "supervisor": sup}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--workers", type=int, default=6)
    ap.add_argument("--obs-dir", default=None,
                    help="write obs telemetry streams (spans/steps/"
                         "decisions/metrics JSONL) under this directory")
    args = ap.parse_args()
    from repro.obs import ObsRun
    obs = ObsRun(args.obs_dir) if args.obs_dir else None
    out = run_supervised(steps=args.steps, seed=args.seed,
                         n_workers=args.workers, obs=obs)
    if obs is not None:
        obs.close()
        print(f"obs streams -> {args.obs_dir} "
              f"(render: python -m repro.obs {args.obs_dir})")
    return 0 if out["match"] else 1


if __name__ == "__main__":
    sys.exit(main())
