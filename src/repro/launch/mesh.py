"""Production meshes.

Functions (not module-level constants) so importing never touches jax device
state.  Single pod: 16x16 = 256 chips (v5e pod).  Multi-pod: 2x16x16 = 512
chips; the leading "pod" axis crosses DCN — batch (and gradient all-reduce)
shards over it, model sharding never does.
"""
from __future__ import annotations

from repro.dist.compat import AxisType, make_mesh as _make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes,
                      axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    return _make_mesh(tuple(shape), tuple(axes),
                      axis_types=(AxisType.Auto,) * len(axes))
