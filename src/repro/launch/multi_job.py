"""Multi-job training driver: J Trainers through ONE multi-tenant PS.

Builds J seeded tiny training jobs over disjoint partitions of one
simulated cluster (``cluster.simulator.PartitionedSim``), admits each to
a shared :class:`repro.ps.PSServer`, and runs a scheduler-driven tick
loop: every tick the policy picks which jobs the cluster services, each
serviced job runs one Trainer step (its cutoff fetched lazily from the
batched decision), and ``server.flush()`` dispatches ONE vmapped fused
observe+decide for the whole service set.

Per-job elasticity rides the existing protocol end-to-end: a ChurnEvent
killing workers inside partition p shrinks job p's timer view, its
Trainer resizes through ``JobHandle.resize``, the server degrades that
job to the warm Elfving fallback and refits its DMM from the surviving
window — the other J-1 jobs never leave the batched path.

  PYTHONPATH=src python -m repro.launch.multi_job [--jobs 3] [--ticks 40]
                                                  [--policy rr|priority|spsf]
"""
from __future__ import annotations

import argparse
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


@dataclass
class JobRun:
    """One tenant: its Trainer, its server handle, its timer view."""
    job_id: str
    trainer: object
    handle: object
    view: object
    serviced: int = 0


def build_multi_job(n_jobs: int = 3, n_per_job: int = 8, *,
                    seed: int = 0, k_samples: int = 32,
                    fit_steps: int = 120, churn_events=(),
                    priorities=None, global_batch: int = 24,
                    refit_steps: int = 100, refit_fresh: int = 3,
                    refit_async: bool = False, metrics_every: int = 10,
                    obs=None):
    """J seeded tiny Trainers over a partitioned paper cluster, one
    shared PSServer.  Returns (server, jobs dict, sim).

    ``obs`` (a :class:`repro.obs.ObsRun`) instruments the server's flush
    dispatches, every trainer's step loop (``Trainer.name`` = job id, so
    the interleaved step stream stays attributable), and wraps each
    job's handle in the decision-quality recorder — decisions are
    bit-identical with it attached."""
    import jax

    from repro import optim
    from repro.cluster.simulator import (PartitionedSim, paper_cluster_158,
                                         partition_ids)
    from repro.configs.base import bench_tiny_config
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, jit_train_step
    from repro.models import model as M
    from repro.ps import PSServer

    n_total = n_jobs * n_per_job
    cfg = bench_tiny_config()
    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)      # ONE jit, shared by every job
    base = paper_cluster_158(seed=seed + 1, n_workers=n_total)
    sim = PartitionedSim(base, partition_ids(n_total, n_jobs),
                         events=list(churn_events))
    server = PSServer(refit_steps=refit_steps, refit_fresh=refit_fresh,
                      refit_async=refit_async, obs=obs)
    jobs: Dict[str, JobRun] = {}
    for j in range(n_jobs):
        job_id = f"job{j}"
        ids = sim.partitions[j]
        # per-job DMM fit on a seeded same-phenomenology trace at the
        # partition width (the per-job instrumentation run)
        trace = paper_cluster_158(seed=seed + 10 + j,
                                  n_workers=n_per_job).run(
            max(40, fit_steps // 3))
        rm = RuntimeModel(n_workers=n_per_job, lag=10).init(seed + j)
        rm.fit(trace, steps=fit_steps, batch=8, seed=seed + j)
        handle = server.admit(
            job_id, rm, window=trace[-(rm.lag + 1):], members=ids,
            priority=(priorities[j] if priorities is not None else 0.0),
            k_samples=k_samples, seed=seed + 100 * j)
        view = sim.view(j)
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=global_batch, seed=seed + j)
        ctl = obs.wrap(handle, policy=job_id) if obs is not None else handle
        tr = Trainer(cfg=cfg, step_fn=step_fn, data=data, controller=ctl,
                     timer=view, n_workers=n_per_job, members=ids,
                     metrics_every=metrics_every, obs=obs, name=job_id)

        def init_fn(jj=j):
            params = M.init_model(cfg, jax.random.PRNGKey(seed + jj))
            return {"params": params, "opt": opt.init(params)}

        tr.restore_or_init(init_fn)
        jobs[job_id] = JobRun(job_id=job_id, trainer=tr, handle=handle,
                              view=view)
    return server, jobs, sim


def run_ticks(server, jobs: Dict[str, JobRun], scheduler, ticks: int, *,
              capacity: Optional[int] = None, verbose: bool = False):
    """The multi-tenant hot loop: schedule -> prefetch -> serve -> flush.

    Returns per-tick service lists plus aggregate counters."""
    from contextlib import nullcontext

    from repro.ps.scheduler import job_views

    obs = getattr(server, "obs", None)
    schedule_log: List[List[str]] = []
    serviced = {job_id: 0 for job_id in jobs}
    d0 = server.dispatches
    for tick in range(ticks):
        span = (obs.trace.span("multi_job.tick", track="driver", tick=tick)
                if obs is not None else nullcontext())
        with span:
            order = scheduler.order(job_views(server), capacity)
            server.prefetch(order)
            for job_id in order:
                jobs[job_id].trainer.run(1)
                jobs[job_id].serviced += 1
                serviced[job_id] += 1
            server.flush()
        schedule_log.append(order)
        if verbose and (tick + 1) % 10 == 0:
            modes = {j.job_id: j.handle.mode for j in jobs.values()}
            print(f"  tick {tick + 1}: serviced={order} modes={modes}")
    if obs is not None:
        obs.drain()
    return {"schedule": schedule_log,
            "dispatches": server.dispatches - d0,
            "serviced": serviced}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--workers-per-job", type=int, default=8)
    ap.add_argument("--ticks", type=int, default=40)
    ap.add_argument("--capacity", type=int, default=None,
                    help="jobs serviced per tick (default: all)")
    ap.add_argument("--policy", default="rr",
                    choices=["rr", "priority", "spsf"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-dir", default=None,
                    help="write obs telemetry streams (spans/steps/"
                         "decisions/metrics JSONL) under this directory")
    args = ap.parse_args()

    from repro.cluster.simulator import ChurnEvent
    from repro.obs import ObsRun
    from repro.ps import make_scheduler

    kill_at = args.ticks // 3
    back_at = 2 * args.ticks // 3
    # kill two workers of job1's partition mid-run, restore later
    victim = [args.workers_per_job + 0, args.workers_per_job + 1]
    events = [ChurnEvent(step=kill_at, kill=tuple(victim)),
              ChurnEvent(step=back_at, restore=tuple(victim))]
    print(f"=== building {args.jobs} jobs x {args.workers_per_job} workers, "
          f"churn kills {victim} at tick {kill_at} ===")
    obs = ObsRun(args.obs_dir) if args.obs_dir else None
    server, jobs, _ = build_multi_job(
        args.jobs, args.workers_per_job, seed=args.seed,
        churn_events=events if args.jobs > 1 else (), obs=obs)
    sched = make_scheduler(args.policy)
    out = run_ticks(server, jobs, sched, args.ticks,
                    capacity=args.capacity, verbose=True)
    if obs is not None:
        obs.close()
        print(f"obs streams -> {args.obs_dir} "
              f"(render: python -m repro.obs {args.obs_dir})")
    print(f"=== {args.ticks} ticks, {out['dispatches']} fused dispatches "
          f"({out['dispatches'] / max(1, args.ticks):.2f}/tick) ===")
    for job_id, run in jobs.items():
        hist = run.trainer.history
        losses = [h["loss"] for h in hist[-3:]]
        print(f"  {job_id}: serviced={run.serviced} steps={len(hist)} "
              f"width={run.handle.n} mode={run.handle.mode} "
              f"last3loss={np.mean(losses):.4f}")


if __name__ == "__main__":
    main()
