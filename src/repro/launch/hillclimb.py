import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb harness: measure one (arch x shape) cell under a knob
setting, via the layer-wise accounting (same machinery as the dry-run).

  PYTHONPATH=src python -m repro.launch.hillclimb --arch gemma3-12b \
      --shape train_4k --tag ring_ce --set ce_impl=ring --set q_chunk=512

Writes experiments/perf/<arch>__<shape>__<tag>.json with the roofline terms
so before/after deltas land in EXPERIMENTS.md §Perf.
"""
import argparse
import json
import time

from repro.configs.base import SHAPES, get_config
from repro.launch.dryrun import account_cell, grad_accum_for, lower_cell, zero1_for
from repro.launch.mesh import make_production_mesh
from repro.perf import hlo_stats
from repro.perf.knobs import Knobs, use_knobs

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "perf")


def parse_sets(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        field = Knobs.__dataclass_fields__[k]
        if field.type in ("int", int):
            v = int(v)
        elif field.type in ("float", float):
            v = float(v)
        elif field.type in ("bool", bool):
            v = v.lower() in ("1", "true", "yes")
        out[k] = v
    return out


def measure(arch, shape_name, *, mesh=None, knob_kw=None, grad_accum=None,
            zero1=None, with_memory=False, layout="sp"):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh()
    knob_kw = knob_kw or {}
    t0 = time.time()
    with use_knobs(**knob_kw):
        acc = account_cell(cfg, shape, mesh, grad_accum=grad_accum,
                           zero1=zero1, layout=layout)
        rec = {
            "arch": arch, "shape": shape_name, "knobs": knob_kw,
            "layout": layout,
            "grad_accum": acc["grad_accum"],
            "flops_per_device": acc["flops"],
            "bytes_per_device": acc["bytes"],
            "collective_bytes_per_device": acc["coll"],
            "collectives_by_kind": acc["coll_by_kind"],
            "roofline": hlo_stats.roofline_terms(acc["flops"], acc["bytes"],
                                                 acc["coll"]),
            "measure_s": round(time.time() - t0, 1),
        }
        if with_memory:
            lowered, _ = lower_cell(cfg, shape, mesh, grad_accum=grad_accum,
                                    zero1=zero1, layout=layout)
            mem = lowered.compile().memory_analysis()
            rec["memory_peak_gb"] = round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
                / 2**30, 2)
    return rec


def kernel_adjusted(arch, shape_name, *, mesh=None, knob_kw=None,
                    grad_accum=None, zero1=None, layout="sp"):
    """Kernel-adjusted memory term.

    The XLA (CPU-lowered) graph materializes attention score tiles in HBM;
    the Pallas flash kernel (validated in interpret mode) keeps them in
    VMEM.  Because Mosaic cannot lower for the CPU dry-run target, the
    kernel's effect on the memory term is measured DIFFERENTIALLY:

      1. cost a 1-layer graph with full attention  (score elems P_full)
      2. cost the same layer with a small sliding window (score elems P_win)
      3. bytes-per-score-element  k = dBytes / dP  (linear model)
      4. adjusted = total - sum_layers k*P(layer) + sum_layers kernel_streams

    kernel_streams = (q,k,v,o) HBM traffic of the flash kernel itself
    (x ~3.5 for train: fwd + remat-fwd + bwd read/write).
    """
    import dataclasses as _dc

    from repro.launch import dryrun as DR
    from repro.models import model as M

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh()
    knob_kw = knob_kw or {}
    kind = shape.kind
    assert kind in ("train", "prefill"), "decode bytes are real HBM traffic"
    ga = (grad_accum if grad_accum is not None
          else DR.grad_accum_for(cfg, shape)) if kind == "train" else 1
    z1 = zero1 if zero1 is not None else DR.zero1_for(cfg)
    mshape = _dc.replace(shape, global_batch=shape.global_batch // ga)

    with use_knobs(**knob_kw):
        base = measure(arch, shape_name, mesh=mesh, knob_kw=knob_kw,
                       grad_accum=grad_accum, zero1=zero1, layout=layout)
        # differential attention-byte measurement on a full-attn layer
        from repro.models.blocks import LayerSpec
        attn_kinds = {"attn_mlp": None, "attn_moe": None, "attn_dense": None,
                      "hybrid": None, "enc": None, "dec": None}
        probe_kind = next(s.kind for s in M.layer_specs(cfg)
                          if s.kind in attn_kinds)
        kw = dict(grad_accum=1, zero1=z1, grads_only=(kind == "train"),
                  layout=layout)
        c_full = DR._cost_of(DR._single_layer_cfg(
            cfg, LayerSpec(kind=probe_kind, window=0)), mshape, mesh, **kw)
        W = 1024
        c_win = DR._cost_of(DR._single_layer_cfg(
            cfg, LayerSpec(kind=probe_kind, window=W)), mshape, mesh, **kw)

        tp = mesh.shape.get("model", 1)
        if layout == "fsdp":
            lay_dp = mesh.size
            tp_seq = 1
        else:
            lay_dp = max(mesh.shape.get("data", 1)
                         * mesh.shape.get("pod", 1), 1)
            tp_seq = tp
        B_l = max(mshape.global_batch // lay_dp, 1)
        S = shape.seq_len
        S_loc = S // tp_seq
        qc = 256
        span_win = min(W + qc, S)
        H = cfg.n_heads
        p_full = B_l * H * S_loc * S
        p_win = B_l * H * S_loc * span_win
        k_per = max((c_full["bytes"] - c_win["bytes"]) / (p_full - p_win), 0)

        # subtract XLA attention bytes / add kernel streams, per layer
        adj = base["bytes_per_device"]
        kern_total = 0.0
        for s in (M.layer_specs(cfg)
                  + (M.encoder_layer_specs(cfg) if cfg.is_encoder_decoder
                     else [])):
            if s.kind not in attn_kinds:
                continue
            span = S if s.window == 0 else min(s.window + qc, S)
            p = B_l * H * S_loc * span
            adj -= ga * k_per * p
            streams = (2 * B_l * S_loc * (cfg.qkv_dim + cfg.kv_dim)
                       + 2 * B_l * S * 2 * cfg.kv_dim)  # q,o local + k,v full
            passes = 3.5 if kind == "train" else 1.0
            kern_total += ga * passes * streams
        adj = max(adj + kern_total, 0.0)
    rec = dict(base)
    rec["bytes_per_device_kernel_adjusted"] = adj
    rec["xla_attn_bytes_per_score_elem"] = k_per
    rec["roofline_kernel_adjusted"] = hlo_stats.roofline_terms(
        base["flops_per_device"], adj, base["collective_bytes_per_device"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", dest="sets", default=[])
    ap.add_argument("--grad-accum", type=int, default=None)
    ap.add_argument("--zero1", type=int, default=None)
    ap.add_argument("--memory", action="store_true")
    ap.add_argument("--kernel-adjust", action="store_true")
    ap.add_argument("--layout", default="sp", choices=["sp", "fsdp"])
    args = ap.parse_args()

    fn = kernel_adjusted if args.kernel_adjust else measure
    kw = ({"layout": args.layout} if args.kernel_adjust
          else {"with_memory": args.memory, "layout": args.layout})
    rec = fn(args.arch, args.shape, knob_kw=parse_sets(args.sets),
             grad_accum=args.grad_accum,
             zero1=None if args.zero1 is None else bool(args.zero1), **kw)
    os.makedirs(os.path.abspath(OUT), exist_ok=True)
    path = os.path.join(os.path.abspath(OUT),
                        f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec["roofline"]
    print(f"{args.tag}: compute={rl['compute_s']:.4f}s "
          f"memory={rl['memory_s']:.4f}s coll={rl['collective_s']:.4f}s "
          f"bound={rl['bound']}  ({rec['measure_s']}s to measure)")
    if "roofline_kernel_adjusted" in rec:
        ra = rec["roofline_kernel_adjusted"]
        print(f"  kernel-adjusted: memory={ra['memory_s']:.4f}s "
              f"bound={ra['bound']} "
              f"(attn bytes/elem={rec['xla_attn_bytes_per_score_elem']:.1f})")
    if "memory_peak_gb" in rec:
        print(f"  peak HBM: {rec['memory_peak_gb']} GB/device")


if __name__ == "__main__":
    main()
