import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Elastic-recovery dry-run: prove the framework survives losing hardware.

Scenario: a 16x16 pod loses a rack -> the job restarts on a DEGRADED
(8,16) = 128-chip mesh.  This script shows, abstractly (AOT, no allocation):

  1. train_step lowers + compiles on the degraded mesh (sharding rules are
     mesh-shape-agnostic: FSDP dim-0 / batch divisibility recomputed);
  2. the checkpoint restores: arrays are saved in logical (unsharded) form,
     so `restore(..., shardings=<new mesh>)` is the whole resharding story;
  3. the cutoff controller shrinks from 16 to 8 DP workers — the
     ElfvingController takes over until the DMM is refit (DESIGN.md §3).

  PYTHONPATH=src python -m repro.launch.elastic [--arch qwen2-0.5b]
"""
import argparse
import time

import jax

from repro import optim
from repro.configs.base import SHAPES, get_config
from repro.dist import sharding as shd
from repro.launch import inputs as I
from repro.launch import train as T
from repro.launch.mesh import make_mesh, make_production_mesh


def compile_on(cfg, shape, mesh, label):
    lay = shd.make_layout(mesh, "train_sp")
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    with shd.use_layout(lay), jax.set_mesh(mesh):
        opt = optim.adamw(1e-4)
        step = T.make_train_step(cfg, opt, grad_accum=1)
        state_abs = T.abstract_state(cfg, opt, key)
        sshard = T.state_shardings(cfg, state_abs["params"], lay)
        sshard["opt"] = {k: sshard["opt"][k] for k in state_abs["opt"]}
        batch, bshard = I.input_specs(cfg, shape, lay)
        compiled = jax.jit(step, in_shardings=(sshard, bshard),
                           out_shardings=(sshard, None)).lower(
            state_abs, batch).compile()
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30
    print(f"[{label}] {mesh.shape}: compiled in {time.time()-t0:.1f}s, "
          f"peak {peak:.1f} GB/device")
    return sshard


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    shape = SHAPES["train_4k"]

    print("=== healthy pod: 16x16 = 256 chips ===")
    healthy = make_production_mesh()
    compile_on(cfg, shape, healthy, "healthy")

    print("=== rack loss -> degraded 8x16 = 128 chips ===")
    degraded = make_mesh((8, 16), ("data", "model"))
    sshard = compile_on(cfg, shape, degraded, "degraded")

    print("=== checkpoint reshard path ===")
    print("checkpoints store logical (unsharded) arrays; restore() takes the")
    print("NEW mesh's NamedShardings and device_puts onto the survivors —")
    print("see repro.checkpoint.store.restore(shardings=...) and")
    print("tests/test_system.py::test_trainer_checkpoint_restart_resumes.")
    n_leaves = len(jax.tree.leaves(sshard["params"]))
    print(f"({n_leaves} param leaves get degraded-mesh shardings)")

    print("=== controller ===")
    print("DP workers 16 -> 8: Trainer(n_workers=8) + ElfvingController")
    print("until the DMM is refit on the new cluster shape (DESIGN.md §3).")
    print("\nelastic recovery dry-run OK")


if __name__ == "__main__":
    import sys
    sys.exit(main())
