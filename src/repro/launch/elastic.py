"""Elastic recovery: survive losing (and regaining) hardware — for real.

Default mode runs a SEEDED degraded-capacity scenario end-to-end on this
container (no mesh required):

  1. fit the DMM on an 8-worker paper-cluster trace and train with the
     ``ElasticController`` driving cutoffs;
  2. a churn event kills two workers mid-run (``ChurnSim``): the Trainer
     detects the width change, remaps the controller's lag window
     (survivors column-exact), and decisions route through the analytic
     Elfving fallback while the DMM refits at width 6;
  3. the workers return: a second resize back to 8, same protocol;
  4. a checkpoint written mid-churn is restored into a fresh Trainer at
     the degraded width — the controller window comes back warm
     (allclose), straggler prediction does not restart cold.

``--aot`` runs the original dry-run instead: prove train_step lowers and
compiles on a degraded (8,16) mesh after losing a rack of a 16x16 pod,
and that the mesh-agnostic checkpoint reshards onto the survivors.

  PYTHONPATH=src python -m repro.launch.elastic [--steps N]
  PYTHONPATH=src python -m repro.launch.elastic --aot [--arch qwen2-0.5b]
"""
import argparse
import os
import sys


# ---------------------------------------------------------------------------
# Default mode: seeded degraded-capacity run (CPU, no mesh).
# ---------------------------------------------------------------------------


def run_churn_demo(steps: int = 60, seed: int = 0, obs=None) -> dict:
    import jax
    import numpy as np

    from repro import optim
    from repro.cluster.simulator import (ChurnEvent, ChurnSim,
                                         paper_cluster_158)
    from repro.configs.base import bench_tiny_config
    from repro.core.controller import ElasticController, FullSyncController
    from repro.core.runtime_model.api import RuntimeModel
    from repro.data.pipeline import SyntheticTokens
    from repro.launch.train import Trainer, clock_to_loss, jit_train_step
    from repro.models import model as M
    from repro.obs import ObsRun

    cfg = bench_tiny_config()
    n = 8
    shrink_at, recover_at = steps // 3, 2 * steps // 3
    ckpt_dir = "/tmp/repro_elastic_demo"
    import shutil
    # reprolint: disable=nonatomic-checkpoint-write -- demo scrubs its own /tmp scratch root before a fresh run; nothing published lives here yet
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    print(f"=== fit the DMM on a {n}-worker paper-cluster trace ===")
    trace = paper_cluster_158(seed, n_workers=n).run(120)
    rm = RuntimeModel(n_workers=n, lag=10).init(seed)
    rm.fit(trace, steps=150, batch=8, seed=seed)

    def make_timer():
        return ChurnSim(paper_cluster_158(seed + 1, n_workers=n),
                        [ChurnEvent(step=shrink_at, kill=(6, 7)),
                         ChurnEvent(step=recover_at, restore=(6, 7))])

    opt = optim.adamw(3e-3)
    step_fn = jit_train_step(cfg, opt)

    def init_fn():
        params = M.init_model(cfg, jax.random.PRNGKey(seed))
        return {"params": params, "opt": opt.init(params)}

    mid = (shrink_at + recover_at) // 2   # a ckpt lands mid-churn

    def make_trainer(ctl, timer, ckpt=None, run_obs=None, name=None):
        data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                               global_batch=24, seed=seed)
        tr = Trainer(cfg=cfg, step_fn=step_fn, data=data, controller=ctl,
                     timer=timer, n_workers=timer.n_workers, ckpt_dir=ckpt,
                     ckpt_every=mid, obs=run_obs, name=name)
        return tr.restore_or_init(init_fn)

    print(f"=== churn run: n {n} -> 6 at step {shrink_at}, "
          f"-> {n} at step {recover_at} ===")
    # the elastic trainer records to the caller's obs run (or an
    # in-memory one); the sync baseline gets its OWN in-memory run so
    # each step stream holds exactly one trajectory — clock_to_loss
    # reads both straight from the obs recorders
    obs_el = obs if obs is not None else ObsRun()
    obs_sync = ObsRun()
    ctl = ElasticController(rm, k_samples=32, seed=seed, refit_steps=60)
    ctl.seed_window(trace[-40:])
    tr = make_trainer(ctl, make_timer(), ckpt=ckpt_dir, run_obs=obs_el,
                      name="elastic")
    tr.run(recover_at - 1)                # shrink fires; ckpt at width 6

    print("=== restart from the mid-churn checkpoint ===")
    from repro.checkpoint import store
    saved_step = store.latest_step(ckpt_dir)
    saved = store.restore_group(ckpt_dir, "ctl")
    n_saved = int(saved["n"])
    ctl2 = ElasticController(rm, k_samples=32, seed=seed, refit_steps=60)
    timer2 = make_timer()
    for _ in range(saved_step):          # replay the schedule to the ckpt
        timer2.step()
    tr2 = Trainer(cfg=cfg, step_fn=step_fn, controller=ctl2,
                  data=SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=8,
                                       global_batch=24, seed=seed),
                  timer=timer2, n_workers=n, ckpt_dir=ckpt_dir)
    tr2.restore_or_init(init_fn)
    warm = np.allclose(ctl2.window_array(), saved["window"])
    print(f"  resumed at step {tr2.step}, width {tr2.n_workers} "
          f"(ckpt width {n_saved}), controller window warm: {warm}")
    assert warm and tr2.n_workers == n_saved == 6
    tr2.run(3)

    tr.run(steps - tr.step)               # recovery back to 8 workers
    widths = [h["n"] for h in tr.history]
    print(f"  widths seen: {sorted(set(widths))}; "
          f"fallback steps: {ctl.fallback_steps}")
    assert 6 in widths and 8 in widths, "churn did not fire"

    print("=== full-sync baseline on the identical churn schedule ===")
    sync = make_trainer(FullSyncController(n), make_timer(),
                        run_obs=obs_sync, name="sync")
    sync.run(steps)

    target = sync.obs.steps.final_loss(window=3)
    t_el = clock_to_loss(tr.obs.steps, target)
    t_sync = clock_to_loss(sync.obs.steps, target)
    fmt = lambda v: "n/a" if v is None else f"{v:.1f}s"
    print(f"  wall-clock to sync's final loss: elastic {fmt(t_el)} "
          f"vs full-sync {fmt(t_sync)}")
    print("\nelastic degraded-capacity run OK")
    return {"widths": widths, "t_elastic": t_el, "t_sync": t_sync,
            "resumed_step": int(tr2.step), "resumed_n": int(tr2.n_workers)}


# ---------------------------------------------------------------------------
# --aot mode: mesh-level dry-run (lowering + reshard story, no allocation).
# ---------------------------------------------------------------------------


def compile_on(cfg, shape, mesh, label):
    import time

    import jax

    from repro import optim
    from repro.dist import sharding as shd
    from repro.launch import inputs as I
    from repro.launch import train as T

    lay = shd.make_layout(mesh, "train_sp")
    key = jax.random.PRNGKey(0)
    t0 = time.time()
    with shd.use_layout(lay), jax.set_mesh(mesh):
        opt = optim.adamw(1e-4)
        step = T.make_train_step(cfg, opt, grad_accum=1)
        state_abs = T.abstract_state(cfg, opt, key)
        sshard = T.state_shardings(cfg, state_abs["params"], lay)
        sshard["opt"] = {k: sshard["opt"][k] for k in state_abs["opt"]}
        batch, bshard = I.input_specs(cfg, shape, lay)
        compiled = jax.jit(step, in_shardings=(sshard, bshard),
                           out_shardings=(sshard, None)).lower(
            state_abs, batch).compile()
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30
    print(f"[{label}] {mesh.shape}: compiled in {time.time()-t0:.1f}s, "
          f"peak {peak:.1f} GB/device")
    return sshard


def run_aot(arch: str):
    # every jax import in this module is deferred, so setting the fake
    # device count here (not at module import) covers programmatic
    # callers too — as long as jax has not been imported elsewhere first
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=512")
    import jax

    from repro.configs.base import SHAPES, get_config
    from repro.launch.mesh import make_mesh, make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES["train_4k"]

    print("=== healthy pod: 16x16 = 256 chips ===")
    healthy = make_production_mesh()
    compile_on(cfg, shape, healthy, "healthy")

    print("=== rack loss -> degraded 8x16 = 128 chips ===")
    degraded = make_mesh((8, 16), ("data", "model"))
    sshard = compile_on(cfg, shape, degraded, "degraded")

    print("=== checkpoint reshard path ===")
    print("checkpoints store logical (unsharded) arrays; restore() takes "
          "the NEW mesh's NamedShardings and device_puts onto the "
          "survivors — see repro.checkpoint.store.restore(shardings=...).")
    n_leaves = len(jax.tree.leaves(sshard["params"]))
    print(f"({n_leaves} param leaves get degraded-mesh shardings)")
    print("\nelastic AOT dry-run OK")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--aot", action="store_true",
                    help="mesh-level compile dry-run instead of the "
                         "end-to-end churn demo")
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-dir", default=None,
                    help="write obs telemetry streams (spans/steps/"
                         "decisions/metrics JSONL) under this directory")
    args = ap.parse_args()
    if args.aot:
        run_aot(args.arch)
    else:
        obs = None
        if args.obs_dir:
            from repro.obs import ObsRun
            obs = ObsRun(args.obs_dir)
        run_churn_demo(steps=args.steps, seed=args.seed, obs=obs)
        if obs is not None:
            obs.close()
            print(f"obs streams -> {args.obs_dir} "
                  f"(render: python -m repro.obs {args.obs_dir})")


if __name__ == "__main__":
    sys.exit(main())
