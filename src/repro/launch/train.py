"""Training entry points: cutoff train step + the production Trainer.

``make_train_step`` builds the jit-able step:

  * ``mask_agg="weights"`` (production, paper Alg. 1 / §4.3 variant):
    per-example weights carry the cutoff bit-array — masked gradients,
    renormalized by c, with no extra collectives beyond the DP psum GSPMD
    already emits;
  * ``mask_agg="psum"`` (explicit, Chen et al.'s PS semantics): the step
    computes per-worker microbatch gradients (leading worker dim, the
    grad-accum scan machinery) and aggregates them through
    ``dist.collectives.masked_grad_mean`` — the Pallas host combine under
    LOCAL, the shard_map psum under a mesh layout;
  * optional gradient accumulation (microbatching) — the activation-memory
    knob, also what overlaps per-microbatch gradient reduce with compute;
  * ZeRO-1/3: params FSDP-sharded over "model", optimizer moments optionally
    sharded over "data" too.

The ``Trainer`` is the host-side driver: controller -> bit-array ->
weights (or the bit array itself under ``mask_agg="psum"``), per-worker
sampling with replacement, simulated (or measured) step times,
checkpoint/restart (controller window + membership included), and mid-run
elastic resize (``Trainer.resize`` / a width-changing timer such as
``cluster.simulator.ChurnSim``).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.dist import collectives
from repro.dist import sharding as shd
from repro.models import model as M


# ---------------------------------------------------------------------------
# Train step.
# ---------------------------------------------------------------------------


def make_loss_fn(cfg, aux_coef: float = 0.01):
    from repro.perf.knobs import knobs

    def loss_fn(params, batch, normalizer):
        w = batch.get("weights")
        if knobs().ce_impl == "ring":
            x, _, aux = M.forward(cfg, params, batch, mode="train",
                                  head=False)
            ce_sum = M.ring_ce_sum(cfg, params, x, batch["labels"], w)
            loss = ce_sum / normalizer
            return loss + aux_coef * aux, {"ce": loss, "aux": aux}
        if knobs().ce_chunk > 0:
            x, _, aux = M.forward(cfg, params, batch, mode="train",
                                  head=False)
            ce_sum = M.chunked_ce_sum(cfg, params, x, batch["labels"], w,
                                      knobs().ce_chunk)
            loss = ce_sum / normalizer
            return loss + aux_coef * aux, {"ce": loss, "aux": aux}
        logits, _, aux = M.forward(cfg, params, batch, mode="train")
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, batch["labels"][..., None],
                                 axis=-1)[..., 0]
        ce = lse - ll
        if w is not None:
            wb = jnp.broadcast_to(w.astype(jnp.float32)[:, None], ce.shape)
            ce = ce * wb
        loss = jnp.sum(ce) / normalizer
        return loss + aux_coef * aux, {"ce": loss, "aux": aux}
    return loss_fn


MASK_AGG_MODES = ("weights", "psum")


def _split_batch(batch, parts: int):
    """Split every batch entry into ``parts`` leading microbatches."""
    def split(k, v):
        if k == "positions" and v.ndim == 3:
            return v.reshape(
                (3, parts, v.shape[1] // parts)
                + v.shape[2:]).swapaxes(0, 1)
        return v.reshape((parts, v.shape[0] // parts) + v.shape[1:])

    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(cfg, optimizer: optim.Optimizer, *,
                    grad_accum: int = 1, aux_coef: float = 0.01,
                    compress_pod_grads: bool = False,
                    mask_agg: str = "weights", stale_reuse: bool = False):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", ["ef"]}.

    mask_agg="weights": batch["weights"] is the per-example cutoff mask
    expanded by ``dist.collectives.example_weights``; the masked mean is
    implicit in the loss normalization + the DP gradient psum.

    mask_agg="psum": batch["mask"] is the per-worker CONTRIBUTION vector
    ((n_workers,) float, n_workers | global batch).  The discard policy
    passes the 0/1 bit array; the anytime policy
    (``core.controller.AnytimeController``) passes completed-microbatch
    fractions in [0, 1].  The step scans the per-worker microbatches; a
    worker with contribution f keeps only its first ``round(f *
    grad_accum)`` microbatch gradients (the ``jax.lax.scan`` grad-accum
    partial sums — the partial work an anytime straggler actually
    shipped), normalized by its completed token count, and the stack is
    aggregated with ``collectives.masked_grad_mean`` weighted by f — an
    explicit combine whose numerics are independent of how many workers
    were dropped.  With an all-0/1 vector every multiplication is by
    exactly 1.0, so the generalized path is bit-identical to the bit-array
    path.  Costs n_workers x gradient memory; the production path is
    "weights".

    stale_reuse=True (mask_agg="psum" only, the
    ``core.controller.StaleReuseController`` policy): the step also
    returns the DROPPED workers' mean gradient under ``metrics["stale"]``
    (a ``(tree, count)`` pair the Trainer buffers), and consumes
    ``batch["stale_g"]`` / ``batch["stale_w"]`` — last step's dropped
    mean and its decayed weight — folding them into this step's masked
    mean in-jit: ``g = (c * g_fresh + w * g_stale) / (c + w)``.  With
    ``stale_w = 0`` the fold multiplies by exactly 1.0/0.0 and the
    update matches plain discard bit-for-bit.

    The weights and psum paths are exactly equivalent when the auxiliary
    loss is zero (dense archs, or aux_coef=0) and the contribution vector
    is 0/1.  For MoE archs they differ on dropped workers' load-balance
    aux: "psum" is the true PS semantics (a dropped worker contributes
    nothing, aux included), while "weights" leaves the aux term
    unweighted over the full batch.  For FRACTIONAL contributions they
    differ by design: "psum" aggregates the true partial microbatch sums,
    "weights" approximates them as f-scaled full-batch gradients (the
    per-example weight is f for every example of worker w).
    """
    if mask_agg not in MASK_AGG_MODES:
        raise ValueError(f"unknown mask_agg {mask_agg!r} "
                         f"(want one of {MASK_AGG_MODES})")
    if stale_reuse and mask_agg != "psum":
        raise ValueError(
            "stale_reuse needs per-worker gradients: build the step with "
            "mask_agg='psum' (the weights path never materializes a "
            "dropped worker's gradient to buffer)")
    loss_fn = make_loss_fn(cfg, aux_coef)

    def normalizer_of(batch):
        w = batch.get("weights")
        B, S = batch["tokens"].shape
        if w is None:
            return jnp.asarray(B * S, jnp.float32)
        return jnp.maximum(jnp.sum(w.astype(jnp.float32)) * S, 1e-6)

    def accum_grads_of(params, batch, norm, mb_w=None):
        """Summed-over-microbatches gradient at a fixed normalizer.

        ``mb_w`` (optional, (grad_accum,) f32): per-microbatch weights —
        the anytime partial-sum tap.  Each microbatch's gradient (and its
        loss/aux share) is scaled by its weight inside the scan, so a 0/1
        prefix vector yields exactly the straggler's completed partial
        sum.  ``None`` keeps the dense path byte-identical.
        """
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, norm)
            if mb_w is not None:
                w0 = mb_w[0]
                grads = jax.tree.map(lambda g: g * w0.astype(g.dtype),
                                     grads)
                loss = loss * w0
                metrics = {"ce": metrics["ce"] * w0,
                           "aux": metrics["aux"] * w0}
            return loss, metrics, grads

        mb = _split_batch(batch, grad_accum)

        def body(carry, xs):
            mbatch, w = xs if mb_w is not None else (xs, None)
            g_acc, l_acc, a_acc = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mbatch, norm)
            aux = metrics["aux"]
            if w is not None:
                g = jax.tree.map(lambda x: x * w.astype(x.dtype), g)
                loss = loss * w
                aux = aux * w
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + loss, a_acc + aux), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss, aux), _ = jax.lax.scan(
            body, (g0, jnp.float32(0), jnp.float32(0)),
            (mb, mb_w) if mb_w is not None else mb)
        return loss, {"ce": loss, "aux": aux / grad_accum}, grads

    def grads_of(params, batch):
        return accum_grads_of(params, batch, normalizer_of(batch))

    def worker_grads_of(params, batch):
        """Per-worker gradients, stacked on a leading worker dim.

        Each worker w owns the w-th contiguous slice of the global batch
        (the ``example_weights`` convention).  A worker with contribution
        f keeps only its first ``round(f * grad_accum)`` microbatch
        gradients and normalizes by its COMPLETED token count (clamped at
        one microbatch so a zero-contribution worker's loss stays finite
        — its weight in the aggregation is 0 anyway), so the f-weighted
        mean over workers equals the anytime mean over completed
        microbatches, and a 0/1 vector reproduces the bit-array masked
        mean bit-for-bit (every scale is exactly 1.0 or 0.0).
        """
        mask = jnp.asarray(batch["mask"], jnp.float32)
        W = mask.shape[0]
        data = {k: v for k, v in batch.items()
                if k not in ("mask", "stale_g", "stale_w")}
        B, S = data["tokens"].shape
        assert B % W == 0, (B, W)
        base_norm = jnp.asarray((B // W) * S, jnp.float32)
        wb = _split_batch(data, W)

        def body(_, xs):
            mbatch, f = xs
            # completed-microbatch prefix: first round(f * G) of G
            done = jnp.round(f * grad_accum)
            mb_w = (jnp.arange(grad_accum) < done).astype(jnp.float32)
            norm = jnp.maximum(f, 1.0 / grad_accum) * base_norm
            loss, metrics, g = accum_grads_of(params, mbatch, norm,
                                              mb_w=mb_w)
            return None, (g, loss, metrics["ce"], metrics["aux"])

        _, (grads, losses, ces, auxs) = jax.lax.scan(body, None, (wb, mask))
        return grads, losses, ces, auxs

    def psum_grads_of(params, batch):
        mask = jnp.asarray(batch["mask"], jnp.float32)
        grads, losses, ces, auxs = worker_grads_of(params, batch)
        agg = collectives.masked_grad_mean(grads, mask)
        stale = None
        if stale_reuse:
            # the dropped workers' mean gradient, buffered by the Trainer
            # and folded into the NEXT step (Dutta et al.); stale reuse is
            # a 0/1-mask policy, so 1 - mask is the dropped bit array
            stale = (collectives.masked_grad_mean(grads, 1.0 - mask),
                     jnp.sum(1.0 - mask))
        c = jnp.maximum(jnp.sum(mask), 1.0)
        masked_mean = lambda x: jnp.sum(x * mask) / c
        return masked_mean(losses), {"ce": masked_mean(ces),
                                     "aux": masked_mean(auxs)}, agg, stale

    def train_step(state, batch):
        if mask_agg == "psum":
            loss, metrics, grads, stale = psum_grads_of(state["params"],
                                                        batch)
            if stale_reuse:
                # fold last step's dropped-worker mean in with its decayed
                # weight: g = (c * fresh + w * stale) / (c + w); w == 0
                # multiplies by exactly 1.0/0.0 => bit-identical discard
                c = jnp.maximum(
                    jnp.sum(jnp.asarray(batch["mask"], jnp.float32)), 1.0)
                w = jnp.asarray(batch["stale_w"], jnp.float32)
                denom = c + w
                grads = jax.tree.map(
                    lambda a, b: (a * (c / denom).astype(a.dtype)
                                  + b.astype(a.dtype)
                                  * (w / denom).astype(a.dtype)),
                    grads, batch["stale_g"])
        else:
            loss, metrics, grads = grads_of(state["params"], batch)
            stale = None
        if compress_pod_grads:
            grads, ef = optim.error_feedback_compress(grads,
                                                      state.get("ef"))
            new_ef = ef
        ups, opt = optimizer.update(grads, state["opt"], state["params"])
        params = optim.apply_updates(state["params"], ups)
        new_state = {"params": params, "opt": opt}
        if compress_pod_grads:
            new_state["ef"] = new_ef
        metrics = dict(metrics, loss=loss,
                       gnorm=optim.global_norm(grads))
        if stale_reuse:
            metrics["stale"] = stale
        return new_state, metrics

    return train_step


def jit_train_step(cfg, optimizer: optim.Optimizer, *, donate: bool = True,
                   **kwargs):
    """The one place train steps get jitted: donation-clean by default.

    ``donate=True`` donates argument 0 (the train state), so the params and
    optimizer moments update in place instead of doubling peak memory every
    step.  Callers must treat the state they pass in as CONSUMED — rebind to
    the returned state, never read the old one (the ``Trainer`` does this).
    ``**kwargs`` forward to :func:`make_train_step`.
    """
    return jax.jit(make_train_step(cfg, optimizer, **kwargs),
                   donate_argnums=(0,) if donate else ())


# ---------------------------------------------------------------------------
# Sharding trees for the train state.
# ---------------------------------------------------------------------------


def stacked_paths_for(cfg):
    segs = M.build_segments(M.layer_specs(cfg))
    paths = [f"segments/{i}" for i, s in enumerate(segs) if s.repeats > 1]
    if cfg.is_encoder_decoder:
        esegs = M.build_segments(M.encoder_layer_specs(cfg))
        paths += [f"encoder/segments/{i}" for i, s in enumerate(esegs)
                  if s.repeats > 1]
    return tuple(paths)


def state_shardings(cfg, params_tree, lay: shd.Layout, *,
                    zero1: bool = False, has_ef: bool = False):
    """NamedShardings for {"params", "opt"} given an (abstract) params tree.

    zero1: optimizer moments are additionally sharded over "data" on the dim
    the parameter is already "model"-sharded on (ZeRO-1 on top of ZeRO-3);
    XLA inserts the per-step weight-delta all-gather over "data".
    """
    sp = stacked_paths_for(cfg)
    pshard = shd.named_sharding(params_tree, lay, stacked_paths=sp)

    def widen(leaf, ns):
        if ns is None or lay.mesh is None:
            return ns
        dsize = 1
        for a in lay.dp:
            if a == "data":
                dsize = lay.mesh.shape[a]
        spec = list(ns.spec) + [None] * (leaf.ndim - len(ns.spec))
        for i, ax in enumerate(spec):
            if ax == lay.model_axis:
                tp = lay.mesh.shape[lay.model_axis]
                if leaf.shape[i] % (tp * dsize) == 0:
                    spec[i] = (lay.model_axis, "data")
                break
        return NamedSharding(lay.mesh, P(*spec))

    mom = (jax.tree.map(widen, params_tree, pshard) if zero1 else pshard)
    opt_shard = {"step": NamedSharding(lay.mesh, P()) if lay.mesh else None,
                 "m": mom, "v": mom, "mu": mom}
    out = {"params": pshard, "opt": opt_shard}
    if has_ef:
        out["ef"] = pshard
    return out


def abstract_state(cfg, optimizer: optim.Optimizer, key=None):
    """Shape-only train state via jax.eval_shape (no allocation)."""
    key = key if key is not None else jax.random.PRNGKey(0)

    def build():
        params = M.init_model(cfg, key)
        return {"params": params, "opt": optimizer.init(params)}

    return jax.eval_shape(build)


def filter_opt_shardings(opt_shard, opt_state_tree):
    """Keep only the sharding entries present in the actual opt state."""
    return {k: opt_shard[k] if k in opt_shard else None
            for k in opt_state_tree}


def clock_to_loss(history, target: float, window: int = 3):
    """Simulated wall-clock until the ``window``-step trailing mean loss
    reaches ``target``; None if the run never gets there.

    THE wall-clock-to-loss metric for Trainer trajectories — the
    acceptance tests, benches and demos all share this one
    implementation.  ``history`` is either a list of step records or the
    obs step stream (``repro.obs.StepStream`` — anything with a
    ``records`` attribute): benches that attach an ``ObsRun`` read the
    trajectory straight from the one recorder instead of re-threading
    their own ``(t, loss)`` lists.  Losses must already be drained
    floats, i.e. after ``run()`` returned.

    Only FULL windows are eligible: the first ``window - 1`` steps cannot
    trigger the target (a partial early window is a mean over fewer
    losses, so one lucky first step used to fire the target a true
    trailing mean would not).
    """
    records = getattr(history, "records", history)
    losses = [h["loss"] for h in records]
    for i in range(window - 1, len(losses)):
        if np.mean(losses[i - window + 1:i + 1]) <= target:
            return records[i]["clock"]
    return None


# ---------------------------------------------------------------------------
# Production Trainer (host-side driver).
# ---------------------------------------------------------------------------


@dataclass
class Trainer:
    """Cutoff-SGD trainer: controller + masked aggregation + fault tolerance.

    ``n_workers`` virtual workers map onto DP shards (one worker per shard on
    a real mesh; on CPU they are simulated).  ``timer`` provides per-worker
    step times each iteration: a ClusterSim / TraceReplay in this container,
    per-host wall-clock measurement on real hardware.

    ``mask_agg`` picks how the controller's bit array reaches the step
    (and must match the ``make_train_step`` the ``step_fn`` was built
    with): "weights" expands it to per-example loss weights (production),
    "psum" hands the bit array itself to the explicit per-worker gradient
    combine.

    The hot loop is asynchronous: the train step is dispatched (jax async
    dispatch) BEFORE the controller's observe/imputation runs, so the
    parameter server's inference for the next decision overlaps the
    device's gradient compute; per-step losses are kept as device scalars
    and only fetched in batches every ``metrics_every`` steps (and at eval
    / verbose / run-end boundaries).  ``metrics_every=1`` restores the
    blocking per-step loop (useful for benchmarking the overlap win);
    ``metrics_every=0`` drains only at boundaries.

    Elastic membership: when the timer exposes ``n_workers`` /
    ``active_ids`` (``ChurnSim``), the loop detects worker-set changes
    before each step and calls :meth:`resize` — the controller's lag
    window is remapped (survivors column-exact), the bit-array/weights
    plumbing is rebuilt at the new width, and ``B % W`` divisibility is
    re-checked for both ``mask_agg`` paths.  Checkpoints carry the
    controller window, step and membership (the ``"ctl"`` group), so a
    restart mid-churn resumes with a warm straggler predictor at the
    checkpoint's worker count.
    """
    cfg: Any
    step_fn: Callable
    data: Any
    controller: Any
    timer: Any = None
    n_workers: int = 8
    mask_agg: str = "weights"
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    metrics_every: int = 10

    # telemetry (optional): an ``repro.obs.ObsRun``.  Attaching one adds
    # spans around the step phases, one device metric-ring push per step,
    # and forwards drained history records to the obs step stream — and
    # NOTHING else: decisions, RNG streams and parameters stay
    # bit-identical with obs on or off (tests/test_obs.py pins this).
    obs: Any = None
    name: Optional[str] = None                # job/run label for obs streams

    state: Dict = None
    step: int = 0
    sim_clock: float = 0.0
    members: Optional[np.ndarray] = None      # global worker ids
    history: list = field(default_factory=list)
    _pending_metrics: list = field(default_factory=list, repr=False)
    # stale-reuse buffer: last step's (dropped-mean tree, count) device pair
    _stale: Any = field(default=None, repr=False)

    def restore_or_init(self, init_state_fn):
        """Restore from the newest VALID checkpoint, else init cold.

        Steps are tried newest-first: a corrupt or truncated step
        (``store.CheckpointError`` — bad checksum, missing group, torn
        manifest) is skipped and the previous one is used, so a damaged
        latest checkpoint degrades to losing ``ckpt_every`` steps
        instead of killing the restart.  The controller group is
        restored from the SAME step as the train state.
        """
        from repro.checkpoint import store
        if self.members is None:
            self.members = np.arange(self.n_workers)
        steps = (list(reversed(store.list_steps(self.ckpt_dir)))
                 if self.ckpt_dir else [])
        example = init_state_fn()
        for step in steps:
            try:
                restored = store.restore(self.ckpt_dir,
                                         {"state": example, "meta": {
                                             "step": 0, "clock": 0.0}},
                                         step=step)
                self.state = restored["state"]
                self.step = int(restored["meta"]["step"])
                self.sim_clock = float(restored["meta"]["clock"])
                self._restore_controller(store, step)
                return self
            except store.CheckpointError as e:
                print(f"checkpoint step {step} unusable ({e}); "
                      f"falling back to the previous step")
        self.state = example
        return self

    def _restore_controller(self, store, step=None):
        """Warm-restore the straggler predictor from the ``ctl`` group."""
        grp = store.restore_group(self.ckpt_dir, "ctl", step=step)
        if grp is None:
            return
        n_saved = int(grp["n"])
        members = np.asarray(grp["members"], int)
        if (n_saved != self.n_workers
                or not np.array_equal(members, self.members)):
            # the checkpoint was taken mid-churn with a different worker
            # set: remap onto the SAVED membership (survivor columns by
            # global id, not by position — the set may not be a prefix)
            old = {wid: col for col, wid in enumerate(self.members)}
            col_map = np.array([old.get(wid, -1) for wid in members], int)
            self.resize(n_saved, col_map=col_map, members=members)
        ctl = self.controller
        if "window" in grp and hasattr(ctl, "seed_window"):
            ctl.seed_window(grp["window"])
        if hasattr(ctl, "_step"):
            ctl._step = int(grp["step"])

    def _controller_ckpt(self) -> Dict[str, np.ndarray]:
        members = (self.members if self.members is not None
                   else np.arange(self.n_workers))
        grp = {"n": np.int64(self.n_workers),
               "members": np.asarray(members, np.int64),
               "step": np.int64(getattr(self.controller, "_step",
                                        self.step))}
        if hasattr(self.controller, "window_array"):
            try:
                grp["window"] = np.asarray(self.controller.window_array(),
                                           np.float64)
            except ValueError:      # window still empty (cold controller)
                pass
        return grp

    # -- elastic membership --------------------------------------------
    def resize(self, n_workers: int, col_map=None, members=None):
        """Elastic worker-membership change, mid-run.

        Re-checks global-batch divisibility for the new width (both
        ``mask_agg`` paths slice the global batch into per-worker
        contiguous shards), remaps the controller's lag window
        (``col_map`` as in ``core.controller.remap_columns``), and
        records the new membership for the checkpoint meta.  The train
        step itself is width-agnostic — the next step's bit array simply
        has the new length (a new jit trace under ``mask_agg="psum"``).
        """
        n_new = int(n_workers)
        B = getattr(self.data, "global_batch", None)
        if B is not None and B % n_new != 0:
            raise ValueError(
                f"cannot resize to {n_new} workers: global batch {B} is "
                f"not divisible by the worker count (mask_agg="
                f"{self.mask_agg!r} slices the batch into B//W per-worker "
                f"shards — pick a worker count that divides {B})")
        if hasattr(self.controller, "resize"):
            # members: GLOBAL worker ids — part of the controller resize
            # protocol; width-only controllers ignore them, the
            # multi-tenant handle records them for restore-by-global-id
            self.controller.resize(n_new, col_map=col_map, members=members)
        elif getattr(self.controller, "n", n_new) != n_new:
            raise ValueError(
                f"controller {type(self.controller).__name__} cannot "
                f"resize to {n_new} workers")
        self.n_workers = n_new
        self.members = (np.asarray(members, int) if members is not None
                        else np.arange(n_new))
        return self

    def _sync_membership(self):
        """Follow the timer's worker set (ChurnSim) before each step."""
        if self.members is None:
            self.members = np.arange(self.n_workers)
        if self.timer is None:
            return
        ids = getattr(self.timer, "active_ids", None)
        w = int(getattr(self.timer, "n_workers", self.n_workers))
        if ids is None:
            if w != self.n_workers:
                self.resize(w)          # prefix survivors
            return
        ids = np.asarray(ids, int)
        if w == self.n_workers and np.array_equal(ids, self.members):
            return
        old = {wid: col for col, wid in enumerate(self.members)}
        col_map = np.array([old.get(wid, -1) for wid in ids], int)
        self.resize(w, col_map=col_map, members=ids)

    def _drain_metrics(self):
        """Fetch every pending device-side loss into its history record
        (and forward the now-host-resident records to the obs step
        stream — the one recorder every trajectory consumer reads)."""
        for rec in self._pending_metrics:
            rec["loss"] = float(rec["loss"])
            if self.obs is not None:
                self.obs.steps.on_step(rec, job=self.name)
        self._pending_metrics.clear()
        if self.obs is not None:
            # the obs drain rides the same boundary as the loss fetch:
            # decision scoring + device metric rings come back here, and
            # ONLY here — never inside the step
            with self.obs.trace.span("obs.drain", track="trainer",
                                     step=self.step):
                self.obs.drain()

    def run(self, n_steps: int, *, eval_fn=None, eval_every: int = 0,
            verbose: bool = False):
        from contextlib import nullcontext
        from repro.checkpoint import store
        ckpt = (store.AsyncCheckpointer(self.ckpt_dir, self.keep)
                if self.ckpt_dir else None)
        null = nullcontext()
        tracer = self.obs.trace if self.obs is not None else None
        ring = (self.obs.metrics.ring(
            "trainer" if self.name is None else f"trainer[{self.name}]",
            ("loss", "gnorm", "c", "iter_time"))
            if self.obs is not None else None)
        for _ in range(n_steps):
            step_span = (tracer.span("trainer.step", track="trainer",
                                     step=self.step + 1, job=self.name)
                         if tracer is not None else null)
            with step_span:
                self._sync_membership()  # elastic: follow the timer's width
                n = self.n_workers
                with (tracer.span("controller.predict_cutoff",
                                  track="trainer")
                      if tracer is not None else null):
                    c = int(self.controller.predict_cutoff())
                c = min(c, n)
                times = (self.timer.step() if self.timer is not None
                         else np.ones(n))
                # fastest c workers participate (the PS's bit array)
                order = np.argsort(times)
                mask = np.zeros(n, np.float32)
                mask[order[:c]] = 1.0
                iter_time = float(times[order[c - 1]])
                # the controller must see the SAME worker set the
                # aggregation used: under ties, a times<=iter_time
                # threshold marks MORE than c workers finished and the
                # two views diverge
                finished = mask.astype(bool)

                # anytime policy: stragglers contribute their completed
                # fraction instead of a zeroed bit; finishers stay 1.0
                contrib = mask
                if hasattr(self.controller, "contribution"):
                    contrib = np.asarray(
                        self.controller.contribution(times, c), np.float32)

                batch = dict(self.data.batch(self.step))
                if self.mask_agg == "psum":
                    batch["mask"] = jnp.asarray(contrib)
                else:
                    batch["weights"] = collectives.example_weights(
                        contrib, batch["tokens"].shape[0])
                decay = getattr(self.controller, "stale_decay", None)
                if decay is not None:
                    if self.mask_agg != "psum":
                        raise ValueError(
                            "StaleReuseController needs mask_agg='psum' "
                            "(the weights path never materializes a "
                            "dropped worker's gradient to buffer)")
                    if self._stale is None:
                        zeros = jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32),
                            self.state["params"])
                        self._stale = (zeros, jnp.float32(0))
                    stale_g, stale_d = self._stale
                    batch["stale_g"] = stale_g
                    # decayed weight of the buffered mean: decay per
                    # worker that contributed to it, kept lazy on device
                    batch["stale_w"] = jnp.float32(decay) * stale_d
                # dispatch the train step FIRST (async), then run the
                # PS's observe/imputation so controller inference
                # overlaps compute
                with (tracer.span("train.dispatch", track="trainer")
                      if tracer is not None else null):
                    self.state, metrics = self.step_fn(self.state, batch)
                if decay is not None:
                    if "stale" not in metrics:
                        raise ValueError(
                            "StaleReuseController needs a step_fn built "
                            "with make_train_step(..., mask_agg='psum', "
                            "stale_reuse=True) — this one returned no "
                            "metrics['stale'] buffer")
                    self._stale = metrics.pop("stale")
                with (tracer.span("controller.observe", track="trainer")
                      if tracer is not None else null):
                    self.controller.observe(times, finished)
                self.step += 1
                self.sim_clock += iter_time
                rec = {"step": self.step, "clock": self.sim_clock, "c": c,
                       "n": n, "iter_time": iter_time,
                       "loss": metrics["loss"]}  # device scalar; drained
                self.history.append(rec)
                self._pending_metrics.append(rec)
                if ring is not None:
                    # ONE donated in-jit push; loss/gnorm stay lazy
                    ring.push((metrics["loss"], metrics["gnorm"],
                               float(c), iter_time))
                if (self.metrics_every
                        and self.step % self.metrics_every == 0):
                    self._drain_metrics()
                if eval_fn and eval_every and self.step % eval_every == 0:
                    self._drain_metrics()
                    rec["eval"] = float(eval_fn(self.state))
                if verbose and self.step % 20 == 0:
                    self._drain_metrics()
                    print(f"  step {self.step}: loss={rec['loss']:.4f} "
                          f"c={c}/{n} t={iter_time:.3f}s "
                          f"clock={self.sim_clock:.1f}s")
                if ckpt and self.step % self.ckpt_every == 0:
                    ckpt.save(self.step, {
                        "state": self.state,
                        "meta": {"step": self.step,
                                 "clock": self.sim_clock},
                        "ctl": self._controller_ckpt()})
        self._drain_metrics()
        if ckpt:
            ckpt.wait()
        return self.history
