"""input_specs(): ShapeDtypeStruct stand-ins + shardings per (arch x shape).

No device allocation — everything is abstract, exactly what
``jax.jit(...).lower()`` needs for the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as shd
from repro.models import model as M


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_structs(cfg, shape, *, with_labels: bool) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        S = 1
    d = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.mrope_sections:
        d["positions"] = _sds((3, B, S), jnp.int32)
    else:
        d["positions"] = _sds((B, S), jnp.int32)
    if with_labels:
        d["labels"] = _sds((B, S), jnp.int32)
        d["weights"] = _sds((B,), jnp.float32)
    if cfg.frontend == "vision_patches" and shape.kind != "decode":
        d["patch_embeds"] = _sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        d["image_mask"] = _sds((B, S), jnp.bool_)
    if cfg.is_encoder_decoder and shape.kind != "decode":
        d["frames"] = _sds((B, cfg.encoder_seq_len, cfg.d_model),
                           jnp.dtype(cfg.dtype))
    return d


def batch_shardings(cfg, batch, lay: shd.Layout) -> Dict[str, Any]:
    if lay.mesh is None:
        return {k: None for k in batch}
    mesh = lay.mesh
    dp = lay.dp if lay.dp else None
    seq_ax = lay.axis("sp")  # None in decode layout

    def spec(k, v):
        if k == "weights":
            return P(dp)
        if k == "positions" and v.ndim == 3:
            return P(None, dp, seq_ax)
        if k in ("frames", "patch_embeds"):
            return P(dp, seq_ax, None)
        if v.ndim >= 2 and v.shape[1] > 1:
            return P(dp, seq_ax)
        return P(dp)

    def shardable(k, v):
        # batch must divide dp; gb=1 long-context replicates over dp
        bdim = 1 if (k == "positions" and v.ndim == 3) else 0
        return v.shape[bdim] % max(lay.dp_size, 1) == 0

    out = {}
    for k, v in batch.items():
        s = spec(k, v)
        if not shardable(k, v):
            parts = list(s)
            bdim = 1 if (k == "positions" and v.ndim == 3) else 0
            parts[bdim] = None
            s = P(*parts)
        out[k] = NamedSharding(mesh, s)
    return out


# ---------------------------------------------------------------------------
# Cache shardings (decode).
# ---------------------------------------------------------------------------


def cache_shardings(cfg, caches, lay: shd.Layout, segs=None):
    if lay.mesh is None:
        return jax.tree.map(lambda _: None, caches)
    segs = segs or M.build_segments(M.layer_specs(cfg))

    def walk(node, name, stacked):
        if isinstance(node, dict):
            return {k: walk(v, k, stacked) for k, v in node.items()}
        if hasattr(node, "_fields"):  # ScanState
            return type(node)(*[
                walk(getattr(node, f), f, stacked) for f in node._fields])
        if isinstance(node, (list, tuple)):
            t = [walk(v, name, stacked) for v in node]
            return tuple(t) if isinstance(node, tuple) else t
        return NamedSharding(
            lay.mesh, M.cache_pspec(name, node.shape, lay, stacked))

    out = []
    for si, seg in enumerate(segs):
        out.append([walk(c, "", seg.repeats > 1) for c in caches[si]])
    return out


def input_specs(cfg, shape, lay: shd.Layout, *, with_labels=None):
    """Returns (args_structs, args_shardings) for the entry point of
    ``shape.kind`` — train: (state-less) batch; prefill: batch; decode:
    (tokens, pos, caches, positions)."""
    with_labels = (shape.kind == "train") if with_labels is None else with_labels
    batch = batch_structs(cfg, shape, with_labels=with_labels)
    bshard = batch_shardings(cfg, batch, lay)
    if shape.kind != "decode":
        return batch, bshard
    caches = M.cache_structs(cfg, shape.global_batch, shape.seq_len)
    cshard = cache_shardings(cfg, caches, lay)
    return (batch, caches), (bshard, cshard)
