"""reprolint core: files, findings, suppressions, reporters.

The lint engine is deliberately small: a :class:`Project` parses every
``.py`` file under the given paths once, each :class:`Rule` walks the
shared ASTs and yields :class:`Finding`s, and suppression comments are
applied at the end so a rule never needs to know about them.

Suppressions are the pragma::

    x = thing.item()  # reprolint: disable=host-sync-in-hot-path -- <why>

The reason string after ``--`` (or an em-dash, or ``:``) is REQUIRED —
a bare disable is itself reported as ``bad-suppression`` and cannot be
suppressed.  A pragma on its own line covers the next line instead, so
annotations survive ``black``-style reflow of long statements.
"""
from __future__ import annotations

import ast
import io
import json
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: rule id used for malformed pragmas; never suppressible.
BAD_SUPPRESSION = "bad-suppression"
#: rule id used for files the parser rejects.
PARSE_ERROR = "parse-error"

_PRAGMA_RE = re.compile(r"#\s*reprolint:\s*disable=([A-Za-z0-9_,-]+)(.*)$")
_REASON_SEP_RE = re.compile(r"^\s*(?:--|—|:)\s*")
_HOT_PATH_RE = re.compile(r"#\s*reprolint:\s*hot-path\b")


@dataclass(frozen=True, order=True)
class Finding:
    """One lint finding, sortable into report order."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def as_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "rule": self.rule, "message": self.message}

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}: {self.message}")


@dataclass
class Suppression:
    line: int            # line the pragma sits on
    rules: Set[str]
    reason: str
    own_line: bool       # pragma is the whole (stripped) line


@dataclass
class SourceFile:
    path: str                    # absolute
    rel: str                     # repo/project-relative, '/'-separated
    text: str
    tree: Optional[ast.AST]
    suppressions: List[Suppression] = field(default_factory=list)
    hot_path_lines: Set[int] = field(default_factory=set)
    parse_findings: List[Finding] = field(default_factory=list)

    @property
    def lines(self) -> List[str]:
        return self.text.splitlines()

    def suppressed_rules_for(self, line: int) -> Set[str]:
        out: Set[str] = set()
        for s in self.suppressions:
            if not s.reason:
                continue             # malformed: never suppresses
            if s.line == line or (s.own_line and s.line + 1 == line):
                out |= s.rules
        return out


def _scan_pragmas(f: SourceFile, known_rules: Set[str]) -> None:
    """Collect disable pragmas + hot-path markers via the tokenizer (so
    pragma-looking text inside string literals is ignored)."""
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(f.text).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        line_no = tok.start[0]
        if _HOT_PATH_RE.search(tok.string):
            f.hot_path_lines.add(line_no)
            continue
        m = _PRAGMA_RE.search(tok.string)
        if m is None:
            if "reprolint" in tok.string:
                f.parse_findings.append(Finding(
                    f.rel, line_no, tok.start[1], BAD_SUPPRESSION,
                    "unrecognized reprolint pragma (want "
                    "'# reprolint: disable=<rule> -- <reason>')"))
            continue
        rules = {r for r in m.group(1).split(",") if r}
        reason = _REASON_SEP_RE.sub("", m.group(2).strip()).strip()
        src_line = f.lines[line_no - 1] if line_no <= len(f.lines) else ""
        own = src_line.strip().startswith("#")
        unknown = sorted(r for r in rules
                         if known_rules and r not in known_rules)
        if unknown:
            f.parse_findings.append(Finding(
                f.rel, line_no, tok.start[1], BAD_SUPPRESSION,
                f"disable names unknown rule(s): {', '.join(unknown)}"))
        if not reason:
            f.parse_findings.append(Finding(
                f.rel, line_no, tok.start[1], BAD_SUPPRESSION,
                "suppression without a reason: write "
                "'# reprolint: disable=" + ",".join(sorted(rules))
                + " -- <why this is safe>'"))
        f.suppressions.append(
            Suppression(line_no, rules, reason, own))


class Project:
    """Every parsed file under the lint roots + shared lazy indexes."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files: List[SourceFile] = sorted(files, key=lambda f: f.rel)
        self.by_rel: Dict[str, SourceFile] = {f.rel: f for f in self.files}
        # dotted module name -> file (suffix-registered so both
        # 'repro.core.controller' and 'controller' resolve)
        self.modules: Dict[str, SourceFile] = {}
        for f in self.files:
            dotted = _dotted_module(f.rel)
            parts = dotted.split(".")
            for i in range(len(parts)):
                self.modules.setdefault(".".join(parts[i:]), f)
            self.modules[dotted] = f
        self._callgraph = None

    @property
    def callgraph(self):
        if self._callgraph is None:
            from repro.analysis.callgraph import CallGraph
            self._callgraph = CallGraph.build(self)
        return self._callgraph


def _dotted_module(rel: str) -> str:
    p = rel[:-3] if rel.endswith(".py") else rel
    parts = [x for x in p.split("/") if x]
    if parts and parts[0] in ("src", "tests"):
        parts = parts[1:] or parts
    if parts and parts[-1] == "__init__":
        parts = parts[:-1] or parts
    return ".".join(parts)


def load_file(path: str, rel: Optional[str] = None,
              known_rules: Optional[Set[str]] = None) -> SourceFile:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    rel = (rel or path).replace(os.sep, "/")
    try:
        tree = ast.parse(text, filename=rel)
        f = SourceFile(path, rel, text, tree)
    except SyntaxError as e:
        f = SourceFile(path, rel, text, None)
        f.parse_findings.append(Finding(
            rel, e.lineno or 1, (e.offset or 1) - 1, PARSE_ERROR,
            f"syntax error: {e.msg}"))
    _scan_pragmas(f, known_rules or set())
    return f


def discover(paths: Sequence[str], root: Optional[str] = None,
             known_rules: Optional[Set[str]] = None) -> Project:
    """Walk ``paths`` (files or directories) into a :class:`Project`."""
    root = os.path.abspath(root or os.getcwd())
    seen: Dict[str, str] = {}
    for p in paths:
        ap = os.path.abspath(p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            seen[ap] = os.path.relpath(ap, root)
        elif os.path.isdir(ap):
            for dirpath, dirnames, filenames in os.walk(ap):
                dirnames[:] = sorted(d for d in dirnames
                                     if not d.startswith(".")
                                     and d != "__pycache__")
                for name in sorted(filenames):
                    if name.endswith(".py"):
                        fp = os.path.join(dirpath, name)
                        seen[fp] = os.path.relpath(fp, root)
    files = [load_file(p, rel, known_rules) for p, rel in sorted(seen.items())]
    return Project(files)


class Rule:
    """Base class: subclasses set ``id``/``doc`` and implement ``run``."""

    id: str = ""
    doc: str = ""

    def run(self, project: Project) -> Iterable[Finding]:
        raise NotImplementedError


def run_rules(project: Project, rules: Sequence[Rule]) -> List[Finding]:
    """Run every rule, apply suppressions, append pragma findings."""
    raw: List[Finding] = []
    for rule in rules:
        for fd in rule.run(project):
            raw.append(fd)
    out: List[Finding] = []
    for fd in raw:
        f = project.by_rel.get(fd.path)
        if f is not None and fd.rule in f.suppressed_rules_for(fd.line):
            continue
        out.append(fd)
    for f in project.files:
        out.extend(f.parse_findings)
    return sorted(set(out))


# -- reporters --------------------------------------------------------------


def render_text(findings: Sequence[Finding]) -> str:
    lines = [f.render() for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding],
                extra: Optional[dict] = None) -> str:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    doc = {"version": 1,
           "findings": [f.as_dict() for f in findings],
           "counts": dict(sorted(counts.items())),
           "total": len(findings)}
    if extra:
        doc.update(extra)
    return json.dumps(doc, indent=2, sort_keys=False) + "\n"


# -- small AST helpers shared by rules --------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def walk_no_nested_defs(node: ast.AST) -> Iterable[ast.AST]:
    """ast.walk over a function body that does NOT descend into nested
    function/class definitions (those are separate lint scopes)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(n))


def const_str_elems(node: ast.AST) -> Optional[List[str]]:
    """List of string constants from a str / tuple / list literal."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return out
    return None


def const_int_elems(node: ast.AST) -> Optional[List[int]]:
    """List of int constants from an int / tuple / list literal."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                out.append(e.value)
            else:
                return None
        return out
    return None
