"""repro.analysis: the contract linter (reprolint) + jaxpr auditor.

Static enforcement of the hot-path invariants the throughput story
rests on.  Run it as::

    python -m repro.analysis src tests --strict        # lint
    python -m repro.analysis --audit                   # -> ANALYSIS.json

See ``src/repro/analysis/README.md`` for the rule catalog.
"""
from repro.analysis.core import (Finding, Project, Rule, discover,
                                 render_json, render_text, run_rules)
from repro.analysis.rules import all_rules, rule_ids

__all__ = ["Finding", "Project", "Rule", "discover", "render_json",
           "render_text", "run_rules", "all_rules", "rule_ids",
           "lint_paths"]


def lint_paths(paths, root=None, rules=None):
    """Lint ``paths`` and return the (suppression-filtered) findings."""
    project = discover(paths, root=root, known_rules=rule_ids())
    return run_rules(project, rules if rules is not None else all_rules())
