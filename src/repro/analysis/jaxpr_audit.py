"""Jaxpr auditor: trace-compile the hot entry points, never execute.

The lint rules prove the HOST side of the hot-path contract; this
module proves the DEVICE side.  Each registered entry point is lowered
ahead-of-time from ``ShapeDtypeStruct``s (no real buffers, nothing
runs) and its closed jaxpr is scanned recursively — pjit/scan/while/
cond sub-jaxprs included — for primitives that would smuggle a host
round-trip into the compiled program (callbacks, infeed/outfeed,
explicit transfers).  For donating entries the lowered MLIR must carry
``tf.aliasing_output`` on the donated operands: donation that silently
fell off (a dtype mismatch, a shape change) doubles peak memory per
step without any visible failure.

Entries:

* ``fused_observe_decide`` — the single-job hot dispatch
  (``core.controller._fused_observe_decide``, censored mode);
* ``batched_observe_decide_ragged`` — the multi-tenant tick at a mixed
  width (J=3, widths 4/6/8 padded to 8);
* ``train_step[mask_agg=weights]`` / ``train_step[mask_agg=psum]`` —
  both aggregation paths of the donated train step on the tiny bench
  config;
* ``obs_ring_push`` — the telemetry spine's per-step device write
  (``obs.metrics._ring_push``): one donated scatter-write, so attaching
  an ``ObsRun`` provably adds zero host syncs to the hot loop.

``run_audit`` returns the report dict and ``write_report`` pins it to
``ANALYSIS.json`` (schema-guarded by ``tests/test_lint_clean.py``).
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Tuple

SCHEMA_VERSION = 1

#: primitive-name substrings that mean "this program talks to the host"
FORBIDDEN_SUBSTRINGS = ("callback", "infeed", "outfeed", "device_put",
                        "host_local", "copy_to_host")


def _sds_like(tree):
    import jax

    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _iter_jaxprs(jaxpr) -> Iterable:
    """Yield a jaxpr and every sub-jaxpr reachable through eqn params."""
    try:
        from jax.extend.core import Jaxpr  # type: ignore
    except ImportError:                    # older jax
        from jax.core import Jaxpr  # type: ignore

    stack = [jaxpr]
    seen = set()
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                for cand in (v if isinstance(v, (list, tuple)) else (v,)):
                    inner = getattr(cand, "jaxpr", cand)
                    if isinstance(inner, Jaxpr):
                        stack.append(inner)


def scan_jaxpr(closed_jaxpr) -> Tuple[int, List[str]]:
    """(total eqn count, sorted forbidden primitive names) over the
    whole jaxpr tree."""
    bad = set()
    count = 0
    for j in _iter_jaxprs(closed_jaxpr.jaxpr):
        for eqn in j.eqns:
            count += 1
            name = eqn.primitive.name
            if any(s in name for s in FORBIDDEN_SUBSTRINGS):
                bad.add(name)
    return count, sorted(bad)


def _audit_lowered(name: str, jitted, args, kwargs=None, *,
                   expect_donation: bool) -> Dict:
    import jax

    kwargs = kwargs or {}
    traced = jitted.trace(*args, **kwargs)
    n_eqns, bad = scan_jaxpr(traced.jaxpr)
    lowered = traced.lower()
    mlir = lowered.as_text()
    n_aliased = mlir.count("tf.aliasing_output")
    return {
        "name": name,
        "n_eqns": n_eqns,
        "forbidden_primitives": bad,
        "transfer_free": not bad,
        "donation": {
            "expected": expect_donation,
            "n_aliased_outputs": n_aliased,
            "effective": (n_aliased > 0) if expect_donation else True,
        },
    }


# -- entry builders ---------------------------------------------------------


def _fused_entry() -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import controller as C

    n, lag, k = 8, 4, 16
    model = C.RuntimeModel(n_workers=n, lag=lag)
    model.init(0)
    params = _sds_like(model.params)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    obs = {"times": f32(n), "mask": jax.ShapeDtypeStruct((n,), np.bool_),
           "mu": f32(n), "std": f32(n),
           "key": jax.ShapeDtypeStruct((2,), np.uint32)}
    args = (params, f32(lag + 1, n), jax.ShapeDtypeStruct((), jnp.int32),
            obs, jax.ShapeDtypeStruct((2,), np.uint32), f32())
    return _audit_lowered(
        "fused_observe_decide", C._fused_observe_decide, args,
        {"mode": "censored", "k_samples": k, "lo": 1},
        expect_donation=False)


def _ragged_entry() -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import controller as C
    from repro.core.runtime_model.api import stack_models_padded

    widths, n_pad, lag, k = (4, 6, 8), 8, 4, 16
    J = len(widths)
    models = []
    for i, w in enumerate(widths):
        m = C.RuntimeModel(n_workers=w, lag=lag)
        m.init(i)
        models.append(m)
    stacked, _scales = stack_models_padded(models, n_pad)
    params = _sds_like(stacked)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    obs = {"times": f32(J, n_pad),
           "mask": jax.ShapeDtypeStruct((J, n_pad), np.bool_),
           "mu": f32(J, n_pad), "std": f32(J, n_pad),
           "key": jax.ShapeDtypeStruct((J, 2), np.uint32),
           "cen": jax.ShapeDtypeStruct((J,), np.bool_)}
    args = (params, f32(J, lag + 1, n_pad), i32(J), obs,
            jax.ShapeDtypeStruct((J, 2), np.uint32), f32(J), i32(J),
            i32(J))
    return _audit_lowered(
        "batched_observe_decide_ragged", C._batched_observe_decide_ragged,
        args, {"k_samples": k}, expect_donation=False)


def _train_entries() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import optim
    from repro.configs.base import bench_tiny_config
    from repro.launch.train import jit_train_step
    from repro.models import model as M

    cfg = bench_tiny_config()
    opt = optim.adamw(1e-3)
    state_sds = jax.eval_shape(lambda: (lambda p: {
        "params": p, "opt": opt.init(p)})(
            M.init_model(cfg, jax.random.PRNGKey(0))))
    B, S, W = 8, 8, 4
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    out = []
    for mode, extra in (("weights", {"weights": f32(B)}),
                        ("psum", {"mask": f32(W)})):
        batch = dict(tokens=tok, labels=tok, positions=tok, **extra)
        step = jit_train_step(cfg, opt, mask_agg=mode)
        out.append(_audit_lowered(
            f"train_step[mask_agg={mode}]", step, (state_sds, batch),
            expect_donation=True))
    return out


def _obs_entry() -> Dict:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.obs.metrics import _ring_push

    cap, k = 256, 4
    f32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
    args = (f32(cap, k), jax.ShapeDtypeStruct((), np.int32),
            tuple(f32() for _ in range(k)))
    return _audit_lowered("obs_ring_push", _ring_push, args,
                          expect_donation=True)


def run_audit() -> Dict:
    import jax

    entries = ([_fused_entry(), _ragged_entry()] + _train_entries()
               + [_obs_entry()])
    ok = all(e["transfer_free"] and e["donation"]["effective"]
             for e in entries)
    return {"version": SCHEMA_VERSION,
            "jax_version": jax.__version__,
            "ok": ok,
            "entries": entries}


def write_report(path: str = "ANALYSIS.json") -> Dict:
    report = run_audit()
    with open(path, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")
    return report
