"""event-kind-drift: the event vocabulary has exactly one source of
truth.

``controlplane/events.py`` declares ``EVENT_KINDS``; ``EventLog.emit``
validates against it at runtime.  Drift still creeps in two ways that
runtime validation cannot catch: (a) an emit site with a NEW literal
kind that was never registered only explodes when that code path runs
(often mid-drill), and (b) a registered kind nobody emits anymore is
dead vocabulary that dashboards and drills keep matching on.  This rule
closes both directions statically: every literal ``kind`` at an
``*.emit(tick, kind, ...)`` call site must be registered, and every
registered kind must appear at some emit site in the linted tree.
Dynamic kinds (``log.emit(tick, ev.kind, ...)``) are skipped — the
runtime check owns those.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Project, Rule, const_str_elems

REGISTRY_NAME = "EVENT_KINDS"


class EventKindDrift(Rule):
    id = "event-kind-drift"
    doc = ("every literal kind= emitted anywhere appears in the "
           "EVENT_KINDS registry, and vice versa")

    def run(self, project: Project) -> Iterable[Finding]:
        registry: Optional[Set[str]] = None
        reg_where: Tuple[str, int] = ("", 0)
        kind_lines: Dict[str, int] = {}
        emits: List[Tuple[str, int, int, str]] = []
        for f in project.files:
            if f.tree is None:
                continue
            for node in ast.walk(f.tree):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id == REGISTRY_NAME):
                    kinds = const_str_elems(node.value)
                    if kinds is not None:
                        registry = set(kinds)
                        reg_where = (f.rel, node.lineno)
                        if isinstance(node.value, (ast.Tuple, ast.List)):
                            for e in node.value.elts:
                                kind_lines[e.value] = e.lineno
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                if not (isinstance(fn, ast.Attribute) and fn.attr == "emit"):
                    continue
                kind_node: Optional[ast.AST] = None
                if len(node.args) >= 2:
                    kind_node = node.args[1]
                for kw in node.keywords:
                    if kw.arg == "kind":
                        kind_node = kw.value
                if (isinstance(kind_node, ast.Constant)
                        and isinstance(kind_node.value, str)):
                    emits.append((f.rel, node.lineno, node.col_offset,
                                  kind_node.value))
        if registry is None:
            return
        emitted = {k for _, _, _, k in emits}
        for rel, line, col, kind in emits:
            if kind not in registry:
                yield Finding(
                    rel, line, col, self.id,
                    f"emit of unregistered kind '{kind}': add it to "
                    f"{REGISTRY_NAME} in {reg_where[0]} (or fix the typo) "
                    f"— the runtime check would reject this at drill "
                    f"time, not review time")
        if emits:
            for kind in sorted(registry - emitted):
                yield Finding(
                    reg_where[0], kind_lines.get(kind, reg_where[1]),
                    0, self.id,
                    f"registered kind '{kind}' is never emitted with a "
                    f"literal anywhere in the linted tree: dead "
                    f"vocabulary, or an emit site the registry has "
                    f"drifted from")
