"""event-kind-drift: the event vocabulary has exactly one source of
truth per stream family.

``controlplane/events.py`` declares ``EVENT_KINDS`` and ``obs/trace.py``
declares ``OBS_KINDS``; ``EventLog.emit`` validates against the class's
registry at runtime.  Drift still creeps in two ways that runtime
validation cannot catch: (a) an emit site with a NEW literal kind that
was never registered only explodes when that code path runs (often
mid-drill), and (b) a registered kind nobody emits anymore is dead
vocabulary that dashboards and drills keep matching on.  This rule
closes both directions statically: every literal ``kind`` at an
``*.emit(tick, kind, ...)`` call site must be registered in SOME
registry, and every kind registered in ANY registry must appear at some
emit site in the linted tree.  (Emit sites are not attributed to a
specific log class statically, so a kind living in both registries —
e.g. ``"run"`` — is fine, and an emit is flagged only when NO registry
knows it.)  Dynamic kinds (``log.emit(tick, ev.kind, ...)``) are
skipped — the runtime check owns those.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Project, Rule, const_str_elems

REGISTRY_NAMES = ("EVENT_KINDS", "OBS_KINDS")


class EventKindDrift(Rule):
    id = "event-kind-drift"
    doc = ("every literal kind= emitted anywhere appears in an EVENT_KINDS/"
           "OBS_KINDS registry, and vice versa")

    def run(self, project: Project) -> Iterable[Finding]:
        registries: Dict[str, Set[str]] = {}
        reg_where: Dict[str, Tuple[str, int]] = {}
        kind_lines: Dict[str, Dict[str, int]] = {}
        emits: List[Tuple[str, int, int, str]] = []
        for f in project.files:
            if f.tree is None:
                continue
            for node in ast.walk(f.tree):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and node.targets[0].id in REGISTRY_NAMES):
                    name = node.targets[0].id
                    kinds = const_str_elems(node.value)
                    if kinds is not None:
                        registries[name] = set(kinds)
                        reg_where[name] = (f.rel, node.lineno)
                        lines = kind_lines.setdefault(name, {})
                        if isinstance(node.value, (ast.Tuple, ast.List)):
                            for e in node.value.elts:
                                lines[e.value] = e.lineno
                if not isinstance(node, ast.Call):
                    continue
                fn = node.func
                if not (isinstance(fn, ast.Attribute) and fn.attr == "emit"):
                    continue
                kind_node: Optional[ast.AST] = None
                if len(node.args) >= 2:
                    kind_node = node.args[1]
                for kw in node.keywords:
                    if kw.arg == "kind":
                        kind_node = kw.value
                if (isinstance(kind_node, ast.Constant)
                        and isinstance(kind_node.value, str)):
                    emits.append((f.rel, node.lineno, node.col_offset,
                                  kind_node.value))
        if not registries:
            return
        union: Set[str] = set()
        for kinds in registries.values():
            union |= kinds
        names = " / ".join(sorted(registries))
        emitted = {k for _, _, _, k in emits}
        for rel, line, col, kind in emits:
            if kind not in union:
                yield Finding(
                    rel, line, col, self.id,
                    f"emit of unregistered kind '{kind}': add it to "
                    f"{names} (or fix the typo) — the runtime check "
                    f"would reject this at drill time, not review time")
        if emits:
            for name in sorted(registries):
                where = reg_where[name]
                for kind in sorted(registries[name] - emitted):
                    yield Finding(
                        where[0],
                        kind_lines.get(name, {}).get(kind, where[1]),
                        0, self.id,
                        f"registered kind '{kind}' in {name} is never "
                        f"emitted with a literal anywhere in the linted "
                        f"tree: dead vocabulary, or an emit site the "
                        f"registry has drifted from")
